#!/usr/bin/env bash
# Tier-1 CI: install the package with the test extra (falls back to the
# PYTHONPATH=src layout when offline) and run the suite on CPU.
set -euo pipefail
cd "$(dirname "$0")/.."

pip_log="$(mktemp)"
if python -m pip install -e ".[test]" >"$pip_log" 2>&1; then
    echo "installed editable package with [test] extra"
    export PYTHONPATH="${PYTHONPATH:-}"
else
    # surface WHY pip failed: a broken pyproject must not be mistaken
    # for being offline (the fallback also skips the hypothesis
    # property tests, so a silent fallback would hide lost coverage)
    echo "pip install failed; output:" >&2
    cat "$pip_log" >&2
    echo "falling back to PYTHONPATH=src (property tests will skip " \
         "unless hypothesis is already installed)" >&2
    export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
fi
rm -f "$pip_log"

JAX_PLATFORMS=cpu python -m pytest -x -q "$@"

# serving acceptance gates (throughput >= 2x, prefill TTFT >= 4x at K=4);
# BENCH_serving.json is the machine-readable perf-trajectory artifact
# (tok/s, TTFT p50/p99, admissible concurrency, per-device cache bytes)
JAX_PLATFORMS=cpu python benchmarks/serving_bench.py --fast \
    --json BENCH_serving.json

# frontend stage: HTTP/SSE server tests + the end-to-end frontend gate
# (token-exact HTTP vs in-process, hot-swap with zero drops/recompiles).
# Both run under a hard wall-clock cap: a hung socket or a deadlocked
# handler thread must fail the stage, not wedge CI.
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    python -m pytest -x -q tests/test_frontend.py
timeout -k 30 600 env JAX_PLATFORMS=cpu \
    python benchmarks/serving_bench.py --frontend --frontend-only

# mesh stage: rerun the serving tests with a forced 2-device CPU host so
# the shard_map member-sharding path executes with REAL collectives
# (single-device runs above exercise it degraded to a 1x1 mesh), then
# gate per-device cache bytes (<= single-device / member-axis size).
# test_serving_paged.py rides the same stage: the paged pool + page
# table must shard over a REAL member axis too (member-sharded + paged
# on every commit), and the paged bench gates token-exactness vs the
# contiguous engine and >= 2x admissible concurrency at equal bytes.
JAX_PLATFORMS=cpu XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python -m pytest -x -q tests/test_serving_mesh.py tests/test_serving.py \
    tests/test_serving_paged.py
# hot-swap on a REAL mesh: swap_params must re-shard the new stack to
# the live 2-device member placement without recompiling (single-device
# runs above exercise the same test degraded to a 1x1 mesh)
timeout -k 30 600 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python -m pytest -x -q tests/test_frontend.py -k hot_swap
JAX_PLATFORMS=cpu XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python benchmarks/serving_bench.py --fast --mesh 2x1 --mesh-only
JAX_PLATFORMS=cpu XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python benchmarks/serving_bench.py --paged --paged-only

# speculative-decoding stage: the student-drafts-for-its-teachers tests
# (greedy bit-identity, rollback, pruning soundness, the compress ->
# checkpoint -> draft round trip) plus the --spec bench gate (>= 2x
# decode tok/s at K=4, output bit-identical to the non-speculative
# engine, --draft off bit-identical to the base path).  Hard wall-clock
# caps, same rationale as the frontend stage; the gate and the tests
# rerun under the forced 2-device host so the member-sharded verify
# (ensemble_log_probs_psum + local prunable_members) executes with
# REAL collectives.
timeout -k 30 1200 env JAX_PLATFORMS=cpu \
    python -m pytest -x -q tests/test_spec.py
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    python benchmarks/serving_bench.py --spec --spec-only
timeout -k 30 1200 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python -m pytest -x -q tests/test_spec.py
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python benchmarks/serving_bench.py --spec --spec-only

# prefix-cache stage: trie/allocator unit + churn + engine-equivalence
# tests, then the --prefix bench gate (>= 5x warm TTFT at K=4, warm
# tokens exact vs cold on GQA AND MLA layouts, prefix-off bit-identical
# to the contiguous engine, zero leaked pages after 10k churned
# requests).  Both rerun under the forced 2-device host: shared pages
# and the COW copy program live in the member-sharded pool, so sharing
# must survive a REAL member axis too.
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    python -m pytest -x -q tests/test_prefix.py
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    python benchmarks/serving_bench.py --prefix --prefix-only
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python -m pytest -x -q tests/test_prefix.py
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python benchmarks/serving_bench.py --prefix --prefix-only

# fleet stage: multi-process replicas over sockets — cancellation,
# backpressure and the SIGKILL+restart soak (tests/test_fleet.py), then
# the --fleet bench gate (disconnect reclaims slot+pages, kill/restart
# recovers token-exact, 429 only past the queue depth).  Generous caps:
# each replica is a fresh process that compiles its own engine.  The
# forced-2-device rerun gives every child a 2-device host, so each
# replica's member-sharded engine runs REAL collectives in its own
# process (children inherit XLA_FLAGS through the environment).
timeout -k 30 1800 env JAX_PLATFORMS=cpu \
    python -m pytest -x -q tests/test_fleet.py
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    python benchmarks/serving_bench.py --fleet --fleet-only
timeout -k 30 1800 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python -m pytest -x -q tests/test_fleet.py
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python benchmarks/serving_bench.py --fleet --fleet-only

# quantized-pages + absorbed-MLA stage: roundtrip error bounds, pool
# layout/dtype accounting, quantized kernel-vs-ref equivalence, engine
# quality across GQA/MLA archs, f32 bit-identity, absorbed-MLA
# token-exactness + step-FLOPs-flat regression, COW/prefix/spec
# composition with quantized pages (tests/test_kv_quant.py), then the
# --kv-quant bench gate (int8 quality delta bounded, >= 2x admissible
# concurrency at equal pool bytes, absorbed-MLA exact + flat).  The
# forced-2-device rerun shards the quantized planes AND their scale
# sidecars over a REAL member axis.
timeout -k 30 1200 env JAX_PLATFORMS=cpu \
    python -m pytest -x -q tests/test_kv_quant.py
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    python benchmarks/serving_bench.py --kv-quant --kv-quant-only
timeout -k 30 1200 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python -m pytest -x -q tests/test_kv_quant.py
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python benchmarks/serving_bench.py --kv-quant --kv-quant-only

# observability stage: trace lifecycle on the hard paths (preempt/
# resume, mid-stream cancel, spec rollback, fleet crash-retry), the
# Prometheus exposition conformance suite, and the --obs bench gate
# (< 2% decode tok/s overhead vs Scheduler(obs=False), server-side
# /metrics histogram TTFT p99 within 20% of the client-measured p99).
# The forced-2-device rerun threads the span recorder and tick-phase
# timer through the member-sharded engine's REAL-collective tick.
timeout -k 30 1200 env JAX_PLATFORMS=cpu \
    python -m pytest -x -q tests/test_obs.py
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    python benchmarks/serving_bench.py --obs --obs-only
timeout -k 30 1200 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python -m pytest -x -q tests/test_obs.py
timeout -k 30 900 env JAX_PLATFORMS=cpu \
    XLA_FLAGS="--xla_force_host_platform_device_count=2" \
    python benchmarks/serving_bench.py --obs --obs-only

# docs must not reference symbols that no longer exist
python scripts/check_docs.py
