#!/usr/bin/env python
"""Fail CI when docs/*.md references a symbol that no longer exists.

Grep-based, deliberately simple: every inline code span in the docs is
classified and checked against the working tree —

  - path-like spans (contain '/'):      the file or directory must exist
  - dotted names (a.b.c) and
    attribute refs (Engine.step(...)):  every identifier component must
                                        appear somewhere in the code
  - bare identifiers (>= 3 chars):      must appear somewhere in the code
  - CLI flags (--mesh, --prefill-chunk): the flag string must appear

Spans containing spaces, shell operators, or placeholders are skipped
(they are commands or prose, not symbol references).  The point is not
perfect resolution — it is that renaming EnsembleEngine.prefill or
deleting kv_cache.slot_row turns the stale doc into a red build instead
of a lie.

  python scripts/check_docs.py [docs_dir]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
CODE_DIRS = ("src", "scripts", "benchmarks", "examples", "tests")
CODE_EXT = {".py", ".sh", ".toml", ".yml", ".yaml"}

IDENT = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")
DOTTED = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*(\.[A-Za-z_][A-Za-z0-9_]*)+$")
SKIP_CHARS = set(" \t'\"$|&;{}<>*=,")
# tokens that are math/shape notation or too generic to grep usefully
IGNORE = {"None", "True", "False", "int32", "float32", "bf16", "jax",
          "jnp", "numpy", "np", "pytest", "pip", "python", "MxD", "KxD",
          "out", "idx", "enc", "pos", "tok"}


def code_corpus() -> str:
    chunks = []
    for d in CODE_DIRS:
        root = REPO / d
        if not root.is_dir():
            continue
        for f in sorted(root.rglob("*")):
            if f.suffix in CODE_EXT and f.is_file():
                chunks.append(f.read_text(errors="ignore"))
    return "\n".join(chunks)


def spans(md_text: str):
    # fenced blocks are runnable examples, not symbol references — the
    # inline-span rule below would misfire on prose inside them
    text = re.sub(r"```.*?```", "", md_text, flags=re.S)
    return re.findall(r"`([^`\n]+)`", text)


def check_span(span: str, corpus: str):
    """-> list of unresolved symbol strings (empty when the span is
    fine or not a symbol reference)."""
    s = span.strip().rstrip(":,.")
    if not s or SKIP_CHARS & set(s):
        return []
    if s.startswith("--"):  # CLI flag
        return [] if s in corpus else [s]
    if "/" in s:  # path-like
        target = s.rstrip("/")
        return [] if (REPO / target).exists() else [s]
    s = re.sub(r"\(.*\)$", "", s)  # Engine.step(slot) -> Engine.step
    if DOTTED.match(s):
        missing = [part for part in s.split(".")
                   if part not in IGNORE and len(part) >= 3
                   and not re.search(r"\b%s\b" % re.escape(part), corpus)]
        return [f"{s} (component {m!r})" for m in missing]
    if IDENT.match(s) and len(s) >= 3 and s not in IGNORE:
        if not re.search(r"\b%s\b" % re.escape(s), corpus):
            return [s]
    return []


def main(argv):
    docs = Path(argv[1]) if len(argv) > 1 else REPO / "docs"
    files = sorted(docs.glob("*.md"))
    if not files:
        print(f"check_docs: no markdown under {docs}", file=sys.stderr)
        return 1
    corpus = code_corpus()
    failures = []
    n_spans = 0
    for f in files:
        try:
            rel = f.relative_to(REPO)
        except ValueError:
            rel = f
        for span in spans(f.read_text()):
            n_spans += 1
            for miss in check_span(span, corpus):
                failures.append(f"{rel}: `{span}` -> unresolved {miss}")
    if failures:
        print("check_docs: stale symbol references:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"check_docs: {len(files)} files, {n_spans} code spans, "
          f"all symbols resolve")
    return 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv))
