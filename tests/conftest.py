import os
import sys

# tests see exactly ONE CPU device (the dry-run's 512-device env is set
# only inside launch/dryrun.py / subprocess tests, per its module rules)
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
