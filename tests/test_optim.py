"""Optimizers, schedules, grad compression, grad-accumulation equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adamw, clip_by_global_norm, cosine_decay,
                         int8_dequantize, int8_quantize,
                         linear_warmup_cosine, sgd_momentum,
                         topk_compress_with_feedback)
from repro.optim.compression import init_residuals


def test_sgd_momentum_trajectory():
    opt = sgd_momentum(lr=0.1, momentum=0.9, clip_norm=0.0)
    p = {"w": jnp.array([1.0])}
    s = opt.init(p)
    g = {"w": jnp.array([1.0])}
    p, s = opt.update(g, s, p)       # mu=1, w=1-0.1
    np.testing.assert_allclose(float(p["w"][0]), 0.9, rtol=1e-6)
    p, s = opt.update(g, s, p)       # mu=1.9, w=0.9-0.19
    np.testing.assert_allclose(float(p["w"][0]), 0.71, rtol=1e-6)


def test_adamw_moves_and_decays():
    opt = adamw(lr=1e-2, weight_decay=0.1)
    p = {"w": jnp.ones((4,))}
    s = opt.init(p)
    g = {"w": jnp.zeros((4,))}
    p2, _ = opt.update(g, s, p)
    assert float(p2["w"][0]) < 1.0  # pure weight decay shrinks


@pytest.mark.parametrize("mdt", [jnp.float32, jnp.bfloat16])
def test_adamw_moment_dtype(mdt):
    opt = adamw(lr=1e-2, moment_dtype=mdt)
    # f32 params: a 1e-2-lr step on bf16 params would round away at |w|=1
    p = {"w": jnp.ones((8,), jnp.float32)}
    s = opt.init(p)
    assert s["m"]["w"].dtype == mdt
    g = {"w": jnp.full((8,), 0.5, jnp.float32)}
    p2, s2 = opt.update(g, s, p)
    assert s2["m"]["w"].dtype == mdt
    assert float(jnp.abs(p2["w"] - p["w"]).max()) > 0


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), 4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = jnp.sqrt(sum(jnp.sum(jnp.square(x))
                         for x in jax.tree.leaves(clipped)))
    np.testing.assert_allclose(float(total), 1.0, rtol=1e-5)


def test_schedules():
    lr = linear_warmup_cosine(1.0, warmup=10, total_steps=110,
                              final_frac=0.0)
    assert float(lr(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr(jnp.asarray(110))) < 0.01
    cd = cosine_decay(2.0, 100, final_frac=0.5)
    assert float(cd(jnp.asarray(0))) == pytest.approx(2.0)
    assert float(cd(jnp.asarray(100))) == pytest.approx(1.0)


def test_topk_feedback_is_lossless_over_time():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (64,))}
    res = init_residuals(g)
    sparse, res = topk_compress_with_feedback(g, res, frac=0.1)
    # sparse + residual == grad exactly
    np.testing.assert_allclose(
        np.asarray(sparse["w"] + res["w"]), np.asarray(g["w"]), atol=1e-6)
    nz = int((np.asarray(sparse["w"]) != 0).sum())
    assert nz <= max(1, int(64 * 0.1)) + 1


def test_int8_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(1), (128,)) * 3
    q, s = int8_quantize(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(int8_dequantize(q, s) - x)).max()
    assert err <= float(s) * 0.51 + 1e-6


def test_grad_accum_equals_full_batch():
    """steps.make_member_grads(accum=N) == accum=1 on the same batch."""
    from repro.configs import registry
    from repro.runtime import steps
    from repro import models
    cfg = registry.get_config("deepseek-7b", reduced=True)
    params = models.init(jax.random.PRNGKey(0), cfg)
    B, T = 4, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                                          cfg.vocab_size)}
    l1, g1 = steps.make_member_grads(cfg, 1)(params, batch, None, 0.0)
    l4, g4 = steps.make_member_grads(cfg, 4)(params, batch, None, 0.0)
    np.testing.assert_allclose(float(l1), float(l4), rtol=2e-3)
    flat1, flat4 = jax.tree.leaves(g1), jax.tree.leaves(g4)
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-2, rtol=2e-2)
