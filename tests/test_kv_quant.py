"""Quantized KV pages + absorbed-MLA paged decode (ISSUE 9).

Layers of evidence, cheapest first:

  - unit: kv_quantize/kv_dequantize roundtrip error bounds (int8 within
    half a quantization step of its per-token absmax scale; fp8 within
    e4m3's relative precision), all-zero vectors exact;
  - kernel: the Pallas paged_attention with k_scale/v_scale/k_extra
    inputs vs kernels/ref.paged_attention's dequant reference, plus the
    unquantized path staying exact;
  - pool layout: int8 pools store "_pages" planes at 1 byte with f32
    "_scale_pages" sidecars, MLA rope keys stay native (they feed the
    kernel as the unquantized k_extra block), sliding-window rings and
    recurrent state stay untouched, page_bytes accounts the real
    (quantized) bytes;
  - engine: int8 paged greedy output vs the f32 contiguous reference
    within a bounded agreement delta across the GQA / ring-mix / MLA
    archs (tiny random-init members sit near argmax ties, so the bound
    is generous, not zero); kv_dtype="f32" allocates the IDENTICAL pool
    as today; absorbed-MLA paged decode stays token-exact at f32 with
    per-step FLOPs ~flat in max_seq;
  - composition: prefix-cache COW sharing, speculative rollback and a
    member mesh all run over quantized pages unchanged (warm vs cold
    and spec vs plain stay token-exact WITHIN the int8 engines: the
    same stored pages dequantize to the same values everywhere).

The >= 2x equal-bytes concurrency gate lives in
benchmarks/serving_bench.py --kv-quant (scripts/ci.sh runs it, also
under a forced-2-device mesh).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.common import sharding as shd
from repro.configs import registry
from repro.kernels import ref
from repro.kernels import paged_attention as pk
from repro.models import transformer as tf
from repro.models.attention import (KV_DTYPES, fp8_dtype, kv_dequantize,
                                    kv_quantize)
from repro.serving import EnsembleEngine, kv_cache

GQA = registry.get_config("deepseek-7b", reduced=True).with_(
    dtype="float32")
GEMMA = registry.get_config("gemma3-1b", reduced=True).with_(
    dtype="float32")
MLA = registry.get_config("deepseek-v2-236b", reduced=True).with_(
    dtype="float32")
ARCHS = {"deepseek-7b": GQA, "gemma3-1b": GEMMA, "deepseek-v2-236b": MLA}


def _params(cfg, K=2, seed=0):
    return jax.vmap(lambda k: tf.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), K))


def _prompts(cfg):
    return [np.arange(1, 12) % cfg.vocab_size, np.arange(2, 5),
            np.arange(3, 10), np.arange(1, 7)]


_KW = dict(n_slots=4, max_prompt=12, max_out=8, prefill_chunk=4)


def _has_fp8():
    try:
        fp8_dtype()
        return True
    except ValueError:
        return False


@pytest.fixture(scope="module")
def contig_ref():
    """f32 contiguous greedy outputs per arch — the quality reference."""
    out = {}
    for name, cfg in ARCHS.items():
        eng = EnsembleEngine(cfg, _params(cfg), **_KW)
        out[name] = eng.generate(_prompts(cfg), max_new=8)
    return out


# -- roundtrip bounds --------------------------------------------------------


def test_int8_roundtrip_error_bound():
    v = jax.random.normal(jax.random.PRNGKey(0), (64, 8, 32),
                          jnp.float32) * 3.0
    q, s = kv_quantize(v, jnp.int8)
    assert q.dtype == jnp.int8 and s.shape == v.shape[:-1]
    d = kv_dequantize(q, s)
    # within half a quantization step of each vector's absmax scale
    bound = np.asarray(s)[..., None] * 0.5 + 1e-6
    assert np.all(np.abs(np.asarray(d - v)) <= bound)


def test_fp8_roundtrip_error_bound():
    if not _has_fp8():
        pytest.skip("no float8_e4m3fn in this jax")
    v = jax.random.normal(jax.random.PRNGKey(1), (32, 4, 16), jnp.float32)
    q, s = kv_quantize(v, fp8_dtype())
    d = kv_dequantize(q, s)
    amax = np.abs(np.asarray(v)).max(-1, keepdims=True)
    # e4m3 keeps ~4 bits of mantissa headroom at the top of the range
    assert np.all(np.abs(np.asarray(d - v)) <= 0.08 * amax + 1e-6)


def test_quantize_all_zero_vector_is_exact():
    v = jnp.zeros((4, 2, 8), jnp.float32)
    q, s = kv_quantize(v, jnp.int8)
    np.testing.assert_array_equal(np.asarray(s), 0.0)
    np.testing.assert_array_equal(np.asarray(kv_dequantize(q, s)), 0.0)


# -- kernel vs dequant reference ---------------------------------------------


def _paged_inputs(dk, dv, dr=0, B=3, Hkv=2, n_pages=12, page=4, P=4,
                  seed=0):
    rng = np.random.default_rng(seed)
    kq = jnp.asarray(rng.integers(-127, 128, (n_pages, page, Hkv, dk)),
                     jnp.int8)
    vq = jnp.asarray(rng.integers(-127, 128, (n_pages, page, Hkv, dv)),
                     jnp.int8)
    ks = jnp.asarray(rng.uniform(0.01, 0.1, (n_pages, page, Hkv)),
                     jnp.float32)
    vs = jnp.asarray(rng.uniform(0.01, 0.1, (n_pages, page, Hkv)),
                     jnp.float32)
    ke = jnp.asarray(rng.normal(size=(n_pages, page, Hkv, dr)),
                     jnp.float32) if dr else None
    table = jnp.asarray(rng.permutation(n_pages)[:B * P].reshape(B, P),
                        jnp.int32)
    lens = jnp.asarray([5, 16, 1], jnp.int32)
    q = jnp.asarray(rng.normal(size=(B, 2 * Hkv, dk + dr)), jnp.float32)
    return q, kq, vq, ks, vs, ke, table, lens


def test_kernel_matches_ref_quantized():
    q, kq, vq, ks, vs, _, table, lens = _paged_inputs(16, 16)
    want = ref.paged_attention(q, kq, vq, table, lens, k_scale=ks,
                               v_scale=vs)
    got = pk.paged_attention(q, kq, vq, table, lens, k_scale=ks,
                             v_scale=vs, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_kernel_matches_ref_quantized_with_extra():
    """The absorbed-MLA shape: int8 latents + unquantized rope keys."""
    q, kq, vq, ks, vs, ke, table, lens = _paged_inputs(16, 16, dr=8)
    scale = (16 + 8) ** -0.5
    want = ref.paged_attention(q, kq, vq, table, lens, scale=scale,
                               k_scale=ks, v_scale=vs, k_extra=ke)
    got = pk.paged_attention(q, kq, vq, table, lens, scale=scale,
                             k_scale=ks, v_scale=vs, k_extra=ke,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_kernel_unquantized_path_still_exact():
    rng = np.random.default_rng(3)
    kf = jnp.asarray(rng.normal(size=(12, 4, 2, 16)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(12, 4, 2, 16)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(3, 4, 16)), jnp.float32)
    table = jnp.asarray(rng.permutation(12).reshape(3, 4), jnp.int32)
    lens = jnp.asarray([5, 16, 1], jnp.int32)
    want = ref.paged_attention(q, kf, vf, table, lens)
    got = pk.paged_attention(q, kf, vf, table, lens, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


# -- pool layout + accounting ------------------------------------------------


def _pool_leaves(pool):
    out = {}

    def visit(path, x):
        name = next((str(e.key) for e in reversed(path)
                     if isinstance(e, jax.tree_util.DictKey)), "")
        out.setdefault(name, []).append(x)

    jax.tree_util.tree_map_with_path(visit, pool["segments"])
    return out


def test_pool_layout_int8_gqa_ring_untouched():
    # max_seq above gemma's reduced local_window (16) so the sliding
    # layers keep rings while the global layers page
    pool = kv_cache.init_pool(GEMMA, 2, 2, 32, page_size=4, n_pages=8,
                              kv_dtype="int8")
    leaves = _pool_leaves(pool)
    for x in leaves["k_pages"] + leaves["v_pages"]:
        assert x.dtype == jnp.int8
    for x in leaves["k_scale_pages"] + leaves["v_scale_pages"]:
        assert x.dtype == jnp.float32
        assert x.shape[-1] == GEMMA.attn.n_kv_heads  # per-token/per-head
    # gemma3's sliding-window rings stay contiguous AND unquantized
    for x in leaves["k"] + leaves["v"]:
        assert x.dtype == jnp.float32


def test_pool_layout_int8_mla_rope_stays_native():
    pool = kv_cache.init_pool(MLA, 2, 2, 16, page_size=4, n_pages=8,
                              kv_dtype="int8")
    leaves = _pool_leaves(pool)
    for x in leaves["c_kv_pages"]:
        assert x.dtype == jnp.int8
    for x in leaves["c_kv_scale_pages"]:
        assert x.dtype == jnp.float32
    # rope keys feed the kernel as the unquantized k_extra block
    for x in leaves["k_r_pages"]:
        assert x.dtype == jnp.float32
    assert "k_r_scale_pages" not in leaves


def test_pool_f32_is_identical_to_default():
    base = kv_cache.init_pool(GQA, 2, 2, 16, page_size=4, n_pages=8)
    same = kv_cache.init_pool(GQA, 2, 2, 16, page_size=4, n_pages=8,
                              kv_dtype="f32")
    assert (jax.tree_util.tree_structure(base)
            == jax.tree_util.tree_structure(same))
    for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(same)):
        assert a.shape == b.shape and a.dtype == b.dtype


def test_page_bytes_accounts_quantized_bytes():
    kw = dict(page_size=4, n_pages=8)
    pb = {d: kv_cache.page_bytes(
        kv_cache.init_pool(GQA, 2, 2, 16, kv_dtype=d, **kw), 8)
        for d in ("f32", "bf16", "int8")}
    assert pb["bf16"] == pb["f32"] // 2
    # int8 planes cost 1/4 the bytes; the f32 scale sidecar adds
    # 1/head_dim back, still well under a third of the f32 pool
    assert pb["int8"] < pb["f32"] // 3
    assert kv_cache.page_bytes(
        kv_cache.init_pool(GQA, 2, 2, 16, kv_dtype="int8", **kw),
        8) * 8 < kv_cache.pool_bytes(
        kv_cache.init_pool(GQA, 2, 2, 16, kv_dtype="int8", **kw))


def test_engine_kv_dtype_validation():
    params = _params(GQA)
    with pytest.raises(ValueError, match="kv_dtype"):
        EnsembleEngine(GQA, params, kv_dtype="int4", **_KW)
    with pytest.raises(ValueError, match="paged"):
        EnsembleEngine(GQA, params, kv_dtype="int8", **_KW)
    assert "int8" in KV_DTYPES and "fp8" in KV_DTYPES


def test_engine_page_stats_reports_bytes():
    eng = EnsembleEngine(GQA, _params(GQA), paged=True, page_size=4,
                         kv_dtype="int8", **_KW)
    ps = eng.page_stats()
    assert ps["kv_dtype"] == "int8" and ps["kv_quantized"] == 1
    assert ps["page_bytes"] > 0
    assert ps["bytes_per_token"] == ps["page_bytes"] // ps["page_size"]


# -- engine quality ----------------------------------------------------------


@pytest.mark.parametrize("arch", list(ARCHS))
def test_int8_quality_bounded_vs_f32_reference(arch, contig_ref):
    cfg = ARCHS[arch]
    got = EnsembleEngine(cfg, _params(cfg), paged=True, page_size=4,
                         kv_dtype="int8", **_KW).generate(_prompts(cfg),
                                                          max_new=8)
    agree = np.mean([np.mean(np.asarray(a) == np.asarray(b))
                     for a, b in zip(got, contig_ref[arch])])
    assert agree >= 0.75, f"{arch} int8 agreement {agree:.3f}"


def test_fp8_quality_bounded(contig_ref):
    if not _has_fp8():
        pytest.skip("no float8_e4m3fn in this jax")
    got = EnsembleEngine(GQA, _params(GQA), paged=True, page_size=4,
                         kv_dtype="fp8", **_KW).generate(_prompts(GQA),
                                                         max_new=8)
    agree = np.mean([np.mean(np.asarray(a) == np.asarray(b))
                     for a, b in zip(got, contig_ref["deepseek-7b"])])
    assert agree >= 0.5, f"fp8 agreement {agree:.3f}"


# -- absorbed MLA ------------------------------------------------------------


def test_absorbed_mla_token_exact_f32(contig_ref):
    """The absorbed reassociation must not change greedy output at f32
    (paged vs contiguous stays token-exact, the PR-4 invariant)."""
    got = EnsembleEngine(MLA, _params(MLA), paged=True, page_size=4,
                         **_KW).generate(_prompts(MLA), max_new=8)
    for a, b in zip(got, contig_ref["deepseek-v2-236b"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_absorb_mla_params_matches_inline_split():
    from repro.models.attention import mla_absorbed
    params = tf.init(jax.random.PRNGKey(0), MLA)
    absorbed = tf.absorb_mla_params(MLA, params)
    seg_raw = params["segments"][0]["slot_0"]["attn"]
    seg_abs = absorbed["segments"][0]["slot_0"]["attn"]
    assert "kv_uk" in seg_abs and "kv_uk" not in seg_raw
    per_layer = {k: v[0] for k, v in seg_raw.items()}
    w_uk, w_uv = mla_absorbed(per_layer, MLA.attn)  # inline fallback
    np.testing.assert_array_equal(np.asarray(w_uk),
                                  np.asarray(seg_abs["kv_uk"][0]))
    np.testing.assert_array_equal(np.asarray(w_uv),
                                  np.asarray(seg_abs["kv_uv"][0]))


def test_absorbed_step_flops_flat_in_max_seq():
    """Regression: the per-step gather+kv_up expand put O(max_seq)
    FLOPs on the decode loop (~3.4x at 4x max_seq on these shapes);
    absorbed decode must stay under 2x."""
    p = tf.absorb_mla_params(MLA, tf.init(jax.random.PRNGKey(0), MLA))

    def step_flops(max_seq):
        cache = tf.init_slot_cache(MLA, 2, max_seq, page_size=16,
                                   n_pages=2 * (max_seq // 16))
        toks = jnp.zeros((2, 1), jnp.int32)
        comp = jax.jit(
            lambda pr, c, t: tf.decode_step_paged(pr, MLA, c, t)
        ).lower(p, cache, toks).compile()
        ca = comp.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        return float(ca.get("flops", 0.0))

    ratio = step_flops(512) / max(step_flops(128), 1.0)
    assert ratio <= 2.0, f"decode-step FLOPs grew {ratio:.2f}x over 4x"


def test_swap_params_validates_raw_tree_and_reabsorbs():
    """swap_params takes RAW checkpoints (no absorbed leaves) and must
    re-derive kv_uk/kv_uv from the new weights."""
    old = _params(MLA, seed=0)
    new = _params(MLA, seed=1)
    eng = EnsembleEngine(MLA, old, paged=True, page_size=4, **_KW)
    eng.swap_params(new)
    got = eng.generate(_prompts(MLA), max_new=8)
    want = EnsembleEngine(MLA, new, paged=True, page_size=4,
                          **_KW).generate(_prompts(MLA), max_new=8)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # wrong-K stacks still rejected against the RAW spec
    with pytest.raises(ValueError, match="swap_params"):
        eng.swap_params(_params(MLA, K=3))


# -- composition: prefix/COW, speculative rollback, member mesh --------------


def test_prefix_cow_int8_warm_exact_vs_cold():
    """Prefix hits replay QUANTIZED pages written by another request;
    COW copies planes + scales together — warm must stay token-exact
    vs a cold int8 engine."""
    params = _params(GQA)
    kw = dict(n_slots=3, max_prompt=24, max_out=6, prefill_chunk=4,
              paged=True, page_size=4, kv_dtype="int8", seed=0)
    shared = list(range(100, 118))
    p1 = np.array(shared + [7, 8], np.int32)
    p2 = np.array(shared + [9, 10, 11], np.int32)  # diverges mid-page
    cold = EnsembleEngine(GQA, params, **kw)
    ref_out = cold.generate([p1, p2], 5)
    warm = EnsembleEngine(GQA, params, prefix_cache=True, **kw)
    np.testing.assert_array_equal(ref_out[0],
                                  warm.generate([p1], 5)[0])
    np.testing.assert_array_equal(ref_out[1],
                                  warm.generate([p2], 5)[0])
    ps = warm.page_stats()
    assert ps["prefix_hits"] >= 1 and ps["cow_pages"] >= 1
    # and the original pages survived the COW writer bit-intact
    np.testing.assert_array_equal(ref_out[0],
                                  warm.generate([p1], 5)[0])


def test_spec_rollback_int8_bit_identical():
    """Speculative decoding over quantized pages (verify writes gamma
    quantized tokens, rejection truncates the page chain) must never
    change tokens vs the plain int8 engine."""
    from repro.serving import SpeculativeEngine
    K, B, plen, steps = 2, 3, 6, 8
    params = _params(GEMMA, K=K, seed=7)
    student = jax.tree.map(lambda x: x[0], params)
    prompts = list(np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (B, plen), 0, GEMMA.vocab_size)))
    kw = dict(n_slots=B, max_prompt=plen, max_out=steps,
              prefill_chunk=4, paged=True, page_size=4, n_pages=64,
              kv_dtype="int8")
    ref_out = EnsembleEngine(GEMMA, params, **kw).generate(
        prompts, max_new=steps)
    spec = SpeculativeEngine(GEMMA, params, student, gamma=3, **kw)
    outs = spec.generate(prompts, max_new=steps)
    for a, b in zip(outs, ref_out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert spec.spec_stats()["spec_steps"] > 0


def test_mesh_int8_token_exact_and_sharded_scales():
    """Quantized planes AND their scale sidecars shard over the member
    axis; the sharded int8 engine is token-exact vs unsharded int8."""
    if len(jax.devices()) < 2:
        pytest.skip("needs a 2-device host "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")
    params = _params(GQA)
    kw = dict(paged=True, page_size=4, kv_dtype="int8", **_KW)
    want = EnsembleEngine(GQA, params, **kw).generate(_prompts(GQA),
                                                      max_new=8)
    mesh = shd.local_mesh(2, 1)
    eng = EnsembleEngine(GQA, params, mesh=mesh, **kw)
    got = eng.generate(_prompts(GQA), max_new=8)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # per-device pool: each device holds its K/M members' planes
    assert eng.cache_bytes() < kv_cache.pool_bytes(eng.cache,
                                                   per_device=False)
