"""Per-architecture smoke: reduced config, forward + train step + decode.

One test per assigned arch (deliverable f): instantiates the REDUCED
config of the same family, runs one forward and one optimizer step on CPU,
asserts output shapes and finiteness.  Full configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models
from repro.configs import registry
from repro.models import transformer as tf
from repro.optim import adamw
from repro.runtime import steps

ARCHS = list(registry.ARCH_IDS)


def _batch_for(cfg, B=2, T=16):
    batch = {"labels": jax.random.randint(jax.random.PRNGKey(9), (B, T), 0,
                                          cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["embeds"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, T, cfg.d_model)).astype(
            jnp.bfloat16) * 0.1
    else:
        batch["tokens"] = jax.random.randint(jax.random.PRNGKey(2), (B, T),
                                             0, cfg.vocab_size)
    if cfg.enc_dec:
        batch["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3),
            (B, cfg.enc_max_frames, cfg.d_model)).astype(jnp.bfloat16) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = registry.get_config(arch, reduced=True)
    B, T = 2, 16
    params = models.init(jax.random.PRNGKey(0), cfg)
    batch = _batch_for(cfg, B, T)

    logits, aux = tf.apply(params, cfg, tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"),
                           enc_embeds=batch.get("enc_embeds"), remat=False)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    # one member-stacked train step (K=2)
    K = 2
    stacked = jax.vmap(lambda k: models.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(4), K))
    opt = adamw(1e-3)
    state = {"params": stacked, "opt": jax.vmap(opt.init)(stacked)}
    kbatch = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (K,) + x.shape), batch)
    step = jax.jit(lambda s, b: steps.make_local_step(cfg, opt)(
        s, b, None, 0.0))
    state2, loss = step(state, kbatch)
    assert bool(jnp.isfinite(loss))
    # params actually moved
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.abs(a.astype(jnp.float32)
                             - b.astype(jnp.float32)).max(),
        state["params"], state2["params"]))
    assert max(float(d) for d in delta) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = registry.get_config(arch, reduced=True)
    B, T = 2, 12
    params = models.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                              cfg.vocab_size)
    kw = {}
    if cfg.enc_dec:
        enc = jax.random.normal(
            jax.random.PRNGKey(2),
            (B, cfg.enc_max_frames, cfg.d_model)).astype(jnp.bfloat16) * .05
        kw["enc_embeds"] = enc
    if cfg.family == "vlm":
        pytest.skip("vlm train path uses embeds; decode covered by tokens "
                    "archs")
    full, _ = tf.apply(params, cfg, tokens=toks, remat=False, **kw)
    cache = tf.init_cache(cfg, B, max_seq=T)
    if cfg.enc_dec:
        cache["enc"] = tf.encode(params, cfg, kw["enc_embeds"])
    step = jax.jit(lambda p, c, t: tf.decode_step(p, cfg, c, t))
    outs = []
    for t in range(T):
        lg, cache = step(params, cache, toks[:, t: t + 1])
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1).astype(jnp.float32)
    scale = float(jnp.abs(full.astype(jnp.float32)).max()) + 1e-6
    err = float(jnp.abs(dec - full.astype(jnp.float32)).max())
    assert err / scale < 0.05, f"decode diverges from forward: {err/scale}"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_shapes(arch):
    cfg = registry.get_config(arch, reduced=True)
    if cfg.family == "vlm":
        pytest.skip("prefill via embeds covered in dry-run")
    B, T = 2, 16
    params = models.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0,
                              cfg.vocab_size)
    kw = {}
    if cfg.enc_dec:
        kw["enc_embeds"] = jnp.zeros((B, cfg.enc_max_frames, cfg.d_model),
                                     jnp.bfloat16)
    logits, pred = tf.prefill(params, cfg, tokens=toks, **kw)
    assert logits.shape == (B, cfg.vocab_size)
    assert pred.shape == (B,)


def test_paper_nin_smoke():
    from repro.models import cnn
    params = cnn.nin_init(jax.random.PRNGKey(0), n_classes=100)
    imgs = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
    logits = cnn.nin_apply(params, imgs)
    assert logits.shape == (4, 100)
    loss, _ = cnn.nin_loss(params, {"images": imgs,
                                    "labels": jnp.array([1, 2, 3, 4])})
    assert bool(jnp.isfinite(loss))


def test_segments_cover_all_layers():
    for arch in ARCHS:
        cfg = registry.get_config(arch)
        n = sum(c * len(s) for c, s in cfg.segments())
        assert n == cfg.n_layers, f"{arch}: segments cover {n} layers"
        assert len(cfg.layer_specs()) == cfg.n_layers
