"""Property tests for the paper's central claim (Section 3).

EC (output averaging): L(ensemble) <= mean_k L(member)  — ALWAYS, by
Jensen, for any member logits whatsoever (hypothesis searches for a
violation and must not find one).

MA (parameter averaging): no such bound — we exhibit a concrete
counterexample where the parameter-averaged model is strictly worse than
every local model (the paper's Figure 1 phenomenon, in miniature).
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional [test] extra")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ensemble as ens


@hypothesis.given(
    logits=hnp.arrays(np.float32, hnp.array_shapes(min_dims=3, max_dims=3,
                                                   min_side=2, max_side=6),
                      elements=st.floats(-30, 30, width=32)),
)
@hypothesis.settings(max_examples=200, deadline=None)
def test_jensen_gap_nonnegative(logits):
    K, B, C = logits.shape
    labels = np.arange(B) % C
    gap = ens.jensen_gap(jnp.asarray(logits), jnp.asarray(labels))
    assert float(gap) >= -1e-4, f"Jensen violated: gap={float(gap)}"


@hypothesis.given(
    logits=hnp.arrays(np.float32, (4, 8, 10),
                      elements=st.floats(-10, 10, width=32)),
    w=hnp.arrays(np.float32, (4,), elements=st.floats(0.0, 1.0, width=32)),
)
@hypothesis.settings(max_examples=100, deadline=None)
def test_jensen_gap_with_quorum_weights(logits, w):
    hypothesis.assume(w.sum() > 1e-3)
    labels = np.arange(8) % 10
    p = ens.ensemble_probs(jnp.asarray(logits), weights=jnp.asarray(w))
    gold = jnp.take_along_axis(p, jnp.asarray(labels)[:, None], 1)[:, 0]
    e_nll = -jnp.log(jnp.maximum(gold, 1e-30)).mean()
    lp = ens.member_log_probs(jnp.asarray(logits))
    m_nll = -jnp.take_along_axis(
        lp, jnp.broadcast_to(jnp.asarray(labels), (4, 8))[..., None],
        axis=-1)[..., 0].mean(1)
    weighted_mean = float((m_nll * (w / w.sum())).sum())
    assert float(e_nll) <= weighted_mean + 1e-4


def test_ma_counterexample():
    """Two perfect XOR-ish members whose parameter mean is near-chance.

    f(x) = softmax(W2 · relu(W1 x)): member A and member B are weight-
    permuted versions of the same perfect classifier (a symmetry of the
    network).  MA averages the permuted weights and destroys the function;
    the ensemble of outputs is untouched by the permutation.
    """
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 8))
    w1 = jax.random.normal(jax.random.PRNGKey(1), (8, 16)) * 1.5
    w2 = jax.random.normal(jax.random.PRNGKey(2), (16, 4)) * 1.5
    labels = jnp.argmax(jax.nn.relu(x @ w1) @ w2, axis=-1)  # teacher

    perm = jax.random.permutation(jax.random.PRNGKey(3), 16)
    members = [
        (w1, w2),
        (w1[:, perm], w2[perm, :]),  # identical function, permuted units
    ]

    def nll(w1_, w2_):
        logits = jax.nn.relu(x @ w1_) @ w2_
        lp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(lp, labels[:, None], 1).mean()

    member_nll = jnp.stack([nll(*m) for m in members])
    ma_nll = nll((members[0][0] + members[1][0]) / 2,
                 (members[0][1] + members[1][1]) / 2)

    member_logits = jnp.stack(
        [jax.nn.relu(x @ a) @ b for a, b in members])
    ec_nll = ens.ensemble_nll(member_logits, labels)

    # MA is catastrophically worse than every member; EC is not.
    assert float(ma_nll) > float(member_nll.max()) + 0.5
    assert float(ec_nll) <= float(member_nll.mean()) + 1e-5


@pytest.mark.parametrize("avg_probs", [True, False])
def test_ensemble_probs_normalized(avg_probs):
    logits = jax.random.normal(jax.random.PRNGKey(0), (3, 5, 7)) * 4
    p = ens.ensemble_probs(logits, average_probs=avg_probs)
    np.testing.assert_allclose(np.asarray(p.sum(-1)), 1.0, rtol=1e-5)


def test_ma_average_is_mean():
    tree = {"a": jnp.arange(12.0).reshape(4, 3),
            "b": jnp.ones((4, 2, 2))}
    out = ens.ma_average(tree)
    np.testing.assert_allclose(np.asarray(out["a"][0]),
                               np.asarray(tree["a"].mean(0)), rtol=1e-6)
    assert out["a"].shape == (4, 3)
    # weighted
    w = jnp.array([1.0, 0.0, 0.0, 0.0])
    out = ens.ma_average(tree, weights=w)
    np.testing.assert_allclose(np.asarray(out["a"][2]),
                               np.asarray(tree["a"][0]), rtol=1e-6)
