"""Trainer integration: EC/MA/sync rounds, failure restart, straggler,
elastic K, pseudo-label distillation path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import ECConfig, ModelConfig
from repro.data import image_member_datasets, lm_member_datasets
from repro.optim import adamw, sgd_momentum
from repro.runtime.trainer import Trainer


def _cnn_trainer(aggr="ec", ckpt=None, K=4, tau=4, label_mode="dense",
                 seed=1):
    cfg = ModelConfig(name="nin-t", family="cnn", n_layers=9, d_model=48,
                      vocab_size=10)
    key = jax.random.PRNGKey(0)
    train, test = image_member_datasets(key, K, per_member=64,
                                        n_classes=10, img=8)
    ec = ECConfig(tau=tau, lam=0.5, p_steps=tau // 2, relabel_fraction=0.5,
                  label_mode=label_mode, aggregator=aggr, top_m=4)
    return Trainer(cfg, ec, sgd_momentum(0.02), K, key, train, test,
                   batch_size=16, ckpt_dir=ckpt, seed=seed)


def _lm_trainer(aggr="ec", K=2, label_mode="topk"):
    from repro.configs import registry
    cfg = registry.get_config("deepseek-7b", reduced=True)
    key = jax.random.PRNGKey(0)
    train, test = lm_member_datasets(key, K, per_member=32, seq_len=16,
                                     vocab=cfg.vocab_size)
    ec = ECConfig(tau=3, lam=0.5, p_steps=2, relabel_fraction=0.5,
                  label_mode=label_mode, aggregator=aggr, top_m=8)
    return Trainer(cfg, ec, adamw(1e-3), K, key, train, test,
                   batch_size=4, seed=2)


@pytest.mark.parametrize("aggr", ["ec", "ma", "sync"])
def test_round_runs_and_evaluates(aggr):
    tr = _cnn_trainer(aggr)
    loss = tr.run_round()
    assert np.isfinite(loss)
    ev = tr.evaluate()
    assert 0 <= ev["local_err"] <= 1 and np.isfinite(ev["global_loss"])


def test_ec_distill_phase_uses_pseudo_buffer():
    tr = _cnn_trainer("ec")
    tr.run_round()
    assert tr.pseudo_buffer is not None
    subset, pseudo = tr.pseudo_buffer
    assert jax.tree.leaves(subset)[0].shape[0] == tr.K
    p = np.asarray(pseudo)
    # dense pseudo labels are distributions
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-4)
    tr.run_round()  # distill steps consume the buffer without error


def test_ec_lm_topk_pseudo_path():
    tr = _lm_trainer("ec", label_mode="topk")
    tr.run_round()
    from repro.core.compression import TopM
    assert isinstance(tr.pseudo_buffer[1], TopM)
    tr.run_round()
    ev = tr.evaluate()
    assert np.isfinite(ev["global_loss"])


def test_jensen_guarantee_on_real_models():
    """Paper Section 3 on actual trained members: ensemble nll <= mean."""
    tr = _cnn_trainer("ec")
    for _ in range(2):
        tr.run_round()
    ev = tr.evaluate()
    assert ev["global_loss"] <= ev["local_loss"] + 1e-5


def test_restart_from_checkpoint(tmp_path):
    ckpt = str(tmp_path)
    tr = _cnn_trainer("ec", ckpt=ckpt, tau=2)
    tr.run_round()
    tr.run_round()
    tr.ckpt.wait()
    w_before = np.asarray(jax.tree.leaves(tr.state["params"])[0])
    r_before = tr.round

    # simulate a node failure: fresh trainer process, resume from disk
    tr2 = _cnn_trainer("ec", ckpt=ckpt, tau=2)
    assert tr2.resume()
    assert tr2.round == r_before
    w_after = np.asarray(jax.tree.leaves(tr2.state["params"])[0])
    np.testing.assert_allclose(w_after, w_before)
    tr2.run_round()  # training continues


def test_straggler_drop_renormalizes():
    tr = _cnn_trainer("ec", K=4)
    mask = np.array([1.0, 1.0, 1.0, 0.0])  # member 3 lags
    tr.run_round(straggler_mask=mask)
    subset, pseudo = tr.pseudo_buffer
    p = np.asarray(pseudo)
    np.testing.assert_allclose(p.sum(-1), 1.0, atol=1e-4)
    # pseudo labels must not depend on the dropped member: recompute with
    # only 3 members
    import repro.core.aggregation as agg
    from repro.runtime import steps
    logits_fn = steps.make_logits_fn(tr.cfg)
    sub3 = jax.tree.map(lambda x: x[:3], subset)
    p3 = jax.jit(lambda pp, b: agg.allgather_relabel(
        pp, b, logits_fn, tr.ec))(
        jax.tree.map(lambda x: x[:3], tr.state["params"]), sub3)
    # member k's own-batch labels with quorum == labels from the 3-member
    # ensemble on the same batches
    np.testing.assert_allclose(p[:3], np.asarray(p3), atol=1e-4)


def test_elastic_reshard_grow_and_shrink():
    tr = _cnn_trainer("ec", K=4, tau=2)
    tr.run_round()
    tr.reshard(6, key=jax.random.PRNGKey(1))
    assert jax.tree.leaves(tr.state["params"])[0].shape[0] == 6
    loss = tr.run_round()
    assert np.isfinite(loss)
    tr.reshard(2)
    loss = tr.run_round()
    assert np.isfinite(loss)


def test_ma_equals_manual_mean():
    tr = _cnn_trainer("ma", K=3, tau=1)
    before = jax.tree.map(lambda x: np.asarray(x).copy(),
                          tr.state["params"])
    tr.run_round()
    after = tr.state["params"]
    for a in jax.tree.leaves(after):
        a = np.asarray(a)
        np.testing.assert_allclose(a[0], a.mean(0), rtol=1e-5, atol=1e-6)


def test_best_member_selection():
    tr = _cnn_trainer("ec", K=3)
    tr.run_round()
    best, k = tr.best_member()
    assert 0 <= k < 3
    assert jax.tree.leaves(best)[0].shape \
        == jax.tree.leaves(tr.state["params"])[0].shape[1:]
