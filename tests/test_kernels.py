"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.distill_loss import fused_distill_loss
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssm_scan import ssm_scan
from repro.kernels.wkv6 import wkv6


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("shape", [
    # (B, T, S, H, Hkv, dh)
    (1, 17, 17, 4, 4, 32),     # MHA, odd seq
    (2, 64, 64, 8, 2, 64),     # GQA
    (1, 130, 130, 4, 1, 128),  # kv=1 (gemma-like), unaligned seq
    (2, 32, 96, 4, 4, 32),     # cross-ish: kv longer than q
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 13),
                                           (False, 0)])
def test_flash_attention_sweep(shape, dtype, causal, window):
    B, T, S, H, Hkv, dh = shape
    if S != T and causal:
        pytest.skip("causal requires aligned positions in this harness")
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (B, T, H, dh), dtype)
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, S, Hkv, dh), dtype)
    vv = jax.random.normal(jax.random.PRNGKey(2), (B, S, Hkv, dh), dtype)
    got = flash_attention(q, kk, vv, causal=causal, window=window,
                          bq=32, bk=32)
    want = ref.attention(q, kk, vv, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("n,v,bn,bv", [
    (8, 100, 8, 32), (33, 517, 16, 128), (64, 2048, 64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_distill_loss_sweep(n, v, bn, bv, dtype):
    k = jax.random.PRNGKey(0)
    logits = (jax.random.normal(k, (n, v)) * 3).astype(dtype)
    labels = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, v)
    pseudo = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(2), (n, v))).astype(dtype)
    lam = jnp.float32(0.4)
    got = fused_distill_loss(logits, labels, pseudo, lam, bn, bv)
    want = ref.distill_loss(logits, labels, pseudo, lam)
    np.testing.assert_allclose(float(got), float(want), rtol=3e-3)


def test_distill_loss_grad_matches():
    n, v = 24, 300
    logits = jax.random.normal(jax.random.PRNGKey(0), (n, v))
    labels = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, v)
    pseudo = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (n, v)))
    lam = jnp.float32(0.8)
    gf = jax.grad(lambda z: fused_distill_loss(z, labels, pseudo, lam))(
        logits)
    gr = jax.grad(lambda z: ref.distill_loss(z, labels, pseudo, lam))(logits)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gr), atol=1e-6)


@pytest.mark.parametrize("shape", [
    (1, 16, 2, 8), (2, 50, 3, 16), (1, 100, 1, 64)])  # (B,T,H,dh)
@pytest.mark.parametrize("chunk", [8, 32])
def test_wkv6_sweep(shape, chunk):
    B, T, H, dh = shape
    k = jax.random.PRNGKey(0)
    mk = lambda i: jax.random.normal(jax.random.PRNGKey(i),  # noqa: E731
                                     (B, T, H, dh))
    r, kk, vv = mk(1), mk(2), mk(3)
    lw = -jnp.exp(mk(4).clip(-3, 2))  # strong + weak decays
    u = jax.random.normal(jax.random.PRNGKey(5), (H, dh)) * 0.3
    s0 = jax.random.normal(jax.random.PRNGKey(6), (B, H, dh, dh)) * 0.1
    y_got, s_got = wkv6(r, kk, vv, lw, u, s0, chunk=chunk)
    y_ref, s_ref = ref.wkv6(r, kk, vv, lw, u, s0)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_ref),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_ref),
                               atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("shape", [(1, 16, 8, 4), (2, 37, 24, 8),
                                   (1, 128, 64, 16)])  # (B,T,D,N)
@pytest.mark.parametrize("chunk,bd", [(16, 16), (64, 256)])
def test_ssm_scan_sweep(shape, chunk, bd):
    B, T, D, N = shape
    k = jax.random.PRNGKey(0)
    a = jnp.exp(-jnp.abs(jax.random.normal(k, (B, T, D, N))))
    b = jax.random.normal(jax.random.PRNGKey(1), (B, T, D, N)) * 0.2
    h0 = jax.random.normal(jax.random.PRNGKey(2), (B, D, N)) * 0.1
    hs_got, hT_got = ssm_scan(a, b, h0, chunk=chunk, bd=bd)
    hs_ref, hT_ref = ref.ssm_scan(a, b, h0)
    np.testing.assert_allclose(np.asarray(hs_got), np.asarray(hs_ref),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(hT_got), np.asarray(hT_ref),
                               atol=1e-5, rtol=1e-5)


def test_model_chunked_paths_match_refs():
    """models/ssm.py's chunked jnp forms == sequential oracles."""
    from repro.configs import registry
    from repro.models import ssm as mssm
    cfg = registry.get_config("rwkv6-7b", reduced=True)
    B, T, d = 2, 40, cfg.d_model
    H, dh = mssm.rwkv_dims(cfg)
    p = mssm.rwkv_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, d)) * 0.5
    r, k, v, g, lw = mssm._rwkv_proj(
        p, x, jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :T], cfg)
    hd = lambda t: t.astype(jnp.float32).reshape(B, T, H, dh)  # noqa: E731
    s0 = jnp.zeros((B, H, dh, dh))
    y_c, s_c = mssm._wkv_chunked(hd(r), hd(k), hd(v), hd(lw),
                                 p["rwkv_first"], s0)
    y_r, s_r = ref.wkv6(hd(r), hd(k), hd(v), hd(lw), p["rwkv_first"], s0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               atol=1e-4, rtol=1e-3)
