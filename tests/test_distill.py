"""Eqn-9 mixed loss: schedule, dense/sparse paths, fused-kernel parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compression as comp
from repro.core import distill


def test_lam_schedule_anneals_to_zero():
    lams = [float(distill.lam_schedule(t, 0.5, 10)) for t in range(12)]
    assert lams[0] == pytest.approx(0.5)
    assert lams[5] == pytest.approx(0.25)
    assert lams[10] == 0.0 and lams[11] == 0.0
    assert all(a >= b for a, b in zip(lams, lams[1:]))


def _setup(n=12, v=50):
    k = jax.random.PRNGKey(0)
    logits = jax.random.normal(k, (n, v)) * 2
    labels = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, v)
    pseudo = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (n, v)))
    return logits, labels, pseudo


def test_mixed_ce_dense_matches_manual():
    logits, labels, pseudo = _setup()
    lam = 0.3
    got = distill.mixed_ce(logits, labels, pseudo, lam, impl="jnp")
    logp = jax.nn.log_softmax(logits)
    ce_true = -jnp.take_along_axis(logp, labels[:, None], 1).mean()
    ce_ps = -(pseudo * logp).sum(-1).mean()
    np.testing.assert_allclose(float(got), float(ce_true + lam * ce_ps),
                               rtol=1e-5)


def test_mixed_ce_lam_zero_is_plain_ce():
    logits, labels, pseudo = _setup()
    a = distill.mixed_ce(logits, labels, pseudo, 0.0, impl="jnp")
    b = distill.true_ce(logits, labels)
    np.testing.assert_allclose(float(a), float(b), rtol=1e-6)


def test_mixed_ce_topm_full_m_matches_dense():
    logits, labels, pseudo = _setup(n=8, v=20)
    t = comp.from_dense(pseudo, 20)  # lossless
    lam = 0.7
    sparse = distill.mixed_ce(logits, labels, t, lam)
    dense = distill.mixed_ce(logits, labels, pseudo, lam, impl="jnp")
    np.testing.assert_allclose(float(sparse), float(dense), rtol=1e-4)


def test_fused_pallas_matches_jnp(monkeypatch):
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    logits, labels, pseudo = _setup(n=16, v=600)
    lam = 0.45
    fused = distill.mixed_ce(logits, labels, pseudo, lam, impl="pallas")
    ref = distill.mixed_ce(logits, labels, pseudo, lam, impl="jnp")
    np.testing.assert_allclose(float(fused), float(ref), rtol=1e-5)
    # gradients too (custom VJP)
    gf = jax.grad(lambda z: distill.mixed_ce(z, labels, pseudo, lam,
                                             impl="pallas"))(logits)
    gr = jax.grad(lambda z: distill.mixed_ce(z, labels, pseudo, lam,
                                             impl="jnp"))(logits)
    np.testing.assert_allclose(np.asarray(gf), np.asarray(gr), atol=1e-5)
