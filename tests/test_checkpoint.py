"""Checkpoint store: atomicity, crash injection, keep-N, async, reshard."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              reshard_members, restore_checkpoint,
                              save_checkpoint)
from repro.checkpoint.store import gc_keep_last


def _tree(k=0):
    return {"params": {"w": jnp.arange(12.0).reshape(3, 4) + k,
                       "b": jnp.ones((4,)) * k},
            "step": jnp.asarray(k, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    root = str(tmp_path)
    t = _tree(3)
    save_checkpoint(root, 3, t)
    assert latest_step(root) == 3
    got = restore_checkpoint(root, 3, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(t)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_save_restore_roundtrip_bf16(tmp_path):
    """ml_dtypes leaves (bfloat16 — the default serving dtype) survive
    the npz round-trip: np.load hands them back as raw |V2 void
    records, and restore must view them through the template dtype
    instead of asking jnp.asarray for a cast it does not have.  This
    is the exact path `launch/serve.py --watch-ckpt` hot-swaps
    through."""
    root = str(tmp_path)
    t = {"w": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3) / 3,
         "b": jnp.ones((4,), jnp.float32)}
    save_checkpoint(root, 1, t)
    got = restore_checkpoint(root, 1, jax.tree.map(jnp.zeros_like, t))
    assert got["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["w"], np.float32),
                                  np.asarray(t["w"], np.float32))
    np.testing.assert_array_equal(np.asarray(got["b"]), np.asarray(t["b"]))


def test_crash_before_commit_is_invisible(tmp_path):
    root = str(tmp_path)
    save_checkpoint(root, 1, _tree(1))
    # simulated crash mid-save of step 2: data written, commit rename never
    # happens -> restart must see step 1
    save_checkpoint(root, 2, _tree(2), fail_before_commit=True)
    assert latest_step(root) == 1
    got = restore_checkpoint(root, 1, _tree(0))
    assert int(got["step"]) == 1
    # gc cleans the stale staging dir
    gc_keep_last(root, keep=5)
    assert not any(n.endswith(".tmp") for n in os.listdir(root))


def test_keep_n_gc(tmp_path):
    root = str(tmp_path)
    for s in range(6):
        save_checkpoint(root, s, _tree(s))
    gc_keep_last(root, keep=2)
    kept = sorted(int(n.split("_")[1]) for n in os.listdir(root)
                  if n.startswith("step_"))
    assert kept == [4, 5]


def test_async_manager_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in range(1, 4):
        mgr.save(s, _tree(s))
    mgr.wait()
    assert mgr.latest() == 3
    got = mgr.restore(_tree(0))
    assert int(got["step"]) == 3
    mgr.close()


def test_manager_restore_without_checkpoint_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree(0))


def test_reshard_members_shrink_grow():
    state = {"w": jnp.arange(8.0).reshape(4, 2)}
    small = reshard_members(state, 2)
    assert small["w"].shape == (2, 2)
    np.testing.assert_allclose(np.asarray(small["w"]),
                               np.asarray(state["w"][:2]))
    big = reshard_members(state, 6, perturb=0.01, key=jax.random.PRNGKey(0))
    assert big["w"].shape == (6, 2)
    # first K members bit-identical, grown members perturbed copies
    np.testing.assert_allclose(np.asarray(big["w"][:4]),
                               np.asarray(state["w"]))
    assert float(jnp.abs(big["w"][4:] - state["w"][:2]).max()) > 0
