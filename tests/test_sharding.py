"""Sharding rules: pspec mapping, layout roles, sanitization, cache specs."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.common import sharding as shd
from repro.common.sharding import (constrain, layout_ctx, make_param_pspecs,
                                   pspec_for)
from repro.common.types import ParallelConfig


PAR = ParallelConfig(model_axis="model", fsdp_axis="")
PAR_FSDP = ParallelConfig(model_axis="model", fsdp_axis="data")


def test_column_row_rules():
    assert pspec_for("w_q", 2, PAR) == P(None, "model")
    assert pspec_for("w_down", 2, PAR) == P("model", None)
    assert pspec_for("w_q", 2, PAR_FSDP) == P("data", "model")
    assert pspec_for("w_down", 2, PAR_FSDP) == P("model", "data")


def test_expert_and_embed_rules():
    assert pspec_for("experts_gate", 3, PAR) == P("model", None, None)
    assert pspec_for("experts_down", 3, PAR_FSDP) == P("model", None, "data")
    assert pspec_for("embed", 2, PAR) == P("model", None)


def test_replicated_prefixes():
    for name in ("norm_scale", "router", "rwkv_decay_base", "mamba_A_log"):
        assert pspec_for(name, 1, PAR) == P(None)


def test_stacked_segment_padding():
    # scan-stacked leaves get left-padded Nones
    assert pspec_for("w_q", 3, PAR) == P(None, None, "model")
    assert pspec_for("w_q", 4, PAR) == P(None, None, None, "model")


def test_make_param_pspecs_sanitizes_nondivisible():
    mesh = shd.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    params = {"embed": jax.ShapeDtypeStruct((51865, 384), jnp.float32),
              "w_q": jax.ShapeDtypeStruct((384, 512), jnp.float32)}
    specs = make_param_pspecs(params, PAR, mesh=FakeMesh())
    assert specs["embed"] == P(None, None)  # 51865 % 16 != 0 -> replicate
    assert specs["w_q"] == P(None, "model")  # 512 % 16 == 0 -> keep


def test_ensemble_leading_axis():
    params = {"w_q": jax.ShapeDtypeStruct((4, 384, 512), jnp.float32)}
    par = ParallelConfig(ensemble_axis="data")
    specs = make_param_pspecs(params, par, ensemble=True)
    assert specs["w_q"] == P("data", None, "model")


def test_constrain_noop_off_mesh():
    x = jnp.ones((4, 8))
    assert constrain(x, None, "model") is x  # no mesh: unchanged


def test_layout_roles():
    from repro.common.sharding import _layout_map
    assert _layout_map()["batch"] == ("pod", "data")
    with layout_ctx(batch=("data",), seq="model"):
        assert _layout_map()["batch"] == ("data",)
        assert _layout_map()["seq"] == "model"
    assert _layout_map()["batch"] == ("pod", "data")


def test_cache_pspecs_rules():
    from repro.launch.specs import cache_pspecs

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    par = ParallelConfig(batch_axes=("data",))
    cache = {
        "idx": jax.ShapeDtypeStruct((), jnp.int32),
        "segments": [{
            "slot_0": {
                "k": jax.ShapeDtypeStruct((2, 128, 32768, 8, 128),
                                          jnp.bfloat16),
                "ssm": jax.ShapeDtypeStruct((2, 128, 8192, 16),
                                            jnp.float32),
            }}],
    }
    specs = cache_pspecs(None, cache, par, FakeMesh())
    # kv=8 < 16 -> seq-sharded; leading stack dim None
    assert specs["segments"][0]["slot_0"]["k"] \
        == P(None, "data", "model", None, None)
    assert specs["segments"][0]["slot_0"]["ssm"] \
        == P(None, "data", "model", None)
    assert specs["idx"] == P()
