"""repro.serving.frontend: online loop, HTTP/SSE, router, hot-swap.

Equivalence strategy mirrors tests/test_serving.py: float32 config so
greedy argmax cannot fork on near-ties, references produced by the
same engine class through the batch `generate()` path (row-independent
vmap makes isolated == in-batch results).  The HTTP layer must be a
transparent transport: every token that crosses the socket is compared
against the in-process reference.
"""
import json
import threading
import time
import urllib.request

import jax
import numpy as np
import pytest

from repro.common import sharding as shd
from repro.configs import registry
from repro.models import transformer as tf
from repro.serving import EnsembleEngine, Scheduler, client
from repro.serving.frontend import FrontendServer, Replica, Router

CFG = registry.get_config("gemma3-1b", reduced=True).with_(dtype="float32")


def _params(K, seed=0, cfg=CFG):
    return jax.vmap(lambda k: tf.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), K))


def _mk_engine(params, **over):
    kw = dict(n_slots=2, max_prompt=8, max_out=6, prefill_chunk=4)
    kw.update(over)
    return EnsembleEngine(CFG, params, **kw)


@pytest.fixture(scope="module")
def params_k2():
    return _params(2)


@pytest.fixture(scope="module")
def params_k4():
    return _params(4)


# -- online scheduler loop ---------------------------------------------------


def test_tick_loop_matches_batch_run(params_k2):
    """Driving tick() by hand == run(): the batch API is a wrapper,
    not a second policy."""
    reqs = [(np.arange(1, 6), 4), (np.arange(2, 4), 3), (np.arange(3, 8), 5)]
    e1 = _mk_engine(params_k2)
    s1 = Scheduler(e1)
    rids1 = [s1.submit(t, m) for t, m in reqs]
    ref = s1.run()

    e2 = _mk_engine(params_k2)
    s2 = Scheduler(e2)
    rids2 = [s2.submit(t, m) for t, m in reqs]
    for _ in range(1000):
        if not s2.has_work:
            break
        s2.tick()
    s2._flush_release()
    assert set(s2.completions) == set(rids2)
    for a, b in zip(rids1, rids2):
        np.testing.assert_array_equal(ref[a].tokens, s2.completions[b].tokens)


def test_streaming_callbacks_in_order_and_complete(params_k2):
    """on_token fires once per generated token, in index order, and the
    streamed sequence equals the completion; on_done fires after the
    last token."""
    eng = _mk_engine(params_k2)
    sched = Scheduler(eng)
    events = {}

    def on_token(rid, i, tok):
        events.setdefault(rid, []).append(("tok", i, tok))

    def on_done(comp):
        events.setdefault(comp.rid, []).append(("done", comp))

    reqs = [(np.arange(1, 6), 4), (np.arange(2, 4), 5)]
    rids = [sched.submit(t, m, on_token=on_token, on_done=on_done)
            for t, m in reqs]
    comps = sched.run()
    assert sched.n_streamed == sum(len(c.tokens) for c in comps.values())
    for rid in rids:
        ev = events[rid]
        assert ev[-1][0] == "done" and ev[-1][1] is comps[rid]
        toks = [rest[1] for kind, *rest in ev if kind == "tok"]
        idxs = [rest[0] for kind, *rest in ev if kind == "tok"]
        assert idxs == list(range(len(comps[rid].tokens)))
        np.testing.assert_array_equal(toks, comps[rid].tokens)


def test_submit_while_serve_forever_runs(params_k2):
    """The online loop accepts requests from another thread mid-decode
    and parks when idle (no busy-spinning: steps stop advancing)."""
    eng = _mk_engine(params_k2)
    sched = Scheduler(eng)
    t = threading.Thread(target=sched.serve_forever, daemon=True)
    t.start()
    try:
        done = threading.Event()
        out = {}
        ref = _mk_engine(params_k2).generate([np.arange(1, 6)], max_new=4)[0]
        sched.submit(np.arange(1, 6), 4,
                     on_done=lambda c: (out.setdefault("c", c), done.set()))
        assert done.wait(60.0)
        np.testing.assert_array_equal(out["c"].tokens, ref)
        # idle loop must not dispatch: quiesce on the scheduler's idle
        # event (drained + releases flushed — no has_work polling),
        # then the step counter freezes
        assert sched.wait_quiesced(60.0)
        steps = eng.steps_run
        time.sleep(0.2)
        assert eng.steps_run == steps
    finally:
        sched.stop()
        t.join(10.0)


def test_streaming_survives_preemption_without_duplicates(params_k2):
    """A preempted streaming request regenerates greedily but must not
    re-emit: every rid's streamed indices stay 0..n-1 exactly once."""
    eng = _mk_engine(params_k2, n_slots=4, paged=True, page_size=2,
                     n_pages=10)  # tight pool: preemption under load
    sched = Scheduler(eng)
    seen = {}

    def on_token(rid, i, tok):
        seen.setdefault(rid, []).append((i, tok))

    reqs = [(np.arange(1, 7), 6) for _ in range(5)]
    rids = [sched.submit(t, m, on_token=on_token) for t, m in reqs]
    comps = sched.run()
    assert sched.preemptions > 0  # the scenario actually exercised it
    for rid in rids:
        idxs = [i for i, _ in seen[rid]]
        assert idxs == list(range(len(comps[rid].tokens)))  # no dupes
        np.testing.assert_array_equal([t for _, t in seen[rid]],
                                      comps[rid].tokens)


# -- HTTP server -------------------------------------------------------------


def _start_frontend(engines, **kw):
    reps = [Replica(f"r{i}", e, **kw) for i, e in enumerate(engines)]
    router = Router(reps)
    srv = FrontendServer(router)
    srv.start()
    return srv, router, reps


def test_http_sse_token_exact_vs_generate_k4(params_k4):
    """ISSUE 5 satellite: SSE stream token-exact vs in-process
    generate() at K=4 — and the non-streamed variant too."""
    prompts = [np.arange(1, 8), np.arange(2, 5), np.arange(3, 9)]
    refs = [_mk_engine(params_k4).generate([p], max_new=5)[0].tolist()
            for p in prompts]
    srv, router, _ = _start_frontend([_mk_engine(params_k4)])
    try:
        for p, ref in zip(prompts, refs):
            sse = client.http_generate(srv.url, p, 5, stream=True)
            plain = client.http_generate(srv.url, p, 5, stream=False)
            assert sse["tokens"] == ref      # http_generate also asserts
            assert plain["tokens"] == ref    # stream == done payload
            assert sse["ttft_ms"] >= 0 and plain["latency_ms"] >= 0
    finally:
        srv.shutdown()


def test_http_concurrent_submits_from_threads(params_k2):
    """Concurrent client threads over 2 replicas: every response is
    token-exact; the fleet actually spread the load."""
    prompts = [np.arange(1, 6), np.arange(2, 8), np.arange(3, 5),
               np.arange(4, 9)]
    refs = [_mk_engine(params_k2).generate([p], max_new=4)[0].tolist()
            for p in prompts]
    srv, router, reps = _start_frontend(
        [_mk_engine(params_k2), _mk_engine(params_k2)])
    results, errors = {}, []

    def fire(i):
        try:
            results[i] = client.http_generate(
                srv.url, prompts[i % 4], 4, stream=(i % 2 == 0))["tokens"]
        except Exception as e:  # noqa: BLE001
            errors.append(repr(e))

    try:
        threads = [threading.Thread(target=fire, args=(i,))
                   for i in range(12)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120.0)
        assert not errors
        assert len(results) == 12
        for i, toks in results.items():
            assert toks == refs[i % 4], i
        stats = router.stats()
        assert stats["completed"] == 12
        assert sum(r["completed"] for r in stats["replicas"]) == 12
        # least-loaded routing used both replicas
        assert all(r["completed"] > 0 for r in stats["replicas"])
    finally:
        srv.shutdown()


def test_http_rejects_bad_requests(params_k2):
    """Every malformed/oversized request is a clean 400 with the
    validation message — the loop and its in-flight work are untouched."""
    srv, router, _ = _start_frontend([_mk_engine(params_k2)])
    try:
        for body, frag in [
                ({"tokens": [], "max_new": 4}, "prompt len"),
                ({"tokens": [1, 2], "max_new": 0}, "max_new"),
                ({"tokens": [1, 2], "max_new": -3}, "max_new"),
                ({"tokens": list(range(99)), "max_new": 4}, "prompt len"),
                ({"tokens": "nope", "max_new": 4}, "tokens"),
                ({"max_new": 4}, "tokens"),
        ]:
            req = urllib.request.Request(
                srv.url + "/v1/generate", data=json.dumps(body).encode(),
                headers={"Content-Type": "application/json"})
            with pytest.raises(urllib.error.HTTPError) as ei:
                urllib.request.urlopen(req)
            assert ei.value.code == 400, body
            assert frag in json.loads(ei.value.read())["error"], body
        # a good request still serves after all those rejects
        out = client.http_generate(srv.url, np.arange(1, 5), 3)
        assert len(out["tokens"]) == 3
    finally:
        srv.shutdown()


def test_healthz_and_metrics_shape(params_k2):
    srv, router, _ = _start_frontend([_mk_engine(params_k2, paged=True,
                                                 page_size=2)])
    try:
        h = client.http_get_json(srv.url, "/healthz")
        assert h["ok"] and not h["draining"]
        assert h["replicas"][0]["members"] == 2
        m = urllib.request.urlopen(srv.url + "/metrics").read().decode()
        for key in ("repro_serving_requests_submitted",
                    "repro_serving_live_slots", "repro_serving_free_pages",
                    "repro_serving_low_water_pages",
                    "repro_serving_swaps_done"):
            assert key in m, key
    finally:
        srv.shutdown()


# -- hot-swap + rollout ------------------------------------------------------


def _mesh_or_none():
    """A real ("member", "data") mesh when >1 device is present (the
    forced-2-device CI stage), else the 1x1 degradation — either way
    the shard_map path + re-sharding swap is exercised."""
    return shd.local_mesh(member=min(2, len(jax.devices())), data=1)


def test_swap_params_rejects_mismatched_stack(params_k2, params_k4):
    eng = _mk_engine(params_k2)
    with pytest.raises(ValueError, match="swap_params"):
        eng.swap_params(jax.tree.map(lambda x: x[:1], params_k4))


def test_hot_swap_under_load_token_exact_and_no_recompile(params_k2):
    """ISSUE 5 satellite: hot-swap under load on a REAL mesh when the
    host has one (CI's forced-2-device stage): old-model and new-model
    completions both token-exact vs their offline references, zero
    dropped requests, zero decode recompiles (same jitted callable)."""
    mesh = _mesh_or_none()
    params_new = _params(2, seed=11)
    kw = dict(n_slots=2, max_prompt=8, max_out=6, prefill_chunk=4,
              mesh=mesh)
    prompts = [np.arange(1, 7), np.arange(2, 6), np.arange(3, 8)]
    refs_old = [EnsembleEngine(CFG, params_k2, **kw)
                .generate([p], max_new=4)[0].tolist() for p in prompts]
    refs_new = [EnsembleEngine(CFG, params_new, **kw)
                .generate([p], max_new=4)[0].tolist() for p in prompts]
    assert refs_old != refs_new  # the swap must be observable

    engines = [EnsembleEngine(CFG, params_k2, **kw) for _ in range(2)]
    for e in engines:
        e.generate([prompts[0]], max_new=2)  # compile both kernels
    srv, router, reps = _start_frontend(engines)
    results, errors = {}, []

    def fire(i):
        try:
            results[i] = client.http_generate(
                srv.url, prompts[i % 3], 4, stream=(i % 2 == 0))["tokens"]
        except Exception as e:  # noqa: BLE001
            errors.append(repr(e))

    try:
        step_ids = [id(e._step) for e in engines]
        sizes = [e._step._cache_size() for e in engines]
        threads = [threading.Thread(target=fire, args=(i,))
                   for i in range(9)]
        for i, t in enumerate(threads):
            t.start()
            if i == 3:
                router.rollout(params_new)
        for t in threads:
            t.join(120.0)
        assert not errors and len(results) == 9  # zero dropped
        n_new = 0
        for i, toks in results.items():
            ok_old = toks == refs_old[i % 3]
            ok_new = toks == refs_new[i % 3]
            assert ok_old or ok_new, (i, toks)
            n_new += ok_new
        assert n_new > 0  # some requests actually hit the new model
        assert all(e.swaps_done == 1 for e in engines)
        assert [id(e._step) for e in engines] == step_ids
        assert [e._step._cache_size() for e in engines] == sizes
        # post-rollout requests serve the new model exclusively
        post = client.http_generate(srv.url, prompts[0], 4, stream=True)
        assert post["tokens"] == refs_new[0]
    finally:
        srv.shutdown()


def test_single_replica_rollout_backlogs_without_drops(params_k2):
    """With one replica, requests arriving mid-rollout park in the
    router backlog and serve on the swapped model — delayed, never
    dropped."""
    params_new = _params(2, seed=11)
    prompt = np.arange(1, 7)
    ref_new = _mk_engine(params_new).generate([prompt], max_new=4)[0]
    eng = _mk_engine(params_k2)
    eng.generate([prompt], max_new=2)
    srv, router, reps = _start_frontend([eng])
    try:
        router.drain("r0")
        assert router.wait_drained("r0", timeout=60.0)
        done = threading.Event()
        got = {}
        name, rid = router.submit(
            prompt, 4, on_done=lambda c: (got.setdefault("c", c),
                                          done.set()))
        assert name == "backlog"  # parked, not dropped
        eng.swap_params(params_new)
        router.rejoin("r0")
        assert done.wait(60.0)
        np.testing.assert_array_equal(got["c"].tokens, ref_new)
    finally:
        srv.shutdown()


# -- drain hygiene -----------------------------------------------------------


def test_router_drain_leaves_zero_orphaned_pages(params_k2):
    """ISSUE 5 satellite: after a drain completes, a paged replica's
    free list is whole again — no page leaks from the online loop's
    flush-on-idle release path."""
    engines = [_mk_engine(params_k2, n_slots=4, paged=True, page_size=2,
                          n_pages=16) for _ in range(2)]
    srv, router, reps = _start_frontend(engines)
    try:
        reqs = [(np.arange(1, 7), 4) for _ in range(10)]
        done = threading.Semaphore(0)
        for t, m in reqs:
            router.submit(t, m, on_done=lambda c: done.release())
        for _ in reqs:
            assert done.acquire(timeout=60.0)
        for name in ("r0", "r1"):
            router.drain(name)
            assert router.wait_drained(name, timeout=60.0)
        # quiesce = drained AND the release batch flushed — the idle
        # event replaces the old free_pages wall-clock poll
        for r in reps:
            assert r.scheduler.wait_quiesced(60.0)
        for e in engines:
            assert e.free_pages == e.n_pages  # zero orphaned pages
            assert all(e.allocator.held_pages(b) == 0
                       for b in range(e.n_slots))
    finally:
        srv.shutdown()


def test_rollout_flushes_prefix_trie_zero_stale_pages(params_k2):
    """ISSUE 7 satellite: a cached prefix from round t must never serve
    round t+1.  After a drained rollout the prefix trie is empty and
    ZERO shared/cached pages survive (Router.rollout asserts it); a
    repeat of the round-t workload then matches a cold engine built on
    the NEW params — token-exact, not served from stale KV."""
    cfg = registry.get_config("deepseek-7b", reduced=True).with_(
        dtype="float32")
    p_old = _params(2, cfg=cfg)
    p_new = _params(2, seed=11, cfg=cfg)
    kw = dict(n_slots=2, max_prompt=16, max_out=6, prefill_chunk=4,
              paged=True, page_size=4, prefix_cache=True)
    shared = list(range(50, 62))
    prompts = [np.array(shared + [7, 8], np.int32),
               np.array(shared + [9], np.int32)]
    refs_new = [EnsembleEngine(cfg, p_new, **kw).generate(
        [p], max_new=4)[0].tolist() for p in prompts]

    eng = EnsembleEngine(cfg, p_old, **kw)
    srv, router, reps = _start_frontend([eng])
    try:
        done = threading.Semaphore(0)
        for p in prompts * 2:  # round t: warm the trie, share pages
            router.submit(p, 4, on_done=lambda c: done.release())
        for _ in range(4):
            assert done.acquire(timeout=60.0)
        # quiesce flushes the batched releases, which insert the round-t
        # chains into the trie — cached_pages is then deterministic
        assert reps[0].scheduler.wait_quiesced(60.0)
        assert eng.page_stats()["cached_pages"] > 0

        router.rollout(p_new)  # round t+1 (asserts zero survivors)
        ps = eng.page_stats()
        assert ps["cached_pages"] == 0 and ps["shared_pages"] == 0

        outs = {}
        for i, p in enumerate(prompts):  # same workload, new round
            router.submit(
                p, 4, on_done=lambda c, i=i: (
                    outs.__setitem__(i, c.tokens.tolist()),
                    done.release()))
        for _ in prompts:
            assert done.acquire(timeout=60.0)
        for i in range(len(prompts)):
            assert outs[i] == refs_new[i]  # new model, not stale KV
    finally:
        srv.shutdown()


def test_replica_loop_crash_leaves_rotation(params_k2):
    """A crashed replica loop (engine exception out of tick) must latch
    failed + draining so the router stops routing to it — not hang
    every subsequent request on a dead thread."""
    engines = [_mk_engine(params_k2), _mk_engine(params_k2)]
    srv, router, reps = _start_frontend(engines)
    try:
        def boom():
            raise RuntimeError("injected engine failure")

        reps[0].engine.step = boom  # next decode on r0 dies
        # this request is routed to r0 (both idle) and dies with it —
        # its handler must answer 500, not park on the queue forever
        wedged = {}

        def fire_wedged():
            try:
                client.http_generate(srv.url, np.arange(1, 5), 3)
                wedged["outcome"] = "completed"
            except RuntimeError as e:
                wedged["outcome"] = str(e)

        t = threading.Thread(target=fire_wedged, daemon=True)
        t.start()
        deadline = time.time() + 30.0
        while reps[0].failed is None and time.time() < deadline:
            time.sleep(0.01)
        assert reps[0].failed is not None and not reps[0].routable
        t.join(30.0)
        assert "HTTP 500" in wedged.get("outcome", "still hanging")
        # the fleet still serves: everything routes to r1
        out = client.http_generate(srv.url, np.arange(1, 5), 3)
        assert out["replica"] == "r1" and len(out["tokens"]) == 3
        h = client.http_get_json(srv.url, "/healthz")
        by_name = {r["name"]: r for r in h["replicas"]}
        assert by_name["r0"]["failed"] and by_name["r1"]["failed"] is None
    finally:
        srv.shutdown(drain=False)  # r0's lost request cannot drain


def test_replica_scheduler_does_not_retain_completions(params_k2):
    """The online loop delivers via on_done and must not grow
    .completions forever (unbounded leak on a long-lived server); the
    lifetime counter still advances."""
    srv, router, reps = _start_frontend([_mk_engine(params_k2)])
    try:
        for _ in range(3):
            client.http_generate(srv.url, np.arange(1, 5), 3)
        sched = reps[0].scheduler
        assert sched.n_completed == 3
        assert sched.completions == {}  # dropped after on_done
        assert router.stats()["replicas"][0]["completed"] == 3
    finally:
        srv.shutdown()


def test_graceful_shutdown_drains_in_flight(params_k2):
    """shutdown(drain=True) serves out queued work before stopping;
    while draining, /healthz flips to 503 (load balancers stop
    routing) and new generate() calls are refused."""
    eng = _mk_engine(params_k2)
    srv, router, _ = _start_frontend([eng])
    comps = []
    for _ in range(4):
        router.submit(np.arange(1, 6), 4, on_done=comps.append)
    srv.draining = True  # what shutdown() flips first
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(srv.url + "/healthz")
    assert ei.value.code == 503
    with pytest.raises(RuntimeError, match="HTTP 503"):
        client.http_generate(srv.url, np.arange(1, 4), 2)
    srv.shutdown(drain=True)
    assert len(comps) == 4
    assert all(len(c.tokens) == 4 for c in comps)
