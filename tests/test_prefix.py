"""Prefix caching (ISSUE 7): refcounted COW pages + shared-prefix trie.

Three layers of evidence, cheapest first:

  - host-only unit tests of the PrefixCache trie and the refcounted
    PageAllocator (match/insert/dedup, COW accounting, LRU eviction,
    flush) — no device work at all;
  - a 10k-request churn storm over the allocator+trie pair with mixed
    shared prefixes, cancellations (release mid-prompt) and
    preemptions: afterwards every refcount is zero and the free list
    is whole — the no-leak guarantee admission accounting leans on;
  - a hypothesis property test (skipped where hypothesis is missing)
    over the same pair: random short interleavings of admit / share /
    cow / grow / rollback / release / flush, with
    PageAllocator.check_invariants() asserted after every single step
    and violating sequences shrunk to minimal reproductions;
  - engine/scheduler equivalence on a real (reduced, float32) GQA
    config: the warm path must be TOKEN-EXACT against the cold path —
    sharing pages, COW-isolating divergent writers and skipping
    prefill below the hit may change latency, never tokens — and the
    prefix-cache-off engine must not change behavior at all.

MLA-layout exactness and the >= 5x TTFT gate live in
benchmarks/serving_bench.py --prefix (scripts/ci.sh runs it, also
under a forced-2-device mesh).
"""
import numpy as np
import pytest

import jax

from repro.configs import registry
from repro.models import transformer as tf
from repro.serving import EnsembleEngine, PrefixCache, Scheduler
from repro.serving.kv_cache import PageAllocator

CFG = registry.get_config("deepseek-7b", reduced=True).with_(
    dtype="float32")


def _params(K, seed=0, cfg=CFG):
    return jax.vmap(lambda k: tf.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), K))


@pytest.fixture(scope="module")
def params_k2():
    return _params(2)


def _wired(n_pages=32, page=4, n_slots=4, pps=8):
    """An allocator with a trie wired in, the engine's arrangement."""
    a = PageAllocator(n_pages, page, n_slots, pps)
    a.cache = PrefixCache(page)
    return a


# -- trie unit tests ---------------------------------------------------------


def test_trie_match_insert_roundtrip_and_partial_tail():
    c = PrefixCache(4)
    toks = list(range(10))  # 2 full pages + 2-token partial leaf
    assert c.insert(toks, [7, 3, 9]) == 3
    # full-page hit, capped below the partial leaf
    hit, full, tail = c.match(toks[:8] + [99], 8)
    assert (hit, full, tail) == (8, [7, 3], None)
    # token-granular tail: 9 shared tokens = 2 full pages + 1 in-page
    hit, full, tail = c.match(toks[:9] + [99, 98], 10)
    assert (hit, full) == (9, [7, 3]) and tail == (9, 1)
    # the max_hit cap truncates INSIDE a full page -> tail into it
    hit, full, tail = c.match(toks, 6)
    assert (hit, full) == (6, [7]) and tail == (3, 2)
    # disjoint prompt: no hit
    assert c.match([55, 56, 57], 3)[0] == 0


def test_trie_dedup_is_content_addressed():
    c = PrefixCache(4)
    assert c.insert(list(range(8)), [0, 1]) == 2
    # same content, different pages: nothing claimed, dedup counted
    assert c.insert(list(range(8)), [5, 6]) == 0
    assert c.deduped_pages == 2
    # shared first page, divergent second -> one new node
    assert c.insert(list(range(4)) + [9, 9, 9, 9], [7, 8]) == 1
    hit, full, _ = c.match(list(range(4)) + [9, 9, 9, 9], 8)
    assert (hit, full) == (8, [0, 8])


def test_trie_peek_has_no_side_effects():
    c = PrefixCache(2)
    c.insert([1, 2, 3, 4], [0, 1])
    before = (c.lookups, c.hits, list(c._lru))
    assert c.peek([1, 2, 3, 4], 3) == c.match([1, 2, 3, 4], 3)
    # match counted and LRU-touched; the peek before it did neither
    assert (c.lookups, c.hits) == (before[0] + 1, before[1] + 1)
    c2 = PrefixCache(2)
    c2.insert([1, 2, 3, 4], [0, 1])
    c2.insert([5, 6], [2])
    order0 = list(c2._lru)
    c2.peek([1, 2], 2)
    assert list(c2._lru) == order0  # peek must not reorder eviction


def test_trie_reclaim_lru_leaf_first_and_flush():
    c = PrefixCache(2)
    c.insert([1, 2, 3, 4], [0, 1])   # chain 0 -> 1
    c.insert([1, 2, 9, 9], [0, 2])   # sibling leaf 2 under 0
    for p in (0, 1, 2):
        c.page_unreferenced(p)
    assert c.evictable == 3
    # oldest leaf first: page 1 (leaf) goes before page 0 (its parent)
    assert c.reclaim(1) == [1]
    assert c.reclaim(2) == [2, 0]
    assert c.cached_pages == 0 and c.evicted_pages == 3
    # flush returns only unreferenced pages; referenced ones are
    # disowned (their unref later frees them at the allocator)
    c.insert([1, 2, 3, 4], [4, 5])
    c.page_unreferenced(4)
    assert sorted(c.flush()) == [4]
    assert c.cached_pages == 0 and c.owns(5) is False


# -- allocator refcount / COW / accounting -----------------------------------


def test_share_refcounts_and_release_order_preserved():
    a = _wired()
    assert a.alloc(0, 3)
    chain = list(a.chain(0))
    a.share(1, chain[:2])
    assert a.ref(chain[0]) == 2 and a.shared_pages == 2
    # slot 0 releases: shared pages live on (ref 1), its private page
    # frees; nothing reaches the trie (it owns none of these)
    a.release(0)
    assert a.ref(chain[0]) == 1 and a.ref(chain[2]) == 0
    a.release(1)
    assert all(a.ref(p) == 0 for p in chain)
    assert a.free_pages == a.n_pages
    # free-list pop order unchanged from the pre-refcount allocator:
    # lowest id comes back out first
    assert a.alloc(2, 1) and a.chain(2) == (0,)


def test_cow_swaps_private_page_and_keeps_src():
    a = _wired()
    assert a.alloc(0, 2)
    src = a.chain(0)[1]
    a.share(1, a.chain(0))          # both pages now shared (ref 2)
    pair = a.cow(1, 1)
    assert pair is not None and pair[0] == src
    assert a.chain(1)[1] == pair[1] != src
    assert a.ref(src) == 1 and a.ref(pair[1]) == 1
    assert a.cow_count == 1
    # exclusive page: no copy needed
    assert a.cow(1, 1) is None


def test_trie_owned_pages_become_evictable_not_free():
    a = _wired(n_pages=8, page=4, n_slots=2, pps=4)
    assert a.alloc(0, 2)
    chain = list(a.chain(0))
    a.cache.insert(list(range(8)), chain)
    a.release(0)
    # pages kept by the trie: not free, but still available
    assert a.free_pages == 6 and a.available_pages == 8
    assert a.cache.evictable == 2
    # allocs drain the free list first...
    assert a.alloc(1, 2) and a.free_pages == 4
    assert a.alloc(0, 4) and a.free_pages == 0
    # ...then the cached pages yield to a live request (LRU reclaim)
    assert a.alloc(1, 4)
    assert a.cache.cached_pages == 0 and a.available_pages == 0


def test_flush_cache_returns_unreferenced_pages():
    a = _wired(n_pages=6, page=2, n_slots=2, pps=3)
    assert a.alloc(0, 2)
    a.cache.insert(list(range(4)), a.chain(0))
    a.release(0)                       # both pages now evictable
    assert a.alloc(1, 1)               # slot 1 holds one fresh page
    assert a.flush_cache() == 2
    assert a.free_pages == 5 and a.cache.cached_pages == 0


# -- 10k churn: no leaks -----------------------------------------------------


def test_allocator_trie_churn_10k_no_leak():
    """10k requests with mixed shared prefixes, churned through admit /
    cancel-mid-prompt / preempt / complete against a small pool: after
    the storm every refcount is zero, the trie holds only evictable
    pages, and flushing returns the free list to WHOLE — the no-leak
    invariant admission accounting (admit_cost/admission_headroom)
    silently assumes on every tick."""
    rng = np.random.default_rng(0)
    page, n_slots, pps = 4, 8, 8
    a = _wired(n_pages=64, page=page, n_slots=n_slots, pps=pps)
    prefixes = [list(rng.integers(1, 1000, rng.integers(4, 20)))
                for _ in range(6)]
    live = {}  # slot -> (tokens, written)
    for i in range(10_000):
        b = int(rng.integers(n_slots))
        if b in live:  # churn the occupant out: cancel / preempt / done
            toks, written = live.pop(b)
            if written > 0:
                n = -(-written // page)
                if len(a.chain(b)) >= n:
                    a.cache.insert(toks[:written], a.chain(b)[:n])
            a.release(b)
        pre = prefixes[int(rng.integers(len(prefixes)))]
        toks = list(pre) + list(rng.integers(1, 1000, rng.integers(1, 8)))
        plen = len(toks)
        hit, full, tail = a.cache.match(toks, plen - 1)
        want = -(-plen // page)
        cost = want - sum(1 for p in full if a.ref(p) > 0)
        if cost > a.available_pages:
            continue  # queue would hold it; nothing mutated
        if full or tail:
            a.share(b, full + ([tail[0]] if tail else []))
        if tail is not None:
            assert a.cow(b, len(full)) is not None
        assert a.alloc(b, want)
        # cancel mid-prompt sometimes: written < plen at next churn
        written = int(rng.integers(hit, plen + 1))
        live[b] = (toks, written)
    for b in list(live):
        a.release(b)
    assert all(r == 0 for r in a._ref)
    assert a.cache.evictable == a.cache.cached_pages
    a.flush_cache()
    assert a.free_pages == a.n_pages
    assert sorted(a._free) == list(range(a.n_pages))
    assert a.cow_count > 0 and a.cache.evicted_pages > 0  # paths hit


# -- property test: every interleaving keeps the pool partitioned ------------


def test_allocator_trie_property_random_interleavings():
    """Hypothesis drives random admit / share / cow / grow / rollback /
    cancel / release / flush interleavings through the wired
    allocator+trie pair and runs PageAllocator.check_invariants()
    after EVERY step: at all times each page is live (ref > 0), free,
    or trie-owned — exactly one of the three — and the free list holds
    no duplicates.  The churn storm above checks the end state of one
    long run; this checks every intermediate state of many short ones,
    and hypothesis shrinks any violating interleaving to a minimal
    reproduction.  Skips cleanly where hypothesis isn't installed
    (importorskip inside the test keeps the rest of this module
    running)."""
    hypothesis = pytest.importorskip(
        "hypothesis", reason="property test needs hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    page, n_slots, pps = 4, 4, 6
    prefixes = [list(range(1000, 1000 + n)) for n in (5, 9, 14)]
    op = st.tuples(
        # touch = admit a free slot / churn out a live one (cancel,
        # preempt and complete all take the insert-then-release path)
        st.sampled_from(["touch", "grow", "rollback", "flush"]),
        st.integers(0, n_slots - 1),         # slot
        st.integers(0, len(prefixes) - 1),   # shared-prefix family
        st.lists(st.integers(1, 999), min_size=1, max_size=5),  # tail
        st.integers(0, 100),                 # % of post-hit toks written
    )

    @hypothesis.settings(max_examples=60, deadline=None)
    @hypothesis.given(st.lists(op, max_size=80))
    def prop(ops):
        a = PageAllocator(16, page, n_slots, pps)
        a.cache = PrefixCache(page)
        live = {}  # slot -> (tokens, written, prompt_pages)
        for kind, b, fam, tail, wpct in ops:
            if kind == "touch" and b in live:  # cancel/preempt/complete
                toks, written, _ = live.pop(b)
                if written > 0:
                    n = -(-written // page)
                    if len(a.chain(b)) >= n:
                        a.cache.insert(toks[:written], a.chain(b)[:n])
                a.release(b)
            elif kind == "touch":              # admit, engine-style
                toks = prefixes[fam] + tail
                plen = len(toks)
                hit, full, t = a.cache.match(toks, plen - 1)
                want = -(-plen // page)
                cost = want - sum(1 for p in full if a.ref(p) > 0)
                if want > pps or cost > a.available_pages:
                    continue  # queued; nothing mutated
                if full or t:
                    a.share(b, full + ([t[0]] if t else []))
                if t is not None:
                    assert a.cow(b, len(full)) is not None
                assert a.alloc(b, want)
                written = hit + (plen - hit) * wpct // 100
                live[b] = (toks, written, want)
            elif kind == "grow" and b in live:  # decode page growth
                want = len(a.chain(b)) + 1
                if want <= pps and a.available_pages >= 1:
                    assert a.alloc(b, want)
            elif kind == "rollback" and b in live:  # spec-decode undo
                a.truncate(b, live[b][2])
            elif kind == "flush":
                a.flush_cache()
            a.check_invariants()
        for b in list(live):
            a.release(b)
        a.check_invariants()
        a.flush_cache()
        assert a.free_pages == a.n_pages
        assert sorted(a._free) == list(range(a.n_pages))

    prop()


# -- engine equivalence (GQA, reduced, float32) ------------------------------

_KW = dict(n_slots=3, max_prompt=24, max_out=6, prefill_chunk=4,
           paged=True, page_size=4, seed=0)


def test_engine_warm_token_exact_vs_cold_and_cow_isolation(params_k2):
    """The warm path returns the SAME tokens as a cold engine — across
    full-page hits, partial-page (COW) hits, and concurrent divergent
    sharers in one batch (a writer behind a COW page must never mutate
    a neighbor reading the shared original)."""
    shared = list(range(100, 118))                    # 18-token prefix
    p1 = np.array(shared + [7, 8], np.int32)
    p2 = np.array(shared + [9, 10, 11], np.int32)     # diverges at 18
    p3 = np.array(shared[:10] + [3, 4], np.int32)     # mid-page split
    cold = EnsembleEngine(CFG, params_k2, **_KW)
    ref = cold.generate([p1, p2, p3], 5)

    warm = EnsembleEngine(CFG, params_k2, prefix_cache=True, **_KW)
    np.testing.assert_array_equal(ref[0], warm.generate([p1], 5)[0])
    # p2 and p3 admit TOGETHER, both sharing p1's cached chain; p2's
    # divergence lands mid-page -> COW; p3 splits inside page 2
    out = warm.generate([p2, p3], 5)
    np.testing.assert_array_equal(ref[1], out[0])
    np.testing.assert_array_equal(ref[2], out[1])
    ps = warm.page_stats()
    assert ps["prefix_hits"] >= 2 and ps["cow_pages"] >= 1
    # and the original is intact: p1 replays warm, token-exact, off
    # the same cached pages the divergent writers shared
    np.testing.assert_array_equal(ref[0], warm.generate([p1], 5)[0])


def test_scheduler_prefix_on_equals_off_under_pressure(params_k2):
    """Continuous batching over a prefix-cache engine with a pool too
    small for the queue (preemptions live) returns the identical
    completions as the prefix-off run, and leaks nothing."""
    rng = np.random.default_rng(1)
    shared = list(range(200, 216))
    reqs = []
    for i in range(9):
        tail = list(rng.integers(1, 99, 1 + int(rng.integers(6))))
        cut = int(rng.integers(4, len(shared) + 1))
        reqs.append((np.array(shared[:cut] + tail, np.int32),
                     2 + i % 4))
    outs = {}
    for on in (False, True):
        eng = EnsembleEngine(CFG, params_k2, prefix_cache=on,
                             n_pages=14, **_KW)
        sched = Scheduler(eng)
        rids = [sched.submit(t, m) for t, m in reqs]
        done = sched.run()
        outs[on] = [done[r].tokens for r in rids]
        eng.update_slots(release=range(eng.n_slots))
        assert eng.allocator.available_pages == eng.n_pages  # no leak
        assert all(r == 0 for r in eng.allocator._ref)
    for a, b in zip(outs[False], outs[True]):
        np.testing.assert_array_equal(a, b)


def test_prefix_cache_requires_eligible_config(params_k2):
    with pytest.raises(ValueError, match="paged=True"):
        EnsembleEngine(CFG, params_k2, n_slots=2, max_prompt=8,
                       max_out=4, prefix_cache=True)
    g = registry.get_config("gemma3-1b", reduced=True).with_(
        dtype="float32")
    # max_seq=24 > gemma3's reduced local_window=16, so the sliding
    # window layers keep per-slot rings a hit could not skip
    with pytest.raises(ValueError, match="per-slot"):
        EnsembleEngine(g, _params(2, cfg=g), n_slots=2, max_prompt=16,
                       max_out=8, paged=True, page_size=4,
                       prefix_cache=True)


def test_speculative_engine_rejects_prefix_cache(params_k2):
    from repro.serving import SpeculativeEngine
    one = jax.tree.map(lambda x: x[:1], params_k2)
    with pytest.raises(ValueError, match="prefix_cache"):
        SpeculativeEngine(CFG, params_k2, one, prefix_cache=True,
                          paged=True, page_size=4, n_slots=2,
                          max_prompt=8, max_out=4, prefill_chunk=4)


# -- prefill chunk autotune (carry-over satellite) ---------------------------


def test_prefill_chunk_autotune_and_override(params_k2):
    # short prompts keep the proven floor of 32 (clamped to max_prompt)
    e = EnsembleEngine(CFG, params_k2, n_slots=2, max_prompt=24,
                       max_out=4)
    assert e.prefill_chunk == 24  # min(max(32, 6), 24)
    # long prompts: a quarter of max_prompt...
    e = EnsembleEngine(CFG, params_k2, n_slots=2, max_prompt=160,
                       max_out=4)
    assert e.prefill_chunk == 40
    # ...rounded up to a whole page on paged engines
    e = EnsembleEngine(CFG, params_k2, n_slots=2, max_prompt=160,
                       max_out=4, paged=True, page_size=16)
    assert e.prefill_chunk == 48
    # an explicit value always wins, including the 0 reference path
    e = EnsembleEngine(CFG, params_k2, n_slots=2, max_prompt=160,
                       max_out=4, prefill_chunk=8)
    assert e.prefill_chunk == 8
    e = EnsembleEngine(CFG, params_k2, n_slots=2, max_prompt=24,
                       max_out=4, prefill_chunk=0)
    assert e.prefill_chunk == 0
