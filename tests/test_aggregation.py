"""Aggregation protocols: allgather oracle, ring equivalence (subprocess
with 4 host devices), quorum, and the distributed top-M."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import ECConfig
from repro.core import aggregation as agg
from repro.core import compression as comp


def _tiny_logits_fn(params, batch):
    # linear "model": logits = x @ W
    return batch["x"] @ params["W"]


def _setup(K=4, m=3, d=6, V=10, seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"W": jax.random.normal(k, (K, d, V))}
    batches = {"x": jax.random.normal(jax.random.PRNGKey(seed + 1),
                                      (K, m, d))}
    return params, batches


def _oracle(params, batches, ec, quorum=None):
    """Literal Eqn 6: every member scores every batch, average probs."""
    K = params["W"].shape[0]
    w = np.ones(K) if quorum is None else np.asarray(quorum)
    w = w / w.sum()
    out = []
    for j in range(K):  # batch owner
        acc = 0
        for kk in range(K):  # member
            logits = np.asarray(batches["x"][j] @ params["W"][kk])
            e = np.exp(logits - logits.max(-1, keepdims=True))
            acc = acc + w[kk] * (e / e.sum(-1, keepdims=True))
        out.append(acc)
    return np.stack(out)


def test_allgather_matches_oracle():
    params, batches = _setup()
    ec = ECConfig(label_mode="dense")
    got = jax.jit(lambda p, b: agg.allgather_relabel(
        p, b, _tiny_logits_fn, ec))(params, batches)
    want = _oracle(params, batches, ec)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


def test_allgather_quorum_drops_member():
    params, batches = _setup()
    ec = ECConfig(label_mode="dense")
    q = jnp.asarray([1.0, 1.0, 0.0, 1.0])
    got = agg.allgather_relabel(params, batches, _tiny_logits_fn, ec,
                                quorum=q)
    want = _oracle(params, batches, ec, quorum=q)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


def test_allgather_topk_bounded_error():
    params, batches = _setup(V=20)
    dense = agg.allgather_relabel(params, batches, _tiny_logits_fn,
                                  ECConfig(label_mode="dense"))
    sparse = agg.allgather_relabel(params, batches, _tiny_logits_fn,
                                   ECConfig(label_mode="topk", top_m=8))
    approx = comp.to_dense(comp.normalize(sparse), 20)
    l1 = np.abs(np.asarray(approx) - np.asarray(dense)).sum(-1)
    bound = np.asarray(comp.l1_error_bound(comp.normalize(sparse)))
    assert (l1 <= bound + 1e-4).all()


def test_distributed_topm_equals_plain():
    probs = jax.nn.softmax(
        jax.random.normal(jax.random.PRNGKey(0), (5, 32)) * 3)
    a = comp.from_dense(probs, 4)
    b = comp.from_dense_sharded(probs, 4, n_shards=4)
    np.testing.assert_allclose(np.asarray(a.vals), np.asarray(b.vals),
                               atol=1e-6)
    assert (np.sort(np.asarray(a.idx)) == np.sort(np.asarray(b.idx))).all()
    np.testing.assert_allclose(np.asarray(a.rest), np.asarray(b.rest),
                               atol=1e-6)


RING_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys
    sys.path.insert(0, "{src}")
    import jax, jax.numpy as jnp, numpy as np
    from repro.common import sharding as shd
    from repro.common.types import ECConfig
    from repro.core import aggregation as agg, compression as comp

    mesh = shd.make_mesh((4,), ("data",))
    K, m, d, V = 4, 3, 6, 12
    k = jax.random.PRNGKey(0)
    params = {{"W": jax.random.normal(k, (K, d, V))}}
    batches = {{"x": jax.random.normal(jax.random.PRNGKey(1), (K, m, d))}}
    fn = lambda p, b: b["x"] @ p["W"]

    with shd.set_mesh(mesh):
        ec = ECConfig(label_mode="dense")
        ring = agg.ring_relabel(mesh, params, batches, fn, ec, axis="data")
        oracle = agg.allgather_relabel(params, batches, fn, ec)
        err = float(jnp.abs(ring - oracle).max())
        assert err < 1e-5, f"ring != oracle: {{err}}"

        # top-M with M == V is lossless: ring merge == dense oracle exactly
        ec_full = ECConfig(label_mode="topk", top_m=V)
        ring_f = agg.ring_relabel(mesh, params, batches, fn, ec_full,
                                  axis="data")
        df = comp.to_dense(comp.normalize(ring_f), V)
        err_f = float(jnp.abs(df - oracle).max())
        assert err_f < 1e-5, f"lossless ring topk != oracle: {{err_f}}"

        # pruned top-M: ring result within its own L1 bound of the oracle
        ec2 = ECConfig(label_mode="topk", top_m=4)
        ring_t = agg.ring_relabel(mesh, params, batches, fn, ec2,
                                  axis="data")
        nt = comp.normalize(ring_t)
        l1 = jnp.abs(comp.to_dense(nt, V) - oracle).sum(-1)
        bound = comp.l1_error_bound(nt)
        assert bool((l1 <= bound + 1e-4).all()), (l1.max(), bound.max())

        q = jnp.asarray([1.0, 0.0, 1.0, 1.0])
        ring_q = agg.ring_relabel(mesh, params, batches, fn, ec,
                                  axis="data", quorum=q)
        oracle_q = agg.allgather_relabel(params, batches, fn, ec, quorum=q)
        err_q = float(jnp.abs(ring_q - oracle_q).max())
        assert err_q < 1e-5, f"ring quorum: {{err_q}}"
    print("RING_OK")
""")


def test_ring_protocol_multidevice():
    """The ring (shard_map + ppermute over 4 devices) equals the dense
    oracle bit-for-bit, in dense, top-M, and quorum modes."""
    import os
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", RING_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "RING_OK" in proc.stdout


def test_psum_gradients_shape_preserved():
    g = {"a": jnp.ones((4, 3))}
    # pmean over a vmapped axis name requires being inside a map; emulate
    # with explicit mean (the sync step uses broadcast-mean directly)
    out = jax.tree.map(lambda x: jnp.broadcast_to(x.mean(0, keepdims=True),
                                                  x.shape), g)
    assert out["a"].shape == (4, 3)
