"""repro.serving: engine/loop equivalence, continuous batching, quorum.

Equivalence strategy: greedy rollout comparisons run on a float32 config
so near-tie argmax flips (the seed fuses in prob space where exp() can
round two close logits flat; bf16 activations make such ties reachable)
cannot fork the rollout, while the teacher-forced check asserts the
engine's member logits are BITWISE those of the seed's batched
decode_step on the default (bf16) config.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import ensemble as ens
from repro.models import transformer as tf
from repro.serving import Completion, EnsembleEngine, Scheduler
from repro.serving import kv_cache

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
# THE seed-loop baseline (per-member jit calls, host stacking, prob-space
# Eqn-6 fusion, greedy) — one copy, shared with the >=2x acceptance gate
from benchmarks.serving_bench import python_loop_decode as _seed_loop

CFG_BF16 = registry.get_config("gemma3-1b", reduced=True)
CFG = CFG_BF16.with_(dtype="float32")


def _params(cfg, K, seed=0):
    return jax.vmap(lambda k: tf.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), K))


def test_engine_matches_seed_loop_greedy_k2():
    K, B, plen, steps = 2, 4, 6, 8
    params = _params(CFG, K)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, plen), 0,
                                CFG.vocab_size)
    ref = _seed_loop(CFG, params, K, prompt, steps)  # (B, steps) np
    eng = EnsembleEngine(CFG, params, n_slots=B, max_prompt=plen,
                         max_out=steps)
    outs = eng.generate(list(np.asarray(prompt)), max_new=steps)
    for b in range(B):
        np.testing.assert_array_equal(outs[b], ref[b])


def test_slot_decode_bitwise_matches_batched_decode_bf16():
    """decode_step_slots == decode_step when all rows share a position."""
    B, T = 4, 10
    p = jax.tree.map(lambda x: x[0], _params(CFG_BF16, 1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                              CFG_BF16.vocab_size)
    c_ref = tf.init_cache(cfg=CFG_BF16, batch=B, max_seq=T)
    c_slot = tf.init_slot_cache(CFG_BF16, B, max_seq=T)
    step_ref = jax.jit(lambda c, t: tf.decode_step(p, CFG_BF16, c, t))
    step_slot = jax.jit(lambda c, t: tf.decode_step_slots(p, CFG_BF16, c, t))
    for t in range(T):
        lg_ref, c_ref = step_ref(c_ref, toks[:, t: t + 1])
        lg_slot, c_slot = step_slot(c_slot, toks[:, t: t + 1])
        np.testing.assert_array_equal(np.asarray(lg_ref), np.asarray(lg_slot))


def test_ensemble_log_probs_matches_probs():
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (3, 5, 17)) * 5
    w = jnp.array([2.0, 1.0, 0.0])
    lp = ens.ensemble_log_probs(logits, weights=w)
    p = ens.ensemble_probs(logits, weights=w)
    np.testing.assert_allclose(np.exp(np.asarray(lp)), np.asarray(p),
                               atol=1e-6)
    # uniform default too
    np.testing.assert_allclose(np.exp(np.asarray(ens.ensemble_log_probs(
        logits))), np.asarray(ens.ensemble_probs(logits)), atol=1e-6)


def test_quorum_weights_drop_and_renormalize():
    w = ens.quorum_weights(jnp.array([1.0, 0.0, 1.0]))
    np.testing.assert_allclose(np.asarray(w), [0.5, 0.0, 0.5], atol=1e-7)
    # all-dead quorum degrades to uniform instead of NaN
    w0 = ens.quorum_weights(jnp.zeros(4))
    np.testing.assert_allclose(np.asarray(w0), [0.25] * 4, atol=1e-7)


def test_quorum_masked_member_equals_serving_the_subset():
    """Quorum [1,1,0] over K=3 == serving the first K-1 members."""
    K, B, plen, steps = 3, 2, 4, 6
    params3 = _params(CFG, K, seed=7)
    prompts = [np.arange(1, plen + 1), np.arange(2, plen + 2)]
    e3 = EnsembleEngine(CFG, params3, n_slots=B, max_prompt=plen,
                        max_out=steps, quorum=[1.0, 1.0, 0.0])
    e2 = EnsembleEngine(CFG, jax.tree.map(lambda x: x[:2], params3),
                        n_slots=B, max_prompt=plen, max_out=steps)
    o3 = e3.generate(prompts, max_new=steps)
    o2 = e2.generate(prompts, max_new=steps)
    for a, b in zip(o3, o2):
        np.testing.assert_array_equal(a, b)


def test_scheduler_interleaves_and_isolates_requests():
    """Mixed-length requests through 2 slots: every completion equals the
    request decoded in isolation (slot recycling leaks nothing), and the
    step count proves the batch was shared, not run sequentially."""
    K, B = 2, 2
    params = _params(CFG, K)
    eng = EnsembleEngine(CFG, params, n_slots=B, max_prompt=8, max_out=8)
    reqs = [(np.arange(1, 6), 8), (np.arange(2, 4), 3),
            (np.arange(3, 9), 5), (np.arange(1, 3), 6)]

    # isolated references (same engine shape -> row-independent vmap
    # makes results identical regardless of batch companions)
    refs = [eng.generate([toks], max_new) for toks, max_new in reqs]

    sched = Scheduler(eng)
    rids = [sched.submit(toks, max_new) for toks, max_new in reqs]
    steps_before = eng.steps_run
    comps = sched.run()
    steps_used = eng.steps_run - steps_before

    assert set(comps) == set(rids)
    for rid, (toks, max_new) in zip(rids, reqs):
        assert len(comps[rid].tokens) == max_new
        np.testing.assert_array_equal(comps[rid].tokens, refs[rids.index(rid)][0])
        assert comps[rid].latency >= 0 and comps[rid].ttft >= 0
    # sequential lower bound: sum of per-request step counts
    sequential = sum(len(t) + m - 1 for t, m in reqs)
    assert steps_used < sequential, (steps_used, sequential)


def test_scheduler_eos_evicts_early():
    K, B, plen = 2, 2, 4
    params = _params(CFG, K)
    probe = EnsembleEngine(CFG, params, n_slots=B, max_prompt=8, max_out=8)
    prompt = np.arange(1, plen + 1)
    full = probe.generate([prompt], max_new=8)[0]
    eos = int(full[2])  # third generated token becomes the EOS id
    stop_at = int(np.nonzero(full == eos)[0][0])  # first occurrence
    eng = EnsembleEngine(CFG, params, n_slots=B, max_prompt=8, max_out=8,
                         eos_id=eos)
    sched = Scheduler(eng)
    rid = sched.submit(prompt, 8)
    comps = sched.run()
    got = comps[rid].tokens
    np.testing.assert_array_equal(got, full[: stop_at + 1])
    assert got[-1] == eos and len(got) < 8


def test_slot_cache_reset_recycles_without_leak():
    """Generating twice through the same slots gives identical output."""
    K, B = 2, 2
    params = _params(CFG, K)
    eng = EnsembleEngine(CFG, params, n_slots=B, max_prompt=8, max_out=4)
    prompts = [np.arange(1, 7), np.arange(4, 8)]
    first = eng.generate(prompts, max_new=4)
    second = eng.generate(prompts, max_new=4)
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a, b)


def test_cache_pool_shapes_and_reset():
    K, B, S = 2, 3, 8
    pool = kv_cache.init_pool(CFG, K, B, S)
    assert pool["idx"].shape == (K, B)
    assert kv_cache.slot_positions(pool).shape == (B,)
    assert kv_cache.pool_bytes(pool) > 0
    bumped = dict(pool)
    bumped["idx"] = pool["idx"] + 5
    mask = jnp.array([True, False, True])
    reset = kv_cache.reset_slots(bumped, mask)
    np.testing.assert_array_equal(np.asarray(reset["idx"]),
                                  [[0, 5, 0]] * K)


def test_enc_dec_arch_serves():
    """whisper (enc-dec) decodes through the engine: stub encoder
    context is computed per member once and survives slot recycling."""
    cfg = registry.get_config("whisper-tiny", reduced=True).with_(
        dtype="float32")
    params = _params(cfg, 2)
    eng = EnsembleEngine(cfg, params, n_slots=2, max_prompt=4, max_out=4)
    prompts = [np.arange(1, 4), np.arange(2, 6)]
    first = eng.generate(prompts, max_new=4)
    second = eng.generate(prompts, max_new=4)
    for a, b in zip(first, second):
        assert len(a) == 4
        np.testing.assert_array_equal(a, b)


# -- batched prefill (ISSUE 2) ----------------------------------------------


def _reference_walk(cfg, params, toks, T):
    """Teacher-forced token-by-token slot-decode logits. -> (B, T, V)."""
    B = toks.shape[0]
    p = jax.tree.map(lambda x: x[0], params)
    cache = tf.init_slot_cache(cfg, B, max_seq=T)
    step = jax.jit(lambda c, t: tf.decode_step_slots(p, cfg, c, t))
    out = []
    for t in range(T):
        lg, cache = step(cache, toks[:, t: t + 1])
        out.append(np.asarray(lg[:, 0]))
    return np.stack(out, 1)


@pytest.mark.parametrize("arch", ["gemma3-1b", "rwkv6-7b",
                                  "jamba-v0.1-52b", "deepseek-v2-236b"])
def test_prefill_slots_matches_teacher_forced_walk(arch):
    """Cache materialized by prefill_slots then decoded == the per-token
    walk, to float tolerance: attention (incl. sliding-window ring),
    MLA latent cache, mamba+moe hybrid, and rwkv recurrent state all
    covered.  Rows carry different prompt lengths, so chunk-tail
    masking and n_tok=0 no-op rows are exercised too."""
    cfg = registry.get_config(arch, reduced=True).with_(dtype="float32")
    T, chunk, plens = 12, 5, [12, 4, 7]
    B = len(plens)
    params = _params(cfg, 1, seed=3)
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                              cfg.vocab_size)
    ref = _reference_walk(cfg, params, toks, T)

    p = jax.tree.map(lambda x: x[0], params)
    cache = tf.init_slot_cache(cfg, B, max_seq=T)
    pf = jax.jit(lambda c, t, n: tf.prefill_slots(p, cfg, c, t, n))
    step = jax.jit(lambda c, t: tf.decode_step_slots(p, cfg, c, t))
    pos = np.zeros(B, np.int32)
    plen = np.array(plens)
    last = np.zeros((B, cfg.vocab_size), np.float32)
    toks_np = np.asarray(toks)
    for _ in range(-(-max(plens) // chunk)):
        n_tok = np.minimum(chunk, np.maximum(plen - pos, 0)).astype(np.int32)
        cols = np.clip(pos[:, None] + np.arange(chunk)[None, :], 0, T - 1)
        lg, cache = pf(cache, jnp.asarray(
            np.take_along_axis(toks_np, cols, axis=1)), jnp.asarray(n_tok))
        fin = (n_tok > 0) & (pos + n_tok >= plen)
        last[fin] = np.asarray(lg)[fin]
        pos += n_tok
    np.testing.assert_array_equal(np.asarray(cache["idx"]), plen)
    for b in range(B):  # last prefill logits == walk logits at plen-1
        np.testing.assert_allclose(last[b], ref[b, plens[b] - 1],
                                   atol=2e-4, rtol=1e-4)
    # decode onward from the prefilled cache, each row at its own pace
    for _ in range(T - max(plens)):
        tok_b = toks_np[np.arange(B), pos][:, None]
        lg, cache = step(cache, jnp.asarray(tok_b))
        for b in range(B):
            np.testing.assert_allclose(np.asarray(lg[b, 0]), ref[b, pos[b]],
                                       atol=2e-4, rtol=1e-4)
        pos += 1


def test_prefill_window_ring_wrap():
    """Prompts longer than the sliding window, chunk > window: the ring
    keeps only the last `window` positions and decode continues exactly."""
    cfg = CFG.with_(local_window=8)
    plen, chunk, steps = 20, 10, 4
    params = _params(cfg, 2)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(5),
                                           (plen,), 0, cfg.vocab_size))
    ref_eng = EnsembleEngine(cfg, params, n_slots=1, max_prompt=plen,
                             max_out=steps, prefill_chunk=0)
    eng = EnsembleEngine(cfg, params, n_slots=1, max_prompt=plen,
                         max_out=steps, prefill_chunk=chunk)
    np.testing.assert_array_equal(
        eng.generate([prompt], max_new=steps)[0],
        ref_eng.generate([prompt], max_new=steps)[0])


@pytest.mark.parametrize("arch", ["gemma3-1b", "rwkv6-7b"])
def test_engine_prefill_matches_per_token_reference(arch):
    """generate() through the chunked-prefill engine == the retained
    per-token teacher-forcing path, K=2, mixed prompt lengths."""
    cfg = registry.get_config(arch, reduced=True).with_(dtype="float32")
    params = _params(cfg, 2)
    prompts = [np.arange(1, 12) % cfg.vocab_size, np.arange(2, 5),
               np.arange(3, 10)]
    kw = dict(n_slots=3, max_prompt=12, max_out=6)
    ref = EnsembleEngine(cfg, params, prefill_chunk=0, **kw).generate(
        prompts, max_new=6)
    got = EnsembleEngine(cfg, params, prefill_chunk=4, **kw).generate(
        prompts, max_new=6)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)


def test_scheduler_prefill_budget_serves_correctly():
    """A tight per-iteration prefill budget (one chunk) still serves
    every request exactly; prefill programs ran chunked, not per-token."""
    K = 2
    params = _params(CFG, K)
    eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=8, max_out=4,
                         prefill_chunk=4)
    reqs = [(np.arange(1, 9), 4), (np.arange(2, 8), 4), (np.arange(3, 6), 4)]
    refs = [eng.generate([t], m) for t, m in reqs]
    sched = Scheduler(eng, prefill_budget=4)
    rids = [sched.submit(t, m) for t, m in reqs]
    prefills_before = eng.prefills_run
    comps = sched.run()
    for rid, ref in zip(rids, refs):
        np.testing.assert_array_equal(comps[rid].tokens, ref[0])
    # 8+6+3 prompt tokens at <=4/iteration needs >= 5 prefill programs
    assert eng.prefills_run - prefills_before >= 5


# -- bugfix regressions (ISSUE 2 satellites) --------------------------------


def test_generate_empty_prompt_list_returns_empty():
    params = _params(CFG, 1)
    eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=4, max_out=4)
    assert eng.generate([], max_new=4) == []


def test_update_slots_rejects_out_of_range_slots():
    """Negative slots must raise, not alias the last slot via numpy
    wraparound; >= n_slots must raise too."""
    params = _params(CFG, 1)
    eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=4, max_out=4)
    for bad in (-1, 2, 17):
        with pytest.raises(ValueError, match="out of range"):
            eng.update_slots(release=[bad])
        with pytest.raises(ValueError, match="out of range"):
            eng.update_slots(admits=[(bad, np.arange(1, 3), 2)])
    state_before = jax.device_get(eng.state)
    with pytest.raises(ValueError):
        eng.update_slots(release=[0], admits=[(-1, np.arange(1, 3), 2)])
    # the failed call must not have mutated slot state
    np.testing.assert_array_equal(state_before.active,
                                  jax.device_get(eng.state).active)


def test_idle_and_done_slots_freeze_position():
    """pos / cache idx must not advance for inactive or finished slots:
    an idle slot on a long-running server must never walk past max_seq."""
    params = _params(CFG, 2)
    eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=4, max_out=2)
    out = eng.generate([np.arange(1, 4)], max_new=2)  # slot 1 never admitted
    extra = eng.max_seq + 8  # enough steps to walk past max_seq unfixed
    for _ in range(extra):
        eng.step()
    st = jax.device_get(eng.state)
    idx = np.asarray(kv_cache.slot_positions(eng.cache))
    # prompt(3) + decode steps(max_new - 1), then frozen
    assert st.pos[0] == idx[0] == 3 + 1
    assert st.pos[1] == idx[1] == 0     # never active
    assert st.pos.max() < eng.max_seq
    # and the frozen steps did not corrupt the slot for the NEXT request
    np.testing.assert_array_equal(
        eng.generate([np.arange(1, 4)], max_new=2)[0], out[0])


def test_completion_ttft_honors_zero_first_token_time():
    """first_token_t=0.0 is a valid stamp, not a missing one: ttft must
    not fall back to finish_t (the old falsy-`or` footgun)."""
    c = Completion(rid=0, tokens=np.arange(2), prompt_len=2, submit_t=0.0,
                   admit_t=0.0, first_token_t=0.0, finish_t=5.0)
    assert c.ttft == 0.0
    c_none = Completion(rid=0, tokens=np.arange(2), prompt_len=2,
                        submit_t=1.0, admit_t=1.0, first_token_t=None,
                        finish_t=5.0)
    assert c_none.ttft == 4.0


def test_harvest_fetches_state_in_one_transfer(monkeypatch):
    """_harvest must issue ONE device_get per iteration, not one per
    finished slot: completions for a full batch finishing together ride
    a single transfer."""
    from repro.serving import scheduler as sched_mod
    params = _params(CFG, 1)
    eng = EnsembleEngine(CFG, params, n_slots=4, max_prompt=4, max_out=3)
    sched = Scheduler(eng)
    rids = [sched.submit(np.arange(1, 4), 3) for _ in range(4)]
    calls = {"n": 0}
    real = jax.device_get

    def counting(x):
        calls["n"] += 1
        return real(x)

    monkeypatch.setattr(sched_mod.jax, "device_get", counting)
    comps = sched.run()
    assert set(comps) == set(rids)
    assert all(len(comps[r].tokens) == 3 for r in rids)
    # one fetch per loop iteration (4 requests finish simultaneously)
    assert calls["n"] <= eng.steps_run + eng.prefills_run + 1


# -- request-validation edge cases (ISSUE 5 satellites) ---------------------


@pytest.mark.parametrize("bad_new", [0, -1, -17])
def test_validate_rejects_nonpositive_max_new(bad_new):
    """max_new <= 0 is a clear door-time error — not a silent clamp
    that would admit a request which can never emit or finish."""
    params = _params(CFG, 1)
    eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=4, max_out=4)
    with pytest.raises(ValueError, match="max_new"):
        eng.validate_request(np.arange(1, 3), bad_new)
    with pytest.raises(ValueError, match="max_new"):
        Scheduler(eng).submit(np.arange(1, 3), bad_new)


def test_validate_rejects_empty_prompt():
    """An empty prompt has no token to seed decode with; it must be
    rejected at the door, in both the engine and the scheduler."""
    params = _params(CFG, 1)
    eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=4, max_out=4)
    for empty in ([], np.zeros((0,), np.int32)):
        with pytest.raises(ValueError, match="prompt len"):
            eng.validate_request(empty, 2)
        with pytest.raises(ValueError, match="prompt len"):
            Scheduler(eng).submit(empty, 2)
        with pytest.raises(ValueError, match="prompt len"):
            eng.generate([empty], max_new=2)


def test_prompt_exactly_max_prompt_serves_full_budget():
    """A prompt of exactly max_prompt tokens with the full max_out
    budget (total == max_seq) serves correctly: positions stop at
    max_seq - 1, no clamp, no OOB — through both prefill paths and the
    paged pool."""
    params = _params(CFG, 2)
    P, G = 6, 4
    prompt = np.arange(1, P + 1)
    outs = {}
    for name, kw in [("per-token", dict(prefill_chunk=0)),
                     ("chunked", dict(prefill_chunk=4)),
                     ("paged", dict(prefill_chunk=4, paged=True,
                                    page_size=2))]:
        eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=P,
                             max_out=G, **kw)
        sched = Scheduler(eng)
        rid = sched.submit(prompt, G)  # boundary case must pass the door
        comps = sched.run()
        outs[name] = comps[rid].tokens
        assert len(outs[name]) == G
        st = jax.device_get(eng.state)
        assert st.pos.max() <= eng.max_seq  # never walked past the cache
    np.testing.assert_array_equal(outs["per-token"], outs["chunked"])
    np.testing.assert_array_equal(outs["per-token"], outs["paged"])


def test_prompt_of_max_seq_fails_with_clear_error():
    """One token over max_prompt — and the max_seq boundary itself —
    raise a message naming the limit, instead of silently truncating
    the prompt buffer."""
    params = _params(CFG, 1)
    eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=4, max_out=4)
    for plen in (eng.max_prompt + 1, eng.max_seq):
        with pytest.raises(ValueError, match=r"prompt len .* \[1, 4\]"):
            eng.validate_request(np.arange(plen), 2)
        with pytest.raises(ValueError, match="prompt len"):
            Scheduler(eng).submit(np.arange(plen), 2)


def test_report_surfaces_scheduler_health():
    """build_report/print_report carry preemptions, peak live slots,
    and the paged free-list low-water mark (ISSUE 5 satellite)."""
    from repro.serving import client
    params = _params(CFG, 2)
    eng = EnsembleEngine(CFG, params, n_slots=4, max_prompt=8, max_out=6,
                         prefill_chunk=4, paged=True, page_size=2,
                         n_pages=12)  # tight: force preemption
    reqs = [(np.arange(1, 7), 6) for _ in range(5)]
    rep = client.run_load(eng, reqs)
    assert rep["n_requests"] == 5
    assert rep["peak_in_flight"] >= 1
    assert rep["preemptions"] >= 1          # the tight pool thrashed
    assert 0 <= rep["low_water_pages"] < 12  # and the mark recorded it
    assert rep["ttft_p99_ms"] >= rep["ttft_p50_ms"]
    client.print_report(rep)  # smoke: the health line renders


def test_score_carries_jensen_guarantee():
    """Engine scoring: ensemble NLL <= mean member NLL (Eqn 4-5)."""
    K, B, T = 3, 4, 6
    params = _params(CFG, K)
    eng = EnsembleEngine(CFG, params, n_slots=1, max_prompt=1, max_out=1)
    key = jax.random.PRNGKey(9)
    toks = jax.random.randint(key, (B, T), 0, CFG.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(10), (B, T), 0,
                                CFG.vocab_size)
    m_nll, e_nll = eng.score(toks, labels)
    assert m_nll.shape == (K,)
    assert float(e_nll) <= float(m_nll.mean()) + 1e-5
