"""repro.serving: engine/loop equivalence, continuous batching, quorum.

Equivalence strategy: greedy rollout comparisons run on a float32 config
so near-tie argmax flips (the seed fuses in prob space where exp() can
round two close logits flat; bf16 activations make such ties reachable)
cannot fork the rollout, while the teacher-forced check asserts the
engine's member logits are BITWISE those of the seed's batched
decode_step on the default (bf16) config.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.core import ensemble as ens
from repro.models import transformer as tf
from repro.serving import EnsembleEngine, Scheduler
from repro.serving import kv_cache

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
# THE seed-loop baseline (per-member jit calls, host stacking, prob-space
# Eqn-6 fusion, greedy) — one copy, shared with the >=2x acceptance gate
from benchmarks.serving_bench import python_loop_decode as _seed_loop

CFG_BF16 = registry.get_config("gemma3-1b", reduced=True)
CFG = CFG_BF16.with_(dtype="float32")


def _params(cfg, K, seed=0):
    return jax.vmap(lambda k: tf.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), K))


def test_engine_matches_seed_loop_greedy_k2():
    K, B, plen, steps = 2, 4, 6, 8
    params = _params(CFG, K)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, plen), 0,
                                CFG.vocab_size)
    ref = _seed_loop(CFG, params, K, prompt, steps)  # (B, steps) np
    eng = EnsembleEngine(CFG, params, n_slots=B, max_prompt=plen,
                         max_out=steps)
    outs = eng.generate(list(np.asarray(prompt)), max_new=steps)
    for b in range(B):
        np.testing.assert_array_equal(outs[b], ref[b])


def test_slot_decode_bitwise_matches_batched_decode_bf16():
    """decode_step_slots == decode_step when all rows share a position."""
    B, T = 4, 10
    p = jax.tree.map(lambda x: x[0], _params(CFG_BF16, 1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, T), 0,
                              CFG_BF16.vocab_size)
    c_ref = tf.init_cache(cfg=CFG_BF16, batch=B, max_seq=T)
    c_slot = tf.init_slot_cache(CFG_BF16, B, max_seq=T)
    step_ref = jax.jit(lambda c, t: tf.decode_step(p, CFG_BF16, c, t))
    step_slot = jax.jit(lambda c, t: tf.decode_step_slots(p, CFG_BF16, c, t))
    for t in range(T):
        lg_ref, c_ref = step_ref(c_ref, toks[:, t: t + 1])
        lg_slot, c_slot = step_slot(c_slot, toks[:, t: t + 1])
        np.testing.assert_array_equal(np.asarray(lg_ref), np.asarray(lg_slot))


def test_ensemble_log_probs_matches_probs():
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (3, 5, 17)) * 5
    w = jnp.array([2.0, 1.0, 0.0])
    lp = ens.ensemble_log_probs(logits, weights=w)
    p = ens.ensemble_probs(logits, weights=w)
    np.testing.assert_allclose(np.exp(np.asarray(lp)), np.asarray(p),
                               atol=1e-6)
    # uniform default too
    np.testing.assert_allclose(np.exp(np.asarray(ens.ensemble_log_probs(
        logits))), np.asarray(ens.ensemble_probs(logits)), atol=1e-6)


def test_quorum_weights_drop_and_renormalize():
    w = ens.quorum_weights(jnp.array([1.0, 0.0, 1.0]))
    np.testing.assert_allclose(np.asarray(w), [0.5, 0.0, 0.5], atol=1e-7)
    # all-dead quorum degrades to uniform instead of NaN
    w0 = ens.quorum_weights(jnp.zeros(4))
    np.testing.assert_allclose(np.asarray(w0), [0.25] * 4, atol=1e-7)


def test_quorum_masked_member_equals_serving_the_subset():
    """Quorum [1,1,0] over K=3 == serving the first K-1 members."""
    K, B, plen, steps = 3, 2, 4, 6
    params3 = _params(CFG, K, seed=7)
    prompts = [np.arange(1, plen + 1), np.arange(2, plen + 2)]
    e3 = EnsembleEngine(CFG, params3, n_slots=B, max_prompt=plen,
                        max_out=steps, quorum=[1.0, 1.0, 0.0])
    e2 = EnsembleEngine(CFG, jax.tree.map(lambda x: x[:2], params3),
                        n_slots=B, max_prompt=plen, max_out=steps)
    o3 = e3.generate(prompts, max_new=steps)
    o2 = e2.generate(prompts, max_new=steps)
    for a, b in zip(o3, o2):
        np.testing.assert_array_equal(a, b)


def test_scheduler_interleaves_and_isolates_requests():
    """Mixed-length requests through 2 slots: every completion equals the
    request decoded in isolation (slot recycling leaks nothing), and the
    step count proves the batch was shared, not run sequentially."""
    K, B = 2, 2
    params = _params(CFG, K)
    eng = EnsembleEngine(CFG, params, n_slots=B, max_prompt=8, max_out=8)
    reqs = [(np.arange(1, 6), 8), (np.arange(2, 4), 3),
            (np.arange(3, 9), 5), (np.arange(1, 3), 6)]

    # isolated references (same engine shape -> row-independent vmap
    # makes results identical regardless of batch companions)
    refs = [eng.generate([toks], max_new) for toks, max_new in reqs]

    sched = Scheduler(eng)
    rids = [sched.submit(toks, max_new) for toks, max_new in reqs]
    steps_before = eng.steps_run
    comps = sched.run()
    steps_used = eng.steps_run - steps_before

    assert set(comps) == set(rids)
    for rid, (toks, max_new) in zip(rids, reqs):
        assert len(comps[rid].tokens) == max_new
        np.testing.assert_array_equal(comps[rid].tokens, refs[rids.index(rid)][0])
        assert comps[rid].latency >= 0 and comps[rid].ttft >= 0
    # sequential lower bound: sum of per-request step counts
    sequential = sum(len(t) + m - 1 for t, m in reqs)
    assert steps_used < sequential, (steps_used, sequential)


def test_scheduler_eos_evicts_early():
    K, B, plen = 2, 2, 4
    params = _params(CFG, K)
    probe = EnsembleEngine(CFG, params, n_slots=B, max_prompt=8, max_out=8)
    prompt = np.arange(1, plen + 1)
    full = probe.generate([prompt], max_new=8)[0]
    eos = int(full[2])  # third generated token becomes the EOS id
    stop_at = int(np.nonzero(full == eos)[0][0])  # first occurrence
    eng = EnsembleEngine(CFG, params, n_slots=B, max_prompt=8, max_out=8,
                         eos_id=eos)
    sched = Scheduler(eng)
    rid = sched.submit(prompt, 8)
    comps = sched.run()
    got = comps[rid].tokens
    np.testing.assert_array_equal(got, full[: stop_at + 1])
    assert got[-1] == eos and len(got) < 8


def test_slot_cache_reset_recycles_without_leak():
    """Generating twice through the same slots gives identical output."""
    K, B = 2, 2
    params = _params(CFG, K)
    eng = EnsembleEngine(CFG, params, n_slots=B, max_prompt=8, max_out=4)
    prompts = [np.arange(1, 7), np.arange(4, 8)]
    first = eng.generate(prompts, max_new=4)
    second = eng.generate(prompts, max_new=4)
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a, b)


def test_cache_pool_shapes_and_reset():
    K, B, S = 2, 3, 8
    pool = kv_cache.init_pool(CFG, K, B, S)
    assert pool["idx"].shape == (K, B)
    assert kv_cache.slot_positions(pool).shape == (B,)
    assert kv_cache.pool_bytes(pool) > 0
    bumped = dict(pool)
    bumped["idx"] = pool["idx"] + 5
    mask = jnp.array([True, False, True])
    reset = kv_cache.reset_slots(bumped, mask)
    np.testing.assert_array_equal(np.asarray(reset["idx"]),
                                  [[0, 5, 0]] * K)


def test_enc_dec_arch_serves():
    """whisper (enc-dec) decodes through the engine: stub encoder
    context is computed per member once and survives slot recycling."""
    cfg = registry.get_config("whisper-tiny", reduced=True).with_(
        dtype="float32")
    params = _params(cfg, 2)
    eng = EnsembleEngine(cfg, params, n_slots=2, max_prompt=4, max_out=4)
    prompts = [np.arange(1, 4), np.arange(2, 6)]
    first = eng.generate(prompts, max_new=4)
    second = eng.generate(prompts, max_new=4)
    for a, b in zip(first, second):
        assert len(a) == 4
        np.testing.assert_array_equal(a, b)


def test_score_carries_jensen_guarantee():
    """Engine scoring: ensemble NLL <= mean member NLL (Eqn 4-5)."""
    K, B, T = 3, 4, 6
    params = _params(CFG, K)
    eng = EnsembleEngine(CFG, params, n_slots=1, max_prompt=1, max_out=1)
    key = jax.random.PRNGKey(9)
    toks = jax.random.randint(key, (B, T), 0, CFG.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(10), (B, T), 0,
                                CFG.vocab_size)
    m_nll, e_nll = eng.score(toks, labels)
    assert m_nll.shape == (K,)
    assert float(e_nll) <= float(m_nll.mean()) + 1e-5
