"""Synthetic data: determinism, disjoint member shards, learnable structure."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (image_member_datasets, lm_member_datasets,
                        sample_batch, sample_relabel_subset)


def test_deterministic():
    k = jax.random.PRNGKey(7)
    a, _ = lm_member_datasets(k, 2, 8, 16, 100)
    b, _ = lm_member_datasets(k, 2, 8, 16, 100)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))


def test_member_shards_disjoint():
    k = jax.random.PRNGKey(0)
    train, _ = lm_member_datasets(k, 4, 16, 12, 50)
    t = np.asarray(train["tokens"])
    # sequences across members differ (random partition of the stream)
    assert not (t[0] == t[1]).all()


def test_labels_are_shifted_tokens():
    k = jax.random.PRNGKey(0)
    train, _ = lm_member_datasets(k, 2, 4, 10, 64)
    # labels[t] is the next-token target: labels[:-1] aligns with
    # tokens[1:] by construction of the stream
    np.testing.assert_array_equal(np.asarray(train["tokens"][..., 1:]),
                                  np.asarray(train["labels"][..., :-1]))


def test_lm_structure_is_learnable():
    """Bigram statistics beat uniform: the affine rules leak into counts."""
    k = jax.random.PRNGKey(1)
    train, _ = lm_member_datasets(k, 1, 64, 32, 16)
    toks = np.asarray(train["tokens"][0]).reshape(-1)
    nxt = np.asarray(train["labels"][0]).reshape(-1)
    counts = np.zeros((16, 16))
    np.add.at(counts, (toks, nxt), 1)
    probs = counts / np.maximum(counts.sum(1, keepdims=True), 1)
    # per-row entropy far below uniform ln(16)
    ent = -(probs * np.log(np.maximum(probs, 1e-12))).sum(1)
    assert ent[counts.sum(1) > 10].mean() < 0.6 * np.log(16)


def test_image_classes_separable():
    k = jax.random.PRNGKey(2)
    train, test = image_member_datasets(k, 2, 128, n_classes=4, img=8,
                                        noise=0.3)
    x = np.asarray(train["images"]).reshape(-1, 8 * 8 * 3)
    y = np.asarray(train["labels"]).reshape(-1)
    # nearest-class-mean classifier should beat chance comfortably
    means = np.stack([x[y == c].mean(0) for c in range(4)])
    pred = ((x[:, None] - means[None]) ** 2).sum(-1).argmin(1)
    assert (pred == y).mean() > 0.8


def test_sampling_shapes():
    rng = np.random.default_rng(0)
    k = jax.random.PRNGKey(3)
    train, _ = image_member_datasets(k, 3, 32, n_classes=5, img=8)
    b = sample_batch(rng, train, 4)
    assert b["images"].shape == (3, 4, 8, 8, 3)
    sub, idx = sample_relabel_subset(rng, train, 0.5)
    assert sub["images"].shape == (3, 16, 8, 8, 3)
    # indices unique per member (sampling without replacement)
    assert all(len(set(row)) == len(row) for row in idx)
