"""Multi-device serving: the member-sharded engine vs the reference.

The engine's mesh path (shard_map kernels, psum-style Eqn-6 fusion)
must be a pure placement change: same tokens, same NLLs, same quorum
semantics as the single-device engine — only the bytes-per-device move.

These tests build the mesh with `common.sharding.local_mesh`, which
degrades to a 1x1 grid on a single-device host, so the SAME shard_map
program (collectives included) is exercised on plain CPU CI; run under
  XLA_FLAGS=--xla_force_host_platform_device_count=2
(scripts/ci.sh does) and the member axis actually spans two devices.
Tests that only make sense with real sharding skip below 2 devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common import sharding as shd
from repro.configs import registry
from repro.core import ensemble as ens
from repro.models import transformer as tf
from repro.serving import EnsembleEngine, Scheduler, kv_cache

CFG = registry.get_config("gemma3-1b", reduced=True).with_(dtype="float32")
K = 4
MULTI = len(jax.devices()) >= 2
needs_devices = pytest.mark.skipif(
    not MULTI, reason="needs >= 2 devices (XLA_FLAGS="
    "--xla_force_host_platform_device_count=2)")


def _params(cfg, k=K, seed=0):
    return jax.vmap(lambda kk: tf.init(kk, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), k))


@pytest.fixture(scope="module")
def mesh():
    return shd.local_mesh(2, 1)


@pytest.fixture(scope="module")
def params():
    return _params(CFG)


# -- placement helpers -------------------------------------------------------


def test_local_mesh_degrades_to_available_devices():
    """Oversized requests clamp instead of erroring, so the shard_map
    code path always runs — 1x1 on a single-device CI box."""
    m = shd.local_mesh(64, 64)
    n = len(jax.devices())
    assert m.axis_names == (shd.MEMBER_AXIS, shd.DATA_AXIS)
    assert m.shape[shd.MEMBER_AXIS] * m.shape[shd.DATA_AXIS] <= n
    assert shd.local_mesh(1, 1).devices.size == 1


def test_parse_mesh_arg():
    assert shd.parse_mesh_arg("") is None
    assert shd.parse_mesh_arg("1x1") is None
    with pytest.raises(ValueError, match="MxD"):
        shd.parse_mesh_arg("two-by-one")
    m = shd.parse_mesh_arg("2x1")
    if MULTI:
        assert m.shape[shd.MEMBER_AXIS] == 2
    else:
        assert m is None or m.shape[shd.MEMBER_AXIS] == 1


def test_member_pspecs_shard_leading_axis_only():
    tree = {"a": jnp.zeros((4, 3, 2)), "b": {"c": jnp.zeros((4,))}}
    specs = shd.member_pspecs(tree)
    assert specs["a"] == jax.sharding.PartitionSpec("member", None, None)
    assert specs["b"]["c"] == jax.sharding.PartitionSpec("member")


def test_fusion_psum_matches_logsumexp(mesh):
    """ensemble_log_probs_psum under shard_map == the single-device
    reference, including zero-weight (dropped) members."""
    logits = jax.random.normal(jax.random.PRNGKey(0), (K, 3, 33)) * 4
    w = jnp.array([1.0, 1.0, 0.0, 1.0])
    f = jax.jit(shd.shard_map(
        lambda lg, ww: ens.ensemble_log_probs_psum(lg, ww, "member"),
        mesh,
        in_specs=(jax.sharding.PartitionSpec("member"),
                  jax.sharding.PartitionSpec("member")),
        out_specs=jax.sharding.PartitionSpec()))
    got = f(logits, w)
    ref = ens.ensemble_log_probs(logits, weights=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    assert (np.asarray(got.argmax(-1)) == np.asarray(ref.argmax(-1))).all()


# -- engine equivalence: decode / prefill / score ----------------------------


def _drive_with_quorum_drop(eng, prompts, max_new, drop_at, drop_mask):
    """Admit -> chunked prefill -> decode, dropping a member mid-stream
    at decode step `drop_at`.  Returns the generated tokens per slot."""
    eng.update_slots(release=range(eng.n_slots),
                     admits=[(i, p, max_new) for i, p in enumerate(prompts)])
    for i, p in enumerate(prompts):
        for _ in range(-(-len(p) // eng.prefill_chunk)):
            eng.prefill(i)
    for t in range(max_new - 1):
        if t == drop_at:
            eng.set_quorum(drop_mask)
        eng.step()
    st = jax.device_get(eng.state)
    return [st.out[i, : st.n_gen[i]] for i in range(len(prompts))]


def test_mesh_decode_and_prefill_match_single_device(mesh, params):
    """Chunked-prefill generate on the mesh == the single-device engine,
    token for token, K=4, mixed prompt lengths — with a quorum drop
    mid-stream in both (straggler drop is placement-independent)."""
    prompts = [np.arange(1, 10) % CFG.vocab_size, np.arange(2, 5)]
    kw = dict(n_slots=2, max_prompt=12, max_out=8, prefill_chunk=4)
    drop = dict(max_new=8, drop_at=3, drop_mask=[1.0, 1.0, 0.0, 1.0])
    ref = _drive_with_quorum_drop(
        EnsembleEngine(CFG, params, **kw), prompts, **drop)
    got = _drive_with_quorum_drop(
        EnsembleEngine(CFG, params, mesh=mesh, **kw), prompts, **drop)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)


def test_mesh_per_token_reference_path_matches_single_device(mesh, params):
    """prefill_chunk=0 (the teacher-forcing reference baseline) is also
    served through shard_map and stays token-exact."""
    prompts = [np.arange(1, 8), np.arange(3, 6)]
    kw = dict(n_slots=2, max_prompt=8, max_out=6, prefill_chunk=0)
    ref = EnsembleEngine(CFG, params, **kw).generate(prompts, max_new=6)
    got = EnsembleEngine(CFG, params, mesh=mesh, **kw).generate(
        prompts, max_new=6)
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)


def test_mesh_score_matches_single_device(mesh, params):
    """Teacher-forced scoring: global (K,) member NLLs and the fused
    ensemble NLL agree across placements, quorum-weighted included."""
    toks = jax.random.randint(jax.random.PRNGKey(3), (3, 5), 0,
                              CFG.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(4), (3, 5), 0,
                                CFG.vocab_size)
    kw = dict(n_slots=1, max_prompt=1, max_out=1,
              quorum=[1.0, 0.0, 1.0, 1.0])
    m_ref, e_ref = EnsembleEngine(CFG, params, **kw).score(toks, labels)
    m_got, e_got = EnsembleEngine(CFG, params, mesh=mesh, **kw).score(
        toks, labels)
    assert m_got.shape == (K,)
    np.testing.assert_allclose(np.asarray(m_got), np.asarray(m_ref),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(e_got), float(e_ref),
                               rtol=1e-6, atol=1e-6)
    # Jensen guarantee survives the placement change: the fused NLL is
    # bounded by the mean over the SURVIVING (quorum-weighted) members
    alive = np.asarray(m_got)[[0, 2, 3]]
    assert float(e_got) <= float(alive.mean()) + 1e-5


def test_mesh_scheduler_serves_identically(mesh, params):
    """Continuous batching over a mesh engine: completions match the
    single-device scheduler run, request for request."""
    reqs = [(np.arange(1, 7), 4), (np.arange(2, 5), 3), (np.arange(3, 9), 4)]
    kw = dict(n_slots=2, max_prompt=8, max_out=4, prefill_chunk=4)
    ref = Scheduler(EnsembleEngine(CFG, params, **kw))
    got = Scheduler(EnsembleEngine(CFG, params, mesh=mesh, **kw))
    rids_r = [ref.submit(t, m) for t, m in reqs]
    rids_g = [got.submit(t, m) for t, m in reqs]
    comp_r, comp_g = ref.run(), got.run()
    for rr, rg in zip(rids_r, rids_g):
        np.testing.assert_array_equal(comp_g[rg].tokens, comp_r[rr].tokens)


# -- placement-specific behavior ---------------------------------------------


@needs_devices
def test_cache_bytes_reports_per_device_not_global(mesh, params):
    """Under a member-sharded pool, cache_bytes must report what ONE
    device holds — global/M — not the global figure (the regression
    this guards: telemetry overstating per-chip footprint M-fold)."""
    kw = dict(n_slots=2, max_prompt=8, max_out=8)
    single = EnsembleEngine(CFG, params, **kw)
    sharded = EnsembleEngine(CFG, params, mesh=mesh, **kw)
    M = mesh.shape[shd.MEMBER_AXIS]
    assert M == 2
    assert sharded.cache_bytes() == single.cache_bytes() // M
    # the global (logical) allocation is unchanged by placement
    assert kv_cache.pool_bytes(sharded.cache, per_device=False) \
        == single.cache_bytes()


@needs_devices
def test_mesh_params_and_pool_actually_shard(mesh, params):
    """Each device must hold 1/M of every param and cache leaf — the
    whole point of the member placement."""
    eng = EnsembleEngine(CFG, params, mesh=mesh, n_slots=2, max_prompt=4,
                         max_out=4)
    M = mesh.shape[shd.MEMBER_AXIS]
    for leaf in jax.tree.leaves(eng.params) + jax.tree.leaves(eng.cache):
        shard = leaf.sharding.shard_shape(leaf.shape)
        assert shard[0] == leaf.shape[0] // M, (leaf.shape, shard)


@needs_devices
def test_mesh_rejects_nondivisible_member_count(mesh):
    p3 = _params(CFG, k=3)
    with pytest.raises(ValueError, match="does not divide"):
        EnsembleEngine(CFG, p3, mesh=mesh, n_slots=1, max_prompt=4,
                       max_out=4)
