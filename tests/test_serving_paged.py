"""Paged KV-cache pool + paged-attention kernel (ISSUE 4).

Equivalence strategy mirrors the rest of the serving suite: every paged
configuration is compared against the path it replaces —

  - the Pallas kernel (interpret mode) against `ref.attention` on the
    live prefix (GQA grouping, MLA-shaped dk != dv heads, sliding
    window) and against the gather oracle `ref.paged_attention`;
  - the paged engine (paged=True) against the contiguous engine
    token-for-token on a float32 config, across archs covering paged
    GQA, paged MLA latents, ring+paged mixes (gemma3), hybrid
    mamba+attn (jamba) and M-RoPE (qwen2-vl), both prefill paths;
  - the scheduler under memory pressure (n_pages too small for the
    queue) against the unpressured run: FIFO completion order, no
    starvation of preempted requests, identical tokens.

Paged planes shard over the mesh member axis exactly like the
contiguous pool; run under
  XLA_FLAGS=--xla_force_host_platform_device_count=2
(scripts/ci.sh does) and the member axis actually spans two devices.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.common import sharding as shd
from repro.configs import registry
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention
from repro.models import transformer as tf
from repro.serving import EnsembleEngine, Scheduler, kv_cache

CFG = registry.get_config("gemma3-1b", reduced=True).with_(dtype="float32")


def _params(cfg, K, seed=0):
    return jax.vmap(lambda k: tf.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), K))


# -- Pallas kernel vs oracles ------------------------------------------------


def _paged_case(B, S_max, lens, page, Hkv, dk, dv, seed=0):
    """Random paged planes + a shuffled page table, plus the gathered
    contiguous (B, S, Hkv, d) view for the dense oracle."""
    rng = np.random.default_rng(seed)
    P = -(-S_max // page)
    n_pages = B * P + 3  # a few pages stay free (unallocated sentinel)
    k_pages = rng.normal(size=(n_pages, page, Hkv, dk)).astype(np.float32)
    v_pages = rng.normal(size=(n_pages, page, Hkv, dv)).astype(np.float32)
    perm = rng.permutation(n_pages)
    table = np.full((B, P), n_pages, np.int32)
    pi = 0
    gk, gv = [], []
    for b in range(B):
        live = -(-int(lens[b]) // page)
        table[b, :live] = perm[pi:pi + live]
        pi += live
        t = np.minimum(table[b], n_pages - 1)
        gk.append(k_pages[t].reshape(P * page, Hkv, dk))
        gv.append(v_pages[t].reshape(P * page, Hkv, dv))
    return k_pages, v_pages, table, np.stack(gk), np.stack(gv)


@pytest.mark.parametrize("name,H,Hkv,dk,dv,window", [
    ("gqa-grouped", 8, 2, 32, 32, 0),        # g=4 grouped query heads
    ("gqa-kv1", 4, 1, 32, 32, 0),            # gemma-like shared kv head
    ("mla-expanded", 4, 4, 48, 32, 0),       # MLA: dk=nope+rope != dv
    ("sliding-window", 8, 2, 32, 32, 24),    # window < live length
])
def test_paged_kernel_matches_ref_attention(name, H, Hkv, dk, dv, window):
    """Interpret-mode kernel == ref.attention's decode row (the last
    query position of a causal run over the live prefix), fp32 tol."""
    B, S_max, page = 3, 64, 8
    lens = np.array([5, 33, 64])
    q = np.random.default_rng(1).normal(size=(B, H, dk)).astype(np.float32)
    kp, vp, table, gk, gv = _paged_case(B, S_max, lens, page, Hkv, dk, dv)
    got = paged_attention(jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                          jnp.asarray(table), jnp.asarray(lens, jnp.int32),
                          window=window)
    for b in range(B):
        L = int(lens[b])
        qf = np.zeros((1, L, H, dk), np.float32)
        qf[0, L - 1] = q[b]
        want = ref.attention(jnp.asarray(qf), jnp.asarray(gk[b:b + 1, :L]),
                             jnp.asarray(gv[b:b + 1, :L]), causal=True,
                             window=window)
        np.testing.assert_allclose(np.asarray(got[b]),
                                   np.asarray(want[0, L - 1]),
                                   atol=2e-5, rtol=1e-5)


def test_paged_kernel_matches_gather_oracle():
    """Kernel == kernels/ref.paged_attention (the lax reference the
    model path dispatches to off-TPU), same inputs bit for bit."""
    B, S_max, page, H, Hkv, dk, dv = 4, 32, 4, 8, 2, 16, 16
    lens = np.array([1, 7, 17, 32])
    q = np.random.default_rng(3).normal(size=(B, H, dk)).astype(np.float32)
    kp, vp, table, _, _ = _paged_case(B, S_max, lens, page, Hkv, dk, dv,
                                      seed=4)
    got = paged_attention(jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                          jnp.asarray(table), jnp.asarray(lens, jnp.int32))
    want = ref.paged_attention(jnp.asarray(q), jnp.asarray(kp),
                               jnp.asarray(vp), jnp.asarray(table),
                               jnp.asarray(lens, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=1e-5)


# -- paged engine vs contiguous engine ---------------------------------------


@pytest.mark.parametrize("arch", ["gemma3-1b", "deepseek-7b",
                                  "deepseek-v2-236b", "jamba-v0.1-52b",
                                  "qwen2-vl-2b"])
def test_paged_engine_matches_contiguous(arch):
    """generate() through the paged pool == the contiguous engine,
    token for token: paged GQA, paged MLA latents, gemma3's ring+paged
    mix, jamba's mamba+attn hybrid, and M-RoPE all covered, with mixed
    prompt lengths exercising per-row positions and chunk-tail drops."""
    cfg = registry.get_config(arch, reduced=True).with_(dtype="float32")
    params = _params(cfg, 2)
    prompts = [np.arange(1, 10) % cfg.vocab_size, np.arange(2, 6)]
    kw = dict(n_slots=2, max_prompt=12, max_out=6, prefill_chunk=4)
    ref_eng = EnsembleEngine(cfg, params, **kw)
    got_eng = EnsembleEngine(cfg, params, paged=True, page_size=4, **kw)
    ref_out = ref_eng.generate(prompts, max_new=6)
    got_out = got_eng.generate(prompts, max_new=6)
    for a, b in zip(got_out, ref_out):
        np.testing.assert_array_equal(a, b)
    # recycling slots through the allocator leaks nothing
    again = got_eng.generate(prompts, max_new=6)
    for a, b in zip(again, ref_out):
        np.testing.assert_array_equal(a, b)


def test_paged_engine_per_token_reference_path():
    """prefill_chunk=0 (teacher-forcing prompt walk) also runs paged —
    decode-path writes land in prompt pages grown at admission."""
    params = _params(CFG, 2)
    prompts = [np.arange(1, 12) % CFG.vocab_size, np.arange(2, 5)]
    kw = dict(n_slots=2, max_prompt=12, max_out=6, prefill_chunk=0)
    ref_out = EnsembleEngine(CFG, params, **kw).generate(prompts, max_new=6)
    got = EnsembleEngine(CFG, params, paged=True, page_size=4,
                         **kw).generate(prompts, max_new=6)
    for a, b in zip(got, ref_out):
        np.testing.assert_array_equal(a, b)


def test_paged_engine_through_pallas_kernel(monkeypatch):
    """REPRO_USE_PALLAS=1 routes paged GQA decode through the interpret
    Pallas kernel; greedy tokens still match the contiguous engine."""
    monkeypatch.setenv("REPRO_USE_PALLAS", "1")
    params = _params(CFG, 2)
    prompts = [np.arange(1, 8), np.arange(2, 5)]
    kw = dict(n_slots=2, max_prompt=8, max_out=4, prefill_chunk=4)
    got = EnsembleEngine(CFG, params, paged=True, page_size=4,
                         **kw).generate(prompts, max_new=4)
    monkeypatch.delenv("REPRO_USE_PALLAS")
    ref_out = EnsembleEngine(CFG, params, **kw).generate(prompts, max_new=4)
    for a, b in zip(got, ref_out):
        np.testing.assert_array_equal(a, b)


def test_paged_engine_on_member_mesh():
    """Paged pool + page table shard their leading (K,) axis over the
    member mesh like the contiguous pool: same tokens, K/M the cache
    bytes per device (1x1 degradation on a single-device host still
    runs the same shard_map program)."""
    params = _params(CFG, 4)
    mesh = shd.local_mesh(2, 1)
    M = mesh.shape[shd.MEMBER_AXIS]
    prompts = [np.arange(1, 10) % CFG.vocab_size, np.arange(2, 5)]
    kw = dict(n_slots=2, max_prompt=12, max_out=6, prefill_chunk=4,
              paged=True, page_size=6)
    single = EnsembleEngine(CFG, params, **kw)
    sharded = EnsembleEngine(CFG, params, mesh=mesh, **kw)
    ref_out = single.generate(prompts, max_new=6)
    got = sharded.generate(prompts, max_new=6)
    for a, b in zip(got, ref_out):
        np.testing.assert_array_equal(a, b)
    if M > 1:
        assert sharded.cache_bytes() == single.cache_bytes() // M


def test_paged_rejects_enc_dec_and_oversized_requests():
    whisper = registry.get_config("whisper-tiny", reduced=True)
    with pytest.raises(ValueError, match="enc-dec"):
        EnsembleEngine(whisper, _params(whisper, 1), n_slots=1,
                       max_prompt=4, max_out=4, paged=True)
    params = _params(CFG, 1)
    eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=8, max_out=8,
                         paged=True, page_size=4, n_pages=2)
    # 8 prompt + 8 new tokens needs 4 pages; the pool holds 2 — this
    # request could NEVER complete, so it must be rejected at the door
    with pytest.raises(ValueError, match="pages"):
        eng.validate_request(np.arange(1, 9), 8)


def test_paged_step_raises_when_pool_dry():
    """engine.step() without a preempting scheduler must fail loudly —
    silently stalling a slot would corrupt its stream."""
    params = _params(CFG, 1)
    eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=4, max_out=8,
                         prefill_chunk=4, paged=True, page_size=4,
                         n_pages=3)  # each request alone fits (3 pages)
    eng.update_slots(admits=[(0, np.arange(1, 5), 8),
                             (1, np.arange(1, 5), 8)])
    eng.prefill(0)
    eng.prefill(1)
    with pytest.raises(RuntimeError, match="out of pages"):
        for _ in range(8):  # both slots want a decode page; only 1 free
            eng.step()


def test_generate_oversubscribed_pool_with_eos_finishes():
    """The host page mirror cannot see an EOS finish; generate() (no
    harvest loop) must fetch done flags on an oversubscribed pool so a
    finished slot stops taking pages — without that, the free list runs
    dry on pages nobody needs and step() raises spuriously."""
    params = _params(CFG, 1)
    kw = dict(n_slots=2, max_prompt=4, max_out=8, prefill_chunk=4,
              paged=True, page_size=4)
    prompts = [np.arange(1, 5), np.arange(2, 6)]
    probe = EnsembleEngine(CFG, params, **kw)
    eos = int(probe.generate(prompts, max_new=8)[0][0])  # slot 0's first
    ref_out = EnsembleEngine(CFG, params, eos_id=eos, **kw).generate(
        prompts, max_new=8)
    # 5 pages: enough for the EOS-shortened run, NOT enough if the done
    # slot kept growing its chain to the full plen+max_new
    tight = EnsembleEngine(CFG, params, eos_id=eos, n_pages=5, **kw)
    got = tight.generate(prompts, max_new=8)
    for a, b in zip(got, ref_out):
        np.testing.assert_array_equal(a, b)


# -- allocator unit behavior -------------------------------------------------


def test_page_allocator_alloc_release_reuse():
    a = kv_cache.PageAllocator(n_pages=6, page_size=4, n_slots=3,
                               pages_per_slot=4)
    assert a.free_pages == 6 and a.pages_for(9) == 3
    assert a.alloc(0, 2) and a.alloc(1, 3)
    assert a.free_pages == 1 and a.held_pages(0) == 2
    assert a.holds(0, 7) and not a.holds(0, 8)
    # all-or-nothing: a failed grow leaves state untouched
    assert not a.alloc(2, 2)
    assert a.free_pages == 1 and a.held_pages(2) == 0
    # per-slot table width is enforced even with pages free
    assert not a.alloc(0, 5)
    t = a.table()
    assert t.shape == (3, 4)
    assert set(t[0, :2]) | set(t[1, :3]) == set(range(5))
    assert (t[2] == 6).all() and (t[0, 2:] == 6).all()  # sentinel
    assert a.release(1) == 3 and a.free_pages == 4
    # released pages are reusable and tables stay disjoint
    assert a.alloc(2, 4)
    t = a.table()
    assert len(set(t[0, :2]) | set(t[2])) == 6


def test_release_leaves_in_flight_slot_planes_bit_identical():
    """Satellite regression: releasing one slot must not touch the
    other B-1 slots' planes — masked per-slot update, bit-exact."""
    K, B = 2, 3
    pool = kv_cache.init_pool(CFG, K, B, 16)
    # make every leaf nonzero so an accidental full-plane zeroing shows
    pool = jax.tree.map(
        lambda x: x + jnp.arange(x.size, dtype=x.dtype).reshape(x.shape)
        if jnp.issubdtype(x.dtype, jnp.floating) else x, pool)
    mask = jnp.array([False, True, False])  # release only slot 1
    reset = kv_cache.reset_slots(pool, mask)

    def rows(tree, b):
        return [np.asarray(leaf[:, :, b]) for leaf in
                jax.tree.leaves(tree["segments"])]

    for b in (0, 2):  # in-flight neighbors: bit-identical
        for before, after in zip(rows(pool, b), rows(reset, b)):
            np.testing.assert_array_equal(before, after)
    np.testing.assert_array_equal(np.asarray(reset["idx"][:, 1]), [0] * K)


# -- scheduler under memory pressure -----------------------------------------


def _pressure_setup(n_pages=None):
    cfg = registry.get_config("deepseek-7b", reduced=True).with_(
        dtype="float32")
    params = _params(cfg, 2)
    eng = EnsembleEngine(cfg, params, n_slots=4, max_prompt=8, max_out=8,
                         prefill_chunk=4, paged=True, page_size=4,
                         n_pages=n_pages)
    reqs = [(np.arange(1, 8), 8), (np.arange(2, 7), 8), (np.arange(3, 9), 8),
            (np.arange(1, 5), 8), (np.arange(2, 5), 8), (np.arange(4, 9), 6)]
    return eng, reqs


def test_scheduler_memory_pressure_preempts_and_stays_fifo():
    """More requests queued than the page pool can hold concurrently:
    the free list runs dry mid-decode, the scheduler preempts back to
    the queue, and the run must (a) complete every request, (b) finish
    in FIFO order, (c) not starve preempted requests, (d) emit exactly
    the unpressured run's tokens."""
    ref_eng, reqs = _pressure_setup()           # default pool: no pressure
    ref_sched = Scheduler(ref_eng)
    ref_rids = [ref_sched.submit(t, m) for t, m in reqs]
    ref_comp = ref_sched.run()
    assert ref_sched.preemptions == 0

    eng, reqs = _pressure_setup(n_pages=6)      # 6 pages for a 4-slot batch
    sched = Scheduler(eng)
    rids = [sched.submit(t, m) for t, m in reqs]
    comps = sched.run()

    assert set(comps) == set(rids)              # nobody starved
    assert sched.preemptions > 0                # pressure actually bit
    for r_ref, r in zip(ref_rids, rids):        # token-for-token
        np.testing.assert_array_equal(comps[r].tokens,
                                      ref_comp[r_ref].tokens)
    finish_order = sorted(rids, key=lambda r: comps[r].finish_t)
    assert finish_order == rids                 # FIFO completions
    # under pressure fewer requests fit concurrently than slots exist
    assert sched.peak_in_flight <= eng.n_slots


def test_scheduler_admits_by_pages_not_slots():
    """With a roomy pool the paged scheduler fills every slot; with a
    tiny one it admits only what the free list covers."""
    eng, reqs = _pressure_setup()
    sched = Scheduler(eng)
    for t, m in reqs[:4]:
        sched.submit(t, m)
    sched._fill_slots()
    assert sched.peak_in_flight == 4

    eng2, reqs = _pressure_setup(n_pages=5)     # room for two 2-page prompts
    sched2 = Scheduler(eng2)
    for t, m in reqs[:4]:
        sched2.submit(t, m)
    sched2._fill_slots()
    assert sched2.peak_in_flight == 2
    assert len(sched2.pending) == 2
