"""Ensemble-speculative decoding (serving/spec): the distilled student
drafts gamma tokens per iteration, all K teachers verify every position
in one batched program, and the longest fused-greedy-agreeing prefix is
accepted.  The invariant under test everywhere: speculation NEVER
changes tokens, only their cost — greedy outputs are bit-identical to
the non-speculative fused path on every engine variant (contiguous,
paged, shallow draft_cfg, --draft off), and the stochastic path is
deterministic under its per-request seed.
"""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import registry
from repro.core import compression as comp
from repro.core import distill
from repro.core import ensemble as ens
from repro.models import transformer as tf
from repro.runtime import steps as rt_steps
from repro.serving import EnsembleEngine, Scheduler, kv_cache
from repro.serving.frontend.router import Replica
from repro.serving.spec import DraftEngine, SpeculativeEngine
from repro.serving.spec import draft as draft_mod

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.serving_bench import python_loop_decode as _seed_loop

CFG = registry.get_config("gemma3-1b", reduced=True).with_(dtype="float32")


def _params(cfg, K, seed=0):
    return jax.vmap(lambda k: tf.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), K))


def _prompts(B, plen, seed=1):
    return list(np.asarray(jax.random.randint(
        jax.random.PRNGKey(seed), (B, plen), 0, CFG.vocab_size)))


# ---------------------------------------------------------------------------
# verify kernel
# ---------------------------------------------------------------------------


def test_verify_slots_matches_sequential_decode():
    """Scoring a C-token chunk in one verify_slots call must reproduce
    C sequential decode_step_slots calls to float tolerance (chunked
    GEMMs reduce in a different order, so logits differ by epsilon;
    the TOKEN stream's bit-identity is pinned by the e2e tests, where
    f32 keeps argmax away from epsilon ties)."""
    B, C, S = 3, 5, 24
    p = jax.tree.map(lambda x: x[0], _params(CFG, 1, seed=3))
    chunk = jax.random.randint(jax.random.PRNGKey(4), (B, C), 0,
                               CFG.vocab_size)

    c_seq = tf.init_slot_cache(CFG, B, max_seq=S)
    seq_logits = []
    for j in range(C):
        lg, c_seq = tf.decode_step_slots(p, CFG, c_seq, chunk[:, j][:, None])
        seq_logits.append(lg[:, 0])
    ref = jnp.stack(seq_logits, axis=1)  # (B, C, V)

    c_ver = tf.init_slot_cache(CFG, B, max_seq=S)
    got, c_ver = tf.verify_slots(p, CFG, c_ver, chunk,
                                 jnp.full((B,), C, jnp.int32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(c_ver), jax.tree.leaves(c_seq)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_verify_slots_n_tok_zero_is_noop():
    B, C, S = 2, 4, 16
    p = jax.tree.map(lambda x: x[0], _params(CFG, 1, seed=5))
    cache = tf.init_slot_cache(CFG, B, max_seq=S)
    before = jax.tree.map(lambda x: np.asarray(x), cache)
    chunk = jnp.zeros((B, C), jnp.int32)
    _, after = tf.verify_slots(p, CFG, cache, chunk,
                               jnp.zeros((B,), jnp.int32))
    for a, b in zip(jax.tree.leaves(after), jax.tree.leaves(before)):
        np.testing.assert_array_equal(np.asarray(a), b)


# ---------------------------------------------------------------------------
# end-to-end bit-identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True])
def test_spec_greedy_bit_identical(paged):
    """Distinct members + a same-architecture student: acceptance is
    low, output must still match the plain fused engine bit for bit —
    on the contiguous pool and on the paged pool (page-table rollback
    via PageAllocator.truncate)."""
    K, B, plen, steps = 3, 3, 6, 10
    params = _params(CFG, K, seed=7)
    student = jax.tree.map(lambda x: x[0], params)
    prompts = _prompts(B, plen)
    kw = dict(n_slots=B, max_prompt=plen, max_out=steps, prefill_chunk=4)
    if paged:
        kw.update(paged=True, page_size=4, n_pages=64)
    ref = EnsembleEngine(CFG, params, **kw).generate(prompts, max_new=steps)
    spec = SpeculativeEngine(CFG, params, student, gamma=3, **kw)
    outs = spec.generate(prompts, max_new=steps)
    for a, b in zip(outs, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    st = spec.spec_stats()
    assert st["spec_steps"] > 0 and st["proposed"] > 0


def test_spec_draft_off_bit_identical():
    """Per-request opt-out ({"draft": False}) must ride the inherited
    plain step — tokens identical to today's engine."""
    K, B, plen, steps = 2, 3, 5, 8
    params = _params(CFG, K, seed=9)
    prompts = _prompts(B, plen, seed=2)
    kw = dict(n_slots=B, max_prompt=plen, max_out=steps, prefill_chunk=4)
    ref = EnsembleEngine(CFG, params, **kw).generate(prompts, max_new=steps)
    spec = SpeculativeEngine(CFG, params,
                             jax.tree.map(lambda x: x[0], params),
                             gamma=3, **kw)
    sched = Scheduler(spec)
    rids = [sched.submit(p, steps, draft=False) for p in prompts]
    comps = sched.run()
    for i, r in enumerate(rids):
        np.testing.assert_array_equal(np.asarray(comps[r].tokens),
                                      np.asarray(ref[i]))
    assert spec.spec_stats()["spec_steps"] == 0  # plain program only


def test_shallow_draft_cfg_perfect_distillation():
    """The bench construction, pinned as a correctness property: members
    whose layers past depth-2 are residual no-ops (w_o = w_down = 0)
    are reproduced BITWISE by the 2-layer truncation of the same
    weights, so every draft is accepted and output still matches."""
    K, B, plen, steps = 4, 2, 4, 9  # steps-1 = 2 chunks of gamma+1 = 4
    gamma = 3
    draft_cfg = CFG.with_(n_layers=2)
    full = tf.init(jax.random.PRNGKey(11), CFG)

    student = tf.init(jax.random.PRNGKey(12), draft_cfg)
    student["embed"] = full["embed"]
    student["final_norm"] = full["final_norm"]
    for i in range(draft_cfg.n_layers):
        student["segments"][0][f"slot_{i}"] = \
            full["segments"][0][f"slot_{i}"]

    member = jax.tree.map(lambda x: x, full)
    names = [(0, f"slot_{i}") for i in range(6)] + [(1, "slot_0")]
    for s, name in names[draft_cfg.n_layers:]:
        layer = member["segments"][s][name]
        layer["attn"]["w_o"] = jnp.zeros_like(layer["attn"]["w_o"])
        layer["mlp"]["w_down"] = jnp.zeros_like(layer["mlp"]["w_down"])
    params = jax.tree.map(lambda x: jnp.stack([x] * K), member)

    prompts = _prompts(B, plen, seed=3)
    kw = dict(n_slots=B, max_prompt=plen, max_out=steps, prefill_chunk=4)
    ref = EnsembleEngine(CFG, params, **kw).generate(prompts, max_new=steps)
    spec = SpeculativeEngine(CFG, params, student, draft_cfg=draft_cfg,
                             gamma=gamma, **kw)
    outs = spec.generate(prompts, max_new=steps)
    for a, b in zip(outs, ref):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert spec.spec_stats()["acceptance_rate"] == 1.0


def test_spec_stochastic_deterministic_under_seed():
    """Rejection sampling (spec_sampling=True) with per-request seeds:
    two identical engines must produce identical tokens."""
    K, B, plen, steps = 2, 2, 4, 8
    params = _params(CFG, K, seed=13)
    student = jax.tree.map(lambda x: x[0], params)
    prompts = _prompts(B, plen, seed=4)
    kw = dict(n_slots=B, max_prompt=plen, max_out=steps, prefill_chunk=4)

    def run():
        spec = SpeculativeEngine(CFG, params, student, gamma=3,
                                 spec_sampling=True, **kw)
        sched = Scheduler(spec)
        rids = [sched.submit(p, steps, temperature=0.9, top_k=20, seed=42)
                for p in prompts]
        comps = sched.run()
        return [np.asarray(comps[r].tokens) for r in rids]

    a, b = run(), run()
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# draft / accept / prune units
# ---------------------------------------------------------------------------


def test_propose_greedy_matches_sequential_and_skips_lp():
    B, G, S = 2, 3, 16
    stack = _params(CFG, 1, seed=15)
    tok = jnp.array([3, 7], jnp.int32)

    cache = draft_mod.init_draft_pool(CFG, B, S - G, G)
    chunk, draft_lp, _ = draft_mod.propose(stack, CFG, cache, tok, G)
    assert draft_lp is None  # greedy path skips the log_softmax passes
    assert chunk.shape == (B, G + 1)

    c_seq = draft_mod.init_draft_pool(CFG, B, S - G, G)
    cur, toks = tok, [tok]
    for _ in range(G):
        lg, c_seq = jax.vmap(
            lambda p, c: tf.decode_step_slots(p, CFG, c, cur[:, None])
        )(stack, c_seq)
        cur = lg[0, :, 0].argmax(-1).astype(jnp.int32)
        toks.append(cur)
    np.testing.assert_array_equal(np.asarray(chunk),
                                  np.asarray(jnp.stack(toks, 1)))


def test_prunable_members_cannot_flip_fused_argmax():
    """The pruning rule is a PROOF, not a heuristic: a prunable member
    may vote ANY distribution (every one-hot included) without moving
    the fused argmax.  Checked exhaustively over the vocab."""
    K, B, V = 4, 6, 40
    lg = jax.random.normal(jax.random.PRNGKey(17), (K, B, V)) * 3.0
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(18), (K,)))
    fused = ens.ensemble_log_probs(lg, weights=w)
    mask = np.asarray(ens.prunable_members(lg, fused, w))
    assert mask.any(), "test needs at least one prunable vote"

    T = np.asarray(jnp.exp(fused))                      # (B, V)
    p = np.asarray(jax.nn.softmax(lg, axis=-1))         # (K, B, V)
    wn = np.asarray(w)
    top = T.argmax(-1)
    for k in range(K):
        for b in range(B):
            if not mask[k, b]:
                continue
            base = T[b] - wn[k] * p[k, b]
            # every one-hot replacement: argmax(base + w_k e_v) for all v
            cand = np.tile(base, (V, 1))
            cand[np.arange(V), np.arange(V)] += wn[k]
            assert (cand.argmax(-1) == top[b]).all()

    # the shared-softmax path must produce the identical mask
    mlp = ens.member_log_probs(lg)
    np.testing.assert_array_equal(
        mask, np.asarray(ens.prunable_members(lg, fused, w,
                                              member_lp=mlp)))


def test_snapshot_restore_rejected_tail():
    B, C = 3, 4
    pool = kv_cache.init_pool(CFG, 1, B, 20)
    start = jnp.array([2, 5, 0], jnp.int32)
    snap = kv_cache.snapshot_positions(pool, start, C)
    dirty = jax.tree.map(lambda x: x + 1.0 if x.dtype.kind == "f" else x,
                         pool)
    dirty["idx"] = pool["idx"]
    keep = jnp.array([1, 4, 0], jnp.int32)
    back = kv_cache.restore_positions(dirty, snap, start, keep)

    def leaves(d):
        return [(p, x) for p, x in
                jax.tree_util.tree_flatten_with_path(d["segments"])[0]]

    for (path, orig), (_, d), (_, got) in zip(
            leaves(pool), leaves(dirty), leaves(back)):
        if orig.shape[:1] == (0,) or orig.dtype.kind != "f":
            continue
        S = orig.shape[3]
        for b in range(B):
            for t in range(C):
                s = (int(start[b]) + t) % S
                want = d if t < int(keep[b]) else orig
                np.testing.assert_array_equal(
                    np.asarray(got[:, :, b, s]),
                    np.asarray(want[:, :, b, s]), err_msg=str(path))


def test_page_allocator_truncate_reclaims_tail():
    a = kv_cache.PageAllocator(n_pages=8, page_size=4, n_slots=2,
                               pages_per_slot=8)
    assert a.alloc(0, 4) and a.held_pages(0) == 4
    free_before = a.free_pages
    assert a.truncate(0, 2) == 2
    assert a.held_pages(0) == 2
    assert a.free_pages == free_before + 2
    assert a.holds(0, 7) and not a.holds(0, 8)
    assert a.truncate(0, 2) == 0  # already short: no-op


# ---------------------------------------------------------------------------
# per-request sampling (satellite: temperature/top_k/seed through HTTP)
# ---------------------------------------------------------------------------


def test_per_request_seed_reproducible_and_distinct():
    K, B, plen, steps = 2, 2, 4, 8
    params = _params(CFG, K, seed=19)
    prompts = _prompts(B, plen, seed=5)
    kw = dict(n_slots=B, max_prompt=plen, max_out=steps, prefill_chunk=4)

    def run(seeds):
        eng = EnsembleEngine(CFG, params, **kw)
        sched = Scheduler(eng)
        rids = [sched.submit(p, steps, temperature=5.0, seed=s)
                for p, s in zip(prompts, seeds)]
        comps = sched.run()
        return [np.asarray(comps[r].tokens) for r in rids]

    a = run([123, 123])
    b = run([123, 123])
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    c = run([123, 777])  # same prompt row 0, different seed row 1
    np.testing.assert_array_equal(a[0], c[0])
    assert not np.array_equal(a[1], c[1])


def test_validate_request_rejects_named_limits():
    params = _params(CFG, 1, seed=21)
    eng = EnsembleEngine(CFG, params, n_slots=2, max_prompt=8, max_out=8)
    ok = eng.validate_request([1, 2, 3], 4, temperature=1.0, top_k=5,
                              seed=0)
    assert ok.dtype == np.int32
    with pytest.raises(ValueError, match="MAX_TEMPERATURE"):
        eng.validate_request([1], 4, temperature=1e9)
    with pytest.raises(ValueError, match="MIN_TEMPERATURE"):
        eng.validate_request([1], 4, temperature=-0.5)
    with pytest.raises(ValueError, match="vocab_size"):
        eng.validate_request([1], 4, top_k=CFG.vocab_size + 1)
    with pytest.raises(ValueError, match="MAX_SEED"):
        eng.validate_request([1], 4, seed=2 ** 31)
    with pytest.raises(ValueError, match="MIN_SEED"):
        eng.validate_request([1], 4, seed=-1)
    # the scheduler rejects at the door with the same check
    with pytest.raises(ValueError, match="MAX_TEMPERATURE"):
        Scheduler(eng).submit([1], 4, temperature=1e9)


# ---------------------------------------------------------------------------
# router (satellite: draining replicas sort as infinitely loaded)
# ---------------------------------------------------------------------------


def test_router_draining_replica_sorts_infinitely_loaded():
    """A draining replica must lose the load sort to ANY live replica,
    even when it has fewer in-flight requests and more free capacity —
    the free-pages tiebreak must never resurrect it."""
    params = _params(CFG, 1, seed=23)
    kw = dict(n_slots=2, max_prompt=4, max_out=4)
    idle = Replica("idle", EnsembleEngine(CFG, params, **kw))
    busy = Replica("busy", EnsembleEngine(CFG, params, **kw))
    busy.scheduler.submit([1, 2], 2)
    busy.scheduler.submit([3], 2)
    assert busy.in_flight == 2 and idle.in_flight == 0

    assert min([idle, busy], key=Replica.load_key) is idle
    idle.draining = True
    assert min([idle, busy], key=Replica.load_key) is busy
    idle.draining = False
    idle.failed = "crashed"
    assert min([idle, busy], key=Replica.load_key) is busy


# ---------------------------------------------------------------------------
# compress -> checkpoint -> serve round trip (satellite)
# ---------------------------------------------------------------------------


def test_compress_checkpoint_draft_roundtrip(tmp_path):
    """The full EC-DNN serving story in one test: compress a K=4
    ensemble's output distribution (core/compression TopM targets),
    take one distillation step on a student, round-trip the student
    through checkpoint/store, and assert the restored student decodes
    token-exactly as the stand-alone DraftEngine vs the seed loop —
    and that drafting for its teachers changes nothing, bit for bit."""
    K, B, plen, steps = 4, 2, 4, 6
    params = _params(CFG, K, seed=25)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(26),
                                          (B, plen), 0, CFG.vocab_size)}
    logits_fn = rt_steps.make_logits_fn(CFG)
    member_logits = jax.vmap(lambda p: logits_fn(p, batch))(params)
    fused = ens.ensemble_probs(member_logits)       # (B, plen, V) Eqn 6
    targets = comp.from_dense(fused, m=16)          # the compression step
    # random-init members fuse to a near-uniform distribution, so the
    # absolute L1 bound is near its 2.0 ceiling — pin the property that
    # matters instead: keeping more mass tightens the bound
    b16 = float(comp.l1_error_bound(targets).max())
    b64 = float(comp.l1_error_bound(comp.from_dense(fused, m=64)).max())
    assert 0.0 < b64 < b16 <= 2.0

    student0 = tf.init(jax.random.PRNGKey(27), CFG)
    grads = jax.grad(
        lambda p: distill.pseudo_ce_topm(logits_fn(p, batch), targets)
    )(student0)
    student = jax.tree.map(lambda p, g: p - 1e-2 * g, student0, grads)

    store.save_checkpoint(str(tmp_path), 0, student)
    template = tf.init(jax.random.PRNGKey(0), CFG)
    restored = store.restore_checkpoint(str(tmp_path), 0, template)
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(student)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    prompts = _prompts(B, plen, seed=6)
    kw = dict(n_slots=B, max_prompt=plen, max_out=steps, prefill_chunk=4)
    draft_eng = DraftEngine(CFG, restored, **kw)
    outs = draft_eng.generate(prompts, max_new=steps)
    ref = _seed_loop(CFG, draft_mod.as_member_stack(restored), 1,
                     np.stack(prompts), steps)
    for b in range(B):
        np.testing.assert_array_equal(np.asarray(outs[b]), ref[b])

    base = EnsembleEngine(CFG, params, **kw).generate(prompts,
                                                      max_new=steps)
    spec = SpeculativeEngine(CFG, params, restored, gamma=2, **kw)
    spec_outs = spec.generate(prompts, max_new=steps)
    for a, b in zip(spec_outs, base):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
