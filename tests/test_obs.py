"""repro.serving.obs: traces, histograms, profiler, Prometheus text.

Three layers of coverage, mirroring how the observability core is
threaded through the stack:

  - UNIT: histogram bucketing + in-bucket quantile interpolation, the
    TraceRing live-pinning invariant (eviction can never corrupt an
    in-flight trace), Prometheus exposition conformance (exactly one
    `# HELP`/`# TYPE` per family, escaped label values, trailing
    newline) via the parse_prometheus round trip, and merge_scrapes'
    fleet synthesis (counters/histograms sum, gauges max).
  - SCHEDULER: span chains on the hard paths — preempt/resume under
    page pressure, mid-flight cancel, speculative accept counts —
    with the obs=False kill-switch staying token-identical.
  - WIRE: the trace rides the completion payload and GET /v1/trace/
    <rid>, /metrics round-trips the conformance parser, POST
    /admin/profile arms a tick-bounded profiler window, and the
    FleetRouter merges >= 2 child scrapes while its parent-side trace
    records crash-retry failover hops.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

import jax

from repro.configs import registry
from repro.models import transformer as tf
from repro.serving import EnsembleEngine, Scheduler, SpeculativeEngine, client
from repro.serving import obs
from repro.serving.frontend import FrontendServer, Replica, Router

CFG = registry.get_config("gemma3-1b", reduced=True).with_(dtype="float32")


def _params(K, seed=0, cfg=CFG):
    return jax.vmap(lambda k: tf.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), K))


def _mk_engine(params, **over):
    kw = dict(n_slots=2, max_prompt=8, max_out=6, prefill_chunk=4)
    kw.update(over)
    return EnsembleEngine(CFG, params, **kw)


@pytest.fixture(scope="module")
def params_k2():
    return _params(2)


def _events(trace_dict):
    return [e["event"] for e in trace_dict["events"]]


# -- histograms --------------------------------------------------------------


def test_histogram_buckets_and_edges():
    h = obs.Histogram("x_seconds", "t", bounds=(0.1, 0.2, 0.4, 0.8))
    for v in (0.05, 0.1, 0.3, 0.5, 1.5):     # 0.1 lands in le=0.1 (<=)
        h.observe(v)
    assert h.count == 5
    assert h.counts == [2, 0, 1, 1, 1]
    assert h.cumulative() == [2, 2, 3, 4, 5]
    assert abs(h.sum - 2.45) < 1e-9
    # a value past every bound lives in +Inf; quantiles clamp to the
    # last finite bound instead of inventing an upper edge
    assert h.quantile(1.0) == 0.8
    with pytest.raises(ValueError, match="sorted"):
        obs.Histogram("y_seconds", "t", bounds=(0.2, 0.1))


def test_histogram_quantile_interpolation_error_bounded():
    """Default bounds are ratio 2^0.25, so any quantile of a point mass
    lands within one bucket of the true value — the error budget the
    20% client/server divergence gate leans on."""
    h = obs.Histogram("z_seconds", "t")
    for _ in range(100):
        h.observe(0.033)
    for q in (0.5, 0.9, 0.99):
        est = h.quantile(q)
        assert 0.033 / 2 ** 0.25 <= est <= 0.033 * 2 ** 0.25


def test_quantile_from_empty_and_merge():
    assert obs.quantile_from_buckets([0.1, 0.2], [0, 0, 0], 0.99) == 0.0
    a = obs.Histogram("a_seconds", "t", bounds=(0.1, 0.2))
    b = obs.Histogram("a_seconds", "t", bounds=(0.1, 0.2))
    a.observe(0.05)
    b.observe(0.15)
    b.observe(5.0)
    a.merge_from(b.counts, b.sum, b.count)
    assert a.count == 3 and a.cumulative() == [1, 2, 3]
    with pytest.raises(ValueError, match="mismatch"):
        a.merge_from([1, 2], 0.0, 3)


# -- traces ------------------------------------------------------------------


def test_trace_ring_eviction_pins_live_traces():
    """Only FINISHED traces age out; a live trace survives arbitrary
    churn untouched — the invariant that makes eviction safe to run
    under load."""
    ring = obs.TraceRing(keep=4)
    live = ring.start(999)
    live.add("enqueued")
    for rid in range(20):
        t = ring.start(rid)
        t.add("enqueued")
        t.add("done")
        ring.finish(rid)
    assert ring.n_finished == 4 and ring.evicted == 16
    assert ring.get(0) is None                 # oldest finished: gone
    assert ring.get(19) is not None
    assert ring.get(999) is live               # pinned across churn
    assert live.has("enqueued") and not live.has("done")
    ring.finish(999)
    assert ring.n_live == 0 and ring.get(999) is live


def test_trace_event_cap_counts_drops():
    t = obs.Trace(0, max_events=3)
    for i in range(5):
        t.add("prefill_chunk", i)
    assert len(t.events) == 3 and t.dropped == 2
    d = t.to_dict()
    assert d["dropped"] == 2 and len(d["events"]) == 3
    ts = [e["t"] for e in d["events"]]
    assert ts == sorted(ts)


# -- Prometheus text exposition ---------------------------------------------


def test_familyset_conformance_and_escaping():
    fs = obs.FamilySet()
    fs.declare("f_total", "counter", "help with \\ slash\nand newline")
    evil = 'a"b\\c\nd'
    fs.sample("f_total", {"k": evil}, 1)
    fs.sample("f_total", {"k": "plain"}, 2.5)
    text = fs.render()
    assert text.endswith("\n")
    assert text.count("# TYPE f_total") == 1
    assert text.count("# HELP f_total") == 1
    meta, samples = obs.parse_prometheus(text)
    assert meta["f_total"]["type"] == "counter"
    assert ("f_total", {"k": evil}, 1.0) in samples
    assert ("f_total", {"k": "plain"}, 2.5) in samples
    # misuse is loud, not silent
    with pytest.raises(ValueError, match="redeclared"):
        fs.declare("f_total", "gauge", "x")
    with pytest.raises(ValueError, match="not declared"):
        fs.sample("ghost", None, 1)
    with pytest.raises(ValueError, match="unknown metric type"):
        fs.declare("g", "summary", "x")


def test_parse_prometheus_rejects_malformed():
    with pytest.raises(ValueError, match="trailing newline"):
        obs.parse_prometheus("a_total 1")
    with pytest.raises(ValueError, match="duplicate # TYPE"):
        obs.parse_prometheus("# TYPE a_total counter\n"
                             "# TYPE a_total counter\na_total 1\n")
    with pytest.raises(ValueError, match="malformed"):
        obs.parse_prometheus("lonely\n")
    # the +Inf bucket label survives the round trip verbatim
    _, samples = obs.parse_prometheus(
        'h_bucket{le="+Inf"} 3\n')
    assert samples == [("h_bucket", {"le": "+Inf"}, 3.0)]


def _child_scrape(reqs, depth, latencies):
    fs = obs.FamilySet()
    fs.declare("reqs_total", "counter", "requests served")
    fs.sample("reqs_total", None, reqs)
    fs.declare("depth", "gauge", "queue depth")
    fs.sample("depth", None, depth)
    h = obs.Histogram("lat_seconds", "latency", bounds=(0.1, 1.0))
    for v in latencies:
        h.observe(v)
    fs.add_histogram(h, {"replica": "r0"})   # child's own label is
    return fs.render()                       # overridden by the merge


def test_merge_scrapes_fleet_synthesis():
    merged = obs.merge_scrapes([
        ("p0", _child_scrape(3, 5, [0.05, 0.5])),
        ("p1", _child_scrape(4, 2, [0.05, 2.0, 0.2])),
    ])
    meta, samples = obs.parse_prometheus(merged)   # conformant merge
    assert meta["lat_seconds"]["type"] == "histogram"

    def vals(series, **want):
        return [v for s, lb, v in samples if s == series
                and all(lb.get(k) == w for k, w in want.items())]

    # per-replica rows preserved under the child's name
    assert vals("reqs_total", replica="p0") == [3.0]
    assert vals("reqs_total", replica="p1") == [4.0]
    # fleet synthesis: counters sum, gauges max, buckets add per-le
    assert vals("reqs_total", replica="fleet") == [7.0]
    assert vals("depth", replica="fleet") == [5.0]
    assert vals("lat_seconds_count", replica="fleet") == [5.0]
    assert vals("lat_seconds_bucket", replica="fleet", le="0.1") == [2.0]
    assert vals("lat_seconds_bucket", replica="fleet", le="1") == [4.0]
    assert vals("lat_seconds_bucket", replica="fleet", le="+Inf") == [5.0]
    # quantile over the merged family sums matching series first
    q = obs.histogram_quantile_from_scrape(
        merged, "lat_seconds", 0.5, match={"replica": "fleet"})
    assert 0.1 <= q <= 1.0
    assert obs.histogram_quantile_from_scrape(merged, "ghost", 0.5) is None


# -- scheduler span chains ---------------------------------------------------


def test_trace_lifecycle_and_histograms(params_k2):
    eng = _mk_engine(params_k2)
    sched = Scheduler(eng)
    reqs = [(np.arange(1, 8), 4), (np.arange(2, 5), 3), (np.arange(3, 7), 5)]
    rids = [sched.submit(t, m) for t, m in reqs]
    comps = sched.run()
    for (toks, _), rid in zip(reqs, rids):
        tr = comps[rid].trace
        assert tr["rid"] == rid
        names = _events(tr)
        assert names[0] == "enqueued" and names[-1] == "done"
        assert "admitted" in names and "first_token" in names
        # one span per chunk program: ceil(prompt / chunk)
        assert names.count("prefill_chunk") == -(-len(toks) // 4)
        ts = [e["t"] for e in tr["events"]]
        assert ts == sorted(ts) and all(t >= 0 for t in ts)
        # terminal traces retire to the bounded finished side
        assert sched.obs.traces.get(rid) is not None
    assert sched.obs.traces.n_live == 0
    # one observation per request in ttft/queue-wait/latency; the
    # inter-token histogram sees every token after each request's first
    o = sched.obs
    assert o.ttft.count == o.queue_wait.count == o.latency.count == 3
    n_tok = sum(len(c.tokens) for c in comps.values())
    assert o.inter_token.count == n_tok - 3
    assert o.ttft.quantile(0.5) > 0


def test_obs_off_kill_switch_is_token_identical(params_k2):
    s_on = Scheduler(_mk_engine(params_k2))
    s_off = Scheduler(_mk_engine(params_k2), obs=False)
    assert s_off.obs is None
    reqs = [(np.arange(1, 6), 4), (np.arange(2, 6), 5)]
    rids_on = [s_on.submit(t, m) for t, m in reqs]
    rids_off = [s_off.submit(t, m) for t, m in reqs]
    c_on, c_off = s_on.run(), s_off.run()
    for a, b in zip(rids_on, rids_off):
        np.testing.assert_array_equal(c_on[a].tokens, c_off[b].tokens)
        assert c_on[a].trace is not None and c_off[b].trace is None
    with pytest.raises(RuntimeError, match="disabled"):
        s_off.profile_next_ticks(1, "/tmp/nowhere")


def test_scheduler_trace_ring_churn_keeps_completion_traces(params_k2):
    """trace_keep smaller than the request count: old finished traces
    evict, but every Completion still carries its full span chain (the
    dict snapshot is taken at `done`, before any eviction)."""
    sched = Scheduler(_mk_engine(params_k2), trace_keep=2)
    rids = [sched.submit(np.arange(1, 5), 3) for _ in range(6)]
    comps = sched.run()
    assert sched.obs.traces.n_finished == 2
    assert sched.obs.traces.evicted == 4
    for rid in rids:
        assert _events(comps[rid].trace)[-1] == "done"


def test_preempt_resume_trace_under_page_pressure():
    """Page-pressure preemptions land in the span chain: every
    completed trace pairs each `preempted` with a `resumed`, the total
    matches the scheduler counter, and queue wait is observed once per
    request (re-admission is `resumed`, not a second `admitted`)."""
    cfg = registry.get_config("deepseek-7b", reduced=True).with_(
        dtype="float32")
    p = jax.vmap(lambda k: tf.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(0), 2))
    reqs = [(np.arange(1, 8), 8), (np.arange(2, 7), 8),
            (np.arange(3, 9), 8), (np.arange(1, 5), 8),
            (np.arange(2, 5), 8), (np.arange(4, 9), 6)]

    def run(n_pages):
        eng = EnsembleEngine(cfg, p, n_slots=4, max_prompt=8, max_out=8,
                             prefill_chunk=4, paged=True, page_size=4,
                             n_pages=n_pages)
        sched = Scheduler(eng)
        rids = [sched.submit(t, m) for t, m in reqs]
        return sched, rids, sched.run()

    ref_sched, ref_rids, ref = run(None)       # unpressured reference
    assert ref_sched.preemptions == 0
    sched, rids, comps = run(6)                # 6 pages: pool runs dry
    assert sched.preemptions > 0
    n_pre = n_res = 0
    for a, b in zip(ref_rids, rids):
        np.testing.assert_array_equal(ref[a].tokens, comps[b].tokens)
        names = _events(comps[b].trace)
        assert names.count("admitted") == 1
        pre, res = names.count("preempted"), names.count("resumed")
        assert pre == res                       # every eviction resumed
        if pre:
            assert names.index("preempted") < names.index("resumed")
        n_pre += pre
        n_res += res
    assert n_pre == sched.preemptions and n_res > 0
    assert sched.obs.queue_wait.count == len(reqs)


def test_cancel_trace_queued_and_live(params_k2):
    eng = _mk_engine(params_k2)
    sched = Scheduler(eng)
    rids = [sched.submit(np.arange(1, 6), 6) for _ in range(4)]
    sched.cancel(rids[3])      # still queued: must never admit
    sched.tick()               # admits rids[0], rids[1]
    sched.cancel(rids[0])      # live: slot+pages release next tick
    comps = sched.run()
    assert set(comps) == {rids[1], rids[2]}
    for rid, admitted in ((rids[0], True), (rids[3], False)):
        tr = sched.obs.traces.get(rid)
        assert tr is not None and tr.events[-1][0] == "cancelled"
        assert tr.has("admitted") == admitted
    assert sched.obs.traces.n_live == 0
    assert sched.n_cancelled == 2


def test_spec_step_trace_counts_accepted_drafts():
    """Each speculative iteration after the first token lands a
    spec_step span whose value is the ACCEPTED draft count for that
    iteration — in [0, gamma], with accepted+1 tokens emitted each."""
    K, plen, steps, gamma = 2, 6, 12, 3
    params = _params(K, seed=7)
    student = jax.tree.map(lambda x: x[0], params)
    spec = SpeculativeEngine(CFG, params, student, gamma=gamma,
                             n_slots=2, max_prompt=plen, max_out=steps,
                             prefill_chunk=4)
    sched = Scheduler(spec)
    assert sched._spec_draft is not None
    prompts = [np.arange(1, 7), np.arange(2, 8)]
    rids = [sched.submit(p, steps) for p in prompts]
    comps = sched.run()
    for rid in rids:
        tr = comps[rid].trace
        vals = [e["v"] for e in tr["events"] if e["event"] == "spec_step"]
        assert vals, "no spec_step spans on a drafting slot"
        assert all(0 <= v <= gamma for v in vals)
        # tokens = first-harvest burst + sum(accepted+1) per later
        # iteration; the first burst is >= 1, never span-counted
        assert sum(v + 1 for v in vals) <= len(comps[rid].tokens) - 1


def test_trace_log_writes_one_jsonl_line_per_request(params_k2, tmp_path):
    log = tmp_path / "traces.jsonl"
    sched = Scheduler(_mk_engine(params_k2), trace_log=str(log))
    rids = [sched.submit(np.arange(1, 5), 3) for _ in range(3)]
    sched.run()
    sched.obs.close()
    recs = [json.loads(line) for line in log.read_text().splitlines()]
    assert sorted(r["rid"] for r in recs) == sorted(rids)
    for r in recs:
        assert r["events"][-1]["event"] == "done"


def test_tick_phases_and_profile_window(params_k2, tmp_path):
    sched = Scheduler(_mk_engine(params_k2))
    sched.profile_next_ticks(2, str(tmp_path))
    assert sched.obs.ticks.profile_pending == 2
    for _ in range(2):
        sched.submit(np.arange(1, 6), 4)
    sched.run()
    tp = sched.obs.ticks
    assert tp.ticks > 0 and tp.profile_pending == 0   # window closed
    snap = tp.snapshot()
    for phase in ("admit", "decode", "prefill", "harvest"):
        assert snap[phase]["count"] > 0
        assert snap[phase]["total_s"] >= 0
        assert snap[phase]["ema_s"] >= 0
    with pytest.raises(ValueError, match=">= 1"):
        tp.arm_profile(0, str(tmp_path))
    with pytest.raises(ValueError, match="output dir"):
        tp.arm_profile(1, "")


# -- the wire ----------------------------------------------------------------


@pytest.fixture(scope="module")
def frontend(params_k2):
    srv = FrontendServer(Router([Replica("r0", _mk_engine(params_k2))]))
    srv.start()
    yield srv
    srv.shutdown(drain=True, timeout=120.0)


def _post(url, path, body):
    req = urllib.request.Request(
        url + path, data=json.dumps(body).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=30.0) as r:
        return json.loads(r.read())


def test_trace_rides_payload_and_trace_route(frontend):
    out = client.http_generate(frontend.url, np.arange(1, 6), 4,
                               stream=False)
    names = _events(out["trace"])
    assert names[0] == "enqueued" and names[-1] == "done"
    # SSE: the span chain rides the terminal done event too
    sse = client.http_generate(frontend.url, np.arange(1, 6), 4,
                               stream=True)
    assert _events(sse["trace"])[-1] == "done"
    # and the same chain is queryable after the fact
    got = client.http_get_json(frontend.url, f"/v1/trace/{out['rid']}")
    assert got["replica"] == "r0" and got["rid"] == out["rid"]
    assert _events(got) == names
    with pytest.raises(urllib.error.HTTPError) as e:
        client.http_get_json(frontend.url, "/v1/trace/999999")
    assert e.value.code == 404
    with pytest.raises(urllib.error.HTTPError) as e:
        client.http_get_json(frontend.url, "/v1/trace/bogus")
    assert e.value.code == 400
    with pytest.raises(urllib.error.HTTPError) as e:
        client.http_get_json(frontend.url,
                             f"/v1/trace/{out['rid']}?replica=ghost")
    assert e.value.code == 404


def test_metrics_scrape_is_conformant(frontend):
    client.http_generate(frontend.url, np.arange(1, 6), 4, stream=False)
    text = client.http_get_text(frontend.url, "/metrics")
    assert text.endswith("\n")
    meta, samples = obs.parse_prometheus(text)   # raises on violations
    fams = {obs.family_of(s) for s, _, _ in samples}
    for fam in fams:                             # HELP + TYPE for every
        assert meta[fam].get("type"), fam        # sampled family
        assert meta[fam].get("help"), fam
    for fam in ("repro_serving_ttft_seconds",
                "repro_serving_queue_wait_seconds",
                "repro_serving_inter_token_seconds",
                "repro_serving_e2e_latency_seconds"):
        assert meta[fam]["type"] == "histogram"
        buckets = sorted(
            ((float("inf") if lb["le"] == "+Inf" else float(lb["le"])), v)
            for s, lb, v in samples
            if s == fam + "_bucket" and lb.get("replica") == "r0")
        vals = [v for _, v in buckets]
        assert vals == sorted(vals)              # cumulative
        count = [v for s, lb, v in samples
                 if s == fam + "_count" and lb.get("replica") == "r0"]
        assert count and vals[-1] == count[0]    # +Inf == _count
    assert meta["repro_serving_ttft_seconds"]["type"] == "histogram"
    phases = {lb["phase"] for s, lb, v in samples
              if s == "repro_serving_tick_phase_seconds_total"}
    assert {"admit", "decode", "prefill", "harvest"} <= phases


def test_admin_profile_endpoint(frontend, tmp_path):
    with pytest.raises(urllib.error.HTTPError) as e:
        _post(frontend.url, "/admin/profile", {"ticks": 0})
    assert e.value.code == 400
    with pytest.raises(urllib.error.HTTPError) as e:
        _post(frontend.url, "/admin/profile", {"ticks": 2})
    assert e.value.code == 400                   # no --profile-dir
    out = _post(frontend.url, "/admin/profile",
                {"ticks": 2, "dir": str(tmp_path)})
    assert out["ok"] and out["replica"] == "r0" and out["ticks"] == 2
    client.http_generate(frontend.url, np.arange(1, 6), 4, stream=False)
    sched = frontend.router.replicas[0].scheduler
    deadline = time.time() + 30.0
    while sched.obs.ticks.profile_pending > 0 and time.time() < deadline:
        time.sleep(0.02)
    assert sched.obs.ticks.profile_pending == 0  # window closed


def test_http_load_report_prefers_server_percentiles(frontend):
    reqs = client.make_requests(6, CFG.vocab_size, prompt_len=(4, 8),
                                max_new=(2, 6), seed=3)
    rep = client.run_http_load(frontend.url, reqs, concurrency=3)
    assert rep["n_errors"] == 0
    assert rep["latency_source"] == "server"
    for p in (50, 95, 99):
        assert rep[f"client_ttft_p{p}_ms"] >= 0
        assert rep[f"ttft_p{p}_ms"] > 0          # from /metrics
    assert rep["ttft_p99_divergence"] >= 0


# -- fleet aggregation -------------------------------------------------------


def test_fleet_scrape_merges_children_and_trace_records_failover():
    """The FleetRouter view: one merged /metrics over both replica
    processes (page + prefix stats included, per-replica labels
    preserved, a synthesized fleet row), fleet gauges appended, and a
    crash mid-request recorded in the parent-side fleet_trace as
    replica_failed -> retried before the survivor serves it.

    (Spec stats cross the boundary too, but the speculative engine
    rejects prefix_cache, so a spec-drafting fleet gets its own test
    below rather than riding this one.)"""
    from repro.serving.frontend import EngineSpec, FleetRouter

    spec = EngineSpec(
        arch="deepseek-7b", reduced=True, dtype="float32", members=2,
        seed=0, n_slots=2, max_prompt=16, max_out=32, prefill_chunk=4,
        paged=True, page_size=4, prefix_cache=True,
        mesh="2x1" if len(jax.devices()) >= 2 else "")
    fleet = FleetRouter(spec, n=2)
    fleet.start(timeout=600.0)
    try:
        # warm BOTH children (least-loaded routing spreads concurrent
        # requests) so the kill below lands mid-decode, not mid-compile
        warm = [threading.Thread(
            target=lambda i=i: fleet.generate([1 + i, 2, 3, 4], 6),
            daemon=True) for i in range(2)]
        for t in warm:
            t.start()
        for t in warm:
            t.join(600.0)

        out = fleet.generate([1, 2, 3, 4, 5, 6], 6)
        ft = out["fleet_trace"]
        names = _events(ft)
        assert names[0] == "enqueued" and names[-1] == "done"
        assert "routed" in names
        assert "trace" in out          # child-side chain rides along

        text = fleet.metrics_text()
        meta, samples = obs.parse_prometheus(text)
        reps = {lb.get("replica") for _, lb, _ in samples}
        assert {"p0", "p1", "fleet"} <= reps

        def vals(series, **want):
            return [v for s, lb, v in samples if s == series
                    and all(lb.get(k) == w for k, w in want.items())]

        # page/prefix stats crossed the process boundary
        for fam in ("repro_serving_total_pages",
                    "repro_serving_prefix_hit_rate"):
            assert vals(fam, replica="p0") and vals(fam, replica="p1")
            assert vals(fam, replica="fleet"), fam
        # latency histograms: the fleet row sums both children
        fam = "repro_serving_ttft_seconds"
        child = sum(vals(fam + "_count", replica="p0")
                    + vals(fam + "_count", replica="p1"))
        assert child >= 3
        assert vals(fam + "_count", replica="fleet") == [child]
        assert meta[fam]["type"] == "histogram"
        # the fleet's own families
        assert vals("repro_serving_fleet_procs") == [2.0]
        assert vals("repro_serving_fleet_live_replicas") == [2.0]
        assert vals("repro_serving_fleet_retries_total") == [0.0]

        # crash mid-request: find the serving child, SIGKILL it, and
        # the retried request's trace must show the failover hop
        box = {}

        def slow():
            box["out"] = fleet.generate([9, 8, 7, 6], 32, retries=5,
                                        timeout=300.0)

        th = threading.Thread(target=slow, daemon=True)
        th.start()
        victim = None
        deadline = time.time() + 60.0
        while victim is None and time.time() < deadline:
            busy = [n for n, c in fleet._in_flight.items() if c > 0]
            if busy:
                victim = busy[0]
            time.sleep(0.002)
        assert victim is not None, "request never reached a replica"
        next(p for p in fleet.procs if p.name == victim).kill()
        th.join(600.0)
        assert not th.is_alive()
        names = _events(box["out"]["fleet_trace"])
        assert "replica_failed" in names and "retried" in names
        assert names.index("replica_failed") < names.index("retried")
        assert names[-1] == "done"
        s = fleet.stats()
        assert s["retried"] >= 1 and s["n_live"] == 1

        # the scrape survives a dead child (skipped, not fatal) and
        # the fleet counters reflect the failover
        fleet.health_sweep()
        meta2, samples2 = obs.parse_prometheus(fleet.metrics_text())

        def vals2(series, **want):
            return [v for s2, lb, v in samples2 if s2 == series
                    and all(lb.get(k) == w for k, w in want.items())]

        assert vals2("repro_serving_fleet_live_replicas") == [1.0]
        assert vals2("repro_serving_fleet_retries_total")[0] >= 1
        # latching is timing-dependent (counts only when the child is
        # already observably dead at error time) — present, not pinned
        assert vals2("repro_serving_fleet_latched_total")[0] >= 0
        assert vals2("repro_serving_fleet_health_sweep_seconds")[0] >= 0
    finally:
        fleet.stop()


def test_fleet_scrape_aggregates_spec_stats():
    """Speculative-decoding counters (steps/proposed/accepted) cross
    the process boundary and merge: both children report them and the
    fleet row sums them."""
    from repro.serving.frontend import EngineSpec, FleetRouter

    spec = EngineSpec(
        arch="gemma3-1b", reduced=True, dtype="float32", members=2,
        seed=0, n_slots=2, max_prompt=8, max_out=8, prefill_chunk=4,
        paged=True, page_size=4, draft_member0=True, gamma=3,
        mesh="2x1" if len(jax.devices()) >= 2 else "")
    fleet = FleetRouter(spec, n=2)
    fleet.start(timeout=600.0)
    try:
        # least-loaded routing: concurrent requests land one per child
        ths = [threading.Thread(
            target=lambda i=i: fleet.generate([1 + i, 2, 3, 4], 6),
            daemon=True) for i in range(2)]
        for t in ths:
            t.start()
        for t in ths:
            t.join(600.0)
        _, samples = obs.parse_prometheus(fleet.metrics_text())

        def vals(series, **want):
            return [v for s, lb, v in samples if s == series
                    and all(lb.get(k) == w for k, w in want.items())]

        p0 = vals("repro_serving_spec_steps", replica="p0")
        p1 = vals("repro_serving_spec_steps", replica="p1")
        assert p0 and p0[0] > 0 and p1 and p1[0] > 0
        assert vals("repro_serving_spec_steps",
                    replica="fleet") == [p0[0] + p1[0]]
        for fam in ("repro_serving_spec_proposed",
                    "repro_serving_spec_accepted"):
            assert vals(fam, replica="fleet", )[0] > 0
    finally:
        fleet.stop()
