"""TopM sparse pseudo-label accumulator: exactness + error-bound properties."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional [test] extra")
hnp = pytest.importorskip("hypothesis.extra.numpy")
st = pytest.importorskip("hypothesis.strategies")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import compression as comp


def _rand_probs(key, shape, vocab):
    return jax.nn.softmax(jax.random.normal(key, shape + (vocab,)) * 3)


def test_from_dense_to_dense_roundtrip_exact_when_m_covers():
    p = _rand_probs(jax.random.PRNGKey(0), (4, 6), 16)
    t = comp.from_dense(p, 16)  # M == V: lossless
    d = comp.to_dense(t, 16)
    np.testing.assert_allclose(np.asarray(d), np.asarray(p), atol=1e-6)
    np.testing.assert_allclose(np.asarray(t.rest), 0.0, atol=1e-6)


def test_merge_combines_duplicates_once():
    v = 12
    a = comp.from_dense(_rand_probs(jax.random.PRNGKey(1), (3,), v), v)
    b = comp.from_dense(_rand_probs(jax.random.PRNGKey(2), (3,), v), v)
    m = comp.merge(a, b)
    dense = comp.to_dense(m, v)
    expect = comp.to_dense(a, v) + comp.to_dense(b, v)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(expect),
                               atol=1e-5)


@hypothesis.given(
    seed=st.integers(0, 1000),
    m=st.integers(2, 8),
    vocab=st.integers(8, 40),
    k=st.integers(2, 5),
)
@hypothesis.settings(max_examples=60, deadline=None)
def test_accumulated_l1_error_bounded(seed, m, vocab, k):
    """K-way accumulation: ||topm - oracle||_1 <= 2 * pruned mass."""
    keys = jax.random.split(jax.random.PRNGKey(seed), k)
    denses = [_rand_probs(kk, (2,), vocab) for kk in keys]
    acc = comp.from_dense(denses[0], m)
    for d in denses[1:]:
        acc = comp.merge(acc, comp.from_dense(d, m))
    oracle = sum(denses)
    approx = comp.to_dense(acc, vocab)
    l1 = np.abs(np.asarray(approx) - np.asarray(oracle)).sum(-1)
    bound = np.asarray(comp.l1_error_bound(acc))
    assert (l1 <= bound + 1e-4).all()
    # mass conservation: kept + rest == total mass exactly
    total = np.asarray(acc.vals.sum(-1) + acc.rest)
    np.testing.assert_allclose(total, float(k), atol=1e-4)


def test_normalize_sums_to_one():
    p = _rand_probs(jax.random.PRNGKey(3), (5,), 32)
    acc = comp.from_dense(p * 7.0, 8)
    n = comp.normalize(acc)
    total = np.asarray(n.vals.sum(-1) + n.rest)
    np.testing.assert_allclose(total, 1.0, atol=1e-5)


def test_topm_keeps_heaviest():
    p = jnp.asarray([[0.4, 0.3, 0.2, 0.05, 0.05]])
    t = comp.from_dense(p, 2)
    assert set(np.asarray(t.idx[0]).tolist()) == {0, 1}
    np.testing.assert_allclose(float(t.rest[0]), 0.3, atol=1e-6)


def test_bytes_per_token():
    assert comp.bytes_per_token(64) == 64 * 8 + 4
