"""HLO analyzer: trip-count-corrected FLOPs/collectives on a known module
(4 host devices in a subprocess so the main test process keeps 1 device)."""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    os.environ["JAX_PLATFORMS"] = "cpu"
    import sys
    sys.path.insert(0, "{src}")
    import jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.common.sharding import make_mesh
    from repro.launch.hlo_analysis import analyze

    mesh = make_mesh((4,), ("model",))

    def body(x, w):
        h = x @ w
        h = jax.lax.with_sharding_constraint(h, P(None, "model"))
        return h @ w.T, None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    with mesh:
        c = jax.jit(f).lower(xs, ws).compile()
    costs = analyze(c.as_text())
    # 7 iters x 2 matmuls x 2*64*128*128 flops / 4 devices
    expect = 2 * 7 * 2 * 64 * 128 * 128 / 4
    ratio = costs.flops / expect
    assert 0.99 < ratio < 1.01, f"flops ratio {{ratio}}"
    ar = costs.collective_count["all-reduce"]
    assert ar == 7, f"expected 7 all-reduces (1/iter), got {{ar}}"
    # all-reduce bytes: 7 x (64x128x4) x 2 (ring factor)
    expect_b = 7 * 64 * 128 * 4 * 2
    assert abs(costs.collective_bytes["all-reduce"] - expect_b) < 1, \\
        costs.collective_bytes
    assert costs.hbm_bytes > 0
    print("HLO_OK")
""")


def test_analyzer_on_known_module():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT.format(src=src)],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "HLO_OK" in proc.stdout


def test_shape_bytes_parser():
    from repro.launch.hlo_analysis import _shape_bytes
    assert _shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert _shape_bytes("pred[]") == 1


def test_parse_module_smoke():
    from repro.launch.hlo_analysis import parse_module
    txt = (
        "ENTRY %main (p: f32[4,4]) -> f32[4,4] {\n"
        "  %p = f32[4,4]{1,0} parameter(0)\n"
        "  ROOT %dot = f32[4,4]{1,0} dot(%p, %p), "
        "lhs_contracting_dims={1}, rhs_contracting_dims={0}\n"
        "}\n")
    comps = parse_module(txt)
    assert "ENTRY" in comps
    ops = [i.opcode for i in comps["ENTRY"].instrs]
    assert "dot" in ops
