"""Multi-process replica fleet: sockets, cancellation, backpressure.

Two tiers of test here:

  - IN-PROCESS cancellation regressions: Scheduler.cancel through every
    release path it composes with — queued, mid-decode, mid-chunked-
    prefill, prefix-shared pages, speculative rollback, and the HTTP
    SSE-disconnect trigger.  Each asserts the page pool is WHOLE
    afterwards (engine.assert_pool_whole walks refcounts, the free
    list, and trie ownership) and that surviving requests stay
    token-exact.
  - PROCESS-FLEET soak/chaos: replicas as OS processes (EngineSpec ->
    ReplicaProcess -> FleetRouter), requests over real sockets, with a
    SIGKILL + restart injected mid-load.  The contract under test:
    every request completes token-exact against an offline reference
    built from the SAME spec (crash-retried requests rerun on a
    survivor — seed-pinned init makes the rerun bit-identical), zero
    wedged handlers, and zero leaked pages, asserted over the wire
    from /healthz page accounting.

Token-exactness uses the repo's standard strategy: float32 config so
greedy argmax cannot fork on near-ties, references from the same
engine class through the batch generate() path.
"""
import dataclasses
import json
import socket
import struct
import threading
import time
import urllib.error
import urllib.request
from http.client import HTTPConnection

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer as tf
from repro.serving import EnsembleEngine, Scheduler, client
from repro.serving.frontend import (EngineSpec, FleetRouter, FrontendServer,
                                    QueueFull, Replica, Router)

# deepseek-7b reduced: every mixer pages its positional state, so the
# prefix cache is eligible at any max_prompt/max_out (gemma3-1b's
# sliding-window layers would cap the sequence at the window)
CFG = registry.get_config("deepseek-7b", reduced=True).with_(dtype="float32")


def _params(K, seed=0, cfg=CFG):
    return jax.vmap(lambda k: tf.init(k, cfg))(
        jax.random.split(jax.random.PRNGKey(seed), K))


def _mk_engine(params, **over):
    kw = dict(n_slots=2, max_prompt=16, max_out=8, prefill_chunk=4,
              paged=True, page_size=4, prefix_cache=True)
    kw.update(over)
    return EnsembleEngine(CFG, params, **kw)


@pytest.fixture(scope="module")
def params_k2():
    return _params(2)


def _serve(sched):
    t = threading.Thread(target=sched.serve_forever, daemon=True)
    t.start()
    return t


# -- cancellation: the scheduler-level contract ------------------------------


def test_cancel_queued_request_never_admits(params_k2):
    """Cancelling a still-pending rid removes it before admission: no
    slot, no pages, no callbacks, pool untouched."""
    eng = _mk_engine(params_k2)
    sched = Scheduler(eng)
    fired = []
    rids = [sched.submit(np.arange(1, 6), 4,
                         on_done=lambda c: fired.append(c.rid))
            for _ in range(4)]  # 2 slots: the last two stay pending
    assert sched.cancel(rids[-1])
    comps = sched.run()
    assert rids[-1] not in comps and rids[-1] not in fired
    assert sorted(fired) == rids[:-1]
    assert sched.n_cancelled == 1
    eng.assert_pool_whole()


def test_cancel_unknown_rid_is_benign(params_k2):
    sched = Scheduler(_mk_engine(params_k2))
    assert not sched.cancel(999)
    rid = sched.submit(np.arange(1, 5), 3)
    sched.run()
    assert not sched.cancel(rid)  # already finished: benign False
    assert sched.n_cancelled == 0


def test_cancel_mid_decode_releases_pages_survivors_exact(params_k2):
    """Cancel a LIVE slot after its first streamed token: the slot and
    its pages free mid-decode, survivors finish token-exact, the pool
    is whole (refcounts zero, free list unbroken)."""
    prompts = [np.arange(1, 7), np.arange(2, 9), np.arange(3, 8)]
    refs = [_mk_engine(params_k2, max_out=32)
            .generate([p], max_new=6)[0].tolist() for p in prompts]
    eng = _mk_engine(params_k2, max_out=32)
    sched = Scheduler(eng, retain_completions=True)
    first_tok = threading.Event()
    done = threading.Semaphore(0)
    # the cancel target decodes far longer than the survivors, so the
    # cancel always lands while it is still live — no timing luck
    rid0 = sched.submit(prompts[0], 32,
                        on_token=lambda r, i, t: first_tok.set())
    others = [sched.submit(p, 6, on_done=lambda c: done.release())
              for p in prompts[1:]]
    t = _serve(sched)
    try:
        assert first_tok.wait(60.0)  # rid0 is live and decoding
        assert sched.cancel(rid0)
        for _ in others:
            assert done.acquire(timeout=60.0)
        assert sched.wait_quiesced(60.0)
        assert sched.n_cancelled == 1
        assert rid0 not in sched.completions
        for rid, ref in zip(others, refs[1:]):
            assert sched.completions[rid].tokens.tolist() == ref
        eng.assert_pool_whole()
    finally:
        sched.stop()
        t.join(10.0)


def test_cancel_during_chunked_prefill(params_k2):
    """Cancel while the prompt is mid-chunked-prefill (prefill_left >
    0): the partially-filled chain frees completely."""
    eng = _mk_engine(params_k2, max_prompt=16)
    sched = Scheduler(eng, prefill_budget=4)  # 16-token prompt: 4 rounds
    rid = sched.submit(np.arange(1, 17), 6)
    t = _serve(sched)
    try:
        deadline = time.time() + 60.0
        while time.time() < deadline:  # wait for admission to a slot
            if any(m is not None and m.req.rid == rid
                   for m in sched.slots):
                break
            time.sleep(0.001)
        assert sched.cancel(rid)
        assert sched.wait_quiesced(60.0)
        assert sched.n_cancelled == 1
        eng.assert_pool_whole()
        # the loop still serves after the mid-prefill cancel
        out = {}
        ev = threading.Event()
        sched.submit(np.arange(1, 6), 4,
                     on_done=lambda c: (out.setdefault("c", c), ev.set()))
        assert ev.wait(60.0)
        ref = _mk_engine(params_k2).generate(
            [np.arange(1, 6)], max_new=4)[0]
        np.testing.assert_array_equal(out["c"].tokens, ref)
    finally:
        sched.stop()
        t.join(10.0)


def test_cancel_prefix_shared_request_keeps_trie_whole(params_k2):
    """Cancel a request decoding on SHARED prefix pages: its refs drop,
    the trie keeps the pages (evictable, not leaked), and a repeat of
    the workload still serves token-exact from cache."""
    shared = list(range(50, 62))
    pa = np.array(shared + [7, 8], np.int32)
    pb = np.array(shared + [9], np.int32)
    ref_b = _mk_engine(params_k2, max_out=32).generate(
        [pb], max_new=6)[0].tolist()
    eng = _mk_engine(params_k2, max_out=32)
    sched = Scheduler(eng, retain_completions=True)
    t = _serve(sched)
    try:
        ev = threading.Event()
        sched.submit(pa, 6, on_done=lambda c: ev.set())  # warm the trie
        assert ev.wait(60.0)
        assert sched.wait_quiesced(60.0)
        assert eng.page_stats()["cached_pages"] > 0

        first_tok = threading.Event()
        rid = sched.submit(pb, 24,  # shares the cached prefix; long
                           # decode so the cancel lands mid-flight
                           on_token=lambda r, i, tk: first_tok.set())
        assert first_tok.wait(60.0)
        assert sched.cancel(rid)
        assert sched.wait_quiesced(60.0)
        assert sched.n_cancelled == 1
        eng.assert_pool_whole()  # trie-owned pages evictable, none lost

        ev2 = threading.Event()
        out = {}
        rid2 = sched.submit(pb, 6, on_done=lambda c: (
            out.setdefault("c", c), ev2.set()))
        assert ev2.wait(60.0)
        assert out["c"].tokens.tolist() == ref_b
        del rid2
    finally:
        sched.stop()
        t.join(10.0)


def test_cancel_during_speculative_decode(params_k2):
    """Cancel mid-decode on a SpeculativeEngine: the cancel composes
    with draft-cache rollback — survivors stay token-exact vs the
    plain fused reference and the paged pool comes back whole."""
    from repro.serving import SpeculativeEngine
    student = jax.tree.map(lambda x: x[0], params_k2)
    kw = dict(n_slots=2, max_prompt=8, max_out=32, prefill_chunk=4,
              paged=True, page_size=4, n_pages=32)
    prompts = [np.arange(1, 7), np.arange(2, 8), np.arange(3, 6)]
    refs = [EnsembleEngine(CFG, params_k2, **kw)
            .generate([p], max_new=8)[0].tolist() for p in prompts]
    eng = SpeculativeEngine(CFG, params_k2, student, gamma=3, **kw)
    sched = Scheduler(eng, retain_completions=True)
    first_tok = threading.Event()
    done = threading.Semaphore(0)
    # speculation accepts runs of tokens per iteration, so the cancel
    # target gets a long budget to guarantee it is still mid-decode
    rid0 = sched.submit(prompts[0], 32,
                        on_token=lambda r, i, tk: first_tok.set())
    others = [sched.submit(p, 8, on_done=lambda c: done.release())
              for p in prompts[1:]]
    t = _serve(sched)
    try:
        assert first_tok.wait(60.0)
        assert sched.cancel(rid0)
        for _ in others:
            assert done.acquire(timeout=60.0)
        assert sched.wait_quiesced(60.0)
        assert sched.n_cancelled == 1
        for rid, ref in zip(others, refs[1:]):
            assert sched.completions[rid].tokens.tolist() == ref
        eng.assert_pool_whole()
    finally:
        sched.stop()
        t.join(10.0)


# -- cancellation + backpressure at the HTTP door ----------------------------


def test_http_sse_disconnect_cancels_in_process(params_k2):
    """A client that opens an SSE stream and drops the socket after the
    first token CANCELS its request: the scheduler counts it, the slot
    and pages free, and the server keeps serving."""
    eng = _mk_engine(params_k2, max_out=64)
    rep = Replica("r0", eng)
    router = Router([rep])
    srv = FrontendServer(router)
    srv.start()
    try:
        body = json.dumps({"tokens": [1, 2, 3, 4], "max_new": 48,
                           "stream": True}).encode()
        conn = HTTPConnection(srv.host, srv.port, timeout=30.0)
        conn.request("POST", "/v1/generate", body=body,
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        assert resp.status == 200
        got = b""
        while b"\n\n" not in got:  # one token event crossed the socket
            got += resp.read1(4096)
        # Abortive close: a plain close() sends a FIN and the kernel keeps
        # ACKing the server's small SSE writes into a dead buffer, so the
        # handler never sees an error. linger(on, 0) turns close() into an
        # RST — the server's next write raises and the handler cancels.
        # (Connection: close moved the socket onto the response object.)
        sock = resp.fp.raw._sock
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                        struct.pack("ii", 1, 0))
        resp.close()
        conn.close()

        deadline = time.time() + 60.0
        while rep.scheduler.n_cancelled == 0 and time.time() < deadline:
            time.sleep(0.01)
        assert rep.scheduler.n_cancelled == 1
        assert rep.scheduler.wait_quiesced(60.0)
        eng.assert_pool_whole()
        out = client.http_generate(srv.url, np.arange(1, 5), 4)
        assert len(out["tokens"]) == 4  # loop unharmed
        assert client.http_get_json(srv.url, "/healthz")["cancelled"] == 1
    finally:
        srv.shutdown(drain=False)


def test_http_429_backpressure_with_retry_after(params_k2):
    """Past max_queue_depth the door answers 429 + Retry-After instead
    of parking handlers; shed requests are counted and the typed
    client exception carries the backoff hint."""
    eng = _mk_engine(params_k2, n_slots=2)
    rep = Replica("r0", eng)
    router = Router([rep], max_queue_depth=1)
    srv = FrontendServer(router)
    srv.start()
    try:
        slow = threading.Thread(
            target=lambda: client.http_generate(srv.url, [1, 2, 3], 8),
            daemon=True)
        slow.start()
        deadline = time.time() + 30.0
        while router.queue_depth == 0 and time.time() < deadline:
            time.sleep(0.001)
        with pytest.raises(client.Backpressure) as ei:
            client.http_generate(srv.url, [4, 5, 6], 4)
        assert ei.value.retry_after > 0
        # raw header shape too: integer seconds per RFC 9110
        req = urllib.request.Request(
            srv.url + "/v1/generate",
            data=json.dumps({"tokens": [7], "max_new": 2}).encode(),
            headers={"Content-Type": "application/json"})
        try:
            urllib.request.urlopen(req)
            raised = None
        except urllib.error.HTTPError as e:
            raised = e
        if raised is not None:  # the slow request may have finished
            assert raised.code == 429
            assert int(raised.headers["Retry-After"]) >= 1
        slow.join(60.0)
        assert router.stats()["shed"] >= 1
        # capacity freed: the same request now serves
        out = client.http_generate(srv.url, [4, 5, 6], 4)
        assert len(out["tokens"]) == 4
    finally:
        srv.shutdown()


def test_queuefull_fields():
    e = QueueFull(depth=7, limit=4, retry_after=0.35)
    assert e.depth == 7 and e.limit == 4 and e.retry_after == 0.35
    assert "queue depth 7" in str(e)


def test_router_add_remove_replica(params_k2):
    """Elastic membership on the in-process tier: add_replica grows the
    fleet under a running router; remove_replica drains and detaches
    (and refuses to empty the fleet)."""
    r0 = Replica("r0", _mk_engine(params_k2))
    router = Router([r0])
    router.start()
    try:
        router.add_replica(Replica("r1", _mk_engine(params_k2)))
        assert {r.name for r in router.replicas} == {"r0", "r1"}
        ev = threading.Event()
        router.submit(np.arange(1, 5), 3, on_done=lambda c: ev.set())
        assert ev.wait(60.0)
        gone = router.remove_replica("r1", timeout=60.0)
        assert gone.name == "r1" and not gone.scheduler.has_work
        assert [r.name for r in router.replicas] == ["r0"]
        with pytest.raises(ValueError, match="last replica"):
            router.remove_replica("r0")
    finally:
        router.stop()


# -- the process fleet -------------------------------------------------------

FLEET_SPEC = EngineSpec(
    arch="deepseek-7b", reduced=True, dtype="float32", members=2, seed=0,
    n_slots=2, max_prompt=16, max_out=8, prefill_chunk=4,
    paged=True, page_size=4, prefix_cache=True,
    # on the forced-2-device CI host every child process shards its two
    # members over a REAL 2-device mesh (XLA_FLAGS inherits through the
    # child's environment); single-device runs keep the unsharded engine
    mesh="2x1" if len(jax.devices()) >= 2 else "")


def test_engine_spec_json_roundtrip():
    assert EngineSpec.from_json(FLEET_SPEC.to_json()) == FLEET_SPEC
    assert EngineSpec.from_json(
        dataclasses.replace(FLEET_SPEC, seed=3).to_json()) != FLEET_SPEC


def _wait_replica_drained(proc, timeout=60.0):
    """Poll /healthz until the replica process reports no live or
    pending work and a whole page pool; -> the final replica dict."""
    deadline = time.time() + timeout
    while time.time() < deadline:
        r = proc.healthz()["replicas"][0]
        if (r["live_slots"] == 0 and r["pending"] == 0
                and r["available_pages"] == r["n_pages"]):
            return r
        time.sleep(0.05)
    raise AssertionError(
        f"replica {proc.name} never drained: {proc.healthz()}")


@pytest.fixture(scope="module")
def fleet2():
    """One 2-process fleet shared by the soak + rollout + scale tests
    (each compile costs ~10s of wall clock; the tests that mutate the
    fleet restore its shape before returning)."""
    fleet = FleetRouter(FLEET_SPEC, n=2)
    fleet.start(timeout=600.0)
    yield fleet
    fleet.stop()


@pytest.fixture(scope="module")
def fleet_refs():
    """Offline reference map {prompt tuple -> tokens} from the SAME
    spec the processes build from — the cross-process ground truth."""
    shared = list(range(50, 62))
    prompts = ([tuple(shared + [i]) for i in range(4)]
               + [tuple(range(1 + i, 7 + i)) for i in range(4)]
               + [tuple(range(90, 90 + 3 + i)) for i in range(4)])
    eng = FLEET_SPEC.build_engine()
    refs = {}
    for p in prompts:
        refs[p] = eng.generate([list(p)], max_new=6)[0].tolist()
    return refs


def test_fleet_soak_sigkill_restart_token_exact(fleet2, fleet_refs):
    """THE soak gate: ~200 threaded requests against a 2-process fleet
    while one replica is SIGKILLed and restarted mid-load.  Every
    request must complete token-exact against the offline reference
    (lost ones retried on the survivor) — zero drops, zero wedged
    handlers — and both processes must end with whole page pools."""
    prompts = list(fleet_refs)
    n_total = 200
    results = [None] * n_total
    errors = []
    nxt = {"i": 0}
    lock = threading.Lock()

    def worker():
        while True:
            with lock:
                i = nxt["i"]
                if i >= n_total:
                    return
                nxt["i"] += 1
            p = prompts[i % len(prompts)]
            try:
                out = fleet2.generate(list(p), 6, retries=5)
                results[i] = (p, out["tokens"])
            except Exception as e:  # noqa: BLE001 — a drop is the bug
                with lock:
                    errors.append((i, repr(e)))

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(8)]
    for t in threads:
        t.start()

    # chaos: wait until the fleet is genuinely mid-load, then SIGKILL
    # one replica; restart it while the survivor absorbs the traffic
    deadline = time.time() + 120.0
    while time.time() < deadline:
        with lock:
            started = nxt["i"]
        if started >= 20:
            break
        time.sleep(0.01)
    with lock:
        assert nxt["i"] < n_total, "load finished before the kill"
    victim = fleet2.procs[1]
    victim.kill()
    assert not victim.alive
    assert fleet2.health_sweep() == ["p1"]
    fleet2.restart("p1", timeout=600.0)
    assert fleet2.procs[1].alive

    for t in threads:
        t.join(600.0)
    assert not any(t.is_alive() for t in threads), "wedged workers"
    assert errors == []  # zero drops
    for i, item in enumerate(results):
        assert item is not None, f"request {i} vanished"
        p, toks = item
        assert toks == fleet_refs[p], f"request {i} not token-exact"
    # the kill was observed by the router (latched) whenever a request
    # was in flight on the victim; either way the fleet recovered
    s = fleet2.stats()
    assert s["n_live"] == 2
    for proc in fleet2.procs:
        r = _wait_replica_drained(proc, timeout=60.0)
        assert r["failed"] is None


def test_fleet_canary_rollout_over_sockets(fleet2, fleet_refs):
    """rollout(seed=7, canary=0.5): one process swaps first and serves
    the canary fraction; once its completions land, the fleet follows.
    Post-rollout outputs match a fresh seed-7 reference engine."""
    prompt = list(next(iter(fleet_refs)))
    ref7 = dataclasses.replace(FLEET_SPEC, seed=7).build_engine() \
        .generate([prompt], max_new=6)[0].tolist()
    assert ref7 != fleet_refs[tuple(prompt)]  # swap must be observable

    stop = threading.Event()
    errs = []

    def traffic():  # the canary window needs live requests to observe
        while not stop.is_set():
            try:
                fleet2.generate(prompt, 6, retries=3)
            except Exception as e:  # noqa: BLE001
                errs.append(repr(e))
                return

    t = threading.Thread(target=traffic, daemon=True)
    t.start()
    try:
        try:
            fleet2.rollout(seed=7, canary=0.5, canary_requests=2,
                           canary_timeout=300.0)
        finally:
            stop.set()
            t.join(120.0)
        assert not errs
        for proc in fleet2.procs:
            r = _wait_replica_drained(proc, timeout=60.0)
            assert r["swaps_done"] >= 1
        out = fleet2.generate(prompt, 6)
        assert out["tokens"] == ref7
    finally:
        # restore the module fixture's round even on failure, so later
        # tests sharing fleet2 see seed-0 weights
        fleet2.rollout(seed=FLEET_SPEC.seed)


def test_fleet_scale_to_and_autoscale(fleet2, fleet_refs):
    """Elastic membership on the socket tier: scale_to spawns/retires
    whole processes; autoscale is a pure function of queue depth."""
    assert len(fleet2.live()) == 2
    fleet2.scale_to(3, timeout=600.0)
    assert len(fleet2.live()) == 3
    p, ref = next(iter(fleet_refs.items()))
    out = fleet2.generate(list(p), 6)
    assert out["tokens"] == ref  # the new process serves the same spec
    fleet2.scale_to(2)
    assert len(fleet2.live()) == 2
    # autoscale: idle fleet (depth 0 <= low) shrinks toward min_n ...
    assert fleet2.autoscale(min_n=2, max_n=4) == 2
    # ... and a depth past high_depth grows by one
    with fleet2._lock:
        fleet2._in_flight[fleet2.procs[0].name] += 99
    try:
        assert fleet2.autoscale(min_n=2, max_n=4, high_depth=8) == 3
    finally:
        with fleet2._lock:
            fleet2._in_flight[fleet2.procs[0].name] -= 99
    fleet2.scale_to(2)
    assert len(fleet2.live()) == 2


def test_fleet_sigterm_is_graceful():
    """SIGTERM drains: the process serves out in-flight work and exits
    0 — the retirement half of elasticity, distinct from SIGKILL."""
    spec = dataclasses.replace(FLEET_SPEC, prefix_cache=False)
    fleet = FleetRouter(spec, n=1)
    fleet.start(timeout=600.0)
    try:
        out = fleet.generate([1, 2, 3, 4], 4)
        assert len(out["tokens"]) == 4
        code = fleet.procs[0].terminate(timeout=60.0)
        assert code == 0  # drained, not murdered
    finally:
        fleet.stop()


def test_fleet_429_over_sockets():
    """A replica process enforces its own max_queue_depth: saturating
    it answers 429 over the wire, FleetRouter backs off per
    Retry-After and still completes everything."""
    spec = dataclasses.replace(FLEET_SPEC, n_slots=1, max_prompt=8,
                               max_out=16, prefix_cache=False,
                               paged=False)
    fleet = FleetRouter(spec, n=1, max_queue_depth=2)
    fleet.start(timeout=600.0)
    try:
        errs, oks = [], []

        def fire():
            try:
                oks.append(fleet.generate([1, 2, 3], 12, retries=2))
            except Exception as e:  # noqa: BLE001
                errs.append(repr(e))

        threads = [threading.Thread(target=fire, daemon=True)
                   for _ in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(300.0)
        assert not errs and len(oks) == 6  # backoff, not failure
        h = fleet.procs[0].healthz()
        assert h["shed"] >= 1, "the queue never overflowed"
        assert fleet.n_backoffs >= 1
    finally:
        fleet.stop()
