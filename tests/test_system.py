"""End-to-end behaviour: the paper's full loop on CPU + launcher CLIs."""
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.common.types import ECConfig, ModelConfig
from repro.data import image_member_datasets
from repro.optim import sgd_momentum
from repro.runtime.trainer import Trainer


def test_ec_improves_over_rounds():
    """EC training actually learns: nll decreases over rounds on the
    synthetic class-prototype task (the paper's learning dynamic)."""
    key = jax.random.PRNGKey(0)
    K = 4
    # d_model is the NiN width knob: 128 ≈ 2/3 paper width
    cfg = ModelConfig(name="t", family="cnn", n_layers=9, d_model=128,
                      vocab_size=8)
    train, test = image_member_datasets(key, K, per_member=256,
                                        n_classes=8, img=8, noise=0.3)
    ec = ECConfig(tau=10, lam=0.5, p_steps=5, relabel_fraction=0.7,
                  label_mode="dense", aggregator="ec")
    tr = Trainer(cfg, ec, sgd_momentum(0.05, momentum=0.9), K, key, train,
                 test, batch_size=32)
    first = None
    for r in range(6):
        tr.run_round()
        ev = tr.evaluate()
        if first is None:
            first = ev["global_loss"]
    assert ev["global_loss"] < first, (first, ev["global_loss"])
    assert ev["global_err"] < 0.8  # clearly below 7/8 = 0.875 chance


@pytest.mark.parametrize("cmd", [
    [sys.executable, "-m", "repro.launch.train", "--arch", "deepseek-7b",
     "--reduced", "--members", "2", "--rounds", "1", "--tau", "2",
     "--p-steps", "1", "--batch", "2", "--per-member", "8",
     "--seq-len", "16", "--label-mode", "topk"],
    [sys.executable, "-m", "repro.launch.serve", "--arch", "whisper-tiny",
     "--reduced", "--members", "2", "--ensemble", "--batch", "2",
     "--prompt-len", "4", "--steps", "4"],
])
def test_launcher_clis(cmd):
    import os
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, (proc.stdout[-1000:], proc.stderr[-1500:])
