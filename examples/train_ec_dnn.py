"""End-to-end LM training driver: EC-DNN on a transformer with top-M
pseudo-label compression — the framework's production path at CPU scale.

Uses the gemma3-1b REDUCED config (same family: 5:1 SWA pattern, GQA,
geglu, tied embeddings) with 4 members, the ring/allgather relabel, topk
labels, AdamW + cosine, checkpointing and resume.  The identical command
with --arch gemma3-1b and the production mesh is what launch/train.py
runs on hardware; the dry-run (launch/dryrun.py) certifies that config
compiles at 512 chips.

  PYTHONPATH=src python examples/train_ec_dnn.py --rounds 3
"""
import argparse
import tempfile

import jax

from repro.common.types import ECConfig
from repro.configs import registry
from repro.data import lm_member_datasets
from repro.optim import adamw, linear_warmup_cosine
from repro.runtime.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--tau", type=int, default=12)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--top-m", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    train, test = lm_member_datasets(key, args.members, per_member=128,
                                     seq_len=args.seq_len,
                                     vocab=cfg.vocab_size)
    ec = ECConfig(tau=args.tau, lam=0.5, p_steps=args.tau // 2,
                  relabel_fraction=0.5, label_mode="topk",
                  top_m=args.top_m, aggregator="ec")
    opt = adamw(linear_warmup_cosine(3e-3, warmup=8,
                                     total_steps=args.rounds * args.tau))
    ckpt = args.ckpt or tempfile.mkdtemp(prefix="ec_ckpt_")
    tr = Trainer(cfg, ec, opt, args.members, key, train, test,
                 batch_size=args.batch, ckpt_dir=ckpt)
    if tr.resume():
        print(f"resumed from round {tr.round}")

    print(f"EC-DNN LM: {args.arch}(reduced) K={args.members} "
          f"top-M={args.top_m} tau={args.tau}")
    for r in range(tr.round, args.rounds):
        loss = tr.run_round()
        ev = tr.evaluate()
        print(f"round {r}: train ce={loss:.4f} | member nll="
              f"{ev['local_loss']:.4f} ensemble nll={ev['global_loss']:.4f}"
              f" (gap {ev['local_loss']-ev['global_loss']:+.4f})")
    tr.save()
    tr.ckpt.close()
    _, k = tr.best_member()
    print(f"EC-DNN_L: member {k}; checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
