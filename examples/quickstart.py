"""Quickstart: EC-DNN in ~40 lines.

Trains a 4-member ensemble on a synthetic image task, aggregates by
ensemble-compression each round, and prints the paper's Section-3
guarantee live: the ensemble's nll is never worse than the mean member
nll, while the parameter-average (MA) of the same members has no such
bound.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.common.types import ECConfig, ModelConfig
from repro.core import aggregation as agg
from repro.data import image_member_datasets
from repro.optim import sgd_momentum
from repro.runtime.trainer import Trainer


def main():
    key = jax.random.PRNGKey(0)
    K = 4
    cfg = ModelConfig(name="quickstart", family="cnn", n_layers=9,
                      d_model=96, vocab_size=10)
    train, test = image_member_datasets(key, K, per_member=256,
                                        n_classes=10, img=16, noise=0.5)
    ec = ECConfig(tau=8, lam=0.5, p_steps=4, relabel_fraction=0.7,
                  label_mode="dense", aggregator="ec")
    trainer = Trainer(cfg, ec, sgd_momentum(0.05, momentum=0.9), K, key,
                      train, test, batch_size=32)

    print(f"EC-DNN: K={K} members, tau={ec.tau}, lambda0={ec.lam}, "
          f"p={ec.p_steps}")
    for r in range(5):
        loss = trainer.run_round()
        ev = trainer.evaluate()
        gap = ev["local_loss"] - ev["global_loss"]
        print(f"round {r}: train={loss:.3f}  member nll="
              f"{ev['local_loss']:.3f}  ensemble nll="
              f"{ev['global_loss']:.3f}  Jensen gap={gap:+.4f} (>= 0 "
              f"guaranteed)")

    # contrast: parameter-averaging the same members (MA) has no bound
    ma_params = agg.ma_aggregate(trainer.state["params"])
    one = jax.tree.map(lambda x: x[0], ma_params)
    nll, err = trainer._single_eval(one, jax.tree.map(lambda a: a[:256],
                                                      test))
    print(f"\nMA of the same members: nll={float(nll):.3f} "
          f"(vs ensemble {ev['global_loss']:.3f}) — no guarantee, and "
          f"usually worse.")
    best, k = trainer.best_member()
    print(f"EC-DNN_L final model: member {k} (lowest training loss)")


if __name__ == "__main__":
    main()
