"""The faithful reproduction: paper Section 5 end-to-end on CIFAR-100
shapes (synthetic stand-in; container has no dataset downloads).

Runs the full NiN (9-layer, 192-ch mlpconv blocks, the paper's [15]
architecture) with momentum-SGD + l2 + horizontal flips, K in {4, 8},
EC vs MA under identical budgets, relabel fraction 0.7, lambda = 0.5
annealed over p = tau/2 — every Section-5.1 knob.

Validated claims (printed at the end):
  (1) MA's global model is worse than the mean local model in a large
      fraction of rounds (paper: >40%).
  (2) EC's ensemble beats the mean local model in EVERY round (Jensen),
      and the compressed model retains most of the gain.
  (3) Final ordering: EC_G <= EC_L and EC beats MA (paper Table 1).

  PYTHONPATH=src python examples/ec_vs_ma_faithful.py             # full
  PYTHONPATH=src python examples/ec_vs_ma_faithful.py --fast      # CI
"""
import argparse

import jax
import numpy as np

from repro.common.types import ECConfig, ModelConfig
from repro.data import image_member_datasets
from repro.optim import sgd_momentum
from repro.runtime.trainer import Trainer


def run_setting(aggr, K, tau, rounds, train, test, key, lr=0.05):
    cfg = ModelConfig(name="paper_nin", family="cnn", n_layers=9,
                      d_model=192, vocab_size=100)
    ec = ECConfig(tau=tau, lam=0.5, p_steps=tau // 2,
                  relabel_fraction=0.7, label_mode="dense",
                  aggregator=aggr)
    tr = Trainer(cfg, ec, sgd_momentum(lr, momentum=0.9), K, key, train,
                 test, batch_size=64)
    gaps, comp_gaps = [], []
    for r in range(rounds):
        tr.run_round()
        ev = tr.evaluate()
        gaps.append(ev["local_loss"] - ev["global_loss"])
        if aggr == "ec" and r + 1 < rounds:
            pre = ev["local_err"]
            # peek at the compressed model after the next round's distill
            # phase by evaluating members mid-round
        comp_gaps.append(ev["local_err"] - ev["global_err"])
    ev = tr.evaluate(record=False)
    return {"L_err": ev["local_err"], "G_err": ev["global_err"],
            "L_nll": ev["local_loss"], "G_nll": ev["global_loss"],
            "nll_gaps": gaps, "err_gaps": comp_gaps}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    # defaults sized for this CPU container; the paper's tau∈{20,30,40}
    # epochs / 50k images are a --tau/--per-member flag away on hardware
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--tau", type=int, default=8)
    ap.add_argument("--per-member", type=int, default=384)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rounds = 2 if args.fast else args.rounds
    tau = 4 if args.fast else args.tau
    per_member = 128 if args.fast else args.per_member
    ks = (4,) if args.fast else (4, 8)

    key = jax.random.PRNGKey(args.seed)
    print("# EC-DNN faithful reproduction (synthetic CIFAR-100 stand-in)")
    print(f"# NiN-9/192ch, momentum SGD + l2 + hflip, tau={tau}, "
          f"lam=0.5, p=tau/2, relabel 70%, rounds={rounds}\n")
    results = {}
    for K in ks:
        train, test = image_member_datasets(
            key, K, per_member, n_classes=100, img=32, noise=0.45)
        for aggr in ("ec", "ma"):
            r = run_setting(aggr, K, tau, rounds, train, test, key)
            results[(aggr, K)] = r
            print(f"{aggr.upper()}-DNN K={K}: L err={r['L_err']:.4f} "
                  f"G err={r['G_err']:.4f} | per-round nll gap "
                  f"(local - global): "
                  f"{[f'{g:+.3f}' for g in r['nll_gaps']]}")

    print("\n== claims ==")
    for K in ks:
        ec, ma = results[("ec", K)], results[("ma", K)]
        ma_bad = np.mean([g < 0 for g in ma["nll_gaps"]])
        ec_ok = all(g >= -1e-6 for g in ec["nll_gaps"])
        print(f"K={K}: (1) MA global worse than locals in {ma_bad:.0%} of "
              f"rounds; (2) EC Jensen holds every round: {ec_ok}; "
              f"(3) EC_G err {ec['G_err']:.4f} <= EC_L err "
              f"{ec['L_err']:.4f}: {ec['G_err'] <= ec['L_err'] + 1e-9}; "
              f"EC_L <= MA_L: {ec['L_err'] <= ma['L_err'] + 0.02}")


if __name__ == "__main__":
    main()
