"""Batched serving with an EC ensemble (EC-DNN_G), member-sharded.

The paper's Section 4: "take the global model as the final model if
there are enough resources at test time".  This example serves the
ensemble two ways through repro.serving.EnsembleEngine — single-device
and member-sharded over a ("member", "data") mesh — and shows that the
placement changes WHERE the members live (per-device cache bytes drop
K/M-fold), not WHAT the engine computes (scores match; the Jensen
log-likelihood gain is identical).

Runs on plain CPU: host devices are forced below (before jax imports)
so `--mesh 2x1` gets a real 2-device member axis anywhere.

  PYTHONPATH=src python examples/serve_ensemble.py [--mesh 2x1]
"""
import argparse
import os

# force a multi-device CPU host BEFORE jax initializes: the mesh demo
# needs >= 2 devices and a laptop/CI box has 1 (idempotent if the
# caller already forced a count)
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + " --xla_force_host_platform_device_count=2").strip()

import jax  # noqa: E402  (env must be set first)

from repro.common import sharding as shd  # noqa: E402
from repro.configs import registry  # noqa: E402
from repro.data import lm_member_datasets  # noqa: E402
from repro.models import transformer as tf  # noqa: E402
from repro.serving import EnsembleEngine  # noqa: E402


def placement_summary(engine) -> str:
    """Which members, cache bytes — and for a paged engine, how many
    pages — each device holds, plus the free-list occupancy."""
    mesh = engine.mesh
    ps = engine.page_stats()
    paged = (f", {ps['n_pages']} pages x {ps['page_size']} tok"
             if ps else "")
    if mesh is None:
        lines = [f"  single device {jax.devices()[0]}: "
                 f"members 0..{engine.n_members - 1}, "
                 f"{engine.cache_bytes() / 2**20:.2f} MiB cache{paged}"]
    else:
        per = engine.n_members // engine.member_shards
        lines = []
        for i, dev in enumerate(mesh.devices[:, 0]):
            lines.append(f"  device {dev}: members "
                         f"{i * per}..{(i + 1) * per - 1}, "
                         f"{engine.cache_bytes() / 2**20:.2f} MiB cache"
                         f"{paged}")
    if ps:
        lines.append(f"  free list: {ps['free_pages']}/{ps['n_pages']} "
                     f"pages free "
                     f"({ps['used_pages'] / max(ps['n_pages'], 1):.0%} "
                     f"in use)")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--mesh", default="2x1",
                    help="'MxD' member x data grid ('' = single device)")
    ap.add_argument("--paged", action="store_true",
                    help="also demo the paged KV pool (pages/device + "
                         "free-list occupancy after a decode)")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page (--paged)")
    ap.add_argument("--frontend", action="store_true",
                    help="also demo the HTTP frontend: 2 replicas on an "
                         "ephemeral port, one SSE-streamed request, then "
                         "a zero-downtime hot-swap rollout")
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    K = args.members
    params = jax.vmap(lambda k: tf.init(k, cfg))(jax.random.split(key, K))
    _, test = lm_member_datasets(key, 1, 8, seq_len=args.steps,
                                 vocab=cfg.vocab_size)
    toks = test["tokens"][: args.batch]
    labels = test["labels"][: args.batch]

    single = EnsembleEngine(cfg, params, n_slots=1, max_prompt=1, max_out=1)
    mesh = shd.parse_mesh_arg(args.mesh)
    sharded = EnsembleEngine(cfg, params, n_slots=1, max_prompt=1,
                             max_out=1, mesh=mesh)

    print(f"single-device placement:\n{placement_summary(single)}")
    print(f"mesh placement ({args.mesh}):\n{placement_summary(sharded)}")

    member_nll, ens_nll = sharded.score(toks, labels)
    m_ref, e_ref = single.score(toks, labels)

    B, T = toks.shape
    print(f"\nserved {B}x{T} tokens with K={K} members ({args.arch} "
          f"reduced), member axis over "
          f"{sharded.member_shards} device(s)")
    for m in range(K):
        print(f"  member {m}: nll/token = {float(member_nll[m]):.4f}")
    print(f"  EC-DNN_G ensemble: nll/token = {float(ens_nll):.4f} "
          f"(<= mean member {float(member_nll.mean()):.4f} by Jensen)")
    print(f"  single-device check: ensemble nll {float(e_ref):.4f}, "
          f"max member delta "
          f"{float(abs(member_nll - m_ref).max()):.2e} — same math, "
          f"1/{sharded.member_shards} the cache per device")

    if args.paged:
        import numpy as np
        paged = EnsembleEngine(cfg, params, n_slots=4, max_prompt=16,
                               max_out=8, mesh=mesh, paged=True,
                               page_size=args.page_size)
        prompts = [np.arange(1, 9) % cfg.vocab_size, np.arange(2, 6)]
        paged.generate(prompts, max_new=8)
        # mid-flight occupancy: admit without harvesting
        paged.update_slots(release=range(4),
                           admits=[(i, p, 8) for i, p in
                                   enumerate(prompts)])
        print(f"\npaged placement ({args.mesh}, page_size="
              f"{args.page_size}):\n{placement_summary(paged)}")

    if args.frontend:
        demo_frontend(cfg, params, mesh)


def demo_frontend(cfg, params, mesh):
    """2 replicas behind the HTTP frontend: stream one request over
    SSE, then roll a fresh member stack through the fleet with zero
    downtime (drain -> swap_params -> rejoin per replica)."""
    import numpy as np

    from repro.serving import client
    from repro.serving.frontend import FrontendServer, Replica, Router

    kw = dict(n_slots=2, max_prompt=16, max_out=8, prefill_chunk=8,
              mesh=mesh)
    replicas = [Replica(f"r{i}", EnsembleEngine(cfg, params, **kw))
                for i in range(2)]
    router = Router(replicas)
    srv = FrontendServer(router)
    srv.start()
    try:
        print(f"\nfrontend: {srv.url} (2 replicas, least-loaded routing)")
        prompt = np.arange(1, 9) % cfg.vocab_size
        out = client.http_generate(srv.url, prompt, 8, stream=True)
        print(f"  SSE streamed {out['n_gen']} tokens from replica "
              f"{out['replica']}: {out['tokens']} "
              f"(ttft {out['ttft_ms']:.1f} ms)")
        new_params = jax.vmap(lambda k: tf.init(k, cfg))(
            jax.random.split(jax.random.PRNGKey(42),
                             replicas[0].engine.n_members))
        router.rollout(new_params)
        out2 = client.http_generate(srv.url, prompt, 8, stream=False)
        print(f"  rolled out a new member stack with zero downtime "
              f"(swaps: {[r.engine.swaps_done for r in replicas]}); "
              f"post-swap tokens: {out2['tokens']}")
    finally:
        srv.shutdown()
        print("  drained and shut down")


if __name__ == "__main__":
    main()
