"""Batched serving with an EC ensemble (EC-DNN_G) vs a single member.

The paper's Section 4: "take the global model as the final model if there
are enough resources at test time".  This example decodes a token batch
both ways and reports the ensemble's log-likelihood gain on held-out
continuations — the serving-side face of the Jensen guarantee.

  PYTHONPATH=src python examples/serve_ensemble.py
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core import ensemble as ens
from repro.data import lm_member_datasets
from repro.models import transformer as tf


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    K = args.members
    params = jax.vmap(lambda k: tf.init(k, cfg))(jax.random.split(key, K))
    _, test = lm_member_datasets(key, 1, 8, seq_len=args.steps,
                                 vocab=cfg.vocab_size)
    toks = test["tokens"][: args.batch]
    labels = test["labels"][: args.batch]

    B, T = toks.shape
    caches = [tf.init_cache(cfg, B, max_seq=T) for _ in range(K)]
    step = jax.jit(lambda p, c, t: tf.decode_step(p, cfg, c, t))

    member_nll = jnp.zeros((K,))
    ens_nll = 0.0
    for t in range(T):
        logits_k = []
        for m in range(K):
            pm = jax.tree.map(lambda x: x[m], params)
            lg, caches[m] = step(pm, caches[m], toks[:, t: t + 1])
            logits_k.append(lg[:, 0])
        stack = jnp.stack(logits_k)                       # (K, B, V)
        lp = jax.nn.log_softmax(stack.astype(jnp.float32), -1)
        gold = labels[:, t]
        member_nll += -jnp.take_along_axis(
            lp, gold[None, :, None], 2)[..., 0].mean(-1)
        p_ens = ens.ensemble_probs(stack)
        ens_nll += float(-jnp.log(jnp.take_along_axis(
            p_ens, gold[:, None], 1) + 1e-30).mean())

    member_nll = member_nll / T
    ens_nll /= T
    print(f"served {B}x{T} tokens with K={K} members ({args.arch} reduced)")
    for m in range(K):
        print(f"  member {m}: nll/token = {float(member_nll[m]):.4f}")
    print(f"  EC-DNN_G ensemble: nll/token = {ens_nll:.4f} "
          f"(<= mean member {float(member_nll.mean()):.4f} by Jensen)")


if __name__ == "__main__":
    main()
