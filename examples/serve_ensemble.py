"""Batched serving with an EC ensemble (EC-DNN_G) vs a single member.

The paper's Section 4: "take the global model as the final model if there
are enough resources at test time".  This example scores held-out
continuations through the serving engine (repro.serving.EnsembleEngine
— the same vmapped-member decode path that generates tokens) and reports
the ensemble's log-likelihood gain: the serving-side face of the Jensen
guarantee.

  PYTHONPATH=src python examples/serve_ensemble.py
"""
import argparse

import jax

from repro.configs import registry
from repro.data import lm_member_datasets
from repro.models import transformer as tf
from repro.serving import EnsembleEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--steps", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.get_config(args.arch, reduced=True)
    key = jax.random.PRNGKey(0)
    K = args.members
    params = jax.vmap(lambda k: tf.init(k, cfg))(jax.random.split(key, K))
    _, test = lm_member_datasets(key, 1, 8, seq_len=args.steps,
                                 vocab=cfg.vocab_size)
    toks = test["tokens"][: args.batch]
    labels = test["labels"][: args.batch]

    engine = EnsembleEngine(cfg, params, n_slots=1, max_prompt=1, max_out=1)
    member_nll, ens_nll = engine.score(toks, labels)

    B, T = toks.shape
    print(f"served {B}x{T} tokens with K={K} members ({args.arch} reduced)")
    for m in range(K):
        print(f"  member {m}: nll/token = {float(member_nll[m]):.4f}")
    print(f"  EC-DNN_G ensemble: nll/token = {float(ens_nll):.4f} "
          f"(<= mean member {float(member_nll.mean()):.4f} by Jensen)")


if __name__ == "__main__":
    main()
