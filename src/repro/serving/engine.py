"""EC-DNN_G continuous-batching inference engine.

The paper's Section 4 serving mode — "take the global model as the final
model if there are enough resources at test time" — as one compiled
program per decode step instead of the K-jit-calls-per-token Python loop
it replaces:

  - all K members score the step inside a single jit: params and the
    kv_cache pool carry a leading member axis and a jax.vmap over it
    batches every layer's matmuls across the ensemble;
  - each batch row is an independent *slot* at its own sequence position
    (models/transformer.decode_step_slots), so requests of different
    lengths share the decode batch — the substrate continuous batching
    (scheduler.py) admits into and evicts from;
  - member distributions fuse on-device via core.ensemble
    .ensemble_log_probs (Eqn 6 in log space) under a (K,) quorum vector:
    zeroing a member's weight degrades gracefully to the surviving
    subset, mirroring ring_relabel's straggler policy, with no recompile
    (the quorum is a traced argument);
  - sampling, output bookkeeping and EOS/length eviction flags all
    happen inside the jitted step, so the host loop is dispatch-only;
  - prompts go through a SECOND compiled kernel: prefill (also vmapped
    over members; slot index traced) consumes a whole prompt chunk of
    one slot per program and materializes every prompt position's
    KV/recurrent state straight into that slot's cache row (slot_row ->
    chunk forward -> write_slot_row, the prefill-then-insert idiom), so
    a request is decode-ready after ceil(prompt_len / prefill_chunk)
    programs instead of prompt_len steps, costs O(chunk) — not
    O(n_slots x chunk) — and its first generated token is sampled from
    the prefill program's last-token logits.  prefill_chunk=0 keeps the
    original one-token-per-step teacher-forcing path as a reference
    baseline.

Multi-device (mesh=...): the member axis is the unit of parallelism.
The paper's global model is K INDEPENDENT members (Eqn 6), so at
serving time nothing crosses members until the final fusion — sharding
the leading (K,) axis of the stacked params, the cache pool, and the
quorum vector over the "member" axis of a ("member", "data") mesh
(common.sharding.local_mesh) makes per-device cache bytes and FLOPs
scale with K/M instead of K.  Every kernel above then runs under
shard_map: each device vmaps only its local members and the Eqn-6
fusion becomes a psum-style cross-member reduction
(core.ensemble.ensemble_log_probs_psum) — one pmax + one psum of fused
(B, V) partials is ALL the inter-device traffic per step; K full
distributions never move.  Slot state and sampling are replicated, the
quorum stays a traced argument (straggler drop still recompiles and
reshards nothing, mirroring ring_relabel's local-worker placement
story), and mesh=None keeps the original single-jit path bit-identical
as the reference baseline.  A 1-device local_mesh runs the same
shard_map program (collectives become identity), so CPU CI exercises
the mesh code path without multiple devices.

Paged cache (paged=True): the contiguous pool reserves a max_seq KV row
per member per layer per slot — the ensemble's K-fold model-cost tax
(paper §1) paid again in cache bytes, however short the requests.  The
paged pool spends bytes on TOKENS IN FLIGHT instead: full-attention
planes become fixed-size pages shared by all slots behind a per-slot
page table (kv_cache.PageAllocator, pure host policy; the table is a
traced input, so allocation never recompiles), admission bounds by free
pages rather than free slots, decode grows one page per boundary
crossing with zero device sync (a host-side position mirror), and the
Pallas kernel kernels/paged_attention.py reads only a slot's live pages
— O(len) per step, not O(max_seq).  paged=False keeps the contiguous
pool bit-identical as the reference baseline; docs/serving.md "Paged
cache" has the layout diagram and lifecycle.

Every decode in the repo (launch/serve.py CLI, examples, benchmarks,
the scheduler) goes through EnsembleEngine.prefill/step — one path.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common import sharding as shd
from repro.common.types import ModelConfig
from repro.core import ensemble as ens
from repro.models import attention as attn_mod
from repro.models import transformer as tf
from repro.serving import kv_cache, sampling
from repro.serving import prefix as prefix_mod


class SlotState(NamedTuple):
    """Device-resident per-slot serving state (one row per batch slot)."""

    tok: jax.Array         # (B,)   next input token
    pos: jax.Array         # (B,)   tokens consumed so far (== cache idx)
    prompt: jax.Array      # (B,P)  padded prompt buffer
    prompt_len: jax.Array  # (B,)
    max_new: jax.Array     # (B,)   per-request generation budget
    n_gen: jax.Array       # (B,)   tokens emitted so far
    active: jax.Array      # (B,)   slot occupied by a request
    done: jax.Array        # (B,)   finished, awaiting host harvest
    out: jax.Array         # (B,G)  emitted tokens
    key: jax.Array         # PRNG carried across steps
    temp: jax.Array        # (B,)   per-request sampling temperature
    topk: jax.Array        # (B,)   per-request top-k (0 = full vocab)
    skey: jax.Array        # (B,2)  per-request base PRNG key
    draft: jax.Array       # (B,)   speculative drafting enabled


def _param_spec(params):
    """(treedef, [(shape, dtype)]) of a RAW (pre-absorption) stack —
    what swap_params validates incoming checkpoints against."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    return treedef, [(x.shape, x.dtype) for x in leaves]


class EnsembleEngine:
    """Vmapped-member decode engine over a fixed pool of batch slots.

    stacked_params: member params with a leading (K,) axis (the layout
    `jax.vmap(lambda k: tf.init(k, cfg))(keys)` produces and training
    checkpoints store).  K = 1 serves a single/compressed model
    (EC-DNN_L) through the identical path.

    mesh: None (default) runs the single-device reference path — one
    jit, vmap over all K members.  A ("member", "data") mesh from
    `common.sharding.local_mesh` shards the leading (K,) member axis of
    params / cache pool / quorum over "member" (K must divide evenly)
    and compiles every kernel under shard_map: each device holds and
    scores K/M members and only fused log-prob partials cross devices
    (`core.ensemble.ensemble_log_probs_psum`).  Slot state replicates,
    so the host API is placement-oblivious — same calls, same shapes,
    same results (token-exact vs mesh=None at float32).
    """

    def __init__(self, cfg: ModelConfig, stacked_params, *,
                 n_slots: int = 8, max_prompt: int = 64, max_out: int = 64,
                 prefill_chunk: Optional[int] = None,
                 temperature: float = 0.0,
                 top_k: int = 0, eos_id: int = -1,
                 quorum: Optional[Sequence[float]] = None, seed: int = 0,
                 mesh=None, paged: bool = False, page_size: int = 16,
                 n_pages: Optional[int] = None,
                 prefix_cache: bool = False, kv_dtype: str = "f32"):
        self.cfg = cfg
        self.n_members = jax.tree.leaves(stacked_params)[0].shape[0]
        self.mesh = mesh
        self.member_shards = (1 if mesh is None
                              else mesh.shape[shd.MEMBER_AXIS])
        if self.n_members % self.member_shards:
            raise ValueError(
                f"mesh member axis {self.member_shards} does not divide "
                f"K={self.n_members} members")
        if kv_dtype not in attn_mod.KV_DTYPES:
            raise ValueError(
                f"kv_dtype must be one of {attn_mod.KV_DTYPES}, "
                f"got {kv_dtype!r}")
        if kv_dtype != "f32" and not paged:
            raise ValueError(
                "kv_dtype != f32 requires paged=True (only paged planes "
                "are stored quantized; the contiguous pool is the "
                "bit-exact reference)")
        if kv_dtype == "fp8":
            attn_mod.fp8_dtype()  # raises if this jax has no float8
        self.kv_dtype = kv_dtype
        # swap_params validates incoming RAW trees against the raw spec
        # captured here, BEFORE any absorbed-MLA leaves are added
        self._param_spec = _param_spec(stacked_params)
        if paged:
            stacked_params = tf.absorb_mla_params(cfg, stacked_params)
        if mesh is None:
            self.params = stacked_params
        else:
            self.params = jax.device_put(
                stacked_params,
                shd.make_shardings(mesh, shd.member_pspecs(stacked_params)))
        self.n_slots = n_slots
        self.max_prompt = max_prompt
        self.max_out = max_out
        self.max_seq = max_prompt + max_out
        # prompt tokens consumed per prefill program; 0 disables batched
        # prefill and keeps the per-token teacher-forcing reference
        # path.  None picks the chunk from the engine's own budgets
        # instead of a hardcoded constant: a quarter of max_prompt
        # (floor 32, so short-prompt engines keep the proven default),
        # rounded up to a whole page on paged engines so chunk
        # boundaries and page boundaries line up.  An explicit int
        # always overrides.
        if prefill_chunk is None:
            prefill_chunk = max(32, -(-max_prompt // 4))
            if paged and page_size > 0:
                prefill_chunk = -(-prefill_chunk // int(page_size)) \
                    * int(page_size)
        self.prefill_chunk = min(max(prefill_chunk, 0), max_prompt)
        self.temperature = temperature
        self.top_k = top_k
        self.eos_id = eos_id
        self.quorum = (jnp.ones((self.n_members,), jnp.float32)
                       if quorum is None
                       else jnp.asarray(quorum, jnp.float32))
        # paged KV pool: full-attention planes become shared fixed-size
        # pages behind a per-slot page table (kv_cache.PageAllocator);
        # paged=False keeps the contiguous pool BIT-IDENTICAL (none of
        # the code below this constructor changes shape or math).
        self.paged = bool(paged)
        self.page_size = int(page_size)
        self.prefix: Optional[prefix_mod.PrefixCache] = None
        if self.paged:
            if cfg.enc_dec:
                raise ValueError(
                    "paged serving does not support enc-dec archs yet "
                    "(stub encoder context is slot-contiguous)")
            if self.page_size <= 0:
                raise ValueError(f"page_size must be > 0, got {page_size}")
            self.pages_per_slot = -(-self.max_seq // self.page_size)
            # default: full capacity (every slot can reach max_seq) —
            # equal logical capacity to the contiguous pool; pass a
            # smaller n_pages to oversubscribe slots against memory
            # (admission then bounds by free pages, Scheduler preempts)
            self.n_pages = (n_slots * self.pages_per_slot
                            if n_pages is None else int(n_pages))
            self.allocator = kv_cache.PageAllocator(
                self.n_pages, self.page_size, n_slots, self.pages_per_slot)
            # host mirror of each slot's request shape: lets the engine
            # grow pages BEFORE dispatching a step, with zero device sync
            # (EOS-early finishes overshoot by <= one page until harvest)
            self._host_pos = np.zeros(n_slots, np.int64)
            self._host_plen = np.zeros(n_slots, np.int64)
            self._host_new = np.zeros(n_slots, np.int64)
            self._host_active = np.zeros(n_slots, bool)
            self._table_stale = True
            if prefix_cache:
                bad = self._prefix_ineligible()
                if bad:
                    raise ValueError(
                        f"prefix_cache needs every layer's positional "
                        f"state in shared pages, but {bad} keeps per-slot"
                        f" state a hit could not skip rebuilding")
                if self.prefill_chunk <= 0:
                    raise ValueError(
                        "prefix_cache needs chunked prefill "
                        "(prefill_chunk > 0): admission starts the "
                        "chunk walk at the hit boundary")
                # each slot's prompt, mirrored host-side: the trie is
                # keyed on token ids and harvests a chain's prefix at
                # release, long after the admit call's arrays are gone
                self._host_prompt = np.zeros((n_slots, max_prompt),
                                             np.int32)
                self.prefix = prefix_mod.PrefixCache(self.page_size)
                self.allocator.cache = self.prefix
        elif prefix_cache:
            raise ValueError("prefix_cache requires paged=True (the "
                             "contiguous pool has no shareable pages)")
        self.cache = kv_cache.init_pool(
            cfg, self.n_members, n_slots, self.max_seq, mesh=mesh,
            page_size=self.page_size if self.paged else 0,
            n_pages=self.n_pages if self.paged else 0,
            kv_dtype=kv_dtype)
        if cfg.enc_dec:
            self.cache["enc"] = self._encode_stub(n_slots)
        self.state = self._blank_state(seed)
        # per-request sampling: requests that do not pin a seed draw
        # their base key from fold_in(engine key, admission counter) —
        # deterministic for a given admission order, distinct per request
        self._req_base_key = jax.random.PRNGKey(seed)
        self._admitted = 0
        self.steps_run = 0
        self.prefills_run = 0
        self.swaps_done = 0
        if mesh is not None:
            self.quorum = jax.device_put(
                self.quorum, NamedSharding(mesh, P(shd.MEMBER_AXIS)))
        # cache + state are donated: the pool is updated in place across
        # the server's lifetime, never reallocated.  Under a mesh every
        # kernel wraps in shard_map first (member axis manual, slot
        # state replicated); in/out shardings match, so donation still
        # reuses the pool's buffers shard by shard.
        pspec, cspec = (shd.member_pspecs(self.params),
                        shd.member_pspecs(self.cache))
        sspec = shd.replicated_pspecs(self.state)
        q, s = P(shd.MEMBER_AXIS), P()
        self._step = self._compile(
            self._step_impl, donate=(1, 2),
            in_specs=(pspec, cspec, sspec, q),
            out_specs=(sspec, cspec))
        self._prefill = self._compile(
            self._prefill_impl, donate=(1, 2),
            in_specs=(pspec, cspec, sspec, q, s),
            out_specs=(sspec, cspec))
        self._update = self._compile(
            self._update_impl, donate=(0, 1),
            in_specs=(cspec, sspec, s, s, s, s, s, s, s, s, s, s),
            out_specs=(sspec, cspec))
        if self.paged:
            # whole-page device copy for copy-on-write admissions:
            # fixed (B,)-shaped src/dst id vectors (sentinel rows
            # no-op), so any COW pattern reuses one compiled program
            self._copy = self._compile(
                lambda cache, src, dst: kv_cache.copy_pages(
                    cache, src, dst, self.n_pages),
                donate=(0,), in_specs=(cspec, s, s), out_specs=cspec)
        self._score = self._compile(
            self._score_impl, donate=(1,),
            in_specs=(pspec, cspec, s, s, q),
            out_specs=(q, s, cspec))

    def _compile(self, fn, donate, in_specs, out_specs):
        """jit a kernel; under a mesh, wrap it in shard_map first.

        Specs are rank-correct pytrees per argument: the member axis of
        params/cache/quorum is manual-sharded, slot state and scalars
        replicate (shorter specs pad with None, so P() on a vector arg
        means fully replicated).  check_vma stays off: outputs declared
        replicated ARE replicated by construction — every cross-member
        quantity goes through a psum/pmax before it reaches them.
        """
        if self.mesh is None:
            return jax.jit(fn, donate_argnums=donate)
        return jax.jit(
            shd.shard_map(fn, self.mesh, in_specs=in_specs,
                          out_specs=out_specs),
            donate_argnums=donate)

    # -- construction -------------------------------------------------------

    def _blank_state(self, seed: int) -> SlotState:
        B, P, G = self.n_slots, self.max_prompt, self.max_out
        zi = lambda *s: jnp.zeros(s, jnp.int32)
        zb = lambda *s: jnp.zeros(s, bool)
        return SlotState(tok=zi(B), pos=zi(B), prompt=zi(B, P),
                         prompt_len=zi(B), max_new=zi(B), n_gen=zi(B),
                         active=zb(B), done=zb(B), out=zi(B, G),
                         key=jax.random.PRNGKey(seed),
                         temp=jnp.zeros((B,), jnp.float32), topk=zi(B),
                         skey=jnp.zeros((B, 2), jnp.uint32), draft=zb(B))

    def _encode_stub(self, batch: int) -> jax.Array:
        """Per-member encoder outputs over stub frame embeddings.

        Audio/VLM frontends are stubs repo-wide (DESIGN §4); per-request
        encoder state is a serving follow-up (ROADMAP).  Computed once —
        the decode loop only reads it.  Under a mesh the (K, B, S, d)
        result is pinned member-sharded like the rest of the pool.
        """
        from repro.models.layers import dtype_of
        enc_in = jnp.zeros((batch, self.cfg.enc_max_frames,
                            self.cfg.d_model), dtype_of(self.cfg))
        enc = jax.jit(jax.vmap(
            lambda p: tf.encode(p, self.cfg, enc_in)))(self.params)
        if self.mesh is not None:
            enc = jax.device_put(
                enc, NamedSharding(self.mesh, shd.member_pspec(enc.ndim)))
        return enc

    # -- jitted kernels -----------------------------------------------------
    # Each kernel body is placement-oblivious: it sees the full (K,) axis
    # on the reference path and the local (K/M,) shard inside shard_map;
    # the only cross-member op is _fuse, which switches to the psum-style
    # reduction on the mesh path.

    def _member_logits(self, params, cache, tok) -> Tuple[jax.Array, dict]:
        """All (local) members score the step in one program.
        -> ((K, B, V), cache).  Paged engines route through
        decode_step_paged (same contract; KV reads go through each
        member's replica of the page table)."""
        step = (tf.decode_step_paged if self.paged
                else tf.decode_step_slots)

        def one(p, c):
            return step(p, self.cfg, c, tok[:, None])

        logits, cache = jax.vmap(one)(params, cache)  # (K, B, 1, V)
        return logits[:, :, 0], cache

    def _fuse(self, member_logits, quorum) -> jax.Array:
        """Eqn-6 log-space fusion under the traced quorum vector.

        Reference path: logsumexp over the full member axis.  Mesh path:
        each shard fuses its local members, then one pmax + one psum
        over "member" combine the shards — only fused (..., V) partials
        cross devices, never K distributions.
        """
        if self.mesh is None:
            return ens.ensemble_log_probs(member_logits, weights=quorum)
        return ens.ensemble_log_probs_psum(member_logits, quorum,
                                           axis_name=shd.MEMBER_AXIS)

    def _step_impl(self, params, cache, st: SlotState, quorum):
        B = st.tok.shape[0]
        # only live slots advance: an inactive / finished slot must not
        # walk pos (and the cache idx) past max_seq while the server
        # idles.  With batched prefill on, mid-prompt slots also hold
        # still here — the prefill program owns the prompt path.
        adv = st.active & ~st.done
        if self.prefill_chunk > 0:
            adv &= st.pos >= st.prompt_len
        old_cache = cache
        logits, cache = self._member_logits(params, cache, st.tok)
        cache = kv_cache.keep_frozen(cache, old_cache, adv)
        logp = self._fuse(logits, quorum)  # (B, V)
        # per-request sampling params; the key for emission i is
        # fold_in(request base key, i), so a preempted-and-resumed
        # request regenerates token-identically
        keys = jax.vmap(jax.random.fold_in)(st.skey, st.n_gen)
        sampled = sampling.sample_slots(keys, logp, st.temp, st.topk)

        pos1 = st.pos + adv.astype(jnp.int32)
        in_prompt = pos1 < st.prompt_len  # next input is teacher-forced
        P = st.prompt.shape[1]
        nxt_prompt = jnp.take_along_axis(
            st.prompt, jnp.minimum(pos1, P - 1)[:, None], axis=1)[:, 0]

        emit = adv & ~in_prompt
        row = jnp.arange(B)
        col = jnp.minimum(st.n_gen, st.out.shape[1] - 1)
        out = st.out.at[row, col].set(
            jnp.where(emit, sampled, st.out[row, col]))
        n_gen = st.n_gen + emit.astype(jnp.int32)
        finished = emit & (n_gen >= st.max_new)
        if self.eos_id >= 0:
            finished |= emit & (sampled == self.eos_id)
        done = st.done | finished
        tok = jnp.where(adv, jnp.where(in_prompt, nxt_prompt, sampled),
                        st.tok)
        return st._replace(tok=tok, pos=pos1, n_gen=n_gen, done=done,
                           out=out), cache

    def _update_impl(self, cache, st: SlotState, release, admit,
                     prompt, plen, max_new, temp, topk, skey, draft,
                     pos0):
        """Evict `release` slots, (re)fill `admit` slots with new requests.

        pos0 (B,): per-admit start position — 0 on a cold admission,
        the prefix-cache hit length when admission attached shared
        pages holding the prompt's first pos0 positions (update_slots
        computes it; always 0 with the prefix cache off, keeping this
        path bit-identical to the pre-prefix engine).  The slot's
        first prefill chunk then starts at pos0, and its first input
        token is prompt[pos0] rather than prompt[0].
        """
        cache = kv_cache.reset_slots(cache, admit, pos0)
        a2 = admit[:, None]
        tok0 = jnp.take_along_axis(prompt, pos0[:, None], axis=1)[:, 0]
        return SlotState(
            tok=jnp.where(admit, tok0, st.tok),
            pos=jnp.where(admit, pos0, st.pos),
            prompt=jnp.where(a2, prompt, st.prompt),
            prompt_len=jnp.where(admit, plen, st.prompt_len),
            max_new=jnp.where(admit, max_new, st.max_new),
            n_gen=jnp.where(admit, 0, st.n_gen),
            active=(st.active & ~release) | admit,
            done=st.done & ~release & ~admit,
            out=jnp.where(a2, 0, st.out),
            key=st.key,
            temp=jnp.where(admit, temp, st.temp),
            topk=jnp.where(admit, topk, st.topk),
            skey=jnp.where(a2, skey, st.skey),
            draft=jnp.where(admit, draft, st.draft)), cache

    def _prefill_impl(self, params, cache, st: SlotState, quorum, slot):
        """Consume up to prefill_chunk prompt tokens of ONE slot in one
        compiled program (members vmapped, like _step_impl).

        The slot index is a traced scalar, so every slot reuses this one
        program; only the selected slot's cache row rides through the
        chunk forward (slot_row -> prefill -> write_slot_row, maxtext's
        prefill-then-insert), so a prefill costs O(chunk) compute — not
        O(n_slots x chunk) — and in-flight neighbors are untouched.  A
        slot whose prompt completes inside this chunk gets its first
        generated token sampled from the chunk's last-token logits: the
        first token comes out of prefill itself, no decode step needed.
        Idle / decode-phase slots are bit-exact no-ops (n_tok == 0).
        """
        C = self.prefill_chunk
        pos, plen = st.pos[slot], st.prompt_len[slot]
        need = st.active[slot] & ~st.done[slot] & (pos < plen)
        n_tok = jnp.where(need, jnp.minimum(C, plen - pos), 0)
        P = st.prompt.shape[1]
        cols = jnp.clip(pos + jnp.arange(C), 0, P - 1)
        chunk = st.prompt[slot][cols][None]  # (1, C)
        row = kv_cache.slot_row(cache, slot)

        if self.paged:
            def one(p, c):
                return tf.prefill_step_paged(p, self.cfg, c, chunk, n_tok)
        else:
            def one(p, c):
                return tf.prefill_slots(p, self.cfg, c, chunk, n_tok[None])

        logits, row = jax.vmap(one)(params, row)  # (K, 1, V)
        cache = kv_cache.write_slot_row(cache, row, slot)
        logp = self._fuse(logits[:, 0], quorum)  # (V,)
        kb = jax.random.fold_in(st.skey[slot], st.n_gen[slot])
        sampled = sampling.sample_slots(
            kb[None], logp[None], st.temp[slot][None],
            st.topk[slot][None])[0]

        pos1 = pos + n_tok
        completed = need & (pos1 >= plen)
        col = jnp.minimum(st.n_gen[slot], st.out.shape[1] - 1)
        out = st.out.at[slot, col].set(
            jnp.where(completed, sampled, st.out[slot, col]))
        n_gen = st.n_gen.at[slot].add(completed.astype(jnp.int32))
        finished = completed & (st.n_gen[slot] + 1 >= st.max_new[slot])
        if self.eos_id >= 0:
            finished |= completed & (sampled == self.eos_id)
        return st._replace(
            tok=st.tok.at[slot].set(jnp.where(completed, sampled,
                                              st.tok[slot])),
            pos=st.pos.at[slot].set(pos1), n_gen=n_gen,
            done=st.done.at[slot].set(st.done[slot] | finished),
            out=out), cache

    def _score_impl(self, params, cache, tok_t, gold_t, quorum):
        """Teacher-forced scoring step: per-member + ensemble NLL.

        m_nll is laid out along the member axis ((K/M,) per shard on the
        mesh path, concatenating back to the global (K,)); e_nll comes
        out of the fused distribution, so it is replicated.
        """
        logits, cache = self._member_logits(params, cache, tok_t)  # (K,B,V)
        lp = ens.member_log_probs(logits)
        gold = jnp.broadcast_to(gold_t[None], logits.shape[:-1])
        m_nll = -jnp.take_along_axis(lp, gold[..., None],
                                     axis=-1)[..., 0].mean(-1)  # (K,)
        e_lp = self._fuse(logits, quorum)
        e_nll = -jnp.take_along_axis(e_lp, gold_t[:, None],
                                     axis=1)[:, 0].mean()
        return m_nll, e_nll, cache

    # -- host API -----------------------------------------------------------

    def validate_request(self, tokens, max_new: int,
                         temperature: Optional[float] = None,
                         top_k: Optional[int] = None,
                         seed: Optional[int] = None) -> np.ndarray:
        """Check a request against the engine's budgets; -> 1-D int32
        prompt.  The single source of truth for admission limits, used
        by update_slots and by Scheduler.submit (reject at the door).
        Per-request sampling params are optional (None = engine
        default); out-of-range values raise against the NAMED limits in
        serving/sampling.py (temperature/seed) and the model's
        vocab_size (top_k)."""
        t = np.asarray(tokens, np.int32).reshape(-1)
        if not 0 < t.size <= self.max_prompt:
            raise ValueError(f"prompt len {t.size} not in "
                             f"[1, {self.max_prompt}]")
        if not 0 < max_new <= self.max_out:
            raise ValueError(f"max_new {max_new} not in "
                             f"[1, {self.max_out}]")
        if temperature is not None and not (
                sampling.MIN_TEMPERATURE <= float(temperature)
                <= sampling.MAX_TEMPERATURE):
            raise ValueError(
                f"temperature {temperature} not in [MIN_TEMPERATURE="
                f"{sampling.MIN_TEMPERATURE}, MAX_TEMPERATURE="
                f"{sampling.MAX_TEMPERATURE}]")
        if top_k is not None and not (
                0 <= int(top_k) <= self.cfg.vocab_size):
            raise ValueError(
                f"top_k {top_k} not in [0, vocab_size="
                f"{self.cfg.vocab_size}]")
        if seed is not None and not (
                sampling.MIN_SEED <= int(seed) <= sampling.MAX_SEED):
            raise ValueError(
                f"seed {seed} not in [MIN_SEED={sampling.MIN_SEED}, "
                f"MAX_SEED={sampling.MAX_SEED}]")
        if self.paged:
            need = self.allocator.pages_for(t.size + max_new)
            if need > self.n_pages:
                # could never complete even with the whole pool to
                # itself: preemption would loop forever — reject here
                raise ValueError(
                    f"request needs {need} pages ({t.size}+{max_new} "
                    f"tokens at page_size={self.page_size}) but the pool "
                    f"holds {self.n_pages}")
        return t

    # -- paged-pool host accounting -----------------------------------------

    def _prefix_ineligible(self) -> Optional[str]:
        """Why this config cannot reuse cached prefix pages (None = it
        can).  A prefix hit skips prefill for positions [0, hit), so
        EVERY layer's positional state for those positions must live in
        the shared pages: layers that keep per-slot planes
        (sliding-window attention below max_seq, linear-attention
        recurrent states) or per-slot ffn carries (rwkv_cmix's
        cmix_shift) would come up blank for the skipped positions."""
        for _, specs in self.cfg.segments():
            for spec in specs:
                if not tf.layer_pages(self.cfg, spec, self.max_seq):
                    return (f"mixer {spec.mixer!r} keeps per-slot "
                            f"(non-paged) cache planes")
                if spec.ffn == "rwkv_cmix":
                    return "ffn 'rwkv_cmix' carries per-slot cmix_shift"
        return None

    def _sync_table(self):
        """Push the allocator's page table to the device pool (every
        member carries a replica, so the kernels stay member-vmapped)."""
        tbl = jnp.asarray(self.allocator.table())
        arr = jnp.broadcast_to(tbl[None], (self.n_members,) + tbl.shape)
        if self.mesh is not None:
            arr = jax.device_put(
                arr, NamedSharding(self.mesh, shd.member_pspec(arr.ndim)))
        self.cache["page_table"] = arr
        self._table_stale = False

    def _host_decoding(self) -> np.ndarray:
        """(B,) host's view of slots whose NEXT step writes cache at
        _host_pos — the mirror of _step_impl's `adv` (EOS-early
        finishes are invisible here; they over-hold <= one page until
        harvest releases the slot)."""
        live = self._host_active & (
            self._host_pos < self._host_plen + self._host_new)
        if self.prefill_chunk > 0:
            live &= self._host_pos >= self._host_plen  # prefill owns prompt
        return live

    def reserve_decode_pages(self) -> list:
        """Grow each decoding slot's page chain to cover this step's
        write position; -> slots the dry free list left STARVED (the
        caller — Scheduler — must preempt or release before step()).
        No-op list on contiguous engines."""
        if not self.paged:
            return []
        starved = []
        for b in np.nonzero(self._host_decoding())[0]:
            pos = int(self._host_pos[b])
            if self.allocator.holds(b, pos):
                continue
            if self.allocator.alloc(b, pos // self.page_size + 1):
                self._table_stale = True
            else:
                starved.append(int(b))
        if self._table_stale:
            self._sync_table()
        return starved

    def _release_slot(self, b: int):
        """Recycle slot b's chain and host mirrors.  With the prefix
        cache on, the chain's VALID prompt prefix is offered to the trie
        first (release is the only time a chain's content is final):
        claimed pages survive as cached prefix pages — evictable once
        unreferenced — while everything else (decode tail, deduped
        prompt pages) returns to the free list via refcount decrements.
        Only min(pos, plen) tokens are inserted: a preempted mid-prompt
        slot has only written that far, and decode tokens past the
        prompt are per-request content no other request should match.
        """
        if self.prefix is not None and self._host_plen[b] > 0:
            valid = int(min(self._host_pos[b], self._host_plen[b]))
            n = self.allocator.pages_for(valid)
            chain = self.allocator.chain(b)
            if valid > 0 and len(chain) >= n:
                self.prefix.insert(self._host_prompt[b, :valid],
                                   chain[:n])
            self._host_prompt[b, :] = 0
        self.allocator.release(b)
        self._host_active[b] = False
        self._host_pos[b] = 0
        self._host_plen[b] = self._host_new[b] = 0

    def admit_cost(self, tokens) -> int:
        """Pages admitting this prompt would consume RIGHT NOW:
        worst-case ceil(plen/page) minus matched full pages some live
        slot already references (attaching those is a pure refcount
        bump).  Ref-0 trie pages are NOT discounted — they are already
        counted once in available_pages, and a partial tail's page is
        never discounted (its COW copy consumes a fresh page).  Uses
        the trie's read-only peek, so costing a queue of candidates
        skews neither hit-rate telemetry nor LRU order.  The
        Scheduler's admission gate pairs this with admission_headroom.
        """
        t = np.asarray(tokens, np.int32).reshape(-1)
        cost = self.allocator.pages_for(t.size)
        if self.prefix is None or t.size <= 1:
            return cost
        _, full, _ = self.prefix.peek(t.tolist(), t.size - 1)
        return cost - sum(1 for p in full if self.allocator.ref(p) > 0)

    def admission_headroom(self, releasing: Sequence[int] = ()) -> int:
        """Pages an admission batch can draw on: the allocator's
        available pool (free list + evictable trie pages) plus what
        releasing the given slots would certainly return (their chain
        pages at refcount 1 that the trie does not keep).  Conservative:
        a releasing slot's trie-claimed pages become evictable — also
        headroom — but are only counted once they get there."""
        if not self.paged:
            return -1
        return self.allocator.available_pages + sum(
            self.allocator.reclaimable_pages(int(b)) for b in releasing)

    @property
    def free_pages(self) -> int:
        return self.allocator.free_pages if self.paged else -1

    def assert_pool_whole(self) -> None:
        """Drained-state check: no slot holds pages, the pool's global
        accounting is consistent (kv_cache.PageAllocator
        .check_invariants), and every page is free or trie-evictable.
        Raises AssertionError naming the leak.  The fleet soak, the
        cancellation tests, and a replica's post-drain hygiene all gate
        on this — a page that survives a full drain is a leak the
        admission headroom would silently repay forever.  No-op on
        contiguous engines (nothing to leak)."""
        if not self.paged:
            return
        a = self.allocator
        held = {b: a.held_pages(b) for b in range(self.n_slots)
                if a.held_pages(b)}
        assert not held, f"drained engine still holds pages: {held}"
        a.check_invariants()
        assert a.available_pages == a.n_pages, \
            (f"{a.n_pages - a.available_pages} pages neither free nor "
             f"evictable after drain ({a.free_pages} free, "
             f"{a.available_pages} available of {a.n_pages})")

    def page_stats(self) -> dict:
        """Free-list occupancy telemetry (placement summaries, client
        reports).  Empty on contiguous engines."""
        if not self.paged:
            return {}
        a = self.allocator
        pb = kv_cache.page_bytes(self.cache, a.n_pages)
        stats = {"n_pages": a.n_pages, "page_size": a.page_size,
                 "free_pages": a.free_pages, "used_pages": a.used_pages,
                 "available_pages": a.available_pages,
                 "shared_pages": a.shared_pages,
                 "pages_per_slot": a.pages_per_slot,
                 "low_water_pages": a.low_water,
                 "kv_dtype": self.kv_dtype,
                 "kv_quantized": int(self.kv_dtype in ("int8", "fp8")),
                 "page_bytes": pb,
                 "bytes_per_token": pb // max(a.page_size, 1)}
        if self.prefix is not None:
            stats.update(self.prefix.stats())
            stats["cow_pages"] = a.cow_count
            stats["shared_attaches"] = a.shared_attach_count
        return stats

    def step(self) -> SlotState:
        """Advance every slot one token (one compiled program).

        All K members score the step — vmapped in one jit on the
        reference path, K/M members per device under shard_map on the
        mesh path (fused log-probs are the only cross-device traffic).
        Returns the replicated SlotState; the cache pool (leading (K,)
        member axis, sharded over "member" when a mesh is set) advances
        in place via donation.

        Paged engines grow each decoding slot's page chain first
        (reserve_decode_pages); a dry free list raises — callers that
        can preempt (Scheduler) reserve themselves before stepping.
        """
        if self.paged:
            starved = self.reserve_decode_pages()
            if starved:
                raise RuntimeError(
                    f"paged pool out of pages for decoding slots "
                    f"{starved} ({self.allocator.free_pages} free of "
                    f"{self.n_pages}); release finished slots or preempt "
                    f"(Scheduler.run does) before stepping")
        self.state, self.cache = self._step(self.params, self.cache,
                                            self.state, self.quorum)
        self.steps_run += 1
        if self.paged:
            adv = self._host_decoding()
            self._host_pos[adv] += 1
        return self.state

    def prefill(self, slot: int) -> SlotState:
        """Advance one mid-prompt slot by up to prefill_chunk prompt
        tokens (one compiled program, slot index traced — every slot
        reuses it); a slot whose prompt completes emits its first
        generated token from this same program.

        An admitted request is decode-ready after
        ceil(prompt_len / prefill_chunk) prefill programs instead of
        prompt_len engine steps, and the program touches only this
        slot's cache row — in-flight neighbors don't pay for it.
        """
        if self.prefill_chunk <= 0:
            raise ValueError("engine built with prefill_chunk=0 "
                             "(per-token reference path)")
        if not 0 <= int(slot) < self.n_slots:
            raise ValueError(f"slot {slot} out of range "
                             f"[0, {self.n_slots})")
        if self.paged and self._table_stale:
            self._sync_table()
        self.state, self.cache = self._prefill(
            self.params, self.cache, self.state, self.quorum,
            jnp.asarray(slot, jnp.int32))
        self.prefills_run += 1
        if self.paged:
            b = int(slot)
            left = self._host_plen[b] - self._host_pos[b]
            if self._host_active[b] and left > 0:
                self._host_pos[b] += min(self.prefill_chunk, int(left))
        return self.state

    def update_slots(self, release: Sequence[int] = (),
                     admits: Sequence[tuple] = ()):
        """Evict finished slots and admit new requests.

        admits: (slot, prompt_tokens, max_new) triples, or 4-tuples
        whose last element is an options dict with any of
        {"temperature", "top_k", "seed", "draft"} — per-request
        sampling/speculation overrides (None/missing = engine default;
        a request with no seed gets fold_in(engine key, admission
        counter), so admission order fixes its draws).  Fixed-shape
        masked updates, so any admission pattern reuses one compiled
        program.  Admission is a slot-axis operation: it touches every
        member's row of the (K, ...) pool identically, so the mesh path
        runs it shard-local with zero communication.

        Returns {slot: hit_tokens} for admissions the prefix cache
        served (serving/prefix.py): those slots start prefilling at
        position `hit`, so callers that drive prefill themselves
        (generate, Scheduler) owe ceil((plen - hit) / prefill_chunk)
        chunks, not ceil(plen / chunk).  Empty whenever the prefix
        cache is off — and the whole path below is then bit-identical
        to the pre-prefix engine (pos0 stays all-zero).
        """
        B, P = self.n_slots, self.max_prompt

        def check_slot(b) -> int:
            # validate BEFORE indexing: numpy wraparound would silently
            # alias slot -1 onto the last slot
            b = int(b)
            if not 0 <= b < B:
                raise ValueError(f"slot {b} out of range [0, {B})")
            return b

        rel = np.zeros((B,), bool)
        adm = np.zeros((B,), bool)
        prompt = np.zeros((B, P), np.int32)
        plen = np.zeros((B,), np.int32)
        mnew = np.zeros((B,), np.int32)
        temp = np.full((B,), self.temperature, np.float32)
        topk = np.full((B,), self.top_k, np.int32)
        skey = np.zeros((B, 2), np.uint32)
        draft = np.zeros((B,), bool)
        for b in release:
            rel[check_slot(b)] = True
        for entry in admits:
            b, toks, max_new = entry[0], entry[1], entry[2]
            opts = dict(entry[3]) if len(entry) > 3 and entry[3] else {}
            b = check_slot(b)
            t = self.validate_request(
                toks, max_new, temperature=opts.get("temperature"),
                top_k=opts.get("top_k"), seed=opts.get("seed"))
            adm[b] = True
            prompt[b, :t.size] = t
            plen[b] = t.size
            mnew[b] = max_new
            if opts.get("temperature") is not None:
                temp[b] = float(opts["temperature"])
            if opts.get("top_k") is not None:
                topk[b] = int(opts["top_k"])
            if opts.get("seed") is not None:
                skey[b] = np.asarray(
                    jax.random.PRNGKey(int(opts["seed"])), np.uint32)
            else:
                skey[b] = np.asarray(jax.random.fold_in(
                    self._req_base_key, self._admitted), np.uint32)
            draft[b] = bool(opts.get("draft", self._default_draft()))
            self._admitted += 1
        hits: dict = {}
        pos0 = np.zeros((B,), np.int32)
        if self.paged:
            # all-or-nothing page accounting BEFORE any state mutates:
            # released/recycled slots return their chains, admitted
            # prompts take ceil(plen/page) up front (decode pages grow
            # step by step via reserve_decode_pages).  Two-tier check:
            # worst case (no prefix discount) first; if that fails and
            # the prefix cache is on, re-probe with admit_cost (full
            # pages a live slot already references attach for free) —
            # the same charge model Scheduler._fill_slots gates with.
            recycled = [b for b in range(B) if rel[b] or adm[b]]
            avail = self.allocator.available_pages + sum(
                self.allocator.reclaimable_pages(b) for b in recycled)
            need = sum(self.allocator.pages_for(int(plen[b]))
                       for b in range(B) if adm[b])
            if need > avail and self.prefix is not None:
                need = sum(self.admit_cost(prompt[b, :plen[b]])
                           for b in range(B) if adm[b])
            if need > avail:
                raise RuntimeError(
                    f"admission needs {need} pages, only {avail} "
                    f"available (pool {self.n_pages}); queue instead — "
                    f"Scheduler._fill_slots admits by free pages")
            for b in recycled:
                self._release_slot(b)
            cow_src = np.full((B,), self.n_pages, np.int32)
            cow_dst = np.full((B,), self.n_pages, np.int32)
            any_cow = False
            for b in range(B):
                if not adm[b]:
                    continue
                p = int(plen[b])
                if self.prefix is not None:
                    toks = prompt[b, :p]
                    # cap the hit at plen - 1: the request's first
                    # sampled token needs last-token logits, so at
                    # least one prompt position always prefills
                    hit, full, tail = self.prefix.match(toks, p - 1)
                    if full or tail:
                        self.allocator.share(
                            b, full + ([tail[0]] if tail else []))
                    if tail is not None:
                        # the hit ends mid-page: the slot's first write
                        # (position hit, offset hit % page) lands inside
                        # the matched page — swap in a private copy
                        # before any kernel can write it
                        src, dst = self.allocator.cow(b, len(full))
                        cow_src[b], cow_dst[b] = src, dst
                        any_cow = True
                    pos0[b] = hit
                    hits[b] = hit
                    self._host_prompt[b, :p] = toks
                if not self.allocator.alloc(
                        b, self.allocator.pages_for(p)):
                    raise RuntimeError("page accounting violated its "
                                       "feasibility check")  # unreachable
                self._host_active[b] = True
                self._host_pos[b] = int(pos0[b])
                self._host_plen[b] = p
                self._host_new[b] = int(mnew[b])
            self._table_stale = True
            self._sync_table()
            if any_cow:
                # dispatch the page copy BEFORE _update resets the slot
                # and before any prefill: the data dependence through
                # the donated pool orders the src read ahead of every
                # later write, even if src is evicted and handed to
                # another slot inside this same admission batch
                self.cache = self._copy(self.cache, jnp.asarray(cow_src),
                                        jnp.asarray(cow_dst))
        self.state, self.cache = self._update(
            self.cache, self.state, jnp.asarray(rel), jnp.asarray(adm),
            jnp.asarray(prompt), jnp.asarray(plen), jnp.asarray(mnew),
            jnp.asarray(temp), jnp.asarray(topk), jnp.asarray(skey),
            jnp.asarray(draft), jnp.asarray(pos0))
        return hits

    def _default_draft(self) -> bool:
        """Whether an admission with no explicit `draft` option drafts
        speculatively.  The base engine has no draft model; the
        speculative subclass flips this to True."""
        return False

    def _sync_each_step(self) -> bool:
        """generate(): fetch the done flags after every step and exit
        the loop early.  False here — the base engine emits exactly one
        token per live row per step, so the fixed step count is already
        tight and the static-batch loop stays dispatch-only.  The
        speculative subclass returns True: its per-row stride is
        variable (1..gamma+1 tokens per iteration), so without the
        fetch the loop would keep dispatching full speculative programs
        long after every row finished."""
        return False

    def generate(self, prompts: Sequence[np.ndarray],
                 max_new: int) -> list:
        """Static-batch decode: admit up to n_slots prompts, run to done.

        The whole run is dispatch-only (no host sync inside the loop) —
        except on an OVERSUBSCRIBED paged pool with EOS enabled, where
        each step fetches the done flags: the host page mirror cannot
        see an EOS finish, and without a harvest loop to release the
        slot it would keep growing pages for it until the free list
        spuriously ran dry.  Use scheduler.Scheduler for continuous
        admission instead.
        Returns one int32 array of generated tokens per prompt —
        identical whatever the engine's placement (mesh or not) and,
        with prefill_chunk=0, via the per-token teacher-forcing
        reference path every other configuration is tested against.
        """
        if len(prompts) == 0:
            return []
        if len(prompts) > self.n_slots:
            raise ValueError(f"{len(prompts)} prompts > {self.n_slots} slots")
        hits = self.update_slots(
            release=range(self.n_slots),
            admits=[(i, p, max_new) for i, p in enumerate(prompts)])
        plens = [len(np.reshape(p, -1)) for p in prompts]
        if self.prefill_chunk > 0:
            # chunked prefill emits each slot's first token; decode does
            # the remaining max_new - 1.  Prefix-cache hits shorten a
            # slot's walk: it starts at the hit boundary, and hit <=
            # plen - 1 guarantees at least one chunk always runs
            for i, plen in enumerate(plens):
                left = plen - hits.get(i, 0)
                for _ in range(-(-left // self.prefill_chunk)):
                    self.prefill(i)
            steps = max_new - 1
        else:
            steps = max(plens) + max_new - 1
        sync_done = (self.paged and self.eos_id >= 0
                     and self.n_pages < self.n_slots * self.pages_per_slot)
        early = self._sync_each_step()
        for _ in range(steps):
            self.step()
            if sync_done:
                self._host_active &= ~np.asarray(
                    jax.device_get(self.state.done))
            if early:
                act, done = jax.device_get((self.state.active,
                                            self.state.done))
                if not np.any(np.asarray(act) & ~np.asarray(done)):
                    break
        st = jax.device_get(self.state)
        return [st.out[i, :st.n_gen[i]] for i in range(len(prompts))]

    def score(self, tokens: jax.Array, labels: jax.Array):
        """Teacher-forced NLL of a (B, T) batch: (per-member (K,), ensemble).

        The serving-side face of the Jensen guarantee: the returned
        ensemble NLL is <= the mean member NLL for any members — and
        the quorum-weighted subset keeps the bound, so it holds under
        straggler drop too.  Uses a private cache pool (member-sharded
        like the serving pool when a mesh is set); slot state is
        untouched.  The returned per-member vector is always the global
        (K,), whatever the placement.
        """
        tokens = jnp.asarray(tokens, jnp.int32)
        B, T = tokens.shape
        cache = kv_cache.init_pool(self.cfg, self.n_members, B, T,
                                   mesh=self.mesh)
        if self.cfg.enc_dec:
            cache["enc"] = self._encode_stub(B)
        m_tot = jnp.zeros((self.n_members,), jnp.float32)
        e_tot = jnp.zeros((), jnp.float32)
        for t in range(T):
            m, e, cache = self._score(self.params, cache, tokens[:, t],
                                      jnp.asarray(labels[:, t]), self.quorum)
            m_tot, e_tot = m_tot + m, e_tot + e
        return m_tot / T, e_tot / T

    def swap_params(self, new_stacked_params) -> None:
        """Install a new member stack between iterations — model
        hot-swap, the serving end of the paper's train -> compress ->
        serve loop (every aggregation round publishes a new distilled
        global model; the fleet must pick it up without restarting).

        The new pytree must match the live one exactly (treedef,
        leaf shapes, dtypes): the jitted decode/prefill/score kernels
        key their caches on those, so a conforming swap reuses the SAME
        compiled programs — zero recompiles, gated by
        `benchmarks/serving_bench.py --frontend`.  Under a mesh the new
        stack is re-sharded to the live member placement
        (`member_pspecs`), so the device-side layout is also unchanged.

        The KV pool, page table, and slot state are NOT touched:
        in-flight requests keep decoding through the swap (their
        remaining tokens come from the new weights — drain the slots
        first, e.g. `frontend.Router.rollout`, when each request must
        be served end-to-end by one model version).  K itself is fixed;
        grow/shrink the stack with `checkpoint.store.reshard_members`
        BEFORE swapping.
        """
        old_def, old_shapes = self._param_spec
        new_leaves, new_def = jax.tree_util.tree_flatten(new_stacked_params)
        if old_def != new_def:
            raise ValueError(
                f"swap_params: new param tree structure {new_def} does not "
                f"match the live engine's {old_def}")
        for i, ((oshape, odtype), n) in enumerate(zip(old_shapes,
                                                      new_leaves)):
            if oshape != n.shape or odtype != n.dtype:
                raise ValueError(
                    f"swap_params: leaf {i} is {n.shape}/{n.dtype}, live "
                    f"engine has {oshape}/{odtype} — a mismatched stack "
                    f"would recompile every kernel (use "
                    f"checkpoint.store.reshard_members to change K first)")
        if self.paged:
            # re-derive the absorbed projections from the NEW weights
            # (same leaf shapes as the live tree -> no recompiles)
            new_stacked_params = tf.absorb_mla_params(self.cfg,
                                                      new_stacked_params)
        if self.mesh is None:
            self.params = jax.tree.map(jnp.asarray, new_stacked_params)
        else:
            self.params = jax.device_put(
                new_stacked_params,
                shd.make_shardings(self.mesh,
                                   shd.member_pspecs(new_stacked_params)))
        if self.cfg.enc_dec:
            # the stub encoder context is a function of the params;
            # recompute it so decode reads the new model's encodings
            self.cache["enc"] = self._encode_stub(self.n_slots)
        if self.paged and self.prefix is not None:
            # cached prefix pages hold the OLD model's KV: a round-t
            # prefix must never serve round t+1.  Flush the trie; pages
            # still referenced by in-flight slots are disowned and free
            # on their release (drain first — Router.rollout does —
            # when zero stale pages may survive the swap).
            self.allocator.flush_cache()
        self.swaps_done += 1

    def set_quorum(self, mask: Sequence[float]):
        """0/1 liveness per member; renormalized on-device, no recompile.

        The quorum is a traced (K,) argument of every kernel, so
        dropping a straggler mid-stream recompiles NOTHING and — on the
        mesh path, where the vector is member-sharded like the params —
        reshards nothing either: a dead member's shard keeps computing,
        its vote just carries zero weight in the fused reduction.
        """
        q = ens.quorum_weights(jnp.asarray(mask, jnp.float32))
        if q.shape != (self.n_members,):
            raise ValueError(f"quorum mask wants {self.n_members} entries, "
                             f"got {q.shape}")
        if self.mesh is not None:
            q = jax.device_put(
                q, NamedSharding(self.mesh, P(shd.MEMBER_AXIS)))
        self.quorum = q

    def cache_bytes(self) -> int:
        """PER-DEVICE bytes of the cache pool (capacity telemetry).

        Under a member-sharded pool each device holds K/M members'
        planes, so this reports the global figure divided by the mesh
        member-axis size — the number a chip actually budgets.  On the
        unsharded reference path per-device == global.
        """
        return kv_cache.pool_bytes(self.cache, per_device=True)
