"""Continuous batching: a request queue over the engine's slot pool.

The engine decodes a fixed batch of B slots every step; the scheduler
keeps those slots full.  Each loop iteration it (1) admits queued
requests into free slots, (2) runs one engine decode step for the
slots already past their prompt, (3) prefills admitted prompts in
chunks — one compiled multi-token program per selected slot (slot
index traced, so all slots share the program), under a per-iteration
prompt-token budget so one long prompt cannot starve decode latency
for in-flight slots — and (4) harvests slots whose request hit EOS or
its generation budget, freeing them for the next admission.  Requests of different prompt/output lengths
therefore interleave in the same decode batch instead of padding to a
common length — the classic continuous-batching win — and a newly
admitted request reaches its first token after ceil(prompt/chunk)
prefill programs instead of `prompt` engine steps.

All policy lives host-side in this module; the engine's prefill and
decode kernels each stay a single compiled program.  Admission is
FIFO; slots are filled greedily; the prefill budget is spent in FIFO
admission order.  With engines built prefill_chunk=0 the scheduler
degrades to the per-token teacher-forcing path unchanged.

The scheduler is placement-oblivious: slot state is replicated on
every mesh device, so admission, harvest, and the prefill budget work
identically over a single-device engine and a member-sharded
(mesh=...) one — the member axis is the engine's concern, never the
queue's.  Straggler handling composes the same way: engine.set_quorum
drops a member mid-stream with no recompile and no rescheduling.

Over a PAGED engine (engine.paged) two policies change shape, both
still host-side: admission bounds by FREE PAGES rather than free slots
(strictly FIFO — a request that doesn't fit blocks the ones behind it,
so short requests cannot starve a long one), and when the free list
runs dry mid-decode the YOUNGEST in-flight request is preempted back
to the front of the queue (_ensure_decode_pages) — the oldest request
never loses its pages, so completion order stays FIFO, nothing
starves, and a preempted request simply regenerates on re-admission
(bit-identical under greedy sampling).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import numpy as np

from repro.serving.engine import EnsembleEngine


@dataclass
class Request:
    rid: int
    tokens: np.ndarray
    max_new: int
    submit_t: float


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray          # generated ids (prompt not included)
    prompt_len: int
    submit_t: float
    admit_t: float
    first_token_t: Optional[float]
    finish_t: float

    @property
    def ttft(self) -> float:
        """Submit -> first generated token (queue wait + prefill)."""
        first = (self.first_token_t if self.first_token_t is not None
                 else self.finish_t)  # `or` would drop a valid 0.0 stamp
        return first - self.submit_t

    @property
    def latency(self) -> float:
        return self.finish_t - self.submit_t


@dataclass
class _SlotMeta:
    req: Request
    admit_t: float
    first_token_t: Optional[float] = None
    prefill_left: int = 0       # prompt tokens not yet prefilled


class Scheduler:
    """FIFO continuous-batching scheduler over one EnsembleEngine.

    submit() queues a request (validated against the engine's budgets
    at the door); run() drives admit -> decode -> prefill -> harvest
    until the queue drains, returning {rid: Completion}.  Works
    unchanged over any engine placement (single-device or mesh) and
    any prefill_chunk, including the 0 reference baseline.

    prefill_budget caps how many prompt tokens may enter prefill
    programs per loop iteration (default: 2 chunks).  One chunk is
    always allowed, so a single over-budget prompt still progresses.
    """

    def __init__(self, engine: EnsembleEngine,
                 prefill_budget: Optional[int] = None):
        self.engine = engine
        self.prefill_budget = (2 * engine.prefill_chunk
                               if prefill_budget is None else prefill_budget)
        self.pending: deque = deque()
        self.slots: list = [None] * engine.n_slots  # Optional[_SlotMeta]
        self.completions: Dict[int, Completion] = {}
        self._next_rid = 0
        self._to_release: list = []
        self.preemptions = 0     # paged: decode-time evictions to queue
        self.peak_in_flight = 0  # max concurrently admitted requests

    # -- submission ---------------------------------------------------------

    def submit(self, tokens, max_new: int) -> int:
        """Queue a request; returns its id (keyed in .completions).

        Validates against the engine's budgets HERE so one oversized
        request is rejected at the door instead of crashing run() and
        taking every in-flight request down with it.
        """
        t = self.engine.validate_request(tokens, max_new)
        rid = self._next_rid
        self._next_rid += 1
        self.pending.append(Request(rid, t, int(max_new), time.time()))
        return rid

    # -- scheduling loop ----------------------------------------------------

    def _fill_slots(self):
        admits = []
        now = time.time()
        chunked = self.engine.prefill_chunk > 0
        avail = 0
        if self.engine.paged:
            # pages the combined release+admit dispatch below can hand
            # out: the free list plus the chains of slots being released
            # in the same call (update_slots recycles before it admits)
            avail = self.engine.free_pages + sum(
                self.engine.allocator.held_pages(b)
                for b in self._to_release)
        for b in range(self.engine.n_slots):
            if self.slots[b] is None and self.pending:
                nxt = self.pending[0]
                if self.engine.paged:
                    # admit by free pages, not free slots — and strictly
                    # FIFO (no skip-ahead past a request that does not
                    # fit: that is how short requests would starve a
                    # long one forever)
                    need = self.engine.allocator.pages_for(len(nxt.tokens))
                    if need > avail:
                        break
                    avail -= need
                req = self.pending.popleft()
                admits.append((b, req.tokens, req.max_new))
                self.slots[b] = _SlotMeta(
                    req, now,
                    prefill_left=len(req.tokens) if chunked else 0)
        if admits or self._to_release:
            self.engine.update_slots(release=self._to_release, admits=admits)
            self._to_release = []
        self.peak_in_flight = max(
            self.peak_in_flight, sum(m is not None for m in self.slots))

    def _ensure_decode_pages(self):
        """Grow decoding slots' page chains before the step; when the
        free list runs dry, PREEMPT the youngest in-flight request
        (highest rid) back to the front of the queue and retry.

        Preempting youngest-first keeps completion order FIFO and
        starvation-free: the oldest request never loses its pages to a
        newer one, so it always progresses (alone, it always fits —
        submit() rejects requests larger than the whole pool).  A
        preempted request restarts from scratch on re-admission; with
        greedy sampling its tokens are bit-identical, it just pays the
        queue again (counted in .preemptions and its ttft/latency).
        """
        if not self.engine.paged:
            return
        while True:
            starved = self.engine.reserve_decode_pages()
            if not starved:
                return
            live = [b for b, m in enumerate(self.slots) if m is not None]
            victim = max(live, key=lambda b: self.slots[b].req.rid)
            meta = self.slots[victim]
            self.engine.update_slots(release=[victim])
            self.slots[victim] = None
            # every queued rid is younger than every in-flight rid, so
            # appendleft re-sorts the queue into submission order
            self.pending.appendleft(meta.req)
            self.preemptions += 1

    def _run_prefill(self):
        """Spend the iteration's prefill budget in admission (FIFO)
        order — one chunk program per selected slot."""
        chunk = self.engine.prefill_chunk
        if chunk <= 0:
            return
        spent = 0
        waiting = sorted(
            (b for b, m in enumerate(self.slots)
             if m is not None and m.prefill_left > 0),
            key=lambda b: self.slots[b].req.rid)
        for b in waiting:
            meta = self.slots[b]
            take = min(meta.prefill_left, chunk)
            if spent and spent + take > self.prefill_budget:
                break  # over budget; first selection always proceeds
            self.engine.prefill(b)
            spent += take
            meta.prefill_left -= take

    def _decode_ready(self) -> bool:
        return any(m is not None and m.prefill_left == 0
                   for m in self.slots)

    def _harvest(self):
        st = self.engine.state
        # ONE device transfer per iteration: finished slots' outputs ride
        # along with the done/n_gen flags instead of a per-slot fetch
        done, n_gen, out = jax.device_get((st.done, st.n_gen, st.out))
        now = time.time()
        for b, meta in enumerate(self.slots):
            if meta is None:
                continue
            if meta.first_token_t is None and n_gen[b] > 0:
                meta.first_token_t = now
            if done[b]:
                req = meta.req
                self.completions[req.rid] = Completion(
                    rid=req.rid,
                    tokens=out[b, :n_gen[b]].copy(),
                    prompt_len=len(req.tokens),
                    submit_t=req.submit_t, admit_t=meta.admit_t,
                    first_token_t=meta.first_token_t, finish_t=now)
                self.slots[b] = None
                self._to_release.append(b)

    def run(self) -> Dict[int, Completion]:
        """Drive until the queue drains and every slot is idle.

        Decode runs BEFORE prefill each iteration: the harvest stamp
        then directly follows any first token a prefill program just
        produced, so reported TTFT is not inflated by an unrelated
        decode step dispatched after it.
        """
        while self.pending or any(m is not None for m in self.slots):
            self._fill_slots()
            if self._decode_ready():  # skip decode while all mid-prompt
                self._ensure_decode_pages()  # paged: grow or preempt
                if self._decode_ready():     # preemption may empty the set
                    self.engine.step()
            self._run_prefill()
            self._harvest()
        if self._to_release:
            self.engine.update_slots(release=self._to_release)
            self._to_release = []
        return self.completions
