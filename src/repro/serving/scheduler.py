"""Continuous batching: a request queue over the engine's slot pool.

The engine decodes a fixed batch of B slots every step; the scheduler
keeps those slots full.  Each loop iteration — one `tick()` — it
(1) admits queued requests into free slots, (2) runs one engine decode
step for the slots already past their prompt, (3) prefills admitted
prompts in chunks — one compiled multi-token program per selected slot
(slot index traced, so all slots share the program), under a
per-iteration prompt-token budget so one long prompt cannot starve
decode latency for in-flight slots — and (4) harvests slots whose
request hit EOS or its generation budget, freeing them for the next
admission.  Requests of different prompt/output lengths therefore
interleave in the same decode batch instead of padding to a common
length — the classic continuous-batching win — and a newly admitted
request reaches its first token after ceil(prompt/chunk) prefill
programs instead of `prompt` engine steps.

Two drivers share that iteration:

  - `run()` — the batch API: drive tick() until the queue drains and
    every slot is idle, then return {rid: Completion}.  This is the
    original blocking loop, byte for byte — tick() is the refactored
    body, not a new policy.
  - `serve_forever()` — the online API: a long-lived loop for a server
    frontend.  submit() is thread-safe, so requests can arrive from
    HTTP handler threads WHILE decode is running; each request may
    carry a per-token `on_token` callback, so tokens stream out of the
    harvest phase as they are sampled instead of only at completion;
    and when no slot is live the loop parks on an event (woken by the
    next submit) instead of spinning — an idle server burns no CPU
    dispatching no-op steps.

All policy lives host-side in this module; the engine's prefill and
decode kernels each stay a single compiled program.  Admission is
FIFO; slots are filled greedily; the prefill budget is spent in FIFO
admission order.  With engines built prefill_chunk=0 the scheduler
degrades to the per-token teacher-forcing path unchanged.

The scheduler is placement-oblivious: slot state is replicated on
every mesh device, so admission, harvest, and the prefill budget work
identically over a single-device engine and a member-sharded
(mesh=...) one — the member axis is the engine's concern, never the
queue's.  Straggler handling composes the same way: engine.set_quorum
drops a member mid-stream with no recompile and no rescheduling.

Over a PAGED engine (engine.paged) two policies change shape, both
still host-side: admission bounds by FREE PAGES rather than free slots
(strictly FIFO — a request that doesn't fit blocks the ones behind it,
so short requests cannot starve a long one), and when the free list
runs dry mid-decode the YOUNGEST in-flight request is preempted back
to the front of the queue (_ensure_decode_pages) — the oldest request
never loses its pages, so completion order stays FIFO, nothing
starves, and a preempted request simply regenerates on re-admission
(bit-identical under greedy sampling).  A preempted STREAMING request
does not re-emit: the per-request streamed counter survives
preemption, so re-generated tokens are skipped until the stream's
high-water mark and on_token sees each index exactly once (exactly the
greedy-regeneration contract; with temperature > 0 a preempted stream
may diverge from its already-emitted prefix — prefer temperature=0 for
streaming under memory pressure).

Requests can also leave early: `cancel(rid)` (thread-safe) marks a
request abandoned — a queued one is dropped before it can admit, a
live one releases its slot, pages, and prefix-trie references at the
next tick boundary through the same path preemption uses, so
cancellation composes with preemption, prefix sharing (refcount
decrements), and the speculative engine's rollback.  The HTTP frontend
drives this from client disconnects mid-SSE.

Threading contract: ONE thread drives tick()/run()/serve_forever();
any number of threads may call submit()/cancel()/stop().  Slot state,
completions, and the engine are touched only by the driving thread;
callbacks (on_token/on_done) fire on the driving thread, so they must
be quick and non-blocking (push to a queue, set an event).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import numpy as np

from repro.serving import obs as obs_mod
from repro.serving.engine import EnsembleEngine

# on_token(rid, index, token_id) — fired per generated token, in order
TokenCallback = Callable[[int, int, int], None]
# on_done(completion) — fired once, after the last on_token
DoneCallback = Callable[["Completion"], None]


@dataclass
class Request:
    rid: int
    tokens: np.ndarray
    max_new: int
    submit_t: float
    on_token: Optional[TokenCallback] = field(default=None, repr=False)
    on_done: Optional[DoneCallback] = field(default=None, repr=False)
    # per-request sampling / speculation overrides; None = engine
    # default.  Validated at the submit() door against the named limits
    # in serving/sampling.py (and the model's vocab for top_k).
    temperature: Optional[float] = None
    top_k: Optional[int] = None
    seed: Optional[int] = None
    draft: Optional[bool] = None


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray          # generated ids (prompt not included)
    prompt_len: int
    submit_t: float
    admit_t: float
    first_token_t: Optional[float]
    finish_t: float
    # the request's span chain (obs.Trace.to_dict()) when the
    # scheduler's observability layer is on; None under obs=False
    trace: Optional[dict] = None

    @property
    def ttft(self) -> float:
        """Submit -> first generated token (queue wait + prefill)."""
        first = (self.first_token_t if self.first_token_t is not None
                 else self.finish_t)  # `or` would drop a valid 0.0 stamp
        return first - self.submit_t

    @property
    def latency(self) -> float:
        return self.finish_t - self.submit_t


@dataclass
class _SlotMeta:
    req: Request
    admit_t: float
    first_token_t: Optional[float] = None
    prefill_left: int = 0       # prompt tokens not yet prefilled
    prefill_chunks: int = 0     # chunk programs run (trace span index)
    n_seen: int = 0             # tokens observed by harvest so far
    last_token_m: Optional[float] = None  # monotonic last-token stamp


class Scheduler:
    """FIFO continuous-batching scheduler over one EnsembleEngine.

    submit() queues a request (validated against the engine's budgets
    at the door; thread-safe); one tick() runs a single
    admit -> decode -> prefill -> harvest iteration.  run() drives
    tick() until the queue drains, returning {rid: Completion} — the
    batch API.  serve_forever() drives tick() until stop(), idling on
    an event while no work is live — the online API a server frontend
    mounts.  Both work unchanged over any engine placement
    (single-device or mesh) and any prefill_chunk, including the 0
    reference baseline.

    prefill_budget caps how many prompt tokens may enter prefill
    programs per loop iteration (default: 2 chunks).  One chunk is
    always allowed, so a single over-budget prompt still progresses.

    retain_completions=False drops each Completion after its on_done
    fires instead of keeping it in .completions — REQUIRED for a
    long-lived serve_forever loop, where retaining every token array
    forever is an unbounded leak.  The batch run() contract (read
    results out of .completions) needs the default True.
    """

    def __init__(self, engine: EnsembleEngine,
                 prefill_budget: Optional[int] = None,
                 retain_completions: bool = True,
                 obs=True, trace_keep: int = 512,
                 trace_log: Optional[str] = None,
                 profile_dir: Optional[str] = None):
        self.engine = engine
        # observability is ON by default; obs=False is the kill-switch
        # (serving_bench --obs gates its decode cost at <2%).  Pass a
        # prebuilt ServingObs to share or customize one.
        if obs is True:
            self.obs: Optional[obs_mod.ServingObs] = obs_mod.ServingObs(
                trace_keep=trace_keep, trace_log=trace_log)
        elif obs:
            self.obs = obs
        else:
            self.obs = None
        self.profile_dir = profile_dir
        # SpeculativeEngine's live host mirror of which slots draft —
        # harvest stamps spec_step(accepted) spans off it
        self._spec_draft = (getattr(engine, "_host_draft", None)
                            if hasattr(engine, "spec_stats") else None)
        self.prefill_budget = (2 * engine.prefill_chunk
                               if prefill_budget is None else prefill_budget)
        self.retain_completions = retain_completions
        self.pending: deque = deque()
        self.slots: list = [None] * engine.n_slots  # Optional[_SlotMeta]
        self.completions: Dict[int, Completion] = {}
        self.n_completed = 0  # lifetime count (survives non-retention)
        self._next_rid = 0
        self._to_release: list = []
        self.preemptions = 0     # paged: decode-time evictions to queue
        self.peak_in_flight = 0  # max concurrently admitted requests
        self.n_streamed = 0      # tokens delivered through on_token
        self.n_cancelled = 0     # requests cancelled before completion
        # per-rid stream high-water mark: survives preemption so a
        # re-generated (greedy-identical) prefix is never re-emitted
        self._streamed: Dict[int, int] = {}
        # rids cancel() has marked; the loop thread applies them at the
        # next tick boundary (queue removal or slot+page release)
        self._cancel_req: set = set()
        # submit() may be called from any thread while ONE loop thread
        # drives tick(); the lock guards rid allocation + enqueue, the
        # event wakes an idle serve_forever out of its park
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        # set by serve_forever when it parks with nothing queued, live,
        # or pending release — the observable "quiesced" state
        # wait_quiesced blocks on (event-based drain/idle checks
        # instead of wall-clock sleeps)
        self._idle = threading.Event()

    # -- submission ---------------------------------------------------------

    def submit(self, tokens, max_new: int,
               on_token: Optional[TokenCallback] = None,
               on_done: Optional[DoneCallback] = None,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None,
               seed: Optional[int] = None,
               draft: Optional[bool] = None) -> int:
        """Queue a request; returns its id (keyed in .completions).

        Validates against the engine's budgets HERE so one oversized
        request is rejected at the door instead of crashing the loop
        and taking every in-flight request down with it.  Thread-safe:
        HTTP handler threads submit while serve_forever decodes.

        temperature/top_k/seed override the engine-wide sampling
        defaults for THIS request (None keeps the default; out-of-range
        values are rejected here against the named limits in
        serving/sampling.py).  draft toggles speculative decoding per
        request on a SpeculativeEngine (plain engines ignore it).

        on_token(rid, index, token_id) streams each generated token
        from the harvest that first observes it; on_done(completion)
        fires once after the last token.  Both run on the loop thread —
        keep them non-blocking.
        """
        t = self.engine.validate_request(tokens, max_new,
                                         temperature=temperature,
                                         top_k=top_k, seed=seed)
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            if self.obs is not None:
                # start the trace BEFORE the request is visible to the
                # loop thread, so the admit path always finds it
                self.obs.traces.start(rid).add("enqueued")
            self.pending.append(Request(
                rid, t, int(max_new), time.time(),
                on_token=on_token, on_done=on_done,
                temperature=temperature, top_k=top_k, seed=seed,
                draft=draft))
        self._idle.clear()
        self._wake.set()
        return rid

    def cancel(self, rid: int) -> bool:
        """Abandon a request mid-flight (client disconnect, shed load).
        Thread-safe; the loop thread applies it at the next tick
        boundary: a queued request is removed before it can admit, a
        live one releases its slot, its pages, and any prefix-trie
        references mid-decode (or mid-prefill-chunk) through the same
        release path preemption uses — so cancellation composes with
        preemption, COW sharing, and the spec engine's rollback for
        free.  No Completion is delivered and no callback fires.

        -> False when the rid is already finished (or unknown): the
        race where the last token beat the disconnect is benign — the
        completed slot was already harvested — so callers need not
        distinguish.  Cancelling an already-cancelled rid is a no-op.
        """
        with self._lock:
            found = any(r.rid == rid for r in self.pending) or any(
                m is not None and m.req.rid == rid for m in self.slots)
            if found:
                self._cancel_req.add(int(rid))
        self._wake.set()
        return found

    def _apply_cancels(self):
        """Loop-thread half of cancel(): drop marked rids from the
        queue, release marked live slots.  Runs at the top of tick()
        (a cancelled queued request must never admit) and again from
        the harvest path (a cancel that lands mid-tick frees its pages
        this iteration, not the next).  Unknown rids — completed or
        cancelled while the request raced to done — dissolve here."""
        with self._lock:
            if not self._cancel_req:
                return
            wanted, self._cancel_req = self._cancel_req, set()
        survivors = [r for r in self.pending if r.rid not in wanted]
        if len(survivors) != len(self.pending):
            self.n_cancelled += len(self.pending) - len(survivors)
            for r in self.pending:
                if r.rid in wanted:
                    self._trace_cancelled(r.rid)
            self.pending = deque(survivors)
        for b, meta in enumerate(self.slots):
            if meta is not None and meta.req.rid in wanted:
                self.slots[b] = None
                self._to_release.append(b)
                self._streamed.pop(meta.req.rid, None)
                self.n_cancelled += 1
                self._trace_cancelled(meta.req.rid)

    def _trace_cancelled(self, rid: int):
        if self.obs is None:
            return
        tr = self.obs.traces.live(rid)
        if tr is not None:
            tr.add("cancelled")
            self.obs.retire(tr)

    # -- scheduling loop ----------------------------------------------------

    def _fill_slots(self):
        admits = []
        now = time.time()
        chunked = self.engine.prefill_chunk > 0
        avail = 0
        if self.engine.paged:
            # pages the combined release+admit dispatch below can hand
            # out: free list + evictable prefix pages + what the slots
            # being released in the same call certainly return
            # (update_slots recycles before it admits)
            avail = self.engine.admission_headroom(self._to_release)
        for b in range(self.engine.n_slots):
            if self.slots[b] is None and self.pending:
                nxt = self.pending[0]
                if self.engine.paged:
                    # admit by free pages, not free slots — and strictly
                    # FIFO (no skip-ahead past a request that does not
                    # fit: that is how short requests would starve a
                    # long one forever).  admit_cost charges only the
                    # non-shared suffix when the prefix cache holds
                    # pages a live slot already references.
                    need = self.engine.admit_cost(nxt.tokens)
                    if need > avail:
                        break
                    avail -= need
                req = self.pending.popleft()
                # only explicitly-set options ride along, so an unset
                # draft flag takes the ENGINE's default (plain: off,
                # speculative: on)
                opts = {k: v for k, v in (
                    ("temperature", req.temperature),
                    ("top_k", req.top_k), ("seed", req.seed),
                    ("draft", req.draft)) if v is not None}
                admits.append((b, req.tokens, req.max_new, opts))
                self.slots[b] = _SlotMeta(
                    req, now,
                    prefill_left=len(req.tokens) if chunked else 0)
                if self.obs is not None:
                    tr = self.obs.traces.live(req.rid)
                    if tr is not None:
                        if tr.has("preempted"):
                            tr.add("resumed")
                        else:
                            tr.add("admitted")
                            self.obs.queue_wait.observe(
                                obs_mod.MONO() - tr.t0)
        if admits or self._to_release:
            hits = self.engine.update_slots(
                release=self._to_release, admits=admits)
            self._to_release = []
            if chunked:
                # prefix-cache hits skip prefill for the shared prefix:
                # the slot starts its chunk walk at the hit boundary,
                # so it owes only the non-shared suffix
                for b, hit in hits.items():
                    if self.slots[b] is not None and hit > 0:
                        self.slots[b].prefill_left = max(
                            self.slots[b].prefill_left - int(hit), 1)
                        if self.obs is not None:
                            tr = self.obs.traces.live(
                                self.slots[b].req.rid)
                            if tr is not None:
                                tr.add("prefix_hit", int(hit))
        self.peak_in_flight = max(self.peak_in_flight, self.live_slots)

    def _ensure_decode_pages(self):
        """Grow decoding slots' page chains before the step; when the
        free list runs dry, PREEMPT the youngest in-flight request
        (highest rid) back to the front of the queue and retry.

        Preempting youngest-first keeps completion order FIFO and
        starvation-free: the oldest request never loses its pages to a
        newer one, so it always progresses (alone, it always fits —
        submit() rejects requests larger than the whole pool).  A
        preempted request restarts from scratch on re-admission; with
        greedy sampling its tokens are bit-identical, it just pays the
        queue again (counted in .preemptions and its ttft/latency).
        """
        if not self.engine.paged:
            return
        while True:
            starved = self.engine.reserve_decode_pages()
            if not starved:
                return
            live = [b for b, m in enumerate(self.slots) if m is not None]
            victim = max(live, key=lambda b: self.slots[b].req.rid)
            meta = self.slots[victim]
            self.engine.update_slots(release=[victim])
            self.slots[victim] = None
            # every queued rid is younger than every in-flight rid, so
            # appendleft re-sorts the queue into submission order
            self.pending.appendleft(meta.req)
            self.preemptions += 1
            if self.obs is not None:
                tr = self.obs.traces.live(meta.req.rid)
                if tr is not None:
                    tr.add("preempted")

    def _run_prefill(self) -> int:
        """Spend the iteration's prefill budget in admission (FIFO)
        order — one chunk program per selected slot.  -> programs run."""
        chunk = self.engine.prefill_chunk
        if chunk <= 0:
            return 0
        spent = ran = 0
        waiting = sorted(
            (b for b, m in enumerate(self.slots)
             if m is not None and m.prefill_left > 0),
            key=lambda b: self.slots[b].req.rid)
        for b in waiting:
            meta = self.slots[b]
            take = min(meta.prefill_left, chunk)
            if spent and spent + take > self.prefill_budget:
                break  # over budget; first selection always proceeds
            self.engine.prefill(b)
            spent += take
            ran += 1
            meta.prefill_left -= take
            if self.obs is not None:
                tr = self.obs.traces.live(meta.req.rid)
                if tr is not None:
                    tr.add("prefill_chunk", meta.prefill_chunks)
            meta.prefill_chunks += 1
        return ran

    def _decode_ready(self) -> bool:
        return any(m is not None and m.prefill_left == 0
                   for m in self.slots)

    def _stream(self, meta: _SlotMeta, n_gen: int, out_row: np.ndarray):
        """Emit tokens [high-water, n_gen) of one live slot through the
        request's on_token, in order.  The per-rid counter survives
        preemption, so a re-generated prefix is skipped, not re-sent."""
        req = meta.req
        seen = self._streamed.get(req.rid, 0)
        for i in range(seen, int(n_gen)):
            req.on_token(req.rid, i, int(out_row[i]))
        if n_gen > seen:
            self._streamed[req.rid] = int(n_gen)
            self.n_streamed += int(n_gen) - seen

    def _harvest(self):
        self._apply_cancels()  # free cancelled slots this iteration
        st = self.engine.state
        # ONE device transfer per iteration: finished slots' outputs ride
        # along with the done/n_gen flags instead of a per-slot fetch
        done, n_gen, out = jax.device_get((st.done, st.n_gen, st.out))
        now = time.time()
        obs = self.obs
        now_m = obs_mod.MONO() if obs is not None else 0.0
        for b, meta in enumerate(self.slots):
            if meta is None:
                continue
            first = meta.first_token_t is None and n_gen[b] > 0
            if first:
                meta.first_token_t = now
            if meta.req.on_token is not None and n_gen[b] > 0:
                self._stream(meta, n_gen[b], out[b])
            if obs is not None:
                n_new = int(n_gen[b]) - meta.n_seen
                if n_new > 0:
                    tr = obs.traces.live(meta.req.rid)
                    if first:
                        if tr is not None:
                            tr.add("first_token")
                            obs.ttft.observe(now_m - tr.t0)
                    elif (tr is not None and self._spec_draft is not None
                          and self._spec_draft[b]):
                        # one speculative iteration emitted n_new
                        # tokens: n_new-1 accepted drafts + the
                        # verifier's own token
                        tr.add("spec_step", n_new - 1)
                    if meta.last_token_m is not None:
                        dt = (now_m - meta.last_token_m) / n_new
                        for _ in range(n_new):
                            obs.inter_token.observe(dt)
                    meta.last_token_m = now_m
                    meta.n_seen = int(n_gen[b])
            if done[b]:
                req = meta.req
                comp = Completion(
                    rid=req.rid,
                    tokens=out[b, :n_gen[b]].copy(),
                    prompt_len=len(req.tokens),
                    submit_t=req.submit_t, admit_t=meta.admit_t,
                    first_token_t=meta.first_token_t, finish_t=now)
                if obs is not None:
                    tr = obs.traces.live(req.rid)
                    if tr is not None:
                        tr.add("done")
                        obs.latency.observe(now_m - tr.t0)
                        comp.trace = tr.to_dict()
                        obs.retire(tr)
                if self.retain_completions:
                    self.completions[req.rid] = comp
                self.n_completed += 1
                self.slots[b] = None
                self._to_release.append(b)
                self._streamed.pop(req.rid, None)
                if req.on_done is not None:
                    req.on_done(comp)

    # -- drivers ------------------------------------------------------------

    @property
    def live_slots(self) -> int:
        """Slots currently holding an admitted request."""
        return sum(m is not None for m in self.slots)

    @property
    def has_work(self) -> bool:
        return bool(self.pending) or self.live_slots > 0

    def _flush_release(self):
        """Return harvested slots' pages/slots without waiting for the
        next admission to batch the dispatch — an idle or draining
        server must not sit on freed capacity."""
        if self._to_release:
            t0 = obs_mod.MONO() if self.obs is not None else 0.0
            self.engine.update_slots(release=self._to_release)
            self._to_release = []
            if self.obs is not None:
                self.obs.ticks.add("release", obs_mod.MONO() - t0)

    def profile_next_ticks(self, ticks: int,
                           out_dir: Optional[str] = None):
        """Arm a jax.profiler window over the next `ticks` tick()
        calls (POST /admin/profile drives this).  out_dir defaults to
        the profile_dir the scheduler was built with."""
        if self.obs is None:
            raise RuntimeError("observability disabled (obs=False)")
        self.obs.ticks.arm_profile(ticks, out_dir or self.profile_dir)

    def tick(self) -> bool:
        """One admit -> decode -> prefill -> harvest iteration — the
        body run() always looped over, now reentrant so a long-lived
        server loop can interleave it with submits from other threads.
        Returns whether any engine program was dispatched (False means
        the caller may idle).

        With observability on, each phase's wall time lands in
        obs.ticks (repro_serving_tick_phase_seconds_total on /metrics);
        the obs=False path below is the untimed kill-switch baseline
        the <2% overhead gate compares against.
        """
        if self.obs is None:
            self._apply_cancels()  # cancelled queued request never admits
            self._fill_slots()
            stepped = False
            if self._decode_ready():  # skip decode while all mid-prompt
                self._ensure_decode_pages()  # paged: grow or preempt
                if self._decode_ready():     # preemption may empty set
                    self.engine.step()
                    stepped = True
            prefilled = self._run_prefill()
            self._harvest()
            return stepped or prefilled > 0
        tp = self.obs.ticks
        tp.tick_begin()              # opens an armed profiler window
        t0 = obs_mod.MONO()
        self._apply_cancels()
        self._fill_slots()
        t1 = obs_mod.MONO()
        tp.add("admit", t1 - t0)
        stepped = False
        if self._decode_ready():
            self._ensure_decode_pages()
            if self._decode_ready():
                self.engine.step()
                stepped = True
            t2 = obs_mod.MONO()
            tp.add("decode", t2 - t1)
            t1 = t2
        prefilled = self._run_prefill()
        t2 = obs_mod.MONO()
        tp.add("prefill", t2 - t1)
        self._harvest()
        tp.add("harvest", obs_mod.MONO() - t2)
        tp.ticks += 1
        tp.tick_end()
        return stepped or prefilled > 0

    def run(self) -> Dict[int, Completion]:
        """Drive until the queue drains and every slot is idle — the
        batch API, a thin wrapper over tick().

        Within a tick, decode runs BEFORE prefill: the harvest stamp
        then directly follows any first token a prefill program just
        produced, so reported TTFT is not inflated by an unrelated
        decode step dispatched after it.
        """
        while self.has_work:
            self.tick()
        self._flush_release()
        return self.completions

    def serve_forever(self, idle_wait: float = 0.05):
        """Drive tick() until stop(): the online loop a server frontend
        runs on its own thread.  While no request is queued or live the
        loop flushes releases and parks on an event — submit() wakes it
        — so an idle server dispatches nothing and burns no CPU
        (idle_wait bounds the park so stop() is always honored).

        The stop latch is NOT cleared here: a stop() that races thread
        startup must win, not be erased by the loop's first line.  To
        restart a stopped scheduler, clear the latch first
        (`clear_stop`) — Replica.start does.
        """
        while not self._stop.is_set():
            if self.has_work:
                self._idle.clear()
                self.tick()
            else:
                self._flush_release()
                self._apply_cancels()  # queue-only cancels while parked
                if not self.has_work:  # a cancel can't create work, but
                    self._idle.set()   # a racing submit can
                self._wake.wait(idle_wait)
                self._wake.clear()
        self._flush_release()
        self._idle.set()

    def wait_quiesced(self, timeout: float = 120.0) -> bool:
        """Block until the serve_forever loop has parked with nothing
        queued, live, or awaiting release — i.e. every admitted page is
        back in the pool, not merely every request delivered.  Event-
        based: the loop signals its own park, so tests and drains wait
        on the actual state transition instead of sleeping fixed
        wall-clock intervals and hoping the loop got there.  Returns
        False on timeout (and when no loop is running to signal)."""
        deadline = time.time() + timeout
        while True:
            remaining = deadline - time.time()
            if remaining <= 0:
                return False
            if not self._idle.wait(min(remaining, 0.05)):
                continue
            # the flag can be stale for one race window: a submit that
            # landed after the park clears it and re-wakes the loop
            if not self.has_work and not self._to_release:
                return True

    def stop(self):
        """Ask serve_forever to exit after its current iteration.
        In-flight slot state is left intact (drain first to finish it:
        wait for has_work to clear while the loop still runs).  The
        latch persists: a serve_forever entered AFTER stop() exits
        immediately, so stopping can never lose the race with a
        starting loop thread."""
        self._stop.set()
        self._wake.set()

    def clear_stop(self):
        """Re-arm a stopped scheduler so serve_forever runs again.
        Call strictly BEFORE spawning the new loop thread."""
        self._stop.clear()
