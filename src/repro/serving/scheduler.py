"""Continuous batching: a request queue over the engine's slot pool.

The engine decodes a fixed batch of B slots every step; the scheduler
keeps those slots full.  Each loop iteration it (1) admits queued
requests into free slots (per-slot prompt prefill is teacher-forced
inside the engine step, so admission is just a masked state write +
cache-slot reset), (2) runs one engine step, and (3) harvests slots
whose request hit EOS or its generation budget, freeing them for the
next admission.  Requests of different prompt/output lengths therefore
interleave in the same decode batch instead of padding to a common
length — the classic continuous-batching win.

All policy lives host-side in this module; the engine step stays a
single compiled program.  Admission is FIFO; slots are filled greedily.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.serving.engine import EnsembleEngine


@dataclass
class Request:
    rid: int
    tokens: np.ndarray
    max_new: int
    submit_t: float


@dataclass
class Completion:
    rid: int
    tokens: np.ndarray          # generated ids (prompt not included)
    prompt_len: int
    submit_t: float
    admit_t: float
    first_token_t: Optional[float]
    finish_t: float

    @property
    def ttft(self) -> float:
        """Submit -> first generated token (queue wait + prefill)."""
        return (self.first_token_t or self.finish_t) - self.submit_t

    @property
    def latency(self) -> float:
        return self.finish_t - self.submit_t


@dataclass
class _SlotMeta:
    req: Request
    admit_t: float
    first_token_t: Optional[float] = None


class Scheduler:
    """FIFO continuous-batching scheduler over one EnsembleEngine."""

    def __init__(self, engine: EnsembleEngine):
        self.engine = engine
        self.pending: deque = deque()
        self.slots: list = [None] * engine.n_slots  # Optional[_SlotMeta]
        self.completions: Dict[int, Completion] = {}
        self._next_rid = 0
        self._to_release: list = []

    # -- submission ---------------------------------------------------------

    def submit(self, tokens, max_new: int) -> int:
        """Queue a request; returns its id (keyed in .completions).

        Validates against the engine's budgets HERE so one oversized
        request is rejected at the door instead of crashing run() and
        taking every in-flight request down with it.
        """
        t = self.engine.validate_request(tokens, max_new)
        rid = self._next_rid
        self._next_rid += 1
        self.pending.append(Request(rid, t, int(max_new), time.time()))
        return rid

    # -- scheduling loop ----------------------------------------------------

    def _fill_slots(self):
        admits = []
        now = time.time()
        for b in range(self.engine.n_slots):
            if self.slots[b] is None and self.pending:
                req = self.pending.popleft()
                admits.append((b, req.tokens, req.max_new))
                self.slots[b] = _SlotMeta(req, now)
        if admits or self._to_release:
            self.engine.update_slots(release=self._to_release, admits=admits)
            self._to_release = []

    def _harvest(self):
        st = self.engine.state
        done = np.asarray(st.done)      # the per-step host sync point
        n_gen = np.asarray(st.n_gen)
        now = time.time()
        for b, meta in enumerate(self.slots):
            if meta is None:
                continue
            if meta.first_token_t is None and n_gen[b] > 0:
                meta.first_token_t = now
            if done[b]:
                req = meta.req
                self.completions[req.rid] = Completion(
                    rid=req.rid,
                    tokens=np.asarray(st.out[b, :n_gen[b]]),
                    prompt_len=len(req.tokens),
                    submit_t=req.submit_t, admit_t=meta.admit_t,
                    first_token_t=meta.first_token_t, finish_t=now)
                self.slots[b] = None
                self._to_release.append(b)

    def run(self) -> Dict[int, Completion]:
        """Drive until the queue drains and every slot is idle."""
        while self.pending or any(m is not None for m in self.slots):
            self._fill_slots()
            self.engine.step()
            self._harvest()
        if self._to_release:
            self.engine.update_slots(release=self._to_release)
            self._to_release = []
        return self.completions
