"""Draft-acceptance rules for ensemble-speculative decoding.

Pure jnp over already-computed distributions; no model code.  The
verify pass hands in the fused Eqn-6 log-probs at every chunk position
(fused[:, j] is the ensemble's next-token distribution AFTER consuming
chunk entry j) and the student's proposal distributions; these helpers
decide how many drafted tokens survive.

Greedy (the default serving mode): a draft d_{j+1} is accepted iff it
equals the fused argmax c_j, so the emitted tokens are EXACTLY the
greedy chain of the fused ensemble — speculation changes the schedule,
never the text (the --spec bench gate pins this bit-identically).

Stochastic (behind SpeculativeEngine(spec_sampling=True)): classic
rejection sampling — accept d w.p. min(1, p(d)/q(d)) against the
tempered target p and proposal q, resample rejections from the
normalized residual max(p - q, 0), and draw the free bonus token from
the full target when every draft survives; the emitted tokens are then
distributed exactly as sequential sampling from p.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy_accept(drafts: jax.Array, choice: jax.Array) -> jax.Array:
    """Longest accepted prefix under greedy agreement.

    drafts: (B, G) proposed tokens d_1..d_G; choice: (B, >= G) fused
    greedy choices, choice[:, j] = c_j = argmax of the fused
    distribution after consuming chunk entry j.  d_{j+1} survives iff
    it matches c_j AND every earlier draft survived.  -> (B,) int32
    accepted count a in [0, G].
    """
    G = drafts.shape[1]
    agree = (drafts == choice[:, :G]).astype(jnp.int32)
    return jnp.cumprod(agree, axis=1).sum(axis=1)


def stochastic_accept(u: jax.Array, drafts: jax.Array,
                      target_lp: jax.Array,
                      draft_lp: jax.Array) -> jax.Array:
    """Rejection-sampling acceptance: accept d_{j+1} iff
    u_j < p_j(d_{j+1}) / q_j(d_{j+1}).

    u: (B, G) uniforms; drafts: (B, G); target_lp: (B, >= G, V) fused
    log-probs (position j is the target for d_{j+1}); draft_lp:
    (B, G, V) proposal log-probs.  -> (B,) int32 accepted count.
    """
    G = drafts.shape[1]
    g = drafts[..., None]
    lp_p = jnp.take_along_axis(target_lp[:, :G], g, axis=-1)[..., 0]
    lp_q = jnp.take_along_axis(draft_lp, g, axis=-1)[..., 0]
    acc = (u < jnp.exp(jnp.minimum(lp_p - lp_q, 0.0))).astype(jnp.int32)
    return jnp.cumprod(acc, axis=1).sum(axis=1)


def residual_log_probs(target_lp: jax.Array,
                       draft_lp: jax.Array) -> jax.Array:
    """log of normalize(max(p - q, 0)) — the rejection-resample law.

    target_lp / draft_lp: (..., V) log-probs.  Where the residual is
    empty (q covers p exactly, e.g. draft == target) falls back to the
    target itself, which is the correct limit: acceptance is then 1 and
    this branch is never drawn from, but categorical() still needs a
    finite row.
    """
    r = jnp.maximum(jnp.exp(target_lp) - jnp.exp(draft_lp), 0.0)
    rs = r.sum(axis=-1, keepdims=True)
    safe = jnp.where(rs > 1e-9, r / jnp.maximum(rs, 1e-9),
                     jnp.exp(target_lp))
    return jnp.log(jnp.maximum(safe, 1e-30))
