"""The draft side of ensemble-speculative decoding.

The distilled student (core/compression.py's EC-DNN_L output) runs as a
K=1 member stack through the SAME slot-indexed cache machinery as its
teachers: `propose` is the in-kernel drafting loop the speculative
engine traces (gamma+1 sequential per-slot decode steps building the
verify chunk), and `DraftEngine` serves the student stand-alone behind
the ordinary engine API — the reference the round-trip test checks the
in-kernel draft against token-exactly.

The draft pool is sized max_seq + gamma: the contiguous decode write
path CLAMPS out-of-range positions (unlike the chunked verify path,
which drops them), so without the slack a draft proposed past max_seq
would corrupt the last cache entry.  Clamp-free by construction beats
masked-after-the-fact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.serving import kv_cache, sampling
from repro.serving.engine import EnsembleEngine


def as_member_stack(params, like=None):
    """Student params -> a K=1 member stack (leading axis added).

    `like`: a stacked params tree of the same architecture (the
    teachers).  When given, params whose leaves already carry the
    member axis (matching ranks) pass through with K == 1 enforced;
    otherwise a leading length-1 axis is added to every leaf.  With
    like=None the params are taken as UNSTACKED.
    """
    if like is not None:
        l0 = jax.tree.leaves(params)[0]
        r0 = jax.tree.leaves(like)[0]
        if l0.ndim == r0.ndim:
            if l0.shape[0] != 1:
                raise ValueError(
                    f"draft stack carries K={l0.shape[0]} members; the "
                    f"draft model must be a single student (K=1)")
            return params
    return jax.tree.map(lambda x: jnp.asarray(x)[None], params)


def init_draft_pool(cfg, n_slots: int, max_seq: int, gamma: int) -> dict:
    """Slot-indexed K=1 cache pool for the draft, with the +gamma
    overdraft slack (module docstring).  Always contiguous: the draft
    is one small model, so paging its pool buys nothing — "optionally
    paged" in the design stays an option, not a requirement."""
    return kv_cache.init_pool(cfg, 1, n_slots, max_seq + gamma)


def propose(draft_params, cfg, cache: dict, tok: jax.Array, gamma: int,
            keys=None, temperature=None, top_k=None):
    """Draft gamma tokens per slot and materialize their KV.

    draft_params: K=1 member stack; cache: the draft pool (idx (1, B)
    == each spec row's position); tok: (B,) the last ACCEPTED token
    (the chunk's first entry).  Runs gamma+1 sequential per-slot decode
    steps: step j consumes chunk[j] at position idx+j and yields the
    proposal chunk[j+1]; the final step only materializes d_gamma's KV
    (its logits are discarded — the bonus token is the verifier's).

    keys=None drafts greedily (argmax); otherwise keys (B, gamma, 2)
    with per-row temperature/top_k (B,) sample each proposal from the
    tempered, top-k-masked student distribution — rows with
    temperature <= 0 stay greedy.

    -> (chunk (B, gamma+1), draft_lp (B, gamma, V) the log-probs each
    proposal was drawn from — None on the greedy path, where no
    rejection test ever reads them (argmax needs no normalization, so
    greedy skips gamma log_softmax passes) — and the cache with idx
    advanced by gamma+1).
    """
    cols, lps = [tok], []
    cur = tok
    for j in range(gamma + 1):
        def one(p, c):
            return tf.decode_step_slots(p, cfg, c, cur[:, None])

        lg, cache = jax.vmap(one)(draft_params, cache)  # (1, B, 1, V)
        if j == gamma:
            break
        row = lg[0, :, 0].astype(jnp.float32)
        nxt = row.argmax(axis=-1).astype(jnp.int32)
        if keys is not None:
            lp = jax.nn.log_softmax(row, axis=-1)
            stoch = temperature > 0.0
            masked = sampling.top_k_mask_rows(
                lp, jnp.where(stoch, top_k, 0))
            scaled = masked / jnp.maximum(temperature, 1e-6)[:, None]
            drawn = jax.vmap(jax.random.categorical)(keys[:, j], scaled)
            nxt = jnp.where(stoch, drawn.astype(jnp.int32), nxt)
            lp = jnp.where(stoch[:, None],
                           jax.nn.log_softmax(scaled, axis=-1), lp)
            lps.append(lp)
        cols.append(nxt)
        cur = nxt
    draft_lp = jnp.stack(lps, axis=1) if lps else None
    return jnp.stack(cols, axis=1), draft_lp, cache


class DraftEngine(EnsembleEngine):
    """The compressed student behind the full serving API, K = 1.

    Exists for two reasons: (a) the compress -> serve round-trip test
    pins that a student restored through checkpoint/store decodes
    token-exactly whether served directly (here) or as the in-kernel
    draft of its teachers; (b) a deployment without spare capacity for
    the ensemble serves the student alone through the identical path
    (the paper's EC-DNN_L mode).  Everything — continuous batching,
    paging, quorum (trivial at K=1) — is inherited unchanged.
    """

    def __init__(self, cfg, student_params, **kw):
        super().__init__(cfg, as_member_stack(student_params), **kw)
