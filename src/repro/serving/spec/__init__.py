"""Ensemble-speculative decoding: the distilled student drafts, its
teachers verify.

The paper's compression loop produces a single student imitating the
K-member global model (core/compression.py).  Serving keeps BOTH: the
student proposes gamma tokens per request per iteration (spec/draft.py)
and the full Eqn-6 fused ensemble scores every drafted position in one
batched pass (models/transformer.verify_*), accepting the longest
prefix on which the fused choice agrees (spec/verify.py).  Greedy
acceptance emits tokens bit-identical to the non-speculative fused
path; the ensemble pays its K-fold cost once per ACCEPTED RUN instead
of once per token.  spec/engine.SpeculativeEngine plugs the whole loop
into the serving stack behind the ordinary EnsembleEngine API.
"""
from repro.serving.spec.draft import DraftEngine, as_member_stack, propose
from repro.serving.spec.engine import SpeculativeEngine
from repro.serving.spec.verify import (greedy_accept, residual_log_probs,
                                       stochastic_accept)

__all__ = ["SpeculativeEngine", "DraftEngine", "as_member_stack",
           "propose", "greedy_accept", "stochastic_accept",
           "residual_log_probs"]
