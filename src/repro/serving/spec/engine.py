"""SpeculativeEngine: the distilled student drafts for its teachers.

One speculative iteration per request, all inside ONE jitted program
(the plain engine's one-program-per-token discipline, kept):

  1. DRAFT   — the K=1 student runs gamma+1 sequential per-slot decode
               steps (spec/draft.propose), building the verify chunk
               [tok, d_1..d_gamma] and materializing its own KV;
  2. VERIFY  — all K members score ALL gamma+1 chunk positions in one
               batched call (models/transformer.verify_slots, or
               verify_step_paged over the paged pool) and fuse per
               position via Eqn 6 — the same chunked scoring machinery
               as prefill, the same quorum vector, the same psum fusion
               on a member mesh;
  3. ACCEPT  — greedy: the longest prefix where each draft equals the
               fused argmax (emitted tokens are BIT-IDENTICAL to the
               non-speculative fused path); stochastic (flag):
               rejection sampling against the tempered fused target;
  4. ROLLBACK — cache entries past the accepted prefix are restored
               from a pre-step snapshot (serving/kv_cache
               .snapshot_positions / restore_positions) and both
               pools' idx rewind to pos + e; on the paged pool the
               host then reclaims pages past the accepted length
               (PageAllocator.truncate) and resyncs its position
               mirrors from the device.

Speculative member PRUNING rides the verify pass as a traced mask
(core/ensemble.prunable_members): members whose whole vote mass cannot
flip the fused argmax at a position are provably skippable.  Inside
the single fused kernel the mask prices the skip rather than shrinking
compute — it composes with the quorum vector and the shard_map member
mesh with zero extra collectives and surfaces as pruned_frac telemetry.

Why it pays: the fused ensemble's K-fold cost is per PROGRAM, not per
token — verifying gamma+1 positions in one program costs about one
decode dispatch, so e accepted tokens per iteration cut the ensemble's
per-token price by ~e.  The student is the natural free draft: the
compression loop already trains it to imitate exactly the distribution
the verifier fuses, so agreement — and thus acceptance — is what
distillation optimizes.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import sharding as shd
from repro.common.types import ModelConfig
from repro.core import ensemble as ens
from repro.models import transformer as tf
from repro.serving import kv_cache, sampling
from repro.serving.engine import EnsembleEngine, SlotState
from repro.serving.spec import draft as draft_mod
from repro.serving.spec import verify as verify_mod

# fold_in salts separating the speculative PRNG streams from the plain
# path's per-emission keys (fold_in(skey, n_gen)) and from each other
_SALT_DRAFT, _SALT_ACCEPT, _SALT_RESAMPLE = 0x5D1, 0x5D2, 0x5D3

# stats vector layout: [proposed, accepted, spec_steps, prunable_count,
# prunable_total, hist(e = 0..gamma+1)]
_N_HEAD = 5


class SpeculativeEngine(EnsembleEngine):
    """EnsembleEngine + a student draft model; same host API.

    draft_params: the compressed student — unstacked or a K=1 member
    stack.  By default it shares the members' architecture (the shape
    core/compression.py distills into); draft_cfg overrides that with a
    smaller config (fewer layers, the classic cheap-draft setup) as
    long as vocab and dtype match — acceptance then depends on how well
    the small student imitates the fused distribution.  gamma: drafted
    tokens per iteration.  spec_sampling=False (default) is greedy
    speculative decoding — emitted tokens bit-identical to the
    non-speculative fused path; True turns on rejection sampling for
    temperature>0 requests.

    Per-request opt-out: admit with {"draft": False} (scheduler
    Request.draft / HTTP body "draft") — those slots take the plain
    one-token path through the same kernel.  A batch with NO drafting
    slot dispatches the inherited plain step, so `--draft off` serving
    is bit-identical to today's engine, program for program.

    Gated to attention-only stacks (recurrent mixers carry no
    positional axis to roll back) with chunked prefill enabled (the
    verify pass IS chunked scoring).  The draft pool is contiguous,
    replicated on a member mesh (the student is one small model — every
    device re-runs it identically rather than sharding K=1 over M).
    """

    def __init__(self, cfg: ModelConfig, stacked_params, draft_params, *,
                 draft_cfg: Optional[ModelConfig] = None, gamma: int = 4,
                 spec_sampling: bool = False, **kw):
        if gamma < 1:
            raise ValueError(f"gamma must be >= 1, got {gamma}")
        if kw.get("prefix_cache"):
            # the draft pool is slot-contiguous: a prefix hit would skip
            # prefill for positions the DRAFT cache never saw, so the
            # student would draft from blank context.  Per-draft prefix
            # state is a follow-up (ROADMAP).
            raise ValueError("speculative serving does not support "
                             "prefix_cache (the draft cache is "
                             "slot-contiguous; hit-skipped positions "
                             "would leave it blank)")
        self.gamma = int(gamma)
        self.spec_sampling = bool(spec_sampling)
        super().__init__(cfg, stacked_params, **kw)
        self.draft_cfg = cfg if draft_cfg is None else draft_cfg
        if self.prefill_chunk <= 0:
            raise ValueError(
                "speculative serving needs chunked prefill "
                "(prefill_chunk > 0): the verify pass reuses it")
        if self.draft_cfg.vocab_size != cfg.vocab_size \
                or self.draft_cfg.dtype != cfg.dtype:
            raise ValueError(
                f"draft_cfg vocab/dtype "
                f"({self.draft_cfg.vocab_size}/{self.draft_cfg.dtype}) "
                f"must match the ensemble's "
                f"({cfg.vocab_size}/{cfg.dtype})")
        for c in (cfg, self.draft_cfg):
            if c.enc_dec:
                raise ValueError("speculative serving does not support "
                                 "enc-dec archs")
            for _, specs in c.segments():
                for spec in specs:
                    if spec.mixer not in ("attn", "attn_local") \
                            or spec.ffn == "rwkv_cmix":
                        raise ValueError(
                            f"speculative serving needs attention-only, "
                            f"rollback-able layers; got mixer="
                            f"{spec.mixer!r} ffn={spec.ffn!r}")
        self.draft_params = draft_mod.as_member_stack(
            draft_params, like=stacked_params)
        tpl = tf.init(jax.random.PRNGKey(0), self.draft_cfg)
        d_un = jax.tree.map(lambda x: x[0], self.draft_params)
        if jax.tree.structure(tpl) != jax.tree.structure(d_un):
            raise ValueError(
                "draft params do not have the draft architecture's tree "
                "structure — pass draft_cfg matching the student")
        for o, n in zip(jax.tree.leaves(tpl), jax.tree.leaves(d_un)):
            if o.shape != n.shape or o.dtype != n.dtype:
                raise ValueError(
                    f"draft leaf {n.shape}/{n.dtype} does not match the "
                    f"draft architecture's layout {o.shape}/{o.dtype}")
        self.draft_cache = draft_mod.init_draft_pool(
            self.draft_cfg, self.n_slots, self.max_seq, self.gamma)
        self.stats_vec = jnp.zeros((_N_HEAD + self.gamma + 2,),
                                   jnp.float32)
        if self.mesh is not None:
            rep = lambda t: jax.device_put(
                t, shd.make_shardings(self.mesh, shd.replicated_pspecs(t)))
            self.draft_params = rep(self.draft_params)
            self.draft_cache = rep(self.draft_cache)
            self.stats_vec = rep(self.stats_vec)
        # host mirrors: which slots hold live requests / draft-on
        # requests (the scheduler's 'any spec work?' dispatch test)
        self._host_draft = np.zeros(self.n_slots, bool)
        self._host_live = np.zeros(self.n_slots, bool)
        self.spec_steps_run = 0

        from jax.sharding import PartitionSpec as P
        pspec, cspec = (shd.member_pspecs(self.params),
                        shd.member_pspecs(self.cache))
        sspec = shd.replicated_pspecs(self.state)
        dp = shd.replicated_pspecs(self.draft_params)
        dc = shd.replicated_pspecs(self.draft_cache)
        q, s = P(shd.MEMBER_AXIS), P()
        self._spec = self._compile(
            self._spec_step_impl, donate=(2, 3, 4, 6),
            in_specs=(pspec, dp, cspec, dc, sspec, q, s),
            out_specs=(sspec, cspec, dc, s))
        self._dprefill = self._compile(
            self._draft_prefill_impl, donate=(1,),
            in_specs=(dp, dc, sspec, s), out_specs=dc)
        self._dreset = self._compile(
            lambda c, adm: kv_cache.reset_slots(c, adm), donate=(0,),
            in_specs=(dc, s), out_specs=dc)

    def _default_draft(self) -> bool:
        return True

    def _sync_each_step(self) -> bool:
        return True

    # -- jitted kernels -----------------------------------------------------

    def _row_keys(self, st: SlotState, salt: int, width: int) -> jax.Array:
        """(B, width, 2) per-row, per-offset keys for this iteration:
        fold_in(fold_in(fold_in(skey, salt), n_gen), j) — a pure
        function of request state, so a preempted-and-replayed request
        draws identically."""
        def one(k, n):
            base = jax.random.fold_in(jax.random.fold_in(k, salt), n)
            return jax.vmap(
                lambda j: jax.random.fold_in(base, j))(jnp.arange(width))
        return jax.vmap(one)(st.skey, st.n_gen)

    def _spec_step_impl(self, params, draft_params, cache, draft_cache,
                        st: SlotState, quorum, stats):
        """One speculative iteration for every slot, one program.

        Rows mix freely: spec rows (active, decoding, draft-on) draft
        and verify gamma+1 positions; draft-off decoding rows verify
        exactly one (the plain step, through the verify kernel); frozen
        rows (idle / mid-prompt / done) are bit-exact no-ops via
        n_tok=0 masking plus snapshot-restore of their draft window.
        """
        B = st.tok.shape[0]
        G, C = self.gamma, self.gamma + 1
        adv = st.active & ~st.done & (st.pos >= st.prompt_len)
        spec_row = adv & st.draft

        # snapshots BEFORE any write: the ensemble pool's next C ring
        # entries per row, and the draft pool's C entries at each row's
        # OWN draft idx (frozen rows' draft positions differ from
        # st.pos; the propose loop below dirties THEIR window, and a
        # ring plane's wrapped write would clobber live history — the
        # snapshot covers exactly what gets dirtied)
        snap = kv_cache.snapshot_positions(cache, st.pos, C)
        d_idx0 = draft_cache["idx"]
        d_start = d_idx0[0]
        dsnap = kv_cache.snapshot_positions(draft_cache, d_start, C)

        # -- 1. draft
        dkeys = temp = topk = None
        if self.spec_sampling:
            dkeys = self._row_keys(st, _SALT_DRAFT, G)
            temp, topk = st.temp, st.topk
        chunk, draft_lp, draft_cache = draft_mod.propose(
            draft_params, self.draft_cfg, draft_cache, st.tok, G,
            keys=dkeys, temperature=temp, top_k=topk)

        # -- 2. verify: every member scores all C positions at once
        n_val = jnp.where(spec_row, C,
                          jnp.where(adv, 1, 0)).astype(jnp.int32)
        if self.paged:
            def one(p, c):
                return tf.verify_step_paged(p, self.cfg, c, chunk, n_val)
        else:
            def one(p, c):
                return tf.verify_slots(p, self.cfg, c, chunk, n_val)
        lg, cache = jax.vmap(one)(params, cache)  # (K, B, C, V)
        if self.mesh is None:
            # single-device: one log_softmax pass feeds both the Eqn-6
            # fusion and the pruning test below
            mlp = ens.member_log_probs(lg)
            fused = ens.ensemble_log_probs(lg, weights=quorum,
                                           member_lp=mlp)
        else:
            mlp = None
            fused = self._fuse(lg, quorum)        # (B, C, V)
        choice = fused.argmax(axis=-1).astype(jnp.int32)

        # speculative member pruning (telemetry; see module docstring)
        qsum = quorum.sum()
        if self.mesh is not None:
            qsum = jax.lax.psum(qsum, shd.MEMBER_AXIS)
        wn = quorum / jnp.maximum(qsum, 1e-9)
        prunable = ens.prunable_members(lg, fused, wn,
                                        member_lp=mlp)  # (K_local, B, C)
        validp = spec_row[:, None] & (jnp.arange(C)[None, :]
                                      < n_val[:, None])
        pc = jnp.where(validp[None], prunable, False).sum() \
            .astype(jnp.float32)
        pt = jnp.float32(lg.shape[0]) * validp.sum().astype(jnp.float32)
        if self.mesh is not None:
            pc = jax.lax.psum(pc, shd.MEMBER_AXIS)
            pt = jax.lax.psum(pt, shd.MEMBER_AXIS)

        # -- 3. accept
        a = verify_mod.greedy_accept(chunk[:, 1:], choice)
        emit_tok = choice
        if self.spec_sampling:
            stoch = st.temp > 0.0
            f_t = self._tempered(fused, st, stoch)
            akeys = self._row_keys(st, _SALT_ACCEPT, G)
            u = jax.vmap(jax.vmap(
                lambda k: jax.random.uniform(k, ())))(akeys)
            a_s = verify_mod.stochastic_accept(u, chunk[:, 1:], f_t,
                                               draft_lp)
            a = jnp.where(stoch, a_s, a)
            a = jnp.where(spec_row, a, 0)
            # resample the first rejection from the residual; a == G
            # means every draft survived and the bonus token draws from
            # the full target.  Draft-off stochastic rows draw from the
            # tempered fused at position 0 with the PLAIN path's key,
            # so they match a non-speculative stochastic engine.
            aa = jnp.clip(a, 0, G)
            p_a = jnp.take_along_axis(
                f_t, aa[:, None, None], axis=1)[:, 0]
            q_a = jnp.take_along_axis(
                draft_lp, jnp.clip(aa, 0, G - 1)[:, None, None],
                axis=1)[:, 0]
            rep_lp = jnp.where((a >= G)[:, None], p_a,
                               verify_mod.residual_log_probs(p_a, q_a))
            rep_lp = jnp.where(spec_row[:, None], rep_lp, f_t[:, 0])
            rkeys = self._row_keys(st, _SALT_RESAMPLE, 1)[:, 0]
            plain = jax.vmap(jax.random.fold_in)(st.skey, st.n_gen)
            rkeys = jnp.where(spec_row[:, None], rkeys, plain)
            repl = jax.vmap(jax.random.categorical)(
                rkeys, rep_lp).astype(jnp.int32)
            drafts_pad = jnp.concatenate(
                [chunk[:, 1:], jnp.zeros((B, 1), jnp.int32)], axis=1)
            s_emit = jnp.where(jnp.arange(C)[None, :] < a[:, None],
                               drafts_pad, repl[:, None])
            emit_tok = jnp.where(stoch[:, None], s_emit, emit_tok)
        a = jnp.where(spec_row, a, 0)

        # -- clamps: e = tokens consumed/emitted this iteration
        e = a + 1
        e = jnp.minimum(e, jnp.maximum(n_val, 1))  # draft-off rows: 1
        rem = st.max_new - st.n_gen
        e = jnp.minimum(e, jnp.maximum(rem, 1))    # budget
        if self.eos_id >= 0:
            is_eos = emit_tok == self.eos_id
            eos_pos = jnp.where(is_eos.any(axis=1),
                                is_eos.argmax(axis=1), C)
            e = jnp.minimum(e, eos_pos + 1)        # stop AT first EOS
        e = jnp.where(adv, e, 0)

        # -- bookkeeping (the plain step's emit logic, e tokens wide)
        G_out = st.out.shape[1]
        relp = jnp.arange(G_out)[None, :] - st.n_gen[:, None]
        take = (relp >= 0) & (relp < e[:, None])
        vals = jnp.take_along_axis(emit_tok, jnp.clip(relp, 0, C - 1),
                                   axis=1)
        out = jnp.where(take, vals, st.out)
        n_gen = st.n_gen + e
        last = jnp.take_along_axis(
            emit_tok, jnp.clip(e - 1, 0, C - 1)[:, None], axis=1)[:, 0]
        tok = jnp.where(adv, last, st.tok)
        finished = adv & (n_gen >= st.max_new)
        if self.eos_id >= 0:
            finished |= adv & (last == self.eos_id)
        done = st.done | finished
        pos1 = st.pos + e

        # -- 4. rollback past the accepted prefix
        keep = jnp.where(adv, e, 0)
        cache = kv_cache.restore_positions(cache, snap, st.pos, keep)
        cache["idx"] = jnp.broadcast_to(
            jnp.where(adv, pos1, st.pos)[None, :], cache["idx"].shape)
        keep_d = jnp.where(spec_row, e, 0)
        draft_cache = kv_cache.restore_positions(draft_cache, dsnap,
                                                 d_start, keep_d)
        draft_cache["idx"] = jnp.where(spec_row[None, :], pos1[None, :],
                                       d_idx0)

        # -- stats
        sp = spec_row.astype(jnp.float32)
        head = jnp.stack([
            sp.sum() * G,                                  # proposed
            ((e.astype(jnp.float32) - 1.0) * sp).sum(),    # accepted
            jnp.asarray(1.0, jnp.float32),                 # spec steps
            pc, pt])
        hist = (jax.nn.one_hot(jnp.clip(e, 0, G + 1), G + 2)
                * sp[:, None]).sum(axis=0)
        stats = stats + jnp.concatenate([head, hist])

        return st._replace(tok=tok, pos=pos1, n_gen=n_gen, done=done,
                           out=out), cache, draft_cache, stats

    def _tempered(self, fused, st: SlotState, stoch) -> jax.Array:
        """Per-row tempered + top-k-masked target log-probs
        (B, C, V); rows with temperature <= 0 ride through raw."""
        B, C, V = fused.shape
        flat = fused.reshape(B * C, V)
        kk = jnp.repeat(jnp.where(stoch, st.topk, 0), C)
        tt = jnp.repeat(jnp.maximum(st.temp, 1e-6), C)
        m = sampling.top_k_mask_rows(flat, kk) / tt[:, None]
        out = jax.nn.log_softmax(m, axis=-1).reshape(B, C, V)
        return jnp.where(stoch[:, None, None], out, fused)

    def _draft_prefill_impl(self, draft_params, draft_cache,
                            st: SlotState, slot):
        """Mirror of the main prefill for the K=1 draft pool: consume
        up to prefill_chunk prompt tokens of ONE draft-on slot.  Runs
        BEFORE the main prefill program (it reads the pre-advance
        st.pos).  Logits are discarded — the first generated token is
        the VERIFIER's (sampled by the main prefill), and the draft
        consumes it at the next speculative step."""
        C = self.prefill_chunk
        pos, plen = st.pos[slot], st.prompt_len[slot]
        need = (st.active[slot] & ~st.done[slot] & (pos < plen)
                & st.draft[slot])
        n_tok = jnp.where(need, jnp.minimum(C, plen - pos), 0)
        P_ = st.prompt.shape[1]
        cols = jnp.clip(pos + jnp.arange(C), 0, P_ - 1)
        chunk = st.prompt[slot][cols][None]  # (1, C)
        row = kv_cache.slot_row(draft_cache, slot)

        def one(p, c):
            return tf.prefill_slots(p, self.draft_cfg, c, chunk,
                                    n_tok[None])

        _, row = jax.vmap(one)(draft_params, row)
        return kv_cache.write_slot_row(draft_cache, row, slot)

    # -- host API -----------------------------------------------------------

    def reserve_decode_pages(self) -> list:
        """Like the base engine's, but draft-on slots reserve the FULL
        gamma+1 lookahead (clamped to the request's remaining budget):
        the verify pass writes up to C positions before acceptance is
        known.  Pages past the accepted length are reclaimed after the
        step (PageAllocator.truncate)."""
        if not self.paged:
            return []
        starved = []
        for b in np.nonzero(self._host_decoding())[0]:
            pos = int(self._host_pos[b])
            look = 1
            if self._host_draft[b]:
                end = int(self._host_plen[b] + self._host_new[b]) - 1
                look = max(min(self.gamma + 1, end - pos), 1)
            last = pos + look - 1
            if self.allocator.holds(b, last):
                continue
            if self.allocator.alloc(b, last // self.page_size + 1):
                self._table_stale = True
            else:
                starved.append(int(b))
        if self._table_stale:
            self._sync_table()
        return starved

    def step(self) -> SlotState:
        """One speculative iteration when any live slot drafts;
        otherwise the inherited plain step, program for program (so an
        all-draft-off server is bit-identical to EnsembleEngine)."""
        if not bool((self._host_draft & self._host_live).any()):
            return super().step()
        if self.paged:
            starved = self.reserve_decode_pages()
            if starved:
                raise RuntimeError(
                    f"paged pool out of pages for decoding slots "
                    f"{starved} ({self.allocator.free_pages} free of "
                    f"{self.n_pages}); release finished slots or "
                    f"preempt (Scheduler.run does) before stepping")
        (self.state, self.cache, self.draft_cache,
         self.stats_vec) = self._spec(
            self.params, self.draft_params, self.cache,
            self.draft_cache, self.state, self.quorum, self.stats_vec)
        self.steps_run += 1
        self.spec_steps_run += 1
        if self.paged:
            # a speculative step advances each row by its OWN e — the
            # +1-per-step host mirror does not apply.  One transfer
            # resyncs positions (pos = plen + n_gen - 1 during decode)
            # and hands back pages past the accepted length.
            n_gen = np.asarray(jax.device_get(self.state.n_gen))
            for b in np.nonzero(self._host_active)[0]:
                if self._host_pos[b] < self._host_plen[b]:
                    continue  # prefill owns this slot
                newpos = int(self._host_plen[b]
                             + max(int(n_gen[b]), 1) - 1)
                self._host_pos[b] = newpos
                if self.allocator.truncate(
                        int(b), newpos // self.page_size + 1):
                    self._table_stale = True
        return self.state

    def prefill(self, slot: int) -> SlotState:
        if 0 <= int(slot) < self.n_slots and self._host_draft[int(slot)]:
            if self.prefill_chunk <= 0:
                raise ValueError("engine built with prefill_chunk=0 "
                                 "(per-token reference path)")
            self.draft_cache = self._dprefill(
                self.draft_params, self.draft_cache, self.state,
                jnp.asarray(slot, jnp.int32))
        return super().prefill(slot)

    def update_slots(self, release: Sequence[int] = (),
                     admits: Sequence[tuple] = ()):
        norm = []
        for entry in admits:
            opts = dict(entry[3]) if len(entry) > 3 and entry[3] else {}
            opts.setdefault("draft", True)
            norm.append((entry[0], entry[1], entry[2], opts))
        hits = super().update_slots(release=release, admits=norm)
        adm = np.zeros((self.n_slots,), bool)
        for b in release:
            self._host_draft[int(b)] = False
            self._host_live[int(b)] = False
        for b, _, _, opts in norm:
            self._host_draft[int(b)] = bool(opts["draft"])
            self._host_live[int(b)] = True
            adm[int(b)] = True
        if adm.any():
            self.draft_cache = self._dreset(self.draft_cache,
                                            jnp.asarray(adm))
        return hits

    def spec_stats(self) -> dict:
        """Acceptance / pruning telemetry, one device transfer.

        accepted_len counts EMITTED tokens per speculative iteration
        (accepted drafts + the verifier's own token), i.e. e in
        [1, gamma+1]; acceptance_rate is accepted drafts / proposed
        drafts; pruned_frac the fraction of (member, position) votes
        provably unable to flip the fused argmax.
        """
        v = np.asarray(jax.device_get(self.stats_vec), np.float64)
        proposed, accepted, steps, pc, pt = v[:_N_HEAD]
        hist = v[_N_HEAD:]
        tot = hist.sum()
        lens = np.arange(self.gamma + 2, dtype=np.float64)
        p50 = 0.0
        if tot > 0:
            p50 = float(np.argmax(np.cumsum(hist) >= (tot + 1) / 2.0))
        return {
            "gamma": self.gamma,
            "spec_steps": int(steps),
            "proposed": int(proposed),
            "accepted": int(accepted),
            "acceptance_rate": float(accepted / proposed)
            if proposed > 0 else 0.0,
            "mean_accepted_len": float((hist * lens).sum() / tot)
            if tot > 0 else 0.0,
            "accepted_len_p50": p50,
            "pruned_frac": float(pc / pt) if pt > 0 else 0.0,
            "emitted_hist": [int(x) for x in hist],
        }
