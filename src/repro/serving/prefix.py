"""Shared-prefix trie over the paged KV pool (host policy, never traced).

EC-DNN serving pays K-fold KV bytes per token (every member caches the
same positions), which makes a cached prefix page worth K times what it
is in a single-model server — and KV entries are a pure function of
(token ids, positions), so two requests sharing a prompt prefix can
share the physical pages that hold it, bit-exactly.  This module keeps
the map from token prefixes to those pages:

  - nodes are PAGE-GRANULAR: a full node covers exactly `page_size`
    tokens at depth d (positions [d*page, (d+1)*page)); a partial node
    (a leaf) covers 1..page_size-1 tokens of a page's head — the tail
    entries of a partially matched page are garbage to a sharer, but
    causality masks them (a request admitted at hit h only ever attends
    positions < h) until copy-on-write gives the sharer its own page;
  - `match` walks full children page by page, then picks the child with
    the longest common token prefix as a partial tail — so a hit is
    TOKEN-granular, not page-granular, and the copy-on-write path in
    the allocator is load-bearing whenever hit % page_size != 0;
  - `insert` runs at release (the only time a chain's content is
    final): content-addressed, so identical prefixes dedup onto the
    first chain that cached them and the duplicate pages go back to the
    free list;
  - pages the trie owns but no slot references (allocator refcount 0)
    form the EVICTABLE pool: `reclaim` frees them leaf-first in LRU
    order when the allocator's free list runs dry, and `flush` drops
    the whole trie (hot-swap: a round-t prefix must never serve round
    t+1 — engine.swap_params calls it).

Invariant the accounting leans on: a sharer references a node only by
walking from the root, so a referenced node's ancestors are always
referenced too — unreferenced nodes form downward-closed subtrees, and
EVERY unreferenced owned page is transitively evictable.  That is why
`evictable` is a plain counter and reclaim(n) can always deliver n <=
evictable pages.

The allocator owns refcounts; the trie never mutates them.  The two
meet through three notifications (`page_referenced`,
`page_unreferenced`, `owns`) and `reclaim` — see
kv_cache.PageAllocator.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple


class _Node:
    """One cached page: `tokens` is the page's token content (length
    page_size for full nodes, shorter for partial leaves), `page` the
    physical id holding its KV."""

    __slots__ = ("tokens", "page", "parent", "children")

    def __init__(self, tokens: Tuple[int, ...], page: int,
                 parent: Optional["_Node"]):
        self.tokens = tokens
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}


def _lcp(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class PrefixCache:
    """Token-trie of cached prefix pages, LRU-evicted under pressure."""

    def __init__(self, page_size: int):
        if page_size <= 0:
            raise ValueError(f"page_size must be > 0, got {page_size}")
        self.page_size = int(page_size)
        self._root = _Node((), -1, None)
        # page id -> node, in LRU order (most recently touched last)
        self._lru: "OrderedDict[int, _Node]" = OrderedDict()
        # owned pages whose allocator refcount is 0 (the evictable pool)
        self._unref: set = set()
        # telemetry (engine.page_stats / client report / /metrics)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.inserted_pages = 0
        self.deduped_pages = 0
        self.evicted_pages = 0
        self.flushes = 0

    # -- allocator notifications -------------------------------------------

    def owns(self, page: int) -> bool:
        return page in self._lru

    def page_referenced(self, page: int):
        """A slot now references an owned page (refcount 0 -> 1)."""
        self._unref.discard(page)

    def page_unreferenced(self, page: int):
        """The last slot referencing an owned page released it; the page
        keeps its content and becomes evictable."""
        self._unref.add(page)

    @property
    def evictable(self) -> int:
        return len(self._unref)

    @property
    def cached_pages(self) -> int:
        return len(self._lru)

    def owned_pages(self) -> set:
        """Physical ids the trie currently owns (a copy).  The
        allocator's invariant checker partitions the pool with this:
        a ref-0 page must be either free or in here, never both."""
        return set(self._lru)

    # -- lookup -------------------------------------------------------------

    def _walk(self, tokens: Sequence[int], max_hit: int, touch: bool
              ) -> Tuple[int, List[int], Optional[Tuple[int, int]]]:
        ps = self.page_size
        toks = [int(t) for t in tokens]
        node = self._root
        full: List[int] = []
        i = 0
        while i + ps <= min(len(toks), max_hit):
            child = node.children.get(tuple(toks[i:i + ps]))
            if child is None:
                break
            node = child
            full.append(node.page)
            if touch:
                self._lru.move_to_end(node.page)
            i += ps
        tail: Optional[Tuple[int, int]] = None
        want = toks[i:min(len(toks), i + ps)]
        cap = max_hit - i
        best = 0
        for key, child in node.children.items():
            r = min(_lcp(key, want), cap, len(child.tokens))
            if r > best:
                best, tail = r, (child.page, r)
                if touch:
                    self._lru.move_to_end(child.page)
        return i + best, full, tail

    def match(self, tokens: Sequence[int], max_hit: int
              ) -> Tuple[int, List[int], Optional[Tuple[int, int]]]:
        """Longest cached prefix of `tokens`, capped at max_hit tokens.

        -> (hit, full_pages, tail): `full_pages` are the physical pages
        covering tokens [0, len(full_pages)*page_size) — safe to share
        as-is (every entry valid); `tail` is (src_page, r) when r more
        tokens match inside one further page (hit = full + r) — the
        sharer must COPY that page before its first write lands in it
        (kv_cache.PageAllocator.cow), because entries past r are not
        its content.  Matched nodes are LRU-touched.  The caller caps
        max_hit at prompt_len - 1 so at least one token always
        prefills (the first sampled token needs last-token logits).
        """
        self.lookups += 1
        self.lookup_tokens += len(tokens)
        hit, full, tail = self._walk(tokens, max_hit, touch=True)
        if hit > 0:
            self.hits += 1
            self.hit_tokens += hit
        return hit, full, tail

    def peek(self, tokens: Sequence[int], max_hit: int
             ) -> Tuple[int, List[int], Optional[Tuple[int, int]]]:
        """match() without side effects: no LRU touch, no counters.
        The scheduler's admission gate probes with this (admit_cost) so
        a request costed several times before admission doesn't skew
        hit-rate telemetry or eviction order."""
        return self._walk(tokens, max_hit, touch=False)

    # -- insert (at release) ------------------------------------------------

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Cache a released chain's prefix content; -> pages claimed.

        tokens: the VALID token prefix (every position's KV written);
        pages: the physical pages covering it, in logical order.
        Content-addressed: a node whose token tuple already exists is
        reused (the duplicate page is NOT claimed — the releasing
        slot's unref sends it to the free list).  The final non-full
        page becomes a partial leaf.  Claimed pages stay referenced by
        the releasing slot until its unref, so claiming never races
        eviction.
        """
        ps = self.page_size
        toks = [int(t) for t in tokens]
        node = self._root
        claimed = 0
        for j in range(len(toks) // ps):
            key = tuple(toks[j * ps:(j + 1) * ps])
            child = node.children.get(key)
            if child is None:
                child = _Node(key, int(pages[j]), node)
                node.children[key] = child
                self._lru[child.page] = child
                claimed += 1
            else:
                self.deduped_pages += 1
            node = child
        rem = tuple(toks[(len(toks) // ps) * ps:])
        if rem:
            if rem in node.children:
                self.deduped_pages += 1
            else:
                child = _Node(rem, int(pages[len(toks) // ps]), node)
                node.children[rem] = child
                self._lru[child.page] = child
                claimed += 1
        self.inserted_pages += claimed
        return claimed

    # -- eviction -----------------------------------------------------------

    def _evict(self, node: _Node):
        del node.parent.children[node.tokens]
        del self._lru[node.page]
        self._unref.discard(node.page)
        self.evicted_pages += 1

    def reclaim(self, n: int) -> List[int]:
        """Evict up to n unreferenced pages, oldest-first and leaf-first
        (an interior node frees once its children have); -> freed ids.
        The downward-closed invariant guarantees n <= evictable pages
        can always be delivered."""
        freed: List[int] = []
        while len(freed) < n:
            victim = None
            for page, node in self._lru.items():
                if page in self._unref and not node.children:
                    victim = node
                    break
            if victim is None:
                break
            self._evict(victim)
            freed.append(victim.page)
        return freed

    def flush(self) -> List[int]:
        """Drop the whole trie (model hot-swap: cached pages hold the
        OLD model's KV).  -> unreferenced pages for the allocator's
        free list.  Pages still referenced by live slots are merely
        disowned — their last unref frees them normally (drain first,
        Router.rollout does, when zero stale pages must survive)."""
        freed = [p for p in self._lru if p in self._unref]
        self._root = _Node((), -1, None)
        self._lru.clear()
        self._unref.clear()
        self.flushes += 1
        return freed

    # -- telemetry ----------------------------------------------------------

    def hit_rate(self) -> float:
        """Fraction of looked-up prompt tokens served from cache."""
        return self.hit_tokens / max(self.lookup_tokens, 1)

    def stats(self) -> dict:
        return {"cached_pages": self.cached_pages,
                "evictable_pages": self.evictable,
                "prefix_lookups": self.lookups,
                "prefix_hits": self.hits,
                "prefix_hit_rate": self.hit_rate(),
                "inserted_pages": self.inserted_pages,
                "deduped_pages": self.deduped_pages,
                "evicted_pages": self.evicted_pages}
