"""repro.serving — EC-DNN_G as a first-class serving mode.

EnsembleEngine fuses all K members into one jitted decode step over a
pool of slot-addressable KV caches; Scheduler runs continuous batching
on top (batch `run()` or online `serve_forever()` with token
streaming); the `frontend` subpackage mounts N replicas behind an
HTTP/SSE server with zero-downtime hot-swap; client drives synthetic
load — in-process or over HTTP — and reports tok/s / TTFT / latency
percentiles.  See engine.py for the architecture note.

obs.py is the observability core threaded through all of it:
per-request lifecycle traces, log-bucketed latency histograms
(Prometheus exposition on GET /metrics), a tick-phase profiler, and
the scrape-merge used for fleet-wide aggregation.  On by default;
Scheduler(obs=False) is the kill-switch.
"""
from repro.serving.engine import EnsembleEngine, SlotState
from repro.serving.obs import Histogram, ServingObs, Trace, TraceRing
from repro.serving.prefix import PrefixCache
from repro.serving.scheduler import Completion, Request, Scheduler
from repro.serving.spec import DraftEngine, SpeculativeEngine

__all__ = ["EnsembleEngine", "SlotState", "Scheduler", "Request",
           "Completion", "SpeculativeEngine", "DraftEngine",
           "PrefixCache", "ServingObs", "Trace", "TraceRing",
           "Histogram"]
