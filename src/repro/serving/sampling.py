"""Token sampling on the fused ensemble distribution.

Operates in LOG space (the engine fuses members with
core.ensemble.ensemble_log_probs) so greedy/temperature/top-k all work
off one numerically-stable array with no probs->log round trip.

Two tiers: `sample` takes Python-static temperature/top_k (the
engine-wide defaults; one compiled program per configuration), and
`sample_slots` takes PER-SLOT traced (B,) vectors so every request in a
continuous batch can carry its own temperature/top_k/seed through one
compiled program.  Per-request keys are derived with fold_in(base_key,
emission_index), so a preempted request regenerates token-identically.

The MIN_*/MAX_* limits below are the named request-validation bounds:
serving/engine.validate_request rejects out-of-range values at the door
with errors that quote them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

# door-time limits for per-request sampling params (validate_request)
MIN_TEMPERATURE = 0.0
MAX_TEMPERATURE = 100.0
MIN_SEED = 0
MAX_SEED = 2 ** 31 - 1  # top_k's upper bound is the model's vocab_size


def top_k_mask(log_probs: jax.Array, k: int) -> jax.Array:
    """Keep the k largest entries of the last axis, mask the rest."""
    v, _ = jax.lax.top_k(log_probs, k)
    return jnp.where(log_probs < v[..., -1:], NEG_INF, log_probs)


def sample(key, log_probs: jax.Array, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    """(..., V) fused log-probs -> (...) int32 token ids.

    temperature <= 0 is greedy (argmax); otherwise categorical over
    log_probs / temperature, optionally truncated to the top-k bucket.
    """
    if temperature <= 0.0:
        return log_probs.argmax(axis=-1).astype(jnp.int32)
    lp = log_probs
    if top_k > 0:
        lp = top_k_mask(lp, top_k)
    return jax.random.categorical(key, lp / temperature,
                                  axis=-1).astype(jnp.int32)


def top_k_mask_rows(log_probs: jax.Array, k: jax.Array) -> jax.Array:
    """Per-row traced top-k: log_probs (B, V), k (B,) int (<= 0 keeps
    everything).  The traced twin of top_k_mask — a descending sort per
    row, threshold at each row's own k — with the same tie semantics
    (entries equal to the k-th value survive)."""
    V = log_probs.shape[-1]
    srt = jnp.sort(log_probs, axis=-1)[:, ::-1]
    kk = jnp.clip(jnp.where(k > 0, k, V), 1, V)
    thr = jnp.take_along_axis(srt, kk[:, None] - 1, axis=1)
    return jnp.where(log_probs < thr, NEG_INF, log_probs)


def sample_slots(keys: jax.Array, log_probs: jax.Array,
                 temperature: jax.Array, top_k: jax.Array) -> jax.Array:
    """Per-slot sampling: every batch row carries its OWN params.

    keys: (B, 2) uint32 per-row PRNG keys; log_probs: (B, V) fused
    log-probs; temperature/top_k: (B,) traced.  Rows with
    temperature <= 0 are greedy (argmax — bitwise the static `sample`
    path); the rest draw categorically at their own temperature over
    their own top-k bucket.  A lax.cond skips the stochastic branch
    entirely when the whole batch is greedy, so a greedy-only server
    pays nothing for the capability.  -> (B,) int32 token ids.
    """
    greedy = log_probs.argmax(axis=-1).astype(jnp.int32)

    def stochastic(_):
        t = jnp.maximum(temperature, 1e-6)[:, None]
        lp = top_k_mask_rows(log_probs, top_k) / t
        drawn = jax.vmap(
            lambda kb, row: jax.random.categorical(kb, row))(keys, lp)
        return jnp.where(temperature > 0, drawn.astype(jnp.int32), greedy)

    return jax.lax.cond(jnp.any(temperature > 0.0), stochastic,
                        lambda _: greedy, None)
