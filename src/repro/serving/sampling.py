"""Token sampling on the fused ensemble distribution.

Operates in LOG space (the engine fuses members with
core.ensemble.ensemble_log_probs) so greedy/temperature/top-k all work
off one numerically-stable array with no probs->log round trip.
temperature/top_k are Python statics: the engine closes over them, so
each serving configuration compiles exactly one step program.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def top_k_mask(log_probs: jax.Array, k: int) -> jax.Array:
    """Keep the k largest entries of the last axis, mask the rest."""
    v, _ = jax.lax.top_k(log_probs, k)
    return jnp.where(log_probs < v[..., -1:], NEG_INF, log_probs)


def sample(key, log_probs: jax.Array, temperature: float = 0.0,
           top_k: int = 0) -> jax.Array:
    """(..., V) fused log-probs -> (...) int32 token ids.

    temperature <= 0 is greedy (argmax); otherwise categorical over
    log_probs / temperature, optionally truncated to the top-k bucket.
    """
    if temperature <= 0.0:
        return log_probs.argmax(axis=-1).astype(jnp.int32)
    lp = log_probs
    if top_k > 0:
        lp = top_k_mask(lp, top_k)
    return jax.random.categorical(key, lp / temperature,
                                  axis=-1).astype(jnp.int32)
