"""Slot-indexed decode-cache pool for the ensemble serving engine.

One pool holds the caches of all K ensemble members for all B batch
slots, as a single pytree whose leaves carry a leading member axis:

  idx            (K, B)                per-member, per-slot position
  segment leaves (K, count, B, ...)    stacked KV / SSM state planes
  enc            (K, B, S, d)          (enc-dec only; not served yet)

The pool is allocated ONCE (engine construction) and recycled for the
lifetime of the server: finishing a request never frees or reallocates
anything — `reset_slots` rewinds the slot's position to 0 and zeroes the
recurrent planes, and the next request overwrites the attention KV
in-place as it decodes (stale entries are masked by position bookkeeping,
see models/attention.gqa_decode).  The engine donates the pool into its
jitted step so XLA updates it in place.

Placement: on a ("member", "data") mesh (common.sharding.local_mesh)
the leading (K,) axis shards over "member" — each device holds only its
K/M members' caches, which is where the engine's per-device memory win
comes from — and the slot axis replicates ("data" is reserved for slot
sharding, a ROADMAP follow-up).  Every helper below is placement-
oblivious: it only touches per-member-independent dims, so the same
code runs unsharded or inside a shard_map body on the local shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common import sharding as shd
from repro.common.types import ModelConfig
from repro.models import transformer as tf


def init_pool(cfg: ModelConfig, n_members: int, n_slots: int,
              max_seq: int, mesh=None) -> dict:
    """Allocate the (K members) x (B slots) cache pool.

    With `mesh` (a ("member", "data") mesh) every leaf is placed with
    its leading member axis sharded over "member" and everything else
    replicated; n_members must divide evenly.  mesh=None allocates on
    the default device (the single-device reference path).

    enc-dec archs get a zeroed per-member encoder-output plane; the
    engine fills it once at construction (audio frontends are stubs,
    DESIGN §4 — per-request encoder state is a serving follow-up).
    """
    base = tf.init_slot_cache(cfg, n_slots, max_seq)
    pool = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_members,) + x.shape), base)
    if mesh is not None:
        pool = shard_pool(pool, mesh)
    return pool


def shard_pool(pool: dict, mesh) -> dict:
    """Place a pool (or any leading-(K,) pytree) on a member mesh."""
    return jax.device_put(
        pool, shd.make_shardings(mesh, shd.member_pspecs(pool)))


# positional cache planes: stale entries are masked by position
# bookkeeping, so recycling a slot never needs to touch them
_POSITIONAL = frozenset({"k", "v", "c_kv", "k_r"})


def reset_slots(pool: dict, mask: jax.Array) -> dict:
    """Recycle slots where mask (B,) is True, across all members.

    Rewinding idx to 0 is enough for attention state: each KV entry the
    new request can attend to is overwritten before it first becomes
    visible, so the (large) positional planes are left untouched and
    admission cost stays proportional to the (small) recurrent state.
    Recurrent state (mamba conv/ssm planes, rwkv shift/wkv, cmix shift)
    has no position axis, so it IS zeroed explicitly — otherwise the
    previous occupant leaks into the next request.
    """
    out = dict(pool)
    out["idx"] = jnp.where(mask[None, :], 0, pool["idx"])

    def z(path, x):  # leaves are (K, count, B, ...)
        name = next((str(e.key) for e in reversed(path)
                     if isinstance(e, jax.tree_util.DictKey)), "")
        if name in _POSITIONAL:
            return x
        m = mask.reshape((1, 1, -1) + (1,) * (x.ndim - 3))
        return jnp.where(m, jnp.zeros_like(x), x)

    out["segments"] = jax.tree_util.tree_map_with_path(
        z, pool["segments"])
    # "enc" (encoder context) survives reset: it is not decode state
    return out


def slot_row(pool: dict, b: jax.Array) -> dict:
    """Slice one slot's caches (all members) out of the pool: the B axis
    of every leaf narrows to length 1 at (traced) slot b.  The prefill
    kernel runs the chunk forward on this row only, so its cost scales
    with the chunk — not with n_slots."""
    sl = jax.lax.dynamic_slice_in_dim
    out = {"idx": sl(pool["idx"], b, 1, 1),
           "segments": jax.tree.map(lambda x: sl(x, b, 1, 2),
                                    pool["segments"])}
    if "enc" in pool:
        out["enc"] = sl(pool["enc"], b, 1, 1)
    return out


def write_slot_row(pool: dict, row: dict, b: jax.Array) -> dict:
    """Insert a length-1-B row (from slot_row, advanced by prefill) back
    into the pool at slot b — maxtext's prefill-then-insert, as one
    in-place dynamic-update per leaf on the donated pool."""
    up = jax.lax.dynamic_update_slice_in_dim
    out = dict(pool)
    out["idx"] = up(pool["idx"], row["idx"], b, 1)
    out["segments"] = jax.tree.map(lambda x, r: up(x, r, b, 2),
                                   pool["segments"], row["segments"])
    # "enc" is computed once at construction and never advanced
    return out


def keep_frozen(new: dict, old: dict, advance: jax.Array) -> dict:
    """Undo a decode step's cache mutation for rows where advance (B,)
    is False: a frozen slot (inactive, finished-awaiting-harvest, or
    mid-prompt while prefill owns the prompt path) must not walk its
    position forward or mutate recurrent state — otherwise an idle slot
    on a long-running server marches idx past max_seq and leans on
    clamped out-of-range cache writes.

    Only idx and the recurrent planes are restored.  The positional KV
    planes keep the step's (garbage) write: it lands at the frozen idx,
    stays invisible under the position bookkeeping, and is overwritten
    before a later occupant can see it — the same invariant reset_slots
    relies on — so the restore cost stays proportional to the (small)
    recurrent state.
    """
    out = dict(new)
    out["idx"] = jnp.where(advance[None, :], new["idx"], old["idx"])

    def sel(path, n, o):  # leaves are (K, count, B, ...)
        name = next((str(e.key) for e in reversed(path)
                     if isinstance(e, jax.tree_util.DictKey)), "")
        if name in _POSITIONAL:
            return n
        m = advance.reshape((1, 1, -1) + (1,) * (n.ndim - 3))
        return jnp.where(m, n, o)

    out["segments"] = jax.tree_util.tree_map_with_path(
        sel, new["segments"], old["segments"])
    return out


def slot_positions(pool: dict) -> jax.Array:
    """(B,) current per-slot positions (identical across members)."""
    return pool["idx"][0]


def pool_bytes(pool: dict, per_device: bool = True) -> int:
    """Bytes held by the pool (capacity-planning telemetry).

    per_device=True (the default) reports what ONE device actually
    holds: under a member-sharded pool each device stores only its
    K/M members' planes, so the per-device figure is the global one
    divided by the member-axis size (modulo replicated leaves).  That
    is the number capacity planning wants — reporting global bytes for
    a sharded pool would overstate every chip's footprint M-fold.
    per_device=False sums the global (logical) allocation instead.
    Unsharded pools return the same value either way.
    """
    total = 0
    for x in jax.tree.leaves(pool):
        shape = x.shape
        sh = getattr(x, "sharding", None)
        if per_device and sh is not None and hasattr(sh, "shard_shape"):
            shape = sh.shard_shape(x.shape)
        n = 1
        for d in shape:
            n *= d
        total += n * x.dtype.itemsize
    return total
