"""Slot-indexed decode-cache pool for the ensemble serving engine.

One pool holds the caches of all K ensemble members for all B batch
slots, as a single pytree whose leaves carry a leading member axis:

  idx            (K, B)                per-member, per-slot position
  segment leaves (K, count, B, ...)    stacked KV / SSM state planes
  enc            (K, B, S, d)          (enc-dec only; not served yet)

The pool is allocated ONCE (engine construction) and recycled for the
lifetime of the server: finishing a request never frees or reallocates
anything — `reset_slots` rewinds the slot's position to 0 and zeroes the
recurrent planes, and the next request overwrites the attention KV
in-place as it decodes (stale entries are masked by position bookkeeping,
see models/attention.gqa_decode).  The engine donates the pool into its
jitted step so XLA updates it in place.

Paged mode (page_size > 0): full-attention layers' positional planes
swap their per-slot (B, max_seq, ...) rows for a shared page pool —

  paged leaves   (K, count, n_pages, page_size, ...)
  page_table     (K, B, ceil(max_seq/page_size))  logical -> physical

backed by the host-side PageAllocator below (refcounted free-list +
per-slot page chains; sentinel id n_pages = unallocated).  Pool bytes
then scale with the TOKENS IN FLIGHT instead of K x n_slots x max_seq,
admission is bounded by free pages rather than free slots, and
releasing a slot is a refcount decrement — no zeroing, the same
stale-entry invariant as the contiguous path.  With the prefix trie
wired in (serving/prefix.py) chains are shared: several slots (and the
trie) reference the same physical prefix pages, writes into a shared
page go through copy-on-write (PageAllocator.cow + copy_pages), and
pages at refcount zero with trie content are kept evictable rather
than freed.  Ring-bounded sliding-window planes and recurrent state
stay per-slot (transformer.layer_pages).

Placement: on a ("member", "data") mesh (common.sharding.local_mesh)
the leading (K,) axis shards over "member" — each device holds only its
K/M members' caches, which is where the engine's per-device memory win
comes from — and the slot axis replicates ("data" is reserved for slot
sharding, a ROADMAP follow-up).  The page table is identical across
members (carrying the K axis keeps every helper placement-oblivious:
each member shard reads its own replica).  Every helper below only
touches per-member-independent dims, so the same code runs unsharded or
inside a shard_map body on the local shard.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import sharding as shd
from repro.common.types import ModelConfig
from repro.models import transformer as tf


def init_pool(cfg: ModelConfig, n_members: int, n_slots: int,
              max_seq: int, mesh=None, page_size: int = 0,
              n_pages: int = 0, kv_dtype: str = "f32") -> dict:
    """Allocate the (K members) x (B slots) cache pool.

    With `mesh` (a ("member", "data") mesh) every leaf is placed with
    its leading member axis sharded over "member" and everything else
    replicated; n_members must divide evenly.  mesh=None allocates on
    the default device (the single-device reference path).

    page_size > 0 allocates the paged layout (n_pages physical pages
    shared by all slots per full-attention layer, plus the per-slot
    page table, initially all-sentinel = nothing allocated).

    kv_dtype picks the paged-plane storage format ("f32" = native, the
    default; "bf16"; "int8"/"fp8" quantized with per-token absmax
    scales in `*_scale_pages` sidecar leaves).  Sidecars end in
    "_pages", so every pool helper (reset, copy_pages COW, snapshot)
    treats them exactly like the planes they scale; under a member mesh
    they shard like their planes (leading member axis).  Contiguous
    planes (sliding-window rings, recurrent state) are never quantized.

    enc-dec archs get a zeroed per-member encoder-output plane; the
    engine fills it once at construction (audio frontends are stubs,
    DESIGN §4 — per-request encoder state is a serving follow-up).
    """
    base = tf.init_slot_cache(cfg, n_slots, max_seq, page_size=page_size,
                              n_pages=n_pages, kv_dtype=kv_dtype)
    pool = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_members,) + x.shape), base)
    if mesh is not None:
        pool = shard_pool(pool, mesh)
    return pool


def shard_pool(pool: dict, mesh) -> dict:
    """Place a pool (or any leading-(K,) pytree) on a member mesh."""
    return jax.device_put(
        pool, shd.make_shardings(mesh, shd.member_pspecs(pool)))


# positional cache planes: stale entries are masked by position
# bookkeeping, so recycling a slot never needs to touch them.  Paged
# planes ("*_pages") carry the same invariant and additionally have no
# slot axis at all — per-slot masked updates must never see them.
_POSITIONAL = frozenset({"k", "v", "c_kv", "k_r"})


def _leaf_name(path) -> str:
    return next((str(e.key) for e in reversed(path)
                 if isinstance(e, jax.tree_util.DictKey)), "")


def _skip_slot_update(name: str) -> bool:
    return name in _POSITIONAL or name.endswith("_pages")


def reset_slots(pool: dict, mask: jax.Array,
                start: Optional[jax.Array] = None) -> dict:
    """Recycle slots where mask (B,) is True, across all members.

    A strictly per-slot masked update: rows where the mask is False ride
    through BIT-IDENTICAL (tests/test_serving_paged.py pins this), so
    releasing one slot can never perturb the B-1 in-flight neighbors.
    Rewinding idx is enough for attention state: each KV entry the
    new request can attend to is overwritten before it first becomes
    visible, so the (large) positional planes are left untouched and
    admission cost stays proportional to the (small) recurrent state.
    Recurrent state (mamba conv/ssm planes, rwkv shift/wkv, cmix shift)
    has no position axis, so it IS zeroed explicitly — otherwise the
    previous occupant leaks into the next request.  Paged planes have no
    slot axis (pages are reassigned by the host allocator) and the page
    table is host-owned — neither is touched here.

    start (B,): per-slot restart position, default 0.  A prefix-cache
    hit admits a request at its hit boundary (serving/prefix.py): the
    positions below `start` are served by SHARED pages whose content is
    already valid, so idx rewinds to the hit, not to 0.  Only engines
    whose every layer pages may pass start > 0 — recurrent state resets
    to step-0 here regardless, which is exactly why prefix caching is
    gated on all-paged configs (engine._prefix_ineligible).
    """
    out = dict(pool)
    zero = jnp.zeros_like(pool["idx"])
    tgt = zero if start is None else zero + start[None, :]
    out["idx"] = jnp.where(mask[None, :], tgt, pool["idx"])

    def z(path, x):  # leaves are (K, count, B, ...)
        if _skip_slot_update(_leaf_name(path)):
            return x
        m = mask.reshape((1, 1, -1) + (1,) * (x.ndim - 3))
        return jnp.where(m, jnp.zeros_like(x), x)

    out["segments"] = jax.tree_util.tree_map_with_path(
        z, pool["segments"])
    # "enc" (encoder context) survives reset: it is not decode state
    return out


def slot_row(pool: dict, b: jax.Array) -> dict:
    """Slice one slot's caches (all members) out of the pool: the B axis
    of every leaf narrows to length 1 at (traced) slot b.  The prefill
    kernel runs the chunk forward on this row only, so its cost scales
    with the chunk — not with n_slots.  Paged planes have no slot axis
    and pass through whole (the chunk scatters into the slot's pages in
    place); the slot's page-table row rides along."""
    sl = jax.lax.dynamic_slice_in_dim

    def pick(path, x):
        if _leaf_name(path).endswith("_pages"):
            return x
        return sl(x, b, 1, 2)

    out = {"idx": sl(pool["idx"], b, 1, 1),
           "segments": jax.tree_util.tree_map_with_path(
               pick, pool["segments"])}
    if "page_table" in pool:
        out["page_table"] = sl(pool["page_table"], b, 1, 1)
    if "enc" in pool:
        out["enc"] = sl(pool["enc"], b, 1, 1)
    return out


def write_slot_row(pool: dict, row: dict, b: jax.Array) -> dict:
    """Insert a length-1-B row (from slot_row, advanced by prefill) back
    into the pool at slot b — maxtext's prefill-then-insert, as one
    in-place dynamic-update per leaf on the donated pool.  Paged planes
    come back whole (already scatter-updated inside the prefill)."""
    up = jax.lax.dynamic_update_slice_in_dim

    def put(path, x, r):
        if _leaf_name(path).endswith("_pages"):
            return r
        return up(x, r, b, 2)

    out = dict(pool)
    out["idx"] = up(pool["idx"], row["idx"], b, 1)
    out["segments"] = jax.tree_util.tree_map_with_path(
        put, pool["segments"], row["segments"])
    if "page_table" in pool:
        out["page_table"] = up(pool["page_table"], row["page_table"], b, 1)
    # "enc" is computed once at construction and never advanced
    return out


def keep_frozen(new: dict, old: dict, advance: jax.Array) -> dict:
    """Undo a decode step's cache mutation for rows where advance (B,)
    is False: a frozen slot (inactive, finished-awaiting-harvest, or
    mid-prompt while prefill owns the prompt path) must not walk its
    position forward or mutate recurrent state — otherwise an idle slot
    on a long-running server marches idx past max_seq and leans on
    clamped out-of-range cache writes.

    Only idx and the recurrent planes are restored.  The positional KV
    planes keep the step's (garbage) write: it lands at the frozen idx,
    stays invisible under the position bookkeeping, and is overwritten
    before a later occupant can see it — the same invariant reset_slots
    relies on — so the restore cost stays proportional to the (small)
    recurrent state.  (Paged planes drop a frozen row's write entirely
    when its page is unallocated — scatter mode="drop" — and otherwise
    land it in the slot's own page under the same invariant.)
    """
    out = dict(new)
    out["idx"] = jnp.where(advance[None, :], new["idx"], old["idx"])

    def sel(path, n, o):  # leaves are (K, count, B, ...)
        if _skip_slot_update(_leaf_name(path)):
            return n
        m = advance.reshape((1, 1, -1) + (1,) * (n.ndim - 3))
        return jnp.where(m, n, o)

    out["segments"] = jax.tree_util.tree_map_with_path(
        sel, new["segments"], old["segments"])
    return out


# ---------------------------------------------------------------------------
# speculative rollback: snapshot/restore a window of ring positions
# ---------------------------------------------------------------------------
# A speculative step writes a (gamma+1)-token chunk at positions
# pos..pos+C-1 and then keeps only the accepted prefix.  For FULL
# positional planes rewinding idx is enough (entries past idx are
# stale-masked, exactly the reset_slots invariant), but sliding-window
# RING planes reuse slot p % S: the rejected tail's writes LAND ON live
# history (position p - S), which no mask can bring back.  So the engine
# snapshots the C ring entries a chunk will overwrite before the step
# and scatters the rejected tail's originals back after acceptance.
# Both helpers are applied uniformly to every positional leaf — on full
# planes the restore re-writes stale entries, a masked no-op — and are
# traced (they run inside the one jitted speculative step).


def snapshot_positions(pool: dict, start: jax.Array, length: int) -> dict:
    """Copy the pool entries C positions ahead of each slot.

    start: (B,) per-slot first position; length: static C.  For every
    positional leaf (K, count, B, S, ...) gathers the ring slots
    (start+t) % S, t in [0, C) -> (K, count, B, C, ...).  Paged planes
    need no rollback (rejected writes are stale-masked and their pages
    are reclaimed by the host allocator) and are skipped, as are idx /
    page_table / recurrent leaves.
    """
    t = jnp.arange(length)

    def grab(path, x):
        if _leaf_name(path) not in _POSITIONAL:
            # zero-size placeholder keeps the snapshot's tree structure
            # congruent with the pool's (restore skips it by name)
            return jnp.zeros((0,), x.dtype)
        S = x.shape[3]
        bb = jnp.arange(x.shape[2])[:, None]            # (B, 1)
        tt = (start[:, None] + t[None, :]) % S          # (B, C)
        return x[:, :, bb, tt]                          # (K, count, B, C, ..)

    return {"segments": jax.tree_util.tree_map_with_path(
        grab, pool["segments"])}


def restore_positions(pool: dict, snap: dict, start: jax.Array,
                      keep: jax.Array) -> dict:
    """Scatter a snapshot's rejected tail back into the pool.

    start: (B,) the snapshot's first position; keep: (B,) how many of
    the C snapshot entries now hold ACCEPTED tokens (those stay as the
    verify pass wrote them); entries t in [keep, C) are restored to
    their pre-step contents.  keep == C is a full no-op, keep == 0 a
    full rewind.  idx is NOT touched — the caller owns position
    bookkeeping (the speculative kernel sets idx = start + keep for
    advanced rows directly).
    """
    out = dict(pool)

    def put(path, x, s):
        if _leaf_name(path) not in _POSITIONAL:
            return x
        C = s.shape[3]
        S = x.shape[3]
        bb = jnp.arange(x.shape[2])[:, None]            # (B, 1)
        t = jnp.arange(C)[None, :]
        tt = (start[:, None] + t) % S
        tgt = jnp.where(t >= keep[:, None], tt, S)      # kept -> dropped
        return x.at[:, :, bb, tgt].set(s, mode="drop")

    out["segments"] = jax.tree_util.tree_map_with_path(
        put, pool["segments"], snap["segments"])
    return out


# ---------------------------------------------------------------------------
# paged-pool page accounting (host side)
# ---------------------------------------------------------------------------


class PageAllocator:
    """Refcounting free-list allocator behind the paged pool's table.

    Pure host policy — nothing here is traced.  Physical pages are ids
    in [0, n_pages); the sentinel id `n_pages` marks an unallocated
    page-table entry (paged kernels clamp + mask reads through it and
    drop writes).  Each slot holds a chain of pages, one per logical
    page, grown strictly in order (sequence positions only ever
    advance).  Pages are REFCOUNTED, not owned: a chain page fresh from
    `alloc` carries refcount 1 (the old exclusive-ownership behavior,
    bit-identical for chains that never share), while `share` attaches
    pages of an existing prefix — the same physical page then appears
    in several chains and in the prefix trie, and `release`/`truncate`
    become refcount decrements that only free a page at zero.  `cow`
    is the copy-on-write step: a slot about to write into a shared page
    swaps a fresh page into its chain first (the engine dispatches the
    device copy).  No zeroing anywhere: the next owner overwrites every
    entry before the position bookkeeping makes it visible, the same
    invariant the contiguous pool recycles slots with.

    With a PrefixCache wired in (`self.cache`, engine-owned), pages
    whose refcount drops to zero while the trie owns them become
    EVICTABLE instead of free — their KV content is kept for future
    sharers and reclaimed LRU leaf-first only when the free list runs
    dry (`alloc` calls `cache.reclaim`).  `available_pages` is
    therefore free + evictable: the admission headroom.

    The same id space addresses every paged layer's plane (each layer
    has its own (n_pages, page_size, ...) physical pool, all indexed by
    the one table), so allocating a page buys position capacity in ALL
    layers at once — vLLM's block-table layout.
    """

    def __init__(self, n_pages: int, page_size: int, n_slots: int,
                 pages_per_slot: int):
        if n_pages <= 0 or page_size <= 0:
            raise ValueError(f"need n_pages > 0 and page_size > 0, got "
                             f"{n_pages}, {page_size}")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.n_slots = int(n_slots)
        self.pages_per_slot = int(pages_per_slot)
        # pop() takes the lowest id first — keeps tables human-readable
        self._free: List[int] = list(range(self.n_pages - 1, -1, -1))
        self._chain: List[List[int]] = [[] for _ in range(self.n_slots)]
        self._ref: List[int] = [0] * self.n_pages
        # prefix trie (serving/prefix.PrefixCache), wired by the engine
        # when prefix caching is on; None keeps pure free-list behavior
        self.cache = None
        self._dirty = True
        self._table: Optional[np.ndarray] = None
        # fewest free pages ever observed after an alloc — how close
        # the pool came to preemption over its lifetime (capacity
        # telemetry; client.print_report surfaces it)
        self.low_water = self.n_pages
        self.shared_attach_count = 0  # pages attached via share()
        self.cow_count = 0            # pages copied via cow()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def available_pages(self) -> int:
        """Free-list pages plus trie-cached pages no slot references —
        everything an alloc can hand out (admission headroom)."""
        return len(self._free) + (self.cache.evictable
                                  if self.cache is not None else 0)

    @property
    def shared_pages(self) -> int:
        """Pages currently referenced by more than one chain."""
        return sum(1 for r in self._ref if r > 1)

    def ref(self, page: int) -> int:
        return self._ref[page]

    def pages_for(self, n_tokens: int) -> int:
        """Pages covering n_tokens positions from 0."""
        return -(-int(n_tokens) // self.page_size)

    def holds(self, slot: int, position: int) -> bool:
        """Is `position`'s page already allocated to `slot`?"""
        return position // self.page_size < len(self._chain[slot])

    def held_pages(self, slot: int) -> int:
        return len(self._chain[slot])

    def chain(self, slot: int) -> Tuple[int, ...]:
        """`slot`'s physical page chain in logical order (a copy)."""
        return tuple(self._chain[slot])

    def alloc(self, slot: int, n_logical: int) -> bool:
        """Grow `slot` to cover >= n_logical logical pages (fresh pages,
        refcount 1).

        All-or-nothing: returns False (state untouched) when free +
        evictable pages cannot cover the growth or n_logical exceeds
        the per-slot table width — the caller (engine/scheduler) then
        preempts or queues instead of partially admitting.  When the
        free list alone is short, trie-cached unreferenced pages are
        evicted (LRU leaf-first) to cover the difference — the prefix
        cache yields to live requests, never the other way around.
        """
        need = int(n_logical) - len(self._chain[slot])
        if need <= 0:
            return True
        if n_logical > self.pages_per_slot or need > self.available_pages:
            return False
        if need > len(self._free):
            self._free.extend(
                reversed(self.cache.reclaim(need - len(self._free))))
        for _ in range(need):
            p = self._free.pop()
            self._ref[p] = 1
            self._chain[slot].append(p)
        self._dirty = True
        self.low_water = min(self.low_water, len(self._free))
        return True

    def share(self, slot: int, pages: Sequence[int]) -> None:
        """Attach an existing prefix's pages to `slot`'s chain (in
        logical order, extending the tail) and take a reference on
        each.  The pages' KV content is someone else's work — the slot
        may READ through them but must never write below its admission
        hit (engine admission guarantees writes start at the hit; the
        partial tail page goes through `cow` first).
        """
        chain = self._chain[slot]
        if len(chain) + len(pages) > self.pages_per_slot:
            raise ValueError(
                f"share would grow slot {slot} past pages_per_slot="
                f"{self.pages_per_slot}")
        for p in pages:
            p = int(p)
            self._ref[p] += 1
            if self._ref[p] == 1 and self.cache is not None \
                    and self.cache.owns(p):
                self.cache.page_referenced(p)
            chain.append(p)
        self.shared_attach_count += len(pages)
        self._dirty = True

    def cow(self, slot: int, logical: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write: give `slot` an exclusive copy of its logical
        page `logical` before its first divergent write lands there.

        If the current page is shared (refcount > 1, or cached in the
        trie where future sharers could pick it up), a fresh page is
        swapped into the chain and (src, dst) returned — the CALLER
        copies the device content (engine._copy) before dispatching any
        write.  An already-exclusive page returns None (write in
        place).  Raises when no page is available: admission accounting
        charges the COW destination as part of the non-shared suffix,
        so a caller that checked available_pages never trips this.
        """
        src = self._chain[slot][logical]
        shared = self._ref[src] > 1 or (self.cache is not None
                                        and self.cache.owns(src))
        if not shared:
            return None
        if not self._free and self.cache is not None:
            self._free.extend(reversed(self.cache.reclaim(1)))
        if not self._free:
            raise RuntimeError(
                f"cow: no page available for slot {slot} (pool "
                f"{self.n_pages}, all referenced)")
        dst = self._free.pop()
        self._ref[dst] = 1
        self._chain[slot][logical] = dst
        self._unref(src)
        self.cow_count += 1
        self.low_water = min(self.low_water, len(self._free))
        self._dirty = True
        return src, dst

    def _unref(self, page: int) -> bool:
        """Drop one reference; at zero the page goes to the free list —
        or to the trie's evictable pool when the trie owns it (content
        kept for future sharers).  -> True when the page left the
        chain's accounting (always; return is for symmetry/clarity)."""
        self._ref[page] -= 1
        if self._ref[page] < 0:
            raise AssertionError(f"page {page} refcount underflow")
        if self._ref[page] == 0:
            if self.cache is not None and self.cache.owns(page):
                self.cache.page_unreferenced(page)
            else:
                self._free.append(page)
        return True

    def truncate(self, slot: int, n_logical: int) -> int:
        """Shrink `slot` back to n_logical pages; -> pages dropped from
        the chain (freed immediately unless still shared/cached).

        The speculative engine reserves pages for the full gamma-token
        lookahead before a step; a short accepted prefix leaves the tail
        pages holding only rejected (stale-masked) writes, so the
        scheduler hands them back here after harvest.  Chains only ever
        shrink from the tail (positions are append-only) — decode-tail
        pages are refcount-1 and never trie-cached (a chain's shared
        prefix sits strictly below the prompt, and the accepted length
        is >= the prompt), so a spec-decode rollback frees exactly what
        the pre-refcount allocator freed.  Already-short chains are a
        no-op.
        """
        n = len(self._chain[slot]) - max(int(n_logical), 0)
        if n <= 0:
            return 0
        tail = self._chain[slot][-n:]
        self._chain[slot] = self._chain[slot][:-n]
        freed = [p for p in reversed(tail)
                 if self._ref[p] == 1 and not (
                     self.cache is not None and self.cache.owns(p))]
        for p in tail:
            if p in freed:
                self._ref[p] = 0
            else:
                self._unref(p)
        self._free.extend(freed)
        self._dirty = True
        return n

    def release(self, slot: int) -> int:
        """Drop `slot`'s references to its whole chain; -> chain length.
        Pages nobody else references return to the free list (reversed,
        so the lowest id pops first, as before refcounting) — unless
        the trie owns them, in which case they become evictable with
        their content intact (that is how a released request's prefix
        stays warm for the next sharer)."""
        chain = self._chain[slot]
        n = len(chain)
        if n:
            freed = [p for p in reversed(chain)
                     if self._ref[p] == 1 and not (
                         self.cache is not None and self.cache.owns(p))]
            for p in chain:
                if self._ref[p] == 1 and p in freed:
                    self._ref[p] = 0
                else:
                    self._unref(p)
            self._free.extend(freed)
            self._chain[slot] = []
            self._dirty = True
        return n

    def reclaimable_pages(self, slot: int) -> int:
        """Chain pages a release would push onto the FREE list right
        now: refcount exactly 1 and not trie-owned.  (Trie-owned pages
        go evictable instead — still admission headroom, but counted by
        `available_pages` once they get there, so counting them here
        would double-book.)  Scheduler admission adds this for slots in
        its release batch."""
        return sum(1 for p in self._chain[slot]
                   if self._ref[p] == 1 and not (
                       self.cache is not None and self.cache.owns(p)))

    def flush_cache(self) -> int:
        """Drop the prefix trie (engine.swap_params: cached pages hold
        the old model's KV) and return its unreferenced pages to the
        free list; -> pages freed.  No-op without a trie."""
        if self.cache is None:
            return 0
        pages = self.cache.flush()
        self._free.extend(sorted(pages, reverse=True))
        return len(pages)

    def check_invariants(self) -> None:
        """Assert the pool's global accounting is consistent; raises
        AssertionError naming the first violation.

        The conservation law every admit/share/cow/cancel/preempt/
        release interleaving must preserve (the cancellation path and
        the fleet soak gate on this, and the hypothesis property test
        drives random op sequences through it):

          - every page's refcount equals the number of chains holding
            it (the trie owns content, never references);
          - the free list is disjoint from every chain and from the
            trie, and holds no duplicates;
          - refcount 0 <=> free or trie-evictable: every page is
            exactly one of free / chain-referenced / cached-unref;
          - the trie's evictable count matches its ref-0 owned pages.
        """
        chain_refs = [0] * self.n_pages
        for b, chain in enumerate(self._chain):
            for p in chain:
                assert 0 <= p < self.n_pages, \
                    f"slot {b} chain holds invalid page id {p}"
                chain_refs[p] += 1
        free = set(self._free)
        assert len(free) == len(self._free), "free list holds duplicates"
        owned = (self.cache.owned_pages() if self.cache is not None
                 else set())
        evictable = 0
        for p in range(self.n_pages):
            assert self._ref[p] == chain_refs[p], \
                (f"page {p}: refcount {self._ref[p]} != "
                 f"{chain_refs[p]} chain references")
            if p in free:
                assert chain_refs[p] == 0, \
                    f"page {p} is free but referenced by a chain"
                assert p not in owned, \
                    f"page {p} is free but the trie still owns it"
            elif chain_refs[p] == 0:
                assert p in owned, \
                    f"page {p} leaked: not free, not referenced, not cached"
                evictable += 1
        if self.cache is not None:
            assert evictable == self.cache.evictable, \
                (f"trie evictable counter {self.cache.evictable} != "
                 f"{evictable} ref-0 owned pages")

    def table(self) -> np.ndarray:
        """(n_slots, pages_per_slot) int32 logical->physical map,
        sentinel-filled (n_pages) where unallocated.  Cached; rebuilt
        only after an alloc/release."""
        if self._dirty or self._table is None:
            t = np.full((self.n_slots, self.pages_per_slot), self.n_pages,
                        np.int32)
            for b, chain in enumerate(self._chain):
                if chain:
                    t[b, : len(chain)] = chain
            self._table = t
            self._dirty = False
        return self._table


def copy_pages(pool: dict, src: jax.Array, dst: jax.Array,
               n_pages: int) -> dict:
    """Copy whole physical pages src[i] -> dst[i] in every paged plane.

    src, dst: (B,) int32 physical ids, sentinel (n_pages) rows are
    no-ops (reads clamp, writes drop — the same convention the paged
    kernels use).  This is the device half of copy-on-write: the
    allocator swaps a fresh dst page into a chain (PageAllocator.cow)
    and the engine dispatches this copy BEFORE any kernel that writes
    the page, so the data dependence through the donated pool orders
    the read of src ahead of every later write — even if src is evicted
    and handed to another slot in the same admission batch.  Entries
    past the matched prefix length r are copied garbage; they sit at
    positions >= the sharer's hit and stay masked until the sharer's
    own prefill overwrites them.  Traced; compiled once by the engine
    with fixed (B,) shapes so any COW pattern reuses one program.
    """
    out = dict(pool)
    src_c = jnp.clip(src, 0, n_pages - 1)

    def cp(path, x):  # paged leaves are (K, count, n_pages, page, ...)
        if not _leaf_name(path).endswith("_pages"):
            return x
        rows = x[:, :, src_c]                        # (K, count, B, pg, ..)
        return x.at[:, :, dst].set(rows, mode="drop")

    out["segments"] = jax.tree_util.tree_map_with_path(
        cp, pool["segments"])
    return out


def slot_positions(pool: dict) -> jax.Array:
    """(B,) current per-slot positions (identical across members)."""
    return pool["idx"][0]


def pool_bytes(pool: dict, per_device: bool = True) -> int:
    """Bytes held by the pool (capacity-planning telemetry).

    per_device=True (the default) reports what ONE device actually
    holds: under a member-sharded pool each device stores only its
    K/M members' planes, so the per-device figure is the global one
    divided by the member-axis size (modulo replicated leaves).  That
    is the number capacity planning wants — reporting global bytes for
    a sharded pool would overstate every chip's footprint M-fold.
    per_device=False sums the global (logical) allocation instead.
    Unsharded pools return the same value either way.
    """
    total = 0
    for x in jax.tree.leaves(pool):
        shape = x.shape
        sh = getattr(x, "sharding", None)
        if per_device and sh is not None and hasattr(sh, "shard_shape"):
            shape = sh.shard_shape(x.shape)
        n = 1
        for d in shape:
            n *= d
        total += n * x.dtype.itemsize
    return total


def page_bytes(pool: dict, n_pages: int, per_device: bool = True) -> int:
    """Real bytes ONE physical page costs across all paged planes.

    Sums every "_pages"-suffixed leaf (quantized planes at their stored
    itemsize, scale sidecars included) and divides by n_pages — the
    number admission accounting and the placement summary quote.  A
    quantized pool's figure is ~4x smaller than f32's, which is exactly
    the admissible-concurrency win at equal pool bytes.
    """
    total = 0

    def acc(path, x):
        nonlocal total
        if not _leaf_name(path).endswith("_pages"):
            return
        shape = x.shape
        sh = getattr(x, "sharding", None)
        if per_device and sh is not None and hasattr(sh, "shard_shape"):
            shape = sh.shard_shape(x.shape)
        n = 1
        for d in shape:
            n *= d
        total += n * x.dtype.itemsize

    jax.tree_util.tree_map_with_path(acc, pool["segments"])
    return total // max(n_pages, 1)
