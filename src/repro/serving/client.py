"""Synthetic load driver + latency reporting for the serving engine.

Generates a stream of token-id requests with mixed prompt/output
lengths, pushes them through a Scheduler, and reports the numbers a
serving SLO cares about: aggregate tok/s, time-to-first-token, and
per-request latency percentiles.
"""
from __future__ import annotations

import time
from typing import Dict, Sequence

import numpy as np

from repro.serving.engine import EnsembleEngine
from repro.serving.scheduler import Completion, Scheduler


def make_requests(n: int, vocab: int, prompt_len=(4, 24), max_new=(8, 32),
                  seed: int = 0):
    """-> list of (tokens, max_new) with lengths uniform in the ranges."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        gen = int(rng.integers(max_new[0], max_new[1] + 1))
        reqs.append((rng.integers(0, vocab, size=plen, dtype=np.int32), gen))
    return reqs


def percentile(xs: Sequence[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else 0.0


def run_load(engine: EnsembleEngine, requests, prefill_budget=None) -> dict:
    """Serve `requests` through a fresh Scheduler; -> stats report dict."""
    sched = Scheduler(engine, prefill_budget=prefill_budget)
    for tokens, max_new in requests:
        sched.submit(tokens, max_new)
    t0 = time.time()
    completions = sched.run()
    wall = time.time() - t0
    return build_report(completions, wall, engine)


def build_report(completions: Dict[int, Completion], wall: float,
                 engine: EnsembleEngine) -> dict:
    gen_tokens = sum(len(c.tokens) for c in completions.values())
    ttft = [c.ttft for c in completions.values()]
    lat = [c.latency for c in completions.values()]
    return {
        "n_requests": len(completions),
        "members": engine.n_members,
        "slots": engine.n_slots,
        "gen_tokens": gen_tokens,
        "wall_s": wall,
        "tok_s": gen_tokens / max(wall, 1e-9),
        "ttft_p50_ms": percentile(ttft, 50) * 1e3,
        "ttft_p95_ms": percentile(ttft, 95) * 1e3,
        "latency_p50_ms": percentile(lat, 50) * 1e3,
        "latency_p95_ms": percentile(lat, 95) * 1e3,
        "latency_p99_ms": percentile(lat, 99) * 1e3,
        "cache_mb": engine.cache_bytes() / 2**20,  # per-device
        "page_stats": engine.page_stats(),         # {} when contiguous
    }


def print_report(r: dict):
    ps = r.get("page_stats") or {}
    paged = (f", paged {ps['n_pages']}x{ps['page_size']}-tok pages "
             f"({ps['free_pages']} free)" if ps else "")
    print(f"served {r['n_requests']} requests | K={r['members']} members, "
          f"{r['slots']} slots, cache pool {r['cache_mb']:.1f} MiB/device"
          f"{paged}")
    print(f"  {r['gen_tokens']} tokens in {r['wall_s']:.2f}s "
          f"= {r['tok_s']:.1f} tok/s")
    print(f"  ttft    p50 {r['ttft_p50_ms']:.1f} ms   "
          f"p95 {r['ttft_p95_ms']:.1f} ms")
    print(f"  latency p50 {r['latency_p50_ms']:.1f} ms   "
          f"p95 {r['latency_p95_ms']:.1f} ms   "
          f"p99 {r['latency_p99_ms']:.1f} ms")
