"""Synthetic load driver + latency reporting for the serving engine.

Generates a stream of token-id requests with mixed prompt/output
lengths, pushes them through a Scheduler — in-process, or over the
HTTP frontend (`run_http_load`) — and reports the numbers a serving
SLO cares about: aggregate tok/s, time-to-first-token, per-request
latency percentiles, and scheduler health (preemptions, peak live
slots, paged free-list low-water mark).
"""
from __future__ import annotations

import json
import threading
import time
import urllib.request
from http.client import HTTPConnection
from typing import Dict, List, Optional, Sequence, Tuple
from urllib.parse import urlsplit

import numpy as np

from repro.serving.engine import EnsembleEngine
from repro.serving.scheduler import Completion, Scheduler


def make_requests(n: int, vocab: int, prompt_len=(4, 24), max_new=(8, 32),
                  seed: int = 0):
    """-> list of (tokens, max_new) with lengths uniform in the ranges."""
    rng = np.random.default_rng(seed)
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        gen = int(rng.integers(max_new[0], max_new[1] + 1))
        reqs.append((rng.integers(0, vocab, size=plen, dtype=np.int32), gen))
    return reqs


def percentile(xs: Sequence[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p)) if len(xs) else 0.0


def run_load(engine: EnsembleEngine, requests, prefill_budget=None,
             obs: bool = True, trace_log=None) -> dict:
    """Serve `requests` through a fresh Scheduler; -> stats report dict.
    obs=False runs the kill-switch scheduler (no traces/histograms) —
    the baseline side of the serving_bench overhead gate."""
    sched = Scheduler(engine, prefill_budget=prefill_budget, obs=obs,
                      trace_log=trace_log)
    for tokens, max_new in requests:
        sched.submit(tokens, max_new)
    t0 = time.time()
    completions = sched.run()
    wall = time.time() - t0
    if sched.obs is not None and trace_log:
        sched.obs.close()
    return build_report(completions, wall, engine, sched=sched)


def build_report(completions: Dict[int, Completion], wall: float,
                 engine: EnsembleEngine,
                 sched: Optional[Scheduler] = None) -> dict:
    gen_tokens = sum(len(c.tokens) for c in completions.values())
    ttft = [c.ttft for c in completions.values()]
    lat = [c.latency for c in completions.values()]
    page_stats = engine.page_stats()
    return {
        "n_requests": len(completions),
        "members": engine.n_members,
        "slots": engine.n_slots,
        "gen_tokens": gen_tokens,
        "wall_s": wall,
        "tok_s": gen_tokens / max(wall, 1e-9),
        "ttft_p50_ms": percentile(ttft, 50) * 1e3,
        "ttft_p95_ms": percentile(ttft, 95) * 1e3,
        "ttft_p99_ms": percentile(ttft, 99) * 1e3,
        "latency_p50_ms": percentile(lat, 50) * 1e3,
        "latency_p95_ms": percentile(lat, 95) * 1e3,
        "latency_p99_ms": percentile(lat, 99) * 1e3,
        "cache_mb": engine.cache_bytes() / 2**20,  # per-device
        "page_stats": page_stats,                  # {} when contiguous
        # scheduler health — tracked per run, surfaced here instead of
        # dropped on the floor (preemptions cost re-generation; the
        # low-water mark says how close the pool came to thrashing)
        "preemptions": sched.preemptions if sched else None,
        "peak_in_flight": sched.peak_in_flight if sched else None,
        "low_water_pages": page_stats.get("low_water_pages"),
        # prefix-cache telemetry (None unless the engine runs one):
        # hit rate is FRACTION OF PROMPT TOKENS served from cache
        "prefix_hit_rate": page_stats.get("prefix_hit_rate"),
    }


def print_report(r: dict):
    ps = r.get("page_stats") or {}
    paged = (f", paged {ps['n_pages']}x{ps['page_size']}-tok pages "
             f"({ps['free_pages']} free)" if ps else "")
    print(f"served {r['n_requests']} requests | K={r['members']} members, "
          f"{r['slots']} slots, cache pool {r['cache_mb']:.1f} MiB/device"
          f"{paged}")
    print(f"  {r['gen_tokens']} tokens in {r['wall_s']:.2f}s "
          f"= {r['tok_s']:.1f} tok/s")
    print(f"  ttft    p50 {r['ttft_p50_ms']:.1f} ms   "
          f"p95 {r['ttft_p95_ms']:.1f} ms   "
          f"p99 {r['ttft_p99_ms']:.1f} ms")
    print(f"  latency p50 {r['latency_p50_ms']:.1f} ms   "
          f"p95 {r['latency_p95_ms']:.1f} ms   "
          f"p99 {r['latency_p99_ms']:.1f} ms")
    if ps.get("page_bytes") is not None:
        print(f"  cache   {ps['kv_dtype']} pages, {ps['page_bytes']} "
              f"B/page, {ps['bytes_per_token']} B/token")
    if r.get("peak_in_flight") is not None:
        low = (f", free-list low water {r['low_water_pages']}"
               f"/{ps['n_pages']} pages"
               if r.get("low_water_pages") is not None else "")
        print(f"  health  peak {r['peak_in_flight']} in flight, "
              f"{r['preemptions']} preemptions{low}")
    if r.get("prefix_hit_rate") is not None:
        print(f"  prefix  {100 * r['prefix_hit_rate']:.1f}% of prompt "
              f"tokens from cache | {ps['cached_pages']} cached pages, "
              f"{ps['shared_attaches']} attaches, {ps['cow_pages']} COW "
              f"copies, {ps['evicted_pages']} evicted")
    if r.get("n_errors"):
        print(f"  ERRORS  {r['n_errors']} failed requests "
              f"(first: {r['errors'][0]})")


# -- HTTP load mode ----------------------------------------------------------
#
# The same reporting over the frontend: requests go through
# POST /v1/generate (optionally SSE-streamed) against a live
# FrontendServer, concurrency comes from client threads, and TTFT is
# stamped at the first streamed token — the number an actual network
# client would see.


class Backpressure(RuntimeError):
    """The frontend answered 429 (router queue depth at its limit).
    .retry_after carries the server's Retry-After hint in seconds —
    callers back off that long and retry instead of hammering a
    saturated fleet (FleetRouter.generate does exactly that)."""

    def __init__(self, retry_after: float, detail: str):
        super().__init__(f"HTTP 429: {detail} (retry after "
                         f"{retry_after:.2f}s)")
        self.retry_after = retry_after


def parse_sse(raw: bytes) -> List[Tuple[str, dict]]:
    """Parse a Server-Sent-Events body -> [(event, data), ...]
    ("message" for bare data events)."""
    events = []
    for block in raw.decode().split("\n\n"):
        name, data = "message", []
        for line in block.strip().splitlines():
            if line.startswith("event:"):
                name = line[6:].strip()
            elif line.startswith("data:"):
                data.append(line[5:].strip())
        if data:
            events.append((name, json.loads("\n".join(data))))
    return events


def http_generate(url: str, tokens, max_new: int,
                  stream: bool = False, timeout: float = 120.0,
                  temperature: Optional[float] = None,
                  top_k: Optional[int] = None, seed: Optional[int] = None,
                  draft: Optional[bool] = None) -> dict:
    """One POST /v1/generate; -> {"tokens": [...], "ttft": s|None,
    "latency": s, ...completion fields}.

    temperature/top_k/seed/draft ride in the JSON body as per-request
    overrides (omitted when None: the engine defaults apply).

    stream=True reads the SSE feed incrementally and stamps ttft at
    the first token event, asserting per-token ids agree with the
    terminal done event's full sequence.
    """
    u = urlsplit(url)
    payload = {"tokens": [int(t) for t in np.reshape(tokens, -1)],
               "max_new": int(max_new), "stream": bool(stream)}
    for key, val in (("temperature", temperature), ("top_k", top_k),
                     ("seed", seed), ("draft", draft)):
        if val is not None:
            payload[key] = val
    body = json.dumps(payload).encode()
    conn = HTTPConnection(u.hostname, u.port, timeout=timeout)
    try:
        t0 = time.time()
        conn.request("POST", "/v1/generate", body=body,
                     headers={"Content-Type": "application/json"})
        resp = conn.getresponse()
        if resp.status == 429:
            # backpressure is typed, not a generic failure: the caller
            # can honor the server's backoff hint and retry
            try:
                detail = json.loads(resp.read())
                retry_after = float(detail.get("retry_after", 1.0))
                msg = detail.get("error", "queue full")
            except (ValueError, json.JSONDecodeError):
                retry_after, msg = 1.0, "queue full"
            raise Backpressure(retry_after, msg)
        if resp.status != 200:
            err = resp.read().decode()
            raise RuntimeError(f"HTTP {resp.status}: {err}")
        if not stream:
            out = json.loads(resp.read())
            out["ttft"] = None
            out["latency"] = time.time() - t0
            return out
        # SSE: read incrementally so the first-token stamp is real
        buf, ttft, streamed = b"", None, []
        final = None
        while True:
            chunk = resp.read1(65536)
            if chunk:
                buf += chunk
            while b"\n\n" in buf:
                block, buf = buf.split(b"\n\n", 1)
                for name, data in parse_sse(block + b"\n\n"):
                    if name == "error":
                        raise RuntimeError(f"SSE error: {data['error']}")
                    if name == "done":
                        final = data
                    else:
                        if ttft is None:
                            ttft = time.time() - t0
                        streamed.append(int(data["token"]))
            if final is not None:
                break
            if not chunk:
                raise RuntimeError("SSE stream closed before done event")
        if streamed != final["tokens"]:
            raise RuntimeError(
                f"streamed tokens {streamed} != final {final['tokens']}")
        final["ttft"] = ttft
        final["latency"] = time.time() - t0
        return final
    finally:
        conn.close()


def http_get_json(url: str, path: str, timeout: float = 10.0) -> dict:
    with urllib.request.urlopen(url + path, timeout=timeout) as r:
        return json.loads(r.read())


def http_get_text(url: str, path: str, timeout: float = 10.0) -> str:
    with urllib.request.urlopen(url + path, timeout=timeout) as r:
        return r.read().decode()


def server_percentiles(metrics_text: str) -> dict:
    """Pull the serving histograms' percentiles out of a /metrics
    scrape -> {"ttft_p50_ms": ..., "ttft_p99_ms": ..., ...} (empty
    when the scrape has no samples, e.g. obs disabled)."""
    from repro.serving import obs as obs_mod
    out = {}
    fams = {"ttft": "repro_serving_ttft_seconds",
            "latency": "repro_serving_e2e_latency_seconds"}
    for key, fam in fams.items():
        for p in (50, 95, 99):
            try:
                q = obs_mod.histogram_quantile_from_scrape(
                    metrics_text, fam, p / 100.0)
            except ValueError:
                return {}
            if q is None:
                return {}
            out[f"{key}_p{p}_ms"] = q * 1e3
    return out


def run_http_load(url: str, requests, concurrency: int = 8,
                  stream: bool = True) -> dict:
    """Drive `requests` against a live frontend from `concurrency`
    client threads; -> the same report dict run_load builds (fleet
    shape read from /healthz).

    When the server exports latency histograms on /metrics, the
    report's ttft/latency percentiles come from those server-side
    histograms (queue-wait included, no client network skew) and the
    client-measured values move to client_ttft_* keys; a >20%
    p50/p99 TTFT divergence between the two views is flagged with
    ttft_divergence_warn."""
    results: List[Optional[dict]] = [None] * len(requests)
    errors: List[Tuple[int, str]] = []
    nxt = {"i": 0}
    lock = threading.Lock()

    def worker():
        while True:
            with lock:
                i = nxt["i"]
                if i >= len(requests):
                    return
                nxt["i"] += 1
            toks, max_new = requests[i]
            try:
                results[i] = http_generate(url, toks, max_new,
                                           stream=stream)
            except Exception as e:  # noqa: BLE001 — a failed request
                # must become a reported error, not a dead worker that
                # silently halves concurrency and crashes the report
                with lock:
                    errors.append((i, repr(e)))

    t0 = time.time()
    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(max(1, concurrency))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.time() - t0

    health = http_get_json(url, "/healthz")
    reps = health.get("replicas", [])
    done = [r for r in results if r is not None]
    gen_tokens = sum(r["n_gen"] for r in done)
    ttft = [r["ttft"] for r in done if r["ttft"] is not None]
    lat = [r["latency"] for r in done]
    report = {
        "n_requests": len(done),
        "n_errors": len(errors),
        "errors": errors[:8],
        "members": reps[0]["members"] if reps else 0,
        "slots": sum(r["n_slots"] for r in reps),
        "n_replicas": len(reps),
        "gen_tokens": gen_tokens,
        "wall_s": wall,
        "tok_s": gen_tokens / max(wall, 1e-9),
        "ttft_p50_ms": percentile(ttft, 50) * 1e3,
        "ttft_p95_ms": percentile(ttft, 95) * 1e3,
        "ttft_p99_ms": percentile(ttft, 99) * 1e3,
        "latency_p50_ms": percentile(lat, 50) * 1e3,
        "latency_p95_ms": percentile(lat, 95) * 1e3,
        "latency_p99_ms": percentile(lat, 99) * 1e3,
        "cache_mb": 0.0,  # engine-side; see /metrics
        "page_stats": {},
    }
    try:
        srv = server_percentiles(http_get_text(url, "/metrics"))
    except Exception:  # noqa: BLE001 — the report must survive a
        # frontend that predates /metrics histograms or is draining
        srv = {}
    if srv and ttft:
        divs = []
        for p in (50, 99):
            c, s = report[f"ttft_p{p}_ms"], srv[f"ttft_p{p}_ms"]
            if max(c, s) > 0:
                divs.append(abs(c - s) / max(c, s))
        report["ttft_p99_divergence"] = (
            abs(report["ttft_p99_ms"] - srv["ttft_p99_ms"])
            / max(report["ttft_p99_ms"], srv["ttft_p99_ms"], 1e-9))
        if any(d > 0.20 for d in divs):
            report["ttft_divergence_warn"] = True
            print(f"WARNING: client/server TTFT percentiles diverge "
                  f">20%: client p50 {report['ttft_p50_ms']:.1f} ms / "
                  f"p99 {report['ttft_p99_ms']:.1f} ms vs server "
                  f"p50 {srv['ttft_p50_ms']:.1f} ms / "
                  f"p99 {srv['ttft_p99_ms']:.1f} ms")
        # server-side histograms win the headline numbers; keep the
        # client-clock view for cross-checking
        for p in (50, 95, 99):
            report[f"client_ttft_p{p}_ms"] = report[f"ttft_p{p}_ms"]
            report[f"ttft_p{p}_ms"] = srv[f"ttft_p{p}_ms"]
            report[f"client_latency_p{p}_ms"] = report[f"latency_p{p}_ms"]
            report[f"latency_p{p}_ms"] = srv[f"latency_p{p}_ms"]
        report["latency_source"] = "server"
    return report
