"""repro.serving.frontend — the network tier over the ensemble engine.

Three layers, each usable alone:

  - `scheduler.Scheduler.serve_forever` (one module down): the online
    admit/prefill/decode/harvest loop with streaming callbacks;
  - `frontend.router.Router`: N engine replicas behind one least-loaded
    submit() door, with per-replica draining and the zero-downtime
    drain -> swap_params -> rejoin rollout;
  - `frontend.server.FrontendServer`: the stdlib HTTP/SSE face
    (POST /v1/generate, GET /metrics, GET /healthz, graceful drain).
"""
from repro.serving.frontend.router import Replica, Router
from repro.serving.frontend.server import FrontendServer, serve_frontend

__all__ = ["Replica", "Router", "FrontendServer", "serve_frontend"]
