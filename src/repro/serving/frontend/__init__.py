"""repro.serving.frontend — the network tier over the ensemble engine.

Four layers, each usable alone:

  - `scheduler.Scheduler.serve_forever` (one module down): the online
    admit/prefill/decode/harvest loop with streaming callbacks and
    mid-decode cancellation;
  - `frontend.router.Router`: N engine replicas behind one least-loaded
    submit() door, with per-replica draining, queue-depth backpressure
    (QueueFull -> HTTP 429), and the zero-downtime drain ->
    swap_params -> rejoin rollout (canary fraction optional);
  - `frontend.server.FrontendServer`: the stdlib HTTP/SSE face
    (POST /v1/generate, GET /metrics, GET /healthz, graceful drain);
  - `frontend.replica`: the same boundary over sockets — each replica
    its own OS process (EngineSpec -> ReplicaProcess) behind a
    crash-latching FleetRouter with retry, elastic scaling, and
    canary rollout over POST /admin/swap.
"""
from repro.serving.frontend.replica import (EngineSpec, FleetRouter,
                                            ReplicaProcess)
from repro.serving.frontend.router import QueueFull, Replica, Router
from repro.serving.frontend.server import FrontendServer, serve_frontend

__all__ = ["Replica", "Router", "QueueFull", "FrontendServer",
           "serve_frontend", "EngineSpec", "ReplicaProcess",
           "FleetRouter"]
