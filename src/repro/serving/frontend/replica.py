"""Process-backed replicas: the Router/Replica boundary over sockets.

frontend/router.py scales past one ENGINE by running N replicas on N
threads in one process — but they still share a Python runtime (one
GIL, one heap, one blast radius: an aborted XLA call or a segfault in
a kernel takes every replica with it).  This module promotes the same
boundary to OS processes:

    ReplicaProcess  -- supervisor handle: spawns
                       `python -m repro.serving.frontend.replica` with
                       an EngineSpec, waits for the REPLICA_READY
                       handshake, health-checks over /healthz,
                       terminates gracefully (SIGTERM -> drain) or
                       not (SIGKILL, for fault injection)
    replica process -- builds its engine from the spec, mounts ONE
                       Replica behind the existing Router +
                       FrontendServer stack, prints
                       "REPLICA_READY <port>" once the kernels are
                       compiled, serves until SIGTERM
    FleetRouter     -- the parent-side router: least-loaded routing
                       over live replica ports via HTTP/SSE
                       (client.http_generate), crash latching +
                       retry-on-crash, 429 backoff, elastic
                       scale_to/autoscale from queue depth, and canary
                       rollout driven over POST /admin/swap

Determinism is what makes the fleet testable: an EngineSpec carries
init SEEDS, not weights — every process (and the test's offline
reference engine) rebuilds bit-identical params from
`jax.vmap(tf.init)(split(PRNGKey(seed), K))`, so a request retried on
a different replica after a SIGKILL must return token-exact output.

Failure contract (the soak harness in tests/test_fleet.py enforces
it): a killed replica loses ONLY the requests it was serving at the
moment of death; FleetRouter.generate latches it out of rotation and
retries each lost request on a survivor, so the caller sees every
request completed exactly once — zero drops, zero wedged handlers —
and a restarted process rejoins with a whole page pool (asserted over
the wire from /healthz page accounting).
"""
from __future__ import annotations

import http.client
import json
import os
import signal
import subprocess
import sys
import threading
import time
from collections import deque
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from repro.serving import client as sclient
from repro.serving import obs as obs_mod

_READY = "REPLICA_READY"


# -- the spec: everything a process needs to rebuild the engine ---------------


@dataclass
class EngineSpec:
    """JSON-serializable engine recipe, seed-derived params included.

    Weights never cross the process boundary: `seed` (plus arch /
    members) pins the init, `ckpt`/`ckpt_step` optionally point at a
    CheckpointManager round to restore on top.  Two EngineSpecs that
    compare equal build engines that sample identical tokens — the
    property the fleet soak's token-exactness check rests on.
    """

    arch: str = "gemma3-1b"
    reduced: bool = True
    dtype: str = ""  # "" = the arch's default; tests pin "float32" so
    # greedy argmax cannot fork on near-ties across processes
    members: int = 2
    seed: int = 0
    n_slots: int = 2
    max_prompt: int = 16
    max_out: int = 8
    prefill_chunk: Optional[int] = None
    temperature: float = 0.0
    top_k: int = 0
    eos_id: int = -1
    quorum: Optional[List[float]] = None
    mesh: str = ""
    paged: bool = False
    page_size: int = 4
    n_pages: Optional[int] = None
    prefix_cache: bool = False
    kv_dtype: str = "f32"  # paged page storage: f32|bf16|int8|fp8
    draft_member0: bool = False  # speculative: member 0 drafts
    gamma: int = 4
    spec_sampling: bool = False
    ckpt: str = ""
    ckpt_step: Optional[int] = None
    prefill_budget: Optional[int] = None
    # observability: on by default (obs=False is the kill-switch);
    # trace_log appends one JSONL line per finished request (children
    # of one fleet may share a path — O_APPEND keeps lines whole);
    # profile_dir arms POST /admin/profile on the child's frontend
    obs: bool = True
    trace_log: str = ""
    profile_dir: str = ""
    extra: Dict[str, float] = field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, raw: str) -> "EngineSpec":
        return cls(**json.loads(raw))

    def config(self):
        from repro.configs import registry
        cfg = registry.get_config(self.arch, reduced=self.reduced)
        return cfg.with_(dtype=self.dtype) if self.dtype else cfg

    def init_params(self, seed: Optional[int] = None):
        """The K-member stack this spec pins: vmapped tf.init over
        split(PRNGKey(seed), K) — bit-identical in every process."""
        import jax
        from repro.models import transformer as tf
        cfg = self.config()
        key = jax.random.PRNGKey(self.seed if seed is None else seed)
        return jax.vmap(lambda k: tf.init(k, cfg))(
            jax.random.split(key, self.members))

    def build_engine(self):
        import jax
        from repro.common import sharding as shd
        from repro.serving.engine import EnsembleEngine
        cfg = self.config()
        params = self.init_params()
        if self.ckpt:
            from repro.checkpoint.store import (latest_step,
                                                restore_checkpoint)
            step = (latest_step(self.ckpt) if self.ckpt_step is None
                    else self.ckpt_step)
            if step is None:
                raise ValueError(f"ckpt {self.ckpt}: no committed round")
            params = restore_checkpoint(self.ckpt, step, params)
        mesh = shd.parse_mesh_arg(self.mesh) if self.mesh else None
        kw = dict(n_slots=self.n_slots, max_prompt=self.max_prompt,
                  max_out=self.max_out, prefill_chunk=self.prefill_chunk,
                  temperature=self.temperature, top_k=self.top_k,
                  eos_id=self.eos_id, quorum=self.quorum, seed=self.seed,
                  mesh=mesh, paged=self.paged, page_size=self.page_size,
                  n_pages=self.n_pages, prefix_cache=self.prefix_cache,
                  kv_dtype=self.kv_dtype)
        if self.draft_member0:
            from repro.serving.spec.engine import SpeculativeEngine
            draft = jax.tree.map(lambda x: x[0], params)
            return SpeculativeEngine(cfg, params, draft, gamma=self.gamma,
                                     spec_sampling=self.spec_sampling,
                                     **kw)
        return EnsembleEngine(cfg, params, **kw)


# -- the child process entrypoint ---------------------------------------------


def _make_admin_swap(spec: EngineSpec, router):
    """POST /admin/swap hook for a replica process: build the new
    round's params IN the process (seed or checkpoint — weights never
    ride the request body) and run the in-process drain-swap rollout."""

    def admin_swap(body: dict) -> dict:
        eng = router.replicas[0].engine
        if "seed" in body and body["seed"] is not None:
            s = body["seed"]
            if not isinstance(s, int) or isinstance(s, bool):
                raise ValueError(f"seed must be an int, got {s!r}")
            new_params = spec.init_params(seed=s)
        elif "ckpt" in body:
            from repro.checkpoint.store import (latest_step,
                                                restore_checkpoint)
            root = body["ckpt"]
            step = body.get("step")
            if step is None:
                step = latest_step(root)
            if step is None:
                raise ValueError(f"ckpt {root}: no committed round")
            new_params = restore_checkpoint(root, step, eng.params)
        else:
            raise ValueError('swap body needs "seed" or "ckpt"')
        router.rollout(new_params)
        return {"swaps_done": eng.swaps_done}

    return admin_swap


def main(argv: Optional[List[str]] = None) -> int:
    """Run ONE replica process: engine + scheduler loop + HTTP surface.

    Prints "REPLICA_READY <port>" on stdout once the engine's kernels
    are compiled and the port is bound — the supervisor's spawn
    handshake.  SIGTERM drains gracefully (in-flight requests finish,
    pages return to the pool) and exits 0; SIGKILL is the fault the
    soak harness injects.
    """
    import argparse
    ap = argparse.ArgumentParser(prog="repro.serving.frontend.replica")
    ap.add_argument("--spec", required=True,
                    help="EngineSpec JSON, or @path to a file of it")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 binds an ephemeral port; the bound one is "
                         "reported in the ready line")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="shed with 429 past this queue depth")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    raw = args.spec
    if raw.startswith("@"):
        with open(raw[1:]) as f:
            raw = f.read()
    spec = EngineSpec.from_json(raw)

    from repro.serving.frontend.router import Replica, Router
    from repro.serving.frontend.server import FrontendServer

    engine = spec.build_engine()
    # compile BOTH kernels before declaring ready: the supervisor's
    # handshake must mean "this port serves at decode speed", not
    # "this port exists and the first request eats the compile"
    warm = list(range(1, min(4, spec.max_prompt) + 1))
    engine.generate([warm], max_new=2)
    # static generate defers releasing its chains to the NEXT call; free
    # them now so an idle replica reports a whole page pool from tick one
    engine.update_slots(release=range(engine.n_slots))

    rep = Replica("r0", engine, prefill_budget=spec.prefill_budget,
                  obs=spec.obs, trace_log=spec.trace_log or None,
                  profile_dir=spec.profile_dir or None)
    router = Router([rep], max_queue_depth=args.max_queue_depth)
    srv = FrontendServer(router, host=args.host, port=args.port,
                         verbose=args.verbose,
                         admin_swap=_make_admin_swap(spec, router),
                         profile_dir=spec.profile_dir or None)
    srv.start()

    done = threading.Event()
    signal.signal(signal.SIGTERM, lambda *_: done.set())
    signal.signal(signal.SIGINT, lambda *_: done.set())
    print(f"{_READY} {srv.port}", flush=True)
    while not done.wait(0.2):
        pass
    srv.shutdown(drain=True)
    return 0


# -- the supervisor handle ----------------------------------------------------


def _src_pythonpath() -> str:
    """PYTHONPATH for a child: the repo's src root first (conftest
    inserts it into THIS process's sys.path, but sys.path does not
    inherit across exec), then whatever the parent already had."""
    import repro
    # repro is a namespace package (__file__ is None); __path__ holds
    # the directory the import actually resolved to
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    prior = os.environ.get("PYTHONPATH", "")
    return src + (os.pathsep + prior if prior else "")


class ReplicaProcess:
    """Supervisor handle for one replica process.

    start() spawns the interpreter, a reader thread watches stdout for
    the ready line (and keeps draining it after — a full pipe would
    wedge the child); terminate() is the graceful path (SIGTERM ->
    drain -> exit 0), kill() the fault-injection one (SIGKILL, no
    drain, no goodbye).  `tail` keeps the child's last output lines
    for crash diagnostics.
    """

    def __init__(self, name: str, spec: EngineSpec,
                 host: str = "127.0.0.1",
                 max_queue_depth: Optional[int] = None,
                 verbose: bool = False):
        self.name = name
        self.spec = spec
        self.host = host
        self.max_queue_depth = max_queue_depth
        self.verbose = verbose
        self.port: Optional[int] = None
        self.proc: Optional[subprocess.Popen] = None
        self.tail: deque = deque(maxlen=80)
        self._ready = threading.Event()
        self._reader: Optional[threading.Thread] = None

    def start(self):
        if self.proc is not None and self.proc.poll() is None:
            return
        cmd = [sys.executable, "-m", "repro.serving.frontend.replica",
               "--spec", self.spec.to_json(),
               "--host", self.host, "--port", "0"]
        if self.max_queue_depth is not None:
            cmd += ["--max-queue-depth", str(self.max_queue_depth)]
        if self.verbose:
            cmd += ["--verbose"]
        env = dict(os.environ, PYTHONPATH=_src_pythonpath())
        self.port = None
        self._ready.clear()
        self.proc = subprocess.Popen(
            cmd, env=env, stdout=subprocess.PIPE,
            stderr=subprocess.STDOUT, text=True)
        self._reader = threading.Thread(
            target=self._read_stdout, name=f"replica-io-{self.name}",
            daemon=True)
        self._reader.start()

    def _read_stdout(self):
        proc = self.proc
        for line in proc.stdout:
            line = line.rstrip("\n")
            self.tail.append(line)
            if line.startswith(_READY):
                self.port = int(line.split()[1])
                self._ready.set()
        proc.stdout.close()

    def wait_ready(self, timeout: float = 300.0) -> bool:
        """Block until the ready handshake (kernels compiled, port
        bound) or child death; False on timeout/death."""
        deadline = time.time() + timeout
        while time.time() <= deadline:
            if self._ready.wait(0.1):
                return True
            if self.proc is None or self.proc.poll() is not None:
                return False
        return False

    @property
    def url(self) -> str:
        if self.port is None:
            raise RuntimeError(f"replica {self.name} not ready")
        return f"http://{self.host}:{self.port}"

    @property
    def alive(self) -> bool:
        return (self.proc is not None and self.proc.poll() is None
                and self._ready.is_set())

    def healthz(self, timeout: float = 10.0) -> dict:
        return sclient.http_get_json(self.url, "/healthz", timeout=timeout)

    def terminate(self, timeout: float = 60.0) -> Optional[int]:
        """Graceful retirement: SIGTERM -> drain -> exit; escalates to
        SIGKILL only past `timeout`.  -> exit code (None if never
        started)."""
        if self.proc is None:
            return None
        if self.proc.poll() is None:
            self.proc.send_signal(signal.SIGTERM)
            try:
                self.proc.wait(timeout)
            except subprocess.TimeoutExpired:
                self.proc.kill()
                self.proc.wait(10.0)
        return self.proc.poll()

    def kill(self):
        """Fault injection: SIGKILL, mid-anything.  No drain, no flush
        — exactly the failure the soak harness needs to inject."""
        if self.proc is not None and self.proc.poll() is None:
            self.proc.kill()
            self.proc.wait(10.0)


# -- the parent-side fleet router ---------------------------------------------


class FleetRouter:
    """Route over a fleet of replica processes; survive their deaths.

    The socket-tier analogue of Router: least-loaded routing (local
    in-flight counters — the parent's own view, no health-check on the
    hot path), crash latching (a dead process leaves rotation at the
    next failed request or health_sweep), bounded retry-on-crash (a
    request lost to a SIGKILL reruns on a survivor — same spec, same
    seeds, token-exact), 429-aware backoff, and elastic membership
    (scale_to / autoscale from queue depth).

    rollout(seed=..., canary=0.25) swaps one process first over
    POST /admin/swap, routes ~25% of generate() calls at it until
    `canary_requests` complete, then swaps the rest — the in-process
    canary semantics, spoken over sockets.
    """

    def __init__(self, spec: EngineSpec, n: int = 2,
                 host: str = "127.0.0.1",
                 max_queue_depth: Optional[int] = None,
                 verbose: bool = False):
        if n < 1:
            raise ValueError(f"fleet needs n >= 1 replicas, got {n}")
        self.spec = spec
        self.host = host
        self.max_queue_depth = max_queue_depth
        self.verbose = verbose
        self.procs: List[ReplicaProcess] = [
            self._new_proc(f"p{i}") for i in range(n)]
        self._lock = threading.Lock()
        self._in_flight: Dict[str, int] = {p.name: 0 for p in self.procs}
        self._next_id = n
        self.n_retried = 0      # requests rerun after a replica death
        self.n_backoffs = 0     # 429s honored with a sleep-and-retry
        self.n_latched = 0      # replicas latched out after crashing
        self.n_restarts = 0     # replacement processes spawned
        self.last_sweep_s = 0.0  # wall time of the last health_sweep
        self._canary: Optional[str] = None
        self._canary_frac = 0.0
        self._canary_credit = 0.0
        # fleet-side request traces: which replica served each request,
        # every failover hop (replica_failed -> retried), backpressure
        # waits — the parent's view, complementing the child-side span
        # chain that rides each completion payload
        self.traces = obs_mod.TraceRing(keep=256)
        self._next_trace = 0

    def _new_proc(self, name: str) -> ReplicaProcess:
        return ReplicaProcess(name, self.spec, host=self.host,
                              max_queue_depth=self.max_queue_depth,
                              verbose=self.verbose)

    # -- lifecycle ----------------------------------------------------------

    def start(self, timeout: float = 600.0):
        """Spawn every replica concurrently and wait for all ready
        handshakes (compiles overlap — fleet startup costs one compile
        wall-clock, not n)."""
        for p in self.procs:
            p.start()
        deadline = time.time() + timeout
        for p in self.procs:
            if not p.wait_ready(max(0.0, deadline - time.time())):
                tail = "\n".join(p.tail)
                self.stop()
                raise RuntimeError(
                    f"replica {p.name} never became ready; output:\n{tail}")

    def stop(self):
        for p in self.procs:
            p.terminate(timeout=30.0)

    # -- routing + retry ----------------------------------------------------

    def _pick(self, avoid: Optional[str] = None) -> ReplicaProcess:
        with self._lock:
            live = [p for p in self.procs if p.alive]
            if not live:
                raise RuntimeError("no live replicas in the fleet")
            if avoid is not None:
                # crash retry: a just-killed process can read as alive
                # until poll() observes the death — prefer any other
                # replica over the one that just failed
                live = [p for p in live if p.name != avoid] or live
            if self._canary is not None:
                canary = next((p for p in live
                               if p.name == self._canary), None)
                if canary is not None:
                    self._canary_credit += self._canary_frac
                    if self._canary_credit >= 1.0:
                        self._canary_credit -= 1.0
                        self._in_flight[canary.name] += 1
                        return canary
                    rest = [p for p in live if p.name != canary.name]
                    live = rest or live
            p = min(live, key=lambda p: self._in_flight[p.name])
            self._in_flight[p.name] += 1
            return p

    def _done(self, p: ReplicaProcess):
        with self._lock:
            if p.name in self._in_flight:
                self._in_flight[p.name] -= 1

    def _latch(self, p: ReplicaProcess):
        """A request against `p` failed: if its process is gone, latch
        it out of rotation (alive already False) and count it."""
        if not p.alive:
            with self._lock:
                self.n_latched += 1

    def generate(self, tokens, max_new: int, stream: bool = False,
                 retries: int = 3, timeout: float = 120.0,
                 **sample_kw) -> dict:
        """One request against the fleet; crash-retried, 429-backed-off.

        A replica dying mid-request surfaces as a connection error or
        a mid-SSE close: the request reruns on a survivor (preferring
        any replica other than the one that just failed, after a brief
        backoff), up to `retries` times — identical specs make the
        rerun token-exact.
        429 answers honor Retry-After and do not consume a retry (shed
        load is delay, not failure).  Raises after `retries`
        crash-retries; the soak harness treats any raise as a dropped
        request, which is the invariant under test.

        The returned dict carries a "fleet_trace": the parent-side span
        chain (routed -> [replica_failed -> retried ->] done) — a
        retried request's trace records its failover hops, on top of
        the child-side trace in the completion payload itself.
        """
        with self._lock:
            tid = self._next_trace
            self._next_trace += 1
        tr = self.traces.start(tid)
        tr.add("enqueued")
        crash_left = retries
        avoid = None
        while True:
            p = self._pick(avoid=avoid)
            tr.add("routed", p.name)
            try:
                result = sclient.http_generate(
                    p.url, tokens, max_new, stream=stream,
                    timeout=timeout, **sample_kw)
                tr.add("done")
                self.traces.finish(tid)
                result["fleet_trace"] = tr.to_dict()
                return result
            except sclient.Backpressure as e:
                with self._lock:
                    self.n_backoffs += 1
                tr.add("backpressure", round(e.retry_after, 3))
                time.sleep(min(e.retry_after, 1.0))
            except (OSError, RuntimeError, http.client.HTTPException) as e:
                # a SIGKILL surfaces as whatever the socket was doing:
                # reset (OSError), a mid-SSE close (RuntimeError from
                # http_generate), or a truncated body (IncompleteRead)
                self._latch(p)
                tr.add("replica_failed", p.name)
                crash_left -= 1
                if crash_left < 0:
                    tr.add("failed")
                    self.traces.finish(tid)
                    raise RuntimeError(
                        f"request failed on {p.name} with no retries "
                        f"left: {e!r}") from e
                avoid = p.name
                with self._lock:
                    self.n_retried += 1
                tr.add("retried")
                # a dead port refuses connections INSTANTLY — without a
                # pause the whole retry budget can burn inside the
                # kill -> poll() observation window
                time.sleep(0.1)
            finally:
                self._done(p)

    # -- health + elasticity ------------------------------------------------

    @property
    def queue_depth(self) -> int:
        with self._lock:
            return sum(self._in_flight.values())

    def live(self) -> List[ReplicaProcess]:
        return [p for p in self.procs if p.alive]

    def health_sweep(self) -> List[str]:
        """Latch every dead process out of rotation; -> their names.
        Routing already skips dead processes (alive is a poll(), not a
        cache); the sweep exists so supervision logic — restart,
        autoscale — sees deaths it hasn't tripped over yet.  Its wall
        time lands in last_sweep_s (the fleet scrape's
        repro_serving_fleet_health_sweep_seconds gauge)."""
        t0 = time.monotonic()
        dead = [p.name for p in self.procs
                if p.proc is not None and not p.alive]
        self.last_sweep_s = time.monotonic() - t0
        return dead

    def restart(self, name: str, timeout: float = 600.0) -> ReplicaProcess:
        """Replace a (dead or live) replica with a fresh process under
        the same name — the recovery half of fault injection.  Blocks
        until the replacement's ready handshake."""
        idx = next(i for i, p in enumerate(self.procs) if p.name == name)
        old = self.procs[idx]
        old.terminate(timeout=10.0)
        fresh = self._new_proc(name)
        fresh.start()
        if not fresh.wait_ready(timeout):
            tail = "\n".join(fresh.tail)
            raise RuntimeError(
                f"restarted replica {name} never became ready; "
                f"output:\n{tail}")
        with self._lock:
            self.procs[idx] = fresh
            self._in_flight[name] = 0
            self.n_restarts += 1
        return fresh

    def scale_to(self, n: int, timeout: float = 600.0):
        """Grow or shrink the fleet to n live replicas: spawn fresh
        processes (concurrently) or retire the least-loaded ones
        (gracefully — SIGTERM drains in-flight work first)."""
        if n < 1:
            raise ValueError(f"fleet needs n >= 1 replicas, got {n}")
        live = self.live()
        if n > len(live):
            fresh = []
            with self._lock:
                for _ in range(n - len(live)):
                    p = self._new_proc(f"p{self._next_id}")
                    self._next_id += 1
                    fresh.append(p)
            for p in fresh:
                p.start()
            deadline = time.time() + timeout
            for p in fresh:
                if not p.wait_ready(max(0.0, deadline - time.time())):
                    raise RuntimeError(
                        f"scale-out replica {p.name} never became "
                        f"ready; output:\n" + "\n".join(p.tail))
            with self._lock:
                for p in fresh:
                    self.procs.append(p)
                    self._in_flight[p.name] = 0
        elif n < len(live):
            with self._lock:
                victims = sorted(
                    live, key=lambda p: self._in_flight[p.name])[:len(live) - n]
                names = {p.name for p in victims}
                self.procs = [p for p in self.procs
                              if p.name not in names]
                for name in names:
                    self._in_flight.pop(name, None)
            for p in victims:
                p.terminate()

    def autoscale(self, min_n: int = 1, max_n: int = 4,
                  high_depth: int = 8, low_depth: int = 1) -> int:
        """One elastic step from queue depth: grow by one past
        high_depth, shrink by one under low_depth, clamp to
        [min_n, max_n]; -> the fleet size after the step.  Callers run
        it on whatever cadence they like — policy is a pure function
        of current depth, no hysteresis state to keep."""
        depth = self.queue_depth
        n = len(self.live())
        want = n
        if depth >= high_depth:
            want = min(n + 1, max_n)
        elif depth <= low_depth:
            want = max(n - 1, min_n)
        if want != n:
            self.scale_to(want)
        return len(self.live())

    # -- rollout over the wire ----------------------------------------------

    def _swap_proc(self, p: ReplicaProcess, body: dict) -> dict:
        data = json.dumps(body).encode()
        import urllib.request
        req = urllib.request.Request(
            p.url + "/admin/swap", data=data,
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=600.0) as r:
            return json.loads(r.read())

    def rollout(self, seed: Optional[int] = None, ckpt: str = "",
                step: Optional[int] = None, canary: float = 0.0,
                canary_requests: int = 4, canary_timeout: float = 120.0):
        """Fleet-wide model rollout over POST /admin/swap, one process
        at a time (each process runs its own drain -> swap -> rejoin
        internally).  canary > 0: swap the first live replica, route
        that traffic fraction at it until `canary_requests` of its
        completions land on the new round, then swap the rest; a
        canary that dies aborts the rollout with the remaining fleet
        untouched on the old round.
        """
        body = ({"seed": seed} if seed is not None
                else {"ckpt": ckpt, "step": step})
        if seed is None and not ckpt:
            raise ValueError("rollout needs seed or ckpt")
        remaining = self.live()
        if not remaining:
            raise RuntimeError("no live replicas to roll out to")
        if canary > 0 and len(remaining) > 1:
            first = remaining[0]
            base = first.healthz()["completed"]
            self._swap_proc(first, body)
            with self._lock:
                self._canary = first.name
                self._canary_frac = float(min(canary, 1.0))
                self._canary_credit = 0.0
            try:
                deadline = time.time() + canary_timeout
                while True:
                    if not first.alive:
                        raise RuntimeError(
                            f"canary {first.name} died on the new round; "
                            f"rollout aborted, rest of fleet on the old "
                            f"round")
                    if first.healthz()["completed"] - base \
                            >= canary_requests:
                        break
                    if time.time() > deadline:
                        raise TimeoutError(
                            f"canary saw too little traffic in "
                            f"{canary_timeout}s; rollout aborted")
                    time.sleep(0.05)
            finally:
                with self._lock:
                    self._canary = None
            remaining = remaining[1:]
        for p in remaining:
            self._swap_proc(p, body)

    # -- telemetry ----------------------------------------------------------

    def metrics_text(self, timeout: float = 10.0) -> str:
        """ONE scrape for the whole process tree: GET /metrics from
        every live child, merge (obs.merge_scrapes) with each sample
        re-labeled replica=<child name>, a synthesized replica="fleet"
        row per family (sums for counters/histograms — page, prefix,
        spec and latency stats included — max for gauges), then the
        fleet's own gauges appended: retries, restarts, backoffs,
        latched replicas, canary state, health-sweep latency.  A child
        that dies mid-scrape is skipped, not fatal."""
        scrapes = []
        for p in self.procs:
            if not p.alive:
                continue
            try:
                scrapes.append(
                    (p.name,
                     sclient.http_get_text(p.url, "/metrics",
                                           timeout=timeout)))
            except (OSError, http.client.HTTPException):
                continue
        merged = obs_mod.merge_scrapes(scrapes)
        fs = obs_mod.FamilySet()
        for fam, mtype, val, help in (
            ("repro_serving_fleet_procs", "gauge", len(self.procs),
             "Replica processes the fleet tracks (live + dead)."),
            ("repro_serving_fleet_live_replicas", "gauge",
             len(self.live()), "Replica processes serving traffic."),
            ("repro_serving_fleet_queue_depth", "gauge",
             self.queue_depth, "Parent-side in-flight requests."),
            ("repro_serving_fleet_retries_total", "counter",
             self.n_retried, "Requests rerun after a replica death."),
            ("repro_serving_fleet_restarts_total", "counter",
             self.n_restarts, "Replacement replica processes spawned."),
            ("repro_serving_fleet_backoffs_total", "counter",
             self.n_backoffs, "429 answers honored with a backoff."),
            ("repro_serving_fleet_latched_total", "counter",
             self.n_latched, "Replicas latched out after crashing."),
            ("repro_serving_fleet_health_sweep_seconds", "gauge",
             self.last_sweep_s, "Wall time of the last health_sweep."),
        ):
            fs.declare(fam, mtype, help)
            fs.sample(fam, None, val)
        fs.declare("repro_serving_fleet_canary", "gauge",
                   "1 while the labeled replica serves as canary.")
        if self._canary is not None:
            fs.sample("repro_serving_fleet_canary",
                      {"replica": self._canary}, 1)
        return merged + fs.render()

    def stats(self) -> dict:
        reps = []
        for p in self.procs:
            entry = {"name": p.name, "alive": p.alive, "port": p.port}
            if p.alive:
                try:
                    entry["healthz"] = p.healthz()
                except OSError:
                    entry["alive"] = False
            reps.append(entry)
        return {
            "n_procs": len(self.procs),
            "n_live": len(self.live()),
            "queue_depth": self.queue_depth,
            "retried": self.n_retried,
            "backoffs": self.n_backoffs,
            "latched": self.n_latched,
            "restarts": self.n_restarts,
            "last_sweep_s": self.last_sweep_s,
            "canary": self._canary,
            "replicas": reps,
        }


if __name__ == "__main__":
    raise SystemExit(main())
