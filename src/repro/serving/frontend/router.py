"""Multi-replica router: N engine replicas behind one submit() door.

One EnsembleEngine is bounded by its slot pool (and, paged, its page
pool).  The router scales PAST one engine by running N independent
replicas — each with its own mesh placement, cache pool, and online
scheduler loop on its own thread — and routing every request to the
least-loaded live replica.  Replicas never talk to each other: an
EC-DNN global model is K independent members (paper Eqn 6), so a
replica is a complete serving unit and capacity scales by just adding
more — the same embarrassing parallelism the member axis gives inside
one engine, applied one level up.

Routing policy (`Router.submit`): among non-draining replicas, pick
the one with the fewest in-flight requests (live slots + its own
queue), breaking ties toward the most free pages (from
`EnsembleEngine.page_stats`; contiguous engines tie on free slots).
All policy is host-side and O(N) per request.

Draining (`Router.drain`): a draining replica accepts no new routes
but keeps ticking until its queue and slots empty — in-flight requests
finish normally.  That is the unit step of the zero-downtime rollout:

    rollout(new_stacked_params):
        for each replica, one at a time:
            drain -> wait idle -> engine.swap_params -> rejoin

At most one replica is out of rotation at any moment, every request is
served end-to-end by exactly one model version, and nothing is dropped
— a CheckpointManager round directory published by runtime/trainer.py
reaches a serving fleet mid-traffic this way (launch/serve.py wires
the flag).  With a single replica the router parks incoming requests
in a backlog while it drains and flushes them to the swapped replica
on rejoin: still zero drops, at the cost of queueing delay.

`rollout(..., canary=0.25)` swaps ONE replica first and routes that
fraction of traffic to the new round; only once the canary has served
`canary_requests` completions without its loop failing does the
drain-swap proceed fleet-wide — a bad round is caught while the rest
of the fleet still serves the old one.

Overload is shed, not queued without bound: with `max_queue_depth`
set, submit() raises QueueFull once fleet-wide queue depth (in-flight
+ backlog) crosses the threshold; the HTTP layer answers 429 with
Retry-After.  Cancellation propagates the other way — `cancel(name,
rid)` forwards a client disconnect to the owning replica's
Scheduler.cancel (or unparks a backlog ticket), releasing the slot and
its pages mid-decode.

The same boundary also runs over sockets: frontend/replica.py promotes
each replica to its own OS process (engine, mesh, page pool, and
serve_forever loop behind the replica's own HTTP surface) with the
fleet router speaking HTTP/SSE to replica ports — see ReplicaProcess /
FleetRouter there.  This module stays the in-process tier both build
on.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import List, Optional, Sequence, Tuple

from repro.serving.engine import EnsembleEngine
from repro.serving.scheduler import (Completion, DoneCallback, Scheduler,
                                     TokenCallback)


class QueueFull(RuntimeError):
    """Backpressure: Router.submit refused because fleet queue depth
    crossed max_queue_depth.  .retry_after (seconds) is the router's
    drain estimate — the HTTP layer forwards it as a 429 Retry-After
    header so well-behaved clients back off instead of retry-storming
    a saturated fleet."""

    def __init__(self, depth: int, limit: int, retry_after: float):
        super().__init__(
            f"queue depth {depth} >= max_queue_depth {limit}; "
            f"retry after {retry_after:.2f}s")
        self.depth = depth
        self.limit = limit
        self.retry_after = retry_after


class Replica:
    """One engine + its online scheduler loop, on its own thread."""

    def __init__(self, name: str, engine: EnsembleEngine,
                 prefill_budget: Optional[int] = None,
                 obs=True, trace_log: Optional[str] = None,
                 profile_dir: Optional[str] = None):
        self.name = name
        self.engine = engine
        # never retain completions: a replica loop lives for the
        # process lifetime and delivers results via on_done — keeping
        # every token array in .completions would leak without bound.
        # obs/trace_log/profile_dir ride through to the scheduler's
        # observability layer (on by default; obs=False kill-switch).
        self.scheduler = Scheduler(engine, prefill_budget=prefill_budget,
                                   retain_completions=False, obs=obs,
                                   trace_log=trace_log,
                                   profile_dir=profile_dir)
        self.draining = False
        self.failed: Optional[str] = None  # loop-thread crash, if any
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------

    def _loop(self):
        """serve_forever with a crash latch: an exception out of tick()
        (engine bug, transient XLA failure) must take this replica OUT
        of rotation — a silently dead loop would keep receiving routes
        and hang every handler parked on its callbacks."""
        try:
            self.scheduler.serve_forever()
        except BaseException as e:  # noqa: BLE001 — latch, then re-raise
            self.failed = repr(e)
            self.draining = True
            raise

    def start(self):
        if self._thread is not None and self._thread.is_alive():
            return
        self.scheduler.clear_stop()  # re-arm BEFORE the thread exists:
        # a stop() from here on must win the race, not be erased
        self._thread = threading.Thread(
            target=self._loop, name=f"replica-{self.name}", daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 30.0):
        self.scheduler.stop()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    # -- load telemetry -----------------------------------------------------

    @property
    def in_flight(self) -> int:
        s = self.scheduler
        return s.live_slots + len(s.pending)

    @property
    def idle(self) -> bool:
        return not self.scheduler.has_work

    @property
    def routable(self) -> bool:
        """Eligible for new requests: not draining, not crashed, and
        its loop thread is actually running."""
        return (not self.draining and self.failed is None
                and self._thread is not None and self._thread.is_alive())

    def load_key(self) -> Tuple[int, int, int]:
        """Least-loaded sort key: routable replicas first (a draining
        or crashed replica sorts as infinitely loaded — `_route`
        filters them, but drain() can race the filter, and the key must
        hold on its own), then fewest in-flight, then the scarcer
        capacity signal — free pages on a paged engine, free slots
        otherwise (both negated: more free sorts first)."""
        e = self.engine
        free = (e.free_pages if e.paged
                else e.n_slots - self.scheduler.live_slots)
        return (int(self.draining or self.failed is not None),
                self.in_flight, -free)

    def stats(self) -> dict:
        s, e = self.scheduler, self.engine
        return {
            "name": self.name,
            "draining": self.draining,
            "failed": self.failed,
            "live_slots": s.live_slots,
            "pending": len(s.pending),
            "completed": s.n_completed,
            "cancelled": s.n_cancelled,
            "preemptions": s.preemptions,
            "peak_in_flight": s.peak_in_flight,
            "streamed_tokens": s.n_streamed,
            "steps_run": e.steps_run,
            "prefills_run": e.prefills_run,
            "swaps_done": e.swaps_done,
            "members": e.n_members,
            "n_slots": e.n_slots,
            "cache_bytes_per_device": e.cache_bytes(),
            "page_stats": e.page_stats(),
            # duck-typed: only a SpeculativeEngine carries acceptance
            # telemetry; plain engines report an empty dict
            "spec_stats": (e.spec_stats()
                           if hasattr(e, "spec_stats") else {}),
        }


class Router:
    """Fan N replicas behind one thread-safe submit()/stream door."""

    def __init__(self, replicas: Sequence[Replica],
                 max_queue_depth: Optional[int] = None):
        if not replicas:
            raise ValueError("router needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"replica names must be unique: {names}")
        if max_queue_depth is not None and max_queue_depth < 1:
            raise ValueError(
                f"max_queue_depth must be >= 1, got {max_queue_depth}")
        self.replicas: List[Replica] = list(replicas)
        self._by_name = {r.name: r for r in self.replicas}
        self._lock = threading.Lock()
        # requests that arrived while every replica was draining park
        # here and flush on the next rejoin — drained, never dropped.
        # Entries carry their router-level ticket so cancel("backlog",
        # ticket) can unpark one before a replica picks it up.
        self._backlog: deque = deque()
        # backpressure: past this fleet-wide depth (in-flight across
        # replicas + backlog) submit() sheds with QueueFull instead of
        # queueing without bound; None = never shed
        self.max_queue_depth = max_queue_depth
        self.n_submitted = 0
        self.n_completed = 0
        self.n_rejected = 0   # door validation failures (HTTP 400)
        self.n_shed = 0       # backpressure rejections (HTTP 429)
        self.n_cancelled_backlog = 0  # tickets cancelled while parked
        # canary rollout state: while set, _route sends ~frac of
        # submissions to the named (already-swapped) replica
        self._canary: Optional[str] = None
        self._canary_frac = 0.0
        self._canary_credit = 0.0
        self._started = False

    # -- lifecycle ----------------------------------------------------------

    def start(self):
        for r in self.replicas:
            r.start()
        self._started = True

    def stop(self, drain: bool = True, timeout: float = 60.0):
        """Stop the fleet; drain=True serves out every queued and
        in-flight request first (graceful shutdown), drain=False stops
        after the current tick (in-flight state is abandoned)."""
        if drain:
            self.wait_idle(timeout=timeout)
        for r in self.replicas:
            r.stop()
        self._started = False

    # -- routing ------------------------------------------------------------

    def _route(self) -> Optional[Replica]:
        live = [r for r in self.replicas if r.routable]
        if not live:
            return None
        if self._canary is not None:
            canary = self._by_name.get(self._canary)
            if canary is not None and canary.routable:
                # deterministic fractional routing: accumulate credit
                # per submission, send one to the canary each time it
                # crosses 1.0 — no RNG, exact fraction over any window
                self._canary_credit += self._canary_frac
                if self._canary_credit >= 1.0:
                    self._canary_credit -= 1.0
                    return canary
                rest = [r for r in live if r.name != canary.name]
                if rest:
                    return min(rest, key=Replica.load_key)
        return min(live, key=Replica.load_key)

    @property
    def queue_depth(self) -> int:
        """Fleet-wide demand: queued + live requests across replicas,
        plus the backlog — the number max_queue_depth sheds against."""
        return sum(r.in_flight for r in self.replicas) + len(self._backlog)

    def submit(self, tokens, max_new: int,
               on_token: Optional[TokenCallback] = None,
               on_done: Optional[DoneCallback] = None,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None,
               seed: Optional[int] = None,
               draft: Optional[bool] = None) -> Tuple[str, int]:
        """Route one request to the least-loaded live replica;
        -> (replica name, rid on that replica).  Thread-safe.
        temperature/top_k/seed/draft are per-request overrides handed
        through to Scheduler.submit (None = engine default).

        When every replica is draining (single-replica rollout) the
        request parks in the router backlog and is assigned on the next
        rejoin — the returned name is then "backlog" and the rid is a
        router-level ticket (on_done/on_token still fire normally once
        a replica picks it up).

        With max_queue_depth set, raises QueueFull (not ValueError)
        once fleet-wide queue depth reaches the threshold — the caller
        answers 429 + Retry-After instead of parking another handler
        on a saturated fleet.
        """
        sample_kw = dict(temperature=temperature, top_k=top_k,
                         seed=seed, draft=draft)
        with self._lock:
            if self.max_queue_depth is not None:
                depth = self.queue_depth
                if depth >= self.max_queue_depth:
                    self.n_shed += 1
                    # drain estimate: current depth at ~20 req/s/fleet
                    # is deliberately coarse — the header's job is to
                    # spread the retry herd, not to be a promise
                    raise QueueFull(depth, self.max_queue_depth,
                                    max(0.1, 0.05 * depth))
            rep = self._route()
            if rep is None:
                # validate at the door even while parked, so a bad
                # request is rejected now, not after the rollout
                self.replicas[0].engine.validate_request(
                    tokens, max_new, temperature=temperature,
                    top_k=top_k, seed=seed)
                ticket = self.n_submitted
                self.n_submitted += 1
                done = self._count_done(on_done)
                self._backlog.append(
                    (ticket, tokens, max_new, on_token, done, sample_kw))
                return ("backlog", ticket)
            # count only after validation inside submit() passes —
            # door-rejected requests must not inflate the counter (the
            # backlog branch above validates before ticketing too)
            rid = rep.scheduler.submit(tokens, max_new, on_token=on_token,
                                       on_done=self._count_done(on_done),
                                       **sample_kw)
            self.n_submitted += 1
            return (rep.name, rid)

    def count_rejected(self):
        """Door-rejection counter bump, under the router lock (handler
        threads race on it)."""
        with self._lock:
            self.n_rejected += 1

    def replica_dead(self, name: str) -> bool:
        """Can `name` still deliver callbacks?  True once its loop
        thread has crashed or exited — waiters must give up instead of
        parking forever.  "backlog" tickets are router-owned (False)."""
        rep = self._by_name.get(name)
        if rep is None:
            return False
        t = rep._thread
        return rep.failed is not None or (t is not None and not t.is_alive())

    def _count_done(self, on_done: Optional[DoneCallback]) -> DoneCallback:
        def counting(comp: Completion):
            with self._lock:  # loop threads race on the counter
                self.n_completed += 1
            if on_done is not None:
                on_done(comp)
        return counting

    def cancel(self, name: str, rid: int) -> bool:
        """Propagate a client disconnect: forward to the owning
        replica's Scheduler.cancel (which releases the slot, pages,
        and prefix refs at its next tick), or unpark a "backlog"
        ticket before any replica picks it up.  -> False when the
        request already finished (benign race) or the name is gone."""
        if name == "backlog":
            with self._lock:
                for entry in self._backlog:
                    if entry[0] == rid:
                        self._backlog.remove(entry)
                        self.n_cancelled_backlog += 1
                        return True
            return False
        rep = self._by_name.get(name)
        return rep.scheduler.cancel(rid) if rep is not None else False

    def _flush_backlog_locked(self):
        while self._backlog:
            rep = self._route()
            if rep is None:
                return
            (_, tokens, max_new, on_token, done,
             sample_kw) = self._backlog.popleft()
            rep.scheduler.submit(tokens, max_new, on_token=on_token,
                                 on_done=done, **sample_kw)

    # -- elastic membership -------------------------------------------------

    def add_replica(self, rep: Replica):
        """Grow the fleet under traffic: register (and start, if the
        router is running) a new replica and hand it any backlog.  The
        elastic scale-out step — FleetRouter drives the process-backed
        equivalent from queue depth."""
        with self._lock:
            if rep.name in self._by_name:
                raise ValueError(f"replica name {rep.name!r} already "
                                 f"in the fleet")
            self.replicas.append(rep)
            self._by_name[rep.name] = rep
            if self._started:
                rep.start()
            self._flush_backlog_locked()

    def remove_replica(self, name: str, timeout: float = 120.0) -> Replica:
        """Retire one replica gracefully: drain -> wait -> stop -> drop
        from rotation; -> the detached Replica (its engine can be
        reused or discarded).  Refuses to empty the fleet."""
        rep = self._by_name[name]
        with self._lock:
            if len(self.replicas) <= 1:
                raise ValueError("cannot retire the last replica")
        self.drain(name)
        if not self.wait_drained(name, timeout=timeout):
            raise TimeoutError(
                f"replica {name} did not drain within {timeout}s "
                f"({rep.in_flight} in flight); still in rotation "
                f"(draining)")
        rep.stop()
        with self._lock:
            self.replicas.remove(rep)
            self._by_name.pop(name)
        return rep

    # -- draining + rollout -------------------------------------------------

    def drain(self, name: str):
        """Take one replica out of rotation; its in-flight and queued
        requests keep running to completion.  Taken under the router
        lock so a submit that already routed here finishes enqueueing
        first — wait_drained then cannot observe a falsely-idle
        replica."""
        with self._lock:
            self._by_name[name].draining = True

    def rejoin(self, name: str):
        """Put a drained replica back in rotation and hand it any
        backlogged requests."""
        with self._lock:
            self._by_name[name].draining = False
            self._flush_backlog_locked()

    def wait_drained(self, name: str, timeout: float = 120.0,
                     poll: float = 0.005) -> bool:
        """Block until a draining replica has no queued or live work
        AND its loop has flushed every pending page release — event-
        based (Scheduler.wait_quiesced): the loop signals its own park,
        so this waits on the state transition itself, not on a
        wall-clock sleep happening to land after it.  `poll` is kept
        for signature compatibility; the quiesce event supersedes it."""
        del poll
        return self._by_name[name].scheduler.wait_quiesced(timeout)

    def wait_idle(self, timeout: float = 120.0, poll: float = 0.005) -> bool:
        """Block until every replica is quiesced (idle, releases
        flushed) and the backlog is empty."""
        del poll
        deadline = time.time() + timeout
        while time.time() <= deadline:
            if not all(r.scheduler.wait_quiesced(
                    max(0.0, deadline - time.time()))
                    for r in self.replicas):
                return False
            with self._lock:
                # a backlog flush re-fills replicas; re-check quiesce
                # on the next pass if anything moved
                if not self._backlog:
                    if all(not r.scheduler.has_work for r in self.replicas):
                        return True
        return False

    def _swap_one(self, rep: Replica, new_stacked_params,
                  timeout: float):
        """The rollout unit step: drain -> wait -> swap -> assert zero
        stale pages -> rejoin, for one replica."""
        self.drain(rep.name)
        try:
            if not self.wait_drained(rep.name, timeout=timeout):
                raise TimeoutError(
                    f"replica {rep.name} did not drain within "
                    f"{timeout}s ({rep.in_flight} in flight)")
            rep.engine.swap_params(new_stacked_params)
            ps = rep.engine.page_stats()
            if ps.get("cached_pages", 0) or ps.get("shared_pages", 0):
                raise RuntimeError(
                    f"replica {rep.name}: {ps.get('cached_pages', 0)} "
                    f"cached / {ps.get('shared_pages', 0)} shared "
                    f"pages survived a drained rollout — stale "
                    f"round-t KV would serve round t+1")
        finally:
            self.rejoin(rep.name)

    def rollout(self, new_stacked_params, timeout: float = 120.0,
                canary: float = 0.0, canary_requests: int = 8,
                canary_timeout: float = 120.0):
        """Zero-downtime model rollout: drain -> swap -> rejoin, one
        replica at a time, under live traffic.

        Every request is served end-to-end by exactly one model
        version (the drain barrier guarantees no slot is live at swap
        time) and none are dropped (the rest of the fleet — or the
        backlog, for a single replica — absorbs arrivals).  The swap
        itself reuses the replica's compiled kernels: same shapes, same
        jitted callables, zero recompiles.

        canary > 0 (multi-replica fleets): swap ONE replica first and
        route that fraction of incoming traffic to it until it has
        served `canary_requests` completions on the new round; only
        then does the fleet-wide drain-swap proceed.  A canary whose
        loop fails aborts the rollout with the REST of the fleet still
        on the old round (the canary stays latched out of rotation) —
        the blast radius of a bad round is the traffic fraction, not
        the fleet.  The canary window needs live traffic to observe;
        without any it times out (canary_timeout) and aborts the same
        way.  canary on a single-replica fleet degrades to the plain
        rollout (there is no old-round fleet to protect).

        Prefix-cache replicas additionally flush their trie inside
        swap_params — cached pages hold the OLD model's KV — and
        because the replica is fully drained here, the flush must
        leave ZERO shared or cached pages behind; a survivor would be
        a stale round-t prefix able to serve a round-t+1 request, so
        it is asserted, not assumed.
        """
        remaining = list(self.replicas)
        if canary > 0 and len(remaining) > 1:
            first = remaining[0]
            self._swap_one(first, new_stacked_params, timeout)
            base = first.scheduler.n_completed
            with self._lock:
                self._canary = first.name
                self._canary_frac = float(min(canary, 1.0))
                self._canary_credit = 0.0
            try:
                deadline = time.time() + canary_timeout
                while first.scheduler.n_completed - base < canary_requests:
                    if first.failed is not None:
                        raise RuntimeError(
                            f"canary replica {first.name} failed on the "
                            f"new round ({first.failed}); rollout "
                            f"aborted with the rest of the fleet on the "
                            f"old round")
                    if time.time() > deadline:
                        raise TimeoutError(
                            f"canary window saw only "
                            f"{first.scheduler.n_completed - base}/"
                            f"{canary_requests} completions in "
                            f"{canary_timeout}s (a canary needs live "
                            f"traffic); rollout aborted")
                    time.sleep(0.005)
            finally:
                with self._lock:
                    self._canary = None
            remaining = remaining[1:]
        for rep in remaining:
            self._swap_one(rep, new_stacked_params, timeout)

    # -- telemetry ----------------------------------------------------------

    def trace(self, rid: int,
              replica: Optional[str] = None) -> Optional[Tuple[str, dict]]:
        """Look up one request's span chain (GET /v1/trace/<rid>).
        rids are per-replica, so pass `replica` to disambiguate (the
        completion payload carries both); without it the first replica
        holding the rid wins.  -> (replica name, trace dict) or None
        when unknown / already evicted / observability off."""
        reps = ([self._by_name[replica]]
                if replica is not None and replica in self._by_name
                else self.replicas)
        if replica is not None and replica not in self._by_name:
            return None
        for rep in reps:
            obs = rep.scheduler.obs
            if obs is None:
                continue
            tr = obs.traces.get(rid)
            if tr is not None:
                return rep.name, tr.to_dict()
        return None

    def profile(self, ticks: int, out_dir: Optional[str] = None) -> str:
        """Arm a jax.profiler window over the next `ticks` tick() calls
        of the FIRST routable replica (device traces are process-wide —
        arming several schedulers would double-start the profiler).
        -> the armed replica's name (POST /admin/profile)."""
        live = [r for r in self.replicas if r.routable] or self.replicas
        rep = live[0]
        rep.scheduler.profile_next_ticks(ticks, out_dir)
        return rep.name

    def stats(self) -> dict:
        reps = [r.stats() for r in self.replicas]
        return {
            "replicas": reps,
            "n_replicas": len(reps),
            "submitted": self.n_submitted,
            "completed": self.n_completed,
            "rejected": self.n_rejected,
            "shed": self.n_shed,
            "cancelled": (sum(r["cancelled"] for r in reps)
                          + self.n_cancelled_backlog),
            "queue_depth": self.queue_depth,
            "max_queue_depth": self.max_queue_depth,
            "canary": self._canary,
            "backlog": len(self._backlog),
            "live_slots": sum(r["live_slots"] for r in reps),
            "pending": sum(r["pending"] for r in reps),
            "streamed_tokens": sum(r["streamed_tokens"] for r in reps),
        }
