"""Multi-replica router: N engine replicas behind one submit() door.

One EnsembleEngine is bounded by its slot pool (and, paged, its page
pool).  The router scales PAST one engine by running N independent
replicas — each with its own mesh placement, cache pool, and online
scheduler loop on its own thread — and routing every request to the
least-loaded live replica.  Replicas never talk to each other: an
EC-DNN global model is K independent members (paper Eqn 6), so a
replica is a complete serving unit and capacity scales by just adding
more — the same embarrassing parallelism the member axis gives inside
one engine, applied one level up.

Routing policy (`Router.submit`): among non-draining replicas, pick
the one with the fewest in-flight requests (live slots + its own
queue), breaking ties toward the most free pages (from
`EnsembleEngine.page_stats`; contiguous engines tie on free slots).
All policy is host-side and O(N) per request.

Draining (`Router.drain`): a draining replica accepts no new routes
but keeps ticking until its queue and slots empty — in-flight requests
finish normally.  That is the unit step of the zero-downtime rollout:

    rollout(new_stacked_params):
        for each replica, one at a time:
            drain -> wait idle -> engine.swap_params -> rejoin

At most one replica is out of rotation at any moment, every request is
served end-to-end by exactly one model version, and nothing is dropped
— a CheckpointManager round directory published by runtime/trainer.py
reaches a serving fleet mid-traffic this way (launch/serve.py wires
the flag).  With a single replica the router parks incoming requests
in a backlog while it drains and flushes them to the swapped replica
on rejoin: still zero drops, at the cost of queueing delay.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import List, Optional, Sequence, Tuple

from repro.serving.engine import EnsembleEngine
from repro.serving.scheduler import (Completion, DoneCallback, Scheduler,
                                     TokenCallback)


class Replica:
    """One engine + its online scheduler loop, on its own thread."""

    def __init__(self, name: str, engine: EnsembleEngine,
                 prefill_budget: Optional[int] = None):
        self.name = name
        self.engine = engine
        # never retain completions: a replica loop lives for the
        # process lifetime and delivers results via on_done — keeping
        # every token array in .completions would leak without bound
        self.scheduler = Scheduler(engine, prefill_budget=prefill_budget,
                                   retain_completions=False)
        self.draining = False
        self.failed: Optional[str] = None  # loop-thread crash, if any
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle ----------------------------------------------------------

    def _loop(self):
        """serve_forever with a crash latch: an exception out of tick()
        (engine bug, transient XLA failure) must take this replica OUT
        of rotation — a silently dead loop would keep receiving routes
        and hang every handler parked on its callbacks."""
        try:
            self.scheduler.serve_forever()
        except BaseException as e:  # noqa: BLE001 — latch, then re-raise
            self.failed = repr(e)
            self.draining = True
            raise

    def start(self):
        if self._thread is not None and self._thread.is_alive():
            return
        self.scheduler.clear_stop()  # re-arm BEFORE the thread exists:
        # a stop() from here on must win the race, not be erased
        self._thread = threading.Thread(
            target=self._loop, name=f"replica-{self.name}", daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 30.0):
        self.scheduler.stop()
        if self._thread is not None:
            self._thread.join(timeout)
            self._thread = None

    # -- load telemetry -----------------------------------------------------

    @property
    def in_flight(self) -> int:
        s = self.scheduler
        return s.live_slots + len(s.pending)

    @property
    def idle(self) -> bool:
        return not self.scheduler.has_work

    @property
    def routable(self) -> bool:
        """Eligible for new requests: not draining, not crashed, and
        its loop thread is actually running."""
        return (not self.draining and self.failed is None
                and self._thread is not None and self._thread.is_alive())

    def load_key(self) -> Tuple[int, int, int]:
        """Least-loaded sort key: routable replicas first (a draining
        or crashed replica sorts as infinitely loaded — `_route`
        filters them, but drain() can race the filter, and the key must
        hold on its own), then fewest in-flight, then the scarcer
        capacity signal — free pages on a paged engine, free slots
        otherwise (both negated: more free sorts first)."""
        e = self.engine
        free = (e.free_pages if e.paged
                else e.n_slots - self.scheduler.live_slots)
        return (int(self.draining or self.failed is not None),
                self.in_flight, -free)

    def stats(self) -> dict:
        s, e = self.scheduler, self.engine
        return {
            "name": self.name,
            "draining": self.draining,
            "failed": self.failed,
            "live_slots": s.live_slots,
            "pending": len(s.pending),
            "completed": s.n_completed,
            "preemptions": s.preemptions,
            "peak_in_flight": s.peak_in_flight,
            "streamed_tokens": s.n_streamed,
            "steps_run": e.steps_run,
            "prefills_run": e.prefills_run,
            "swaps_done": e.swaps_done,
            "members": e.n_members,
            "n_slots": e.n_slots,
            "cache_bytes_per_device": e.cache_bytes(),
            "page_stats": e.page_stats(),
            # duck-typed: only a SpeculativeEngine carries acceptance
            # telemetry; plain engines report an empty dict
            "spec_stats": (e.spec_stats()
                           if hasattr(e, "spec_stats") else {}),
        }


class Router:
    """Fan N replicas behind one thread-safe submit()/stream door."""

    def __init__(self, replicas: Sequence[Replica]):
        if not replicas:
            raise ValueError("router needs at least one replica")
        names = [r.name for r in replicas]
        if len(set(names)) != len(names):
            raise ValueError(f"replica names must be unique: {names}")
        self.replicas: List[Replica] = list(replicas)
        self._by_name = {r.name: r for r in self.replicas}
        self._lock = threading.Lock()
        # requests that arrived while every replica was draining park
        # here and flush on the next rejoin — drained, never dropped
        self._backlog: deque = deque()
        self.n_submitted = 0
        self.n_completed = 0
        self.n_rejected = 0
        self._started = False

    # -- lifecycle ----------------------------------------------------------

    def start(self):
        for r in self.replicas:
            r.start()
        self._started = True

    def stop(self, drain: bool = True, timeout: float = 60.0):
        """Stop the fleet; drain=True serves out every queued and
        in-flight request first (graceful shutdown), drain=False stops
        after the current tick (in-flight state is abandoned)."""
        if drain:
            self.wait_idle(timeout=timeout)
        for r in self.replicas:
            r.stop()
        self._started = False

    # -- routing ------------------------------------------------------------

    def _route(self) -> Optional[Replica]:
        live = [r for r in self.replicas if r.routable]
        if not live:
            return None
        return min(live, key=Replica.load_key)

    def submit(self, tokens, max_new: int,
               on_token: Optional[TokenCallback] = None,
               on_done: Optional[DoneCallback] = None,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None,
               seed: Optional[int] = None,
               draft: Optional[bool] = None) -> Tuple[str, int]:
        """Route one request to the least-loaded live replica;
        -> (replica name, rid on that replica).  Thread-safe.
        temperature/top_k/seed/draft are per-request overrides handed
        through to Scheduler.submit (None = engine default).

        When every replica is draining (single-replica rollout) the
        request parks in the router backlog and is assigned on the next
        rejoin — the returned name is then "backlog" and the rid is a
        router-level ticket (on_done/on_token still fire normally once
        a replica picks it up).
        """
        sample_kw = dict(temperature=temperature, top_k=top_k,
                         seed=seed, draft=draft)
        with self._lock:
            rep = self._route()
            if rep is None:
                # validate at the door even while parked, so a bad
                # request is rejected now, not after the rollout
                self.replicas[0].engine.validate_request(
                    tokens, max_new, temperature=temperature,
                    top_k=top_k, seed=seed)
                ticket = self.n_submitted
                self.n_submitted += 1
                done = self._count_done(on_done)
                self._backlog.append(
                    (tokens, max_new, on_token, done, sample_kw))
                return ("backlog", ticket)
            # count only after validation inside submit() passes —
            # door-rejected requests must not inflate the counter (the
            # backlog branch above validates before ticketing too)
            rid = rep.scheduler.submit(tokens, max_new, on_token=on_token,
                                       on_done=self._count_done(on_done),
                                       **sample_kw)
            self.n_submitted += 1
            return (rep.name, rid)

    def count_rejected(self):
        """Door-rejection counter bump, under the router lock (handler
        threads race on it)."""
        with self._lock:
            self.n_rejected += 1

    def replica_dead(self, name: str) -> bool:
        """Can `name` still deliver callbacks?  True once its loop
        thread has crashed or exited — waiters must give up instead of
        parking forever.  "backlog" tickets are router-owned (False)."""
        rep = self._by_name.get(name)
        if rep is None:
            return False
        t = rep._thread
        return rep.failed is not None or (t is not None and not t.is_alive())

    def _count_done(self, on_done: Optional[DoneCallback]) -> DoneCallback:
        def counting(comp: Completion):
            with self._lock:  # loop threads race on the counter
                self.n_completed += 1
            if on_done is not None:
                on_done(comp)
        return counting

    def _flush_backlog_locked(self):
        while self._backlog:
            rep = self._route()
            if rep is None:
                return
            (tokens, max_new, on_token, done,
             sample_kw) = self._backlog.popleft()
            rep.scheduler.submit(tokens, max_new, on_token=on_token,
                                 on_done=done, **sample_kw)

    # -- draining + rollout -------------------------------------------------

    def drain(self, name: str):
        """Take one replica out of rotation; its in-flight and queued
        requests keep running to completion.  Taken under the router
        lock so a submit that already routed here finishes enqueueing
        first — wait_drained then cannot observe a falsely-idle
        replica."""
        with self._lock:
            self._by_name[name].draining = True

    def rejoin(self, name: str):
        """Put a drained replica back in rotation and hand it any
        backlogged requests."""
        with self._lock:
            self._by_name[name].draining = False
            self._flush_backlog_locked()

    def wait_drained(self, name: str, timeout: float = 120.0,
                     poll: float = 0.005) -> bool:
        """Block until a draining replica has no queued or live work."""
        rep = self._by_name[name]
        deadline = time.time() + timeout
        while not rep.idle:
            if time.time() > deadline:
                return False
            time.sleep(poll)
        return True

    def wait_idle(self, timeout: float = 120.0, poll: float = 0.005) -> bool:
        """Block until every replica (and the backlog) is quiet."""
        deadline = time.time() + timeout
        while (self._backlog
               or any(not r.idle for r in self.replicas)):
            if time.time() > deadline:
                return False
            time.sleep(poll)
        return True

    def rollout(self, new_stacked_params, timeout: float = 120.0):
        """Zero-downtime model rollout: drain -> swap -> rejoin, one
        replica at a time, under live traffic.

        Every request is served end-to-end by exactly one model
        version (the drain barrier guarantees no slot is live at swap
        time) and none are dropped (the rest of the fleet — or the
        backlog, for a single replica — absorbs arrivals).  The swap
        itself reuses the replica's compiled kernels: same shapes, same
        jitted callables, zero recompiles.

        Prefix-cache replicas additionally flush their trie inside
        swap_params — cached pages hold the OLD model's KV — and
        because the replica is fully drained here, the flush must
        leave ZERO shared or cached pages behind; a survivor would be
        a stale round-t prefix able to serve a round-t+1 request, so
        it is asserted, not assumed.
        """
        for rep in self.replicas:
            self.drain(rep.name)
            try:
                if not self.wait_drained(rep.name, timeout=timeout):
                    raise TimeoutError(
                        f"replica {rep.name} did not drain within "
                        f"{timeout}s ({rep.in_flight} in flight)")
                rep.engine.swap_params(new_stacked_params)
                ps = rep.engine.page_stats()
                if ps.get("cached_pages", 0) or ps.get("shared_pages", 0):
                    raise RuntimeError(
                        f"replica {rep.name}: {ps.get('cached_pages', 0)} "
                        f"cached / {ps.get('shared_pages', 0)} shared "
                        f"pages survived a drained rollout — stale "
                        f"round-t KV would serve round t+1")
            finally:
                self.rejoin(rep.name)

    # -- telemetry ----------------------------------------------------------

    def stats(self) -> dict:
        reps = [r.stats() for r in self.replicas]
        return {
            "replicas": reps,
            "n_replicas": len(reps),
            "submitted": self.n_submitted,
            "completed": self.n_completed,
            "rejected": self.n_rejected,
            "backlog": len(self._backlog),
            "live_slots": sum(r["live_slots"] for r in reps),
            "pending": sum(r["pending"] for r in reps),
            "streamed_tokens": sum(r["streamed_tokens"] for r in reps),
        }
