"""Streaming HTTP frontend over the replica router — stdlib only.

Endpoints (token-id API; tokenizers are out of scope repo-wide):

  POST /v1/generate     body {"tokens": [1,2,3], "max_new": 8,
                              "stream": false}
      stream=false -> one JSON document when the request completes:
          {"tokens": [...], "n_gen": n, "prompt_len": p,
           "replica": name, "rid": i, "ttft_ms": t, "latency_ms": l}
      stream=true  -> Server-Sent Events, one event per generated
          token AS IT IS SAMPLED (the scheduler's harvest phase fires
          the per-token callback straight into the handler's queue):
              data: {"index": 0, "token": 1234}
          then a terminal event carrying the full completion:
              event: done
              data: {"tokens": [...], "n_gen": ..., ...}
  GET /healthz          liveness + per-replica drain state (200, or
                        503 once shutdown begins); paged replicas also
                        report page accounting (n_pages/free/available)
                        so a supervisor can check for leaks remotely
  GET /metrics          Prometheus-style text: requests, tokens,
                        live slots, free pages, preemptions, ...
  POST /admin/swap      (servers built with an admin_swap hook —
                        replica processes wire one in)
                        roll a new round into this process's fleet:
                        body {"seed": s} rebuilds the K-member stack
                        from that init seed, {"ckpt": root, "step": n}
                        restores a CheckpointManager round; the swap
                        runs the router's drain -> swap -> rejoin

A client that disconnects mid-SSE-stream CANCELS its request: the
write failure surfaces as BrokenPipeError in the handler, which
forwards Router.cancel -> Scheduler.cancel, releasing the slot, its
pages, and any prefix-trie refs mid-decode instead of finishing a
stream nobody is reading.  Backpressure composes at the same door:
when the router's queue depth crosses its threshold, POST /v1/generate
answers 429 with a Retry-After header instead of parking another
handler thread on a saturated fleet.

Built on http.server.ThreadingHTTPServer: one handler thread per
connection parks on a queue.Queue that the scheduler loop feeds via
on_token/on_done — the decode path never blocks on a slow client
beyond queue puts, and the server needs no dependency the repo does
not already carry.  SSE responses are close-delimited (Connection:
close) so any HTTP/1.x client can read them without chunked-decoding
support.

Shutdown (`FrontendServer.shutdown`) is a graceful drain by default:
stop accepting new connections, serve out every queued and in-flight
request (handler threads unblock as their completions fire), then stop
the replica loops.  `/healthz` flips to 503 the moment the drain
starts so external load balancers stop sending traffic.
"""
from __future__ import annotations

import json
import queue
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import parse_qs, urlsplit

from repro.serving import obs as obs_mod
from repro.serving.frontend.router import QueueFull, Router

_DONE = object()  # queue sentinel: completion follows no more tokens


def _completion_payload(comp, replica: str, rid: int) -> dict:
    p = {
        "tokens": [int(t) for t in comp.tokens],
        "n_gen": int(len(comp.tokens)),
        "prompt_len": int(comp.prompt_len),
        "replica": replica,
        "rid": int(rid),
        "ttft_ms": round(comp.ttft * 1e3, 3),
        "latency_ms": round(comp.latency * 1e3, 3),
    }
    if comp.trace is not None:
        # the request's span chain rides the terminal payload (SSE
        # `event: done` / the non-streamed JSON document) so clients
        # get their trace without a second round trip
        p["trace"] = comp.trace
    return p


class _Handler(BaseHTTPRequestHandler):
    """One instance per request; the server wires .router in."""

    protocol_version = "HTTP/1.1"
    server_version = "repro-serving"

    # the ThreadingHTTPServer subclass below carries these
    router: Router
    frontend: "FrontendServer"

    def log_message(self, fmt, *args):  # quiet by default
        if self.frontend.verbose:
            super().log_message(fmt, *args)

    # -- plumbing -----------------------------------------------------------

    def _send_json(self, code: int, payload: dict,
                   headers: Optional[dict] = None):
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def _read_body(self) -> Optional[dict]:
        try:
            n = int(self.headers.get("Content-Length", 0))
            return json.loads(self.rfile.read(n) or b"{}")
        except (ValueError, json.JSONDecodeError):
            return None

    # -- routes -------------------------------------------------------------

    def do_GET(self):
        if self.path == "/healthz":
            stats = self.router.stats()
            alive = not self.frontend.draining
            reps = []
            for r in stats["replicas"]:
                rep = {"name": r["name"], "draining": r["draining"],
                       "failed": r["failed"],
                       "live_slots": r["live_slots"], "pending": r["pending"],
                       "completed": r["completed"],
                       "cancelled": r["cancelled"],
                       "members": r["members"], "n_slots": r["n_slots"],
                       "swaps_done": r["swaps_done"]}
                ps = r["page_stats"]
                if ps:
                    # page accounting over the wire: a fleet supervisor
                    # asserts available_pages == n_pages on a drained
                    # replica process without reaching into it
                    rep["n_pages"] = ps["n_pages"]
                    rep["free_pages"] = ps["free_pages"]
                    rep["available_pages"] = ps["available_pages"]
                    rep["shared_pages"] = ps["shared_pages"]
                    rep["cached_pages"] = ps.get("cached_pages", 0)
                reps.append(rep)
            payload = {
                "ok": alive,
                "draining": self.frontend.draining,
                "queue_depth": stats["queue_depth"],
                "cancelled": stats["cancelled"],
                "shed": stats["shed"],
                "completed": stats["completed"],
                "replicas": reps,
            }
            self._send_json(200 if alive else 503, payload)
        elif self.path == "/metrics":
            body = self.frontend.metrics_text().encode()
            self.send_response(200)
            self.send_header("Content-Type", "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
        elif self.path.startswith("/v1/trace/"):
            url = urlsplit(self.path)
            rid_s = url.path[len("/v1/trace/"):]
            if not rid_s.isdigit():
                self._send_json(400, {"error": "trace id must be an "
                                               "integer rid"})
                return
            qs = parse_qs(url.query)
            rep = (qs.get("replica") or [None])[0]
            found = self.router.trace(int(rid_s), replica=rep)
            if found is None:
                self._send_json(404, {"error": f"no trace for rid "
                                               f"{rid_s} (evicted, "
                                               f"unknown, or obs off)"})
                return
            name, trace = found
            self._send_json(200, {"replica": name, **trace})
        else:
            self._send_json(404, {"error": f"no route {self.path}"})

    def do_POST(self):
        if self.path == "/admin/swap":
            self._do_admin_swap()
            return
        if self.path == "/admin/profile":
            self._do_admin_profile()
            return
        if self.path != "/v1/generate":
            self._send_json(404, {"error": f"no route {self.path}"})
            return
        if self.frontend.draining:
            self._send_json(503, {"error": "server is draining"})
            return
        body = self._read_body()
        if body is None:
            self._send_json(400, {"error": "body must be JSON"})
            return
        tokens = body.get("tokens")
        max_new = body.get("max_new")
        if not isinstance(tokens, list) or not isinstance(max_new, int):
            self._send_json(400, {"error": "need tokens: [int] and "
                                           "max_new: int"})
            return
        stream = bool(body.get("stream", False))
        # per-request sampling / speculation overrides (absent = engine
        # default); types are checked here, RANGES by validate_request
        # at the router door so the error quotes the named limits
        sample_kw = {}
        for key, types in (("temperature", (int, float)),
                           ("top_k", (int,)), ("seed", (int,))):
            if key in body and body[key] is not None:
                if not isinstance(body[key], types) \
                        or isinstance(body[key], bool):
                    self._send_json(
                        400, {"error": f"{key} must be a number"})
                    return
                sample_kw[key] = body[key]
        if "draft" in body and body["draft"] is not None:
            if not isinstance(body["draft"], bool):
                self._send_json(400, {"error": "draft must be a bool"})
                return
            sample_kw["draft"] = body["draft"]
        q: "queue.Queue" = queue.Queue()
        try:
            replica, rid = self.router.submit(
                tokens, max_new,
                on_token=(lambda _rid, i, tok: q.put((i, tok)))
                if stream else None,
                on_done=lambda comp: q.put((_DONE, comp)),
                **sample_kw)
        except QueueFull as e:  # backpressure: shed, don't park
            self._send_json(
                429, {"error": str(e), "retry_after": e.retry_after},
                headers={"Retry-After": str(max(1, round(e.retry_after)))})
            return
        except ValueError as e:  # validate_request rejected at the door
            self.router.count_rejected()
            self._send_json(400, {"error": str(e)})
            return

        def next_event():
            """q.get with a liveness poll: if the replica's loop thread
            dies (crash latch) this request's callbacks will never
            fire — answer an error instead of parking forever."""
            while True:
                try:
                    return q.get(timeout=1.0)
                except queue.Empty:
                    if self.router.replica_dead(replica):
                        raise RuntimeError(
                            f"replica {replica} failed mid-request")

        if not stream:
            try:
                item = next_event()
                while item[0] is not _DONE:  # only done without stream
                    item = next_event()
            except RuntimeError as e:
                self._send_json(500, {"error": str(e)})
                return
            self._send_json(200, _completion_payload(item[1], replica, rid))
            return

        # SSE: close-delimited so plain HTTP/1.x clients can read it
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        self.close_connection = True
        try:
            while True:
                try:
                    kind, val = next_event()
                except RuntimeError as e:
                    self.wfile.write(
                        b"event: error\ndata: "
                        + json.dumps({"error": str(e)}).encode() + b"\n\n")
                    self.wfile.flush()
                    return
                if kind is _DONE:
                    payload = _completion_payload(val, replica, rid)
                    self.wfile.write(
                        b"event: done\ndata: "
                        + json.dumps(payload).encode() + b"\n\n")
                    self.wfile.flush()
                    return
                self.wfile.write(
                    b"data: " + json.dumps(
                        {"index": int(kind), "token": int(val)}).encode()
                    + b"\n\n")
                self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            # client went away mid-stream: cancel instead of finishing
            # a stream nobody reads — the replica's next tick releases
            # the slot, its pages, and any prefix-trie refs
            self.router.cancel(replica, rid)
            return

    def _do_admin_swap(self):
        """POST /admin/swap — replica-process model rollout over the
        wire.  Only servers constructed with an admin_swap hook expose
        it (frontend/replica.py wires one in); the hook owns building
        the new round's params and calling Router.rollout."""
        if self.frontend.admin_swap is None:
            self._send_json(404, {"error": "no admin endpoints here"})
            return
        body = self._read_body()
        if body is None:
            self._send_json(400, {"error": "body must be JSON"})
            return
        try:
            result = self.frontend.admin_swap(body)
        except ValueError as e:
            self._send_json(400, {"error": str(e)})
            return
        except Exception as e:  # swap failed mid-flight: report, don't die
            self._send_json(500, {"error": repr(e)})
            return
        self._send_json(200, {"ok": True, **(result or {})})

    def _do_admin_profile(self):
        """POST /admin/profile {"ticks": N[, "dir": path]} — capture a
        jax.profiler device trace of the next N scheduler ticks into
        the server's --profile-dir (or the body's override dir).  The
        window opens at the next tick boundary on the first routable
        replica and closes N ticks later; load the output directory in
        TensorBoard's profile plugin."""
        body = self._read_body()
        if body is None:
            self._send_json(400, {"error": "body must be JSON"})
            return
        ticks = body.get("ticks")
        if not isinstance(ticks, int) or isinstance(ticks, bool) \
                or ticks < 1:
            self._send_json(400, {"error": "need ticks: int >= 1"})
            return
        out_dir = body.get("dir") or self.frontend.profile_dir
        if not out_dir:
            self._send_json(400, {"error": "no profile dir: start the "
                                           "server with --profile-dir "
                                           "or pass \"dir\" in the "
                                           "body"})
            return
        try:
            name = self.router.profile(ticks, out_dir)
        except RuntimeError as e:  # obs=False kill-switch
            self._send_json(409, {"error": str(e)})
            return
        self._send_json(200, {"ok": True, "replica": name,
                              "ticks": ticks, "dir": str(out_dir)})


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True


class FrontendServer:
    """HTTP frontend lifecycle: bind -> start -> (serve) -> shutdown.

    port=0 binds an ephemeral port (tests/benchmarks); .port reports
    the bound one.  start() returns immediately (the accept loop and
    every replica loop run on daemon threads); shutdown(drain=True)
    performs the graceful drain described in the module docstring.
    """

    def __init__(self, router: Router, host: str = "127.0.0.1",
                 port: int = 0, verbose: bool = False,
                 admin_swap=None, profile_dir: Optional[str] = None):
        self.router = router
        self.verbose = verbose
        # default output dir for POST /admin/profile device traces
        # (serve.py --profile-dir); a body "dir" still overrides
        self.profile_dir = profile_dir
        # optional POST /admin/swap hook: callable(body_dict) -> dict,
        # raising ValueError for bad bodies.  Replica processes wire
        # one in (frontend/replica.py); plain frontends leave it off
        # and the route 404s.
        self.admin_swap = admin_swap
        self.draining = False
        handler = type("BoundHandler", (_Handler,),
                       {"router": router, "frontend": self})
        self.httpd = _Server((host, port), handler)
        self.host, self.port = self.httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self):
        self.router.start()
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, name="frontend-http",
            kwargs={"poll_interval": 0.05}, daemon=True)
        self._thread.start()

    def shutdown(self, drain: bool = True, timeout: float = 120.0):
        """Graceful by default: flip /healthz to 503 and refuse new
        generate() calls, serve out everything in flight, then stop
        the accept loop and the replica loops."""
        self.draining = True
        if drain:
            self.router.wait_idle(timeout=timeout)
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        self.router.stop(drain=drain, timeout=timeout)

    # fleet-level families (unlabeled singletons)
    _FLEET_FAMS = (
        ("repro_serving_requests_submitted", "counter", "submitted",
         "Requests accepted at the router door."),
        ("repro_serving_requests_completed", "counter", "completed",
         "Requests completed across the fleet."),
        ("repro_serving_requests_rejected", "counter", "rejected",
         "Requests rejected by door validation (HTTP 400)."),
        ("repro_serving_requests_shed", "counter", "shed",
         "Requests shed by backpressure (HTTP 429)."),
        ("repro_serving_requests_cancelled", "counter", "cancelled",
         "Requests cancelled before completion."),
        ("repro_serving_backlog", "gauge", "backlog",
         "Requests parked in the router backlog."),
        ("repro_serving_queue_depth", "gauge", "queue_depth",
         "Fleet-wide queued + in-flight requests."),
        ("repro_serving_streamed_tokens", "counter", "streamed_tokens",
         "Tokens delivered through streaming callbacks."),
    )
    # per-replica families: (family, type, stats key, help)
    _REPLICA_FAMS = (
        ("repro_serving_live_slots", "gauge", "live_slots",
         "Slots holding an admitted request."),
        ("repro_serving_pending", "gauge", "pending",
         "Requests queued on the replica."),
        ("repro_serving_peak_in_flight", "gauge", "peak_in_flight",
         "High-water mark of concurrently admitted requests."),
        ("repro_serving_preemptions", "counter", "preemptions",
         "Paged decode-time evictions back to the queue."),
        ("repro_serving_cancelled", "counter", "cancelled",
         "Requests cancelled on the replica."),
        ("repro_serving_steps_run", "counter", "steps_run",
         "Engine decode programs dispatched."),
        ("repro_serving_swaps_done", "counter", "swaps_done",
         "Parameter hot-swaps performed."),
        ("repro_serving_cache_bytes_per_device", "gauge",
         "cache_bytes_per_device", "KV cache bytes per device."),
    )
    _PAGE_FAMS = (
        ("repro_serving_total_pages", "gauge", "n_pages",
         "KV pages in the pool."),
        ("repro_serving_free_pages", "gauge", "free_pages",
         "KV pages on the free list."),
        ("repro_serving_available_pages", "gauge", "available_pages",
         "Free + evictable KV pages."),
        ("repro_serving_low_water_pages", "gauge", "low_water_pages",
         "Minimum free pages observed."),
        ("repro_serving_shared_pages", "gauge", "shared_pages",
         "Pages referenced by more than one slot (COW)."),
        ("repro_serving_kv_page_bytes", "gauge", "page_bytes",
         "Bytes per KV page."),
        ("repro_serving_kv_bytes_per_token", "gauge", "bytes_per_token",
         "KV bytes per cached token."),
        ("repro_serving_kv_quantized", "gauge", "kv_quantized",
         "1 when paged KV planes are stored quantized."),
    )
    _PREFIX_FAMS = (
        ("repro_serving_prefix_hit_rate", "gauge", "prefix_hit_rate",
         "Fraction of prompt tokens served from the prefix cache."),
        ("repro_serving_prefix_cached_pages", "gauge", "cached_pages",
         "Pages held by the prefix trie."),
        ("repro_serving_prefix_cow_pages", "counter", "cow_pages",
         "Copy-on-write page copies performed."),
        ("repro_serving_prefix_evicted_pages", "counter",
         "evicted_pages", "Prefix pages evicted (LRU)."),
    )
    _SPEC_FAMS = (
        ("repro_serving_spec_steps", "counter", "spec_steps",
         "Speculative iterations run."),
        ("repro_serving_spec_proposed", "counter", "proposed",
         "Draft tokens proposed."),
        ("repro_serving_spec_accepted", "counter", "accepted",
         "Draft tokens accepted."),
        ("repro_serving_spec_acceptance_rate", "gauge",
         "acceptance_rate", "Accepted / proposed draft tokens."),
        ("repro_serving_spec_mean_accepted_len", "gauge",
         "mean_accepted_len", "Mean tokens emitted per iteration."),
        ("repro_serving_spec_accepted_len_p50", "gauge",
         "accepted_len_p50", "Median tokens emitted per iteration."),
        ("repro_serving_spec_pruned_frac", "gauge", "pruned_frac",
         "Fraction of member votes provably prunable at verify."),
    )

    def metrics_text(self) -> str:
        """Prometheus text exposition of fleet + per-replica health:
        exactly one `# HELP`/`# TYPE` per family (no matter how many
        replica-labeled samples follow), escaped label values, a
        trailing newline — obs.parse_prometheus round-trips the whole
        scrape, and the conformance test holds it to that.  Latency
        histograms (TTFT, queue wait, inter-token, e2e) and the tick-
        phase profiler ride along from each replica's ServingObs."""
        s = self.router.stats()
        fs = obs_mod.FamilySet()
        for fam, mtype, key, help in self._FLEET_FAMS:
            fs.declare(fam, mtype, help)
            fs.sample(fam, None, s[key])
        groups = [(self._REPLICA_FAMS, lambda r: r),
                  (self._PAGE_FAMS, lambda r: r["page_stats"]),
                  (self._PREFIX_FAMS, lambda r: r["page_stats"]),
                  (self._SPEC_FAMS, lambda r: r.get("spec_stats"))]
        for fams, _ in groups:
            for fam, mtype, _, help in fams:
                fs.declare(fam, mtype, help)
        fs.declare("repro_serving_draining", "gauge",
                   "1 while the replica refuses new routes.")
        for r in s["replicas"]:
            lab = {"replica": r["name"]}
            for fams, pick in groups:
                src = pick(r)
                if not src:
                    continue
                for fam, _, key, _ in fams:
                    if key in src:
                        fs.sample(fam, lab, src[key])
            fs.sample("repro_serving_draining", lab, int(r["draining"]))
        # per-replica observability: histograms + tick phases
        fs.declare("repro_serving_tick_phase_seconds_total", "counter",
                   "Wall seconds spent per tick phase.")
        fs.declare("repro_serving_tick_phase_count_total", "counter",
                   "Times each tick phase ran.")
        fs.declare("repro_serving_tick_phase_ema_seconds", "gauge",
                   "EMA of per-tick phase wall seconds.")
        for rep in self.router.replicas:
            obs = rep.scheduler.obs
            if obs is None:
                continue
            lab = {"replica": rep.name}
            for h in obs.histograms():
                fs.add_histogram(h, lab)
            snap = obs.ticks.snapshot()
            for phase, d in snap.items():
                pl = {"replica": rep.name, "phase": phase}
                fs.sample("repro_serving_tick_phase_seconds_total", pl,
                          d["total_s"])
                fs.sample("repro_serving_tick_phase_count_total", pl,
                          d["count"])
                fs.sample("repro_serving_tick_phase_ema_seconds", pl,
                          d["ema_s"])
        return fs.render()


def serve_frontend(router: Router, host: str = "127.0.0.1",
                   port: int = 8000, verbose: bool = True,
                   profile_dir: Optional[str] = None) -> FrontendServer:
    """Convenience: build + start a FrontendServer; caller owns
    shutdown()."""
    srv = FrontendServer(router, host=host, port=port, verbose=verbose,
                         profile_dir=profile_dir)
    srv.start()
    return srv
