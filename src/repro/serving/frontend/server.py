"""Streaming HTTP frontend over the replica router — stdlib only.

Endpoints (token-id API; tokenizers are out of scope repo-wide):

  POST /v1/generate     body {"tokens": [1,2,3], "max_new": 8,
                              "stream": false}
      stream=false -> one JSON document when the request completes:
          {"tokens": [...], "n_gen": n, "prompt_len": p,
           "replica": name, "rid": i, "ttft_ms": t, "latency_ms": l}
      stream=true  -> Server-Sent Events, one event per generated
          token AS IT IS SAMPLED (the scheduler's harvest phase fires
          the per-token callback straight into the handler's queue):
              data: {"index": 0, "token": 1234}
          then a terminal event carrying the full completion:
              event: done
              data: {"tokens": [...], "n_gen": ..., ...}
  GET /healthz          liveness + per-replica drain state (200, or
                        503 once shutdown begins)
  GET /metrics          Prometheus-style text: requests, tokens,
                        live slots, free pages, preemptions, ...

Built on http.server.ThreadingHTTPServer: one handler thread per
connection parks on a queue.Queue that the scheduler loop feeds via
on_token/on_done — the decode path never blocks on a slow client
beyond queue puts, and the server needs no dependency the repo does
not already carry.  SSE responses are close-delimited (Connection:
close) so any HTTP/1.x client can read them without chunked-decoding
support.

Shutdown (`FrontendServer.shutdown`) is a graceful drain by default:
stop accepting new connections, serve out every queued and in-flight
request (handler threads unblock as their completions fire), then stop
the replica loops.  `/healthz` flips to 503 the moment the drain
starts so external load balancers stop sending traffic.
"""
from __future__ import annotations

import json
import queue
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.serving.frontend.router import Router

_DONE = object()  # queue sentinel: completion follows no more tokens


def _completion_payload(comp, replica: str, rid: int) -> dict:
    return {
        "tokens": [int(t) for t in comp.tokens],
        "n_gen": int(len(comp.tokens)),
        "prompt_len": int(comp.prompt_len),
        "replica": replica,
        "rid": int(rid),
        "ttft_ms": round(comp.ttft * 1e3, 3),
        "latency_ms": round(comp.latency * 1e3, 3),
    }


class _Handler(BaseHTTPRequestHandler):
    """One instance per request; the server wires .router in."""

    protocol_version = "HTTP/1.1"
    server_version = "repro-serving"

    # the ThreadingHTTPServer subclass below carries these
    router: Router
    frontend: "FrontendServer"

    def log_message(self, fmt, *args):  # quiet by default
        if self.frontend.verbose:
            super().log_message(fmt, *args)

    # -- plumbing -----------------------------------------------------------

    def _send_json(self, code: int, payload: dict):
        body = json.dumps(payload).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _read_body(self) -> Optional[dict]:
        try:
            n = int(self.headers.get("Content-Length", 0))
            return json.loads(self.rfile.read(n) or b"{}")
        except (ValueError, json.JSONDecodeError):
            return None

    # -- routes -------------------------------------------------------------

    def do_GET(self):
        if self.path == "/healthz":
            stats = self.router.stats()
            alive = not self.frontend.draining
            payload = {
                "ok": alive,
                "draining": self.frontend.draining,
                "replicas": [
                    {"name": r["name"], "draining": r["draining"],
                     "failed": r["failed"],
                     "live_slots": r["live_slots"], "pending": r["pending"],
                     "members": r["members"], "n_slots": r["n_slots"],
                     "swaps_done": r["swaps_done"]}
                    for r in stats["replicas"]],
            }
            self._send_json(200 if alive else 503, payload)
        elif self.path == "/metrics":
            body = self.frontend.metrics_text().encode()
            self.send_response(200)
            self.send_header("Content-Type", "text/plain; version=0.0.4")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)
        else:
            self._send_json(404, {"error": f"no route {self.path}"})

    def do_POST(self):
        if self.path != "/v1/generate":
            self._send_json(404, {"error": f"no route {self.path}"})
            return
        if self.frontend.draining:
            self._send_json(503, {"error": "server is draining"})
            return
        body = self._read_body()
        if body is None:
            self._send_json(400, {"error": "body must be JSON"})
            return
        tokens = body.get("tokens")
        max_new = body.get("max_new")
        if not isinstance(tokens, list) or not isinstance(max_new, int):
            self._send_json(400, {"error": "need tokens: [int] and "
                                           "max_new: int"})
            return
        stream = bool(body.get("stream", False))
        # per-request sampling / speculation overrides (absent = engine
        # default); types are checked here, RANGES by validate_request
        # at the router door so the error quotes the named limits
        sample_kw = {}
        for key, types in (("temperature", (int, float)),
                           ("top_k", (int,)), ("seed", (int,))):
            if key in body and body[key] is not None:
                if not isinstance(body[key], types) \
                        or isinstance(body[key], bool):
                    self._send_json(
                        400, {"error": f"{key} must be a number"})
                    return
                sample_kw[key] = body[key]
        if "draft" in body and body["draft"] is not None:
            if not isinstance(body["draft"], bool):
                self._send_json(400, {"error": "draft must be a bool"})
                return
            sample_kw["draft"] = body["draft"]
        q: "queue.Queue" = queue.Queue()
        try:
            replica, rid = self.router.submit(
                tokens, max_new,
                on_token=(lambda _rid, i, tok: q.put((i, tok)))
                if stream else None,
                on_done=lambda comp: q.put((_DONE, comp)),
                **sample_kw)
        except ValueError as e:  # validate_request rejected at the door
            self.router.count_rejected()
            self._send_json(400, {"error": str(e)})
            return

        def next_event():
            """q.get with a liveness poll: if the replica's loop thread
            dies (crash latch) this request's callbacks will never
            fire — answer an error instead of parking forever."""
            while True:
                try:
                    return q.get(timeout=1.0)
                except queue.Empty:
                    if self.router.replica_dead(replica):
                        raise RuntimeError(
                            f"replica {replica} failed mid-request")

        if not stream:
            try:
                item = next_event()
                while item[0] is not _DONE:  # only done without stream
                    item = next_event()
            except RuntimeError as e:
                self._send_json(500, {"error": str(e)})
                return
            self._send_json(200, _completion_payload(item[1], replica, rid))
            return

        # SSE: close-delimited so plain HTTP/1.x clients can read it
        self.send_response(200)
        self.send_header("Content-Type", "text/event-stream")
        self.send_header("Cache-Control", "no-cache")
        self.send_header("Connection", "close")
        self.end_headers()
        self.close_connection = True
        try:
            while True:
                try:
                    kind, val = next_event()
                except RuntimeError as e:
                    self.wfile.write(
                        b"event: error\ndata: "
                        + json.dumps({"error": str(e)}).encode() + b"\n\n")
                    self.wfile.flush()
                    return
                if kind is _DONE:
                    payload = _completion_payload(val, replica, rid)
                    self.wfile.write(
                        b"event: done\ndata: "
                        + json.dumps(payload).encode() + b"\n\n")
                    self.wfile.flush()
                    return
                self.wfile.write(
                    b"data: " + json.dumps(
                        {"index": int(kind), "token": int(val)}).encode()
                    + b"\n\n")
                self.wfile.flush()
        except (BrokenPipeError, ConnectionResetError):
            return  # client went away; the request still completes


class _Server(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True


class FrontendServer:
    """HTTP frontend lifecycle: bind -> start -> (serve) -> shutdown.

    port=0 binds an ephemeral port (tests/benchmarks); .port reports
    the bound one.  start() returns immediately (the accept loop and
    every replica loop run on daemon threads); shutdown(drain=True)
    performs the graceful drain described in the module docstring.
    """

    def __init__(self, router: Router, host: str = "127.0.0.1",
                 port: int = 0, verbose: bool = False):
        self.router = router
        self.verbose = verbose
        self.draining = False
        handler = type("BoundHandler", (_Handler,),
                       {"router": router, "frontend": self})
        self.httpd = _Server((host, port), handler)
        self.host, self.port = self.httpd.server_address[:2]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self):
        self.router.start()
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, name="frontend-http",
            kwargs={"poll_interval": 0.05}, daemon=True)
        self._thread.start()

    def shutdown(self, drain: bool = True, timeout: float = 120.0):
        """Graceful by default: flip /healthz to 503 and refuse new
        generate() calls, serve out everything in flight, then stop
        the accept loop and the replica loops."""
        self.draining = True
        if drain:
            self.router.wait_idle(timeout=timeout)
        self.httpd.shutdown()
        self.httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=10.0)
            self._thread = None
        self.router.stop(drain=drain, timeout=timeout)

    def metrics_text(self) -> str:
        """Prometheus-style exposition of fleet + per-replica health."""
        s = self.router.stats()
        lines = [
            "# TYPE repro_serving_requests_submitted counter",
            f"repro_serving_requests_submitted {s['submitted']}",
            "# TYPE repro_serving_requests_completed counter",
            f"repro_serving_requests_completed {s['completed']}",
            "# TYPE repro_serving_requests_rejected counter",
            f"repro_serving_requests_rejected {s['rejected']}",
            "# TYPE repro_serving_backlog gauge",
            f"repro_serving_backlog {s['backlog']}",
            "# TYPE repro_serving_streamed_tokens counter",
            f"repro_serving_streamed_tokens {s['streamed_tokens']}",
        ]
        for r in s["replicas"]:
            lab = f'{{replica="{r["name"]}"}}'
            lines += [
                f"repro_serving_live_slots{lab} {r['live_slots']}",
                f"repro_serving_pending{lab} {r['pending']}",
                f"repro_serving_peak_in_flight{lab} {r['peak_in_flight']}",
                f"repro_serving_preemptions{lab} {r['preemptions']}",
                f"repro_serving_steps_run{lab} {r['steps_run']}",
                f"repro_serving_swaps_done{lab} {r['swaps_done']}",
                f"repro_serving_draining{lab} {int(r['draining'])}",
                f"repro_serving_cache_bytes_per_device{lab} "
                f"{r['cache_bytes_per_device']}",
            ]
            ps = r["page_stats"]
            if ps:
                lines += [
                    f"repro_serving_free_pages{lab} {ps['free_pages']}",
                    f"repro_serving_low_water_pages{lab} "
                    f"{ps['low_water_pages']}",
                    f"repro_serving_shared_pages{lab} "
                    f"{ps['shared_pages']}",
                ]
            if "prefix_hit_rate" in ps:
                lines += [
                    f"repro_serving_prefix_hit_rate{lab} "
                    f"{ps['prefix_hit_rate']:.6f}",
                    f"repro_serving_prefix_cached_pages{lab} "
                    f"{ps['cached_pages']}",
                    f"repro_serving_prefix_cow_pages{lab} "
                    f"{ps['cow_pages']}",
                    f"repro_serving_prefix_evicted_pages{lab} "
                    f"{ps['evicted_pages']}",
                ]
            sp = r.get("spec_stats") or {}
            if sp:
                lines += [
                    f"repro_serving_spec_steps{lab} {sp['spec_steps']}",
                    f"repro_serving_spec_proposed{lab} {sp['proposed']}",
                    f"repro_serving_spec_accepted{lab} {sp['accepted']}",
                    f"repro_serving_spec_acceptance_rate{lab} "
                    f"{sp['acceptance_rate']:.6f}",
                    f"repro_serving_spec_mean_accepted_len{lab} "
                    f"{sp['mean_accepted_len']:.6f}",
                    f"repro_serving_spec_accepted_len_p50{lab} "
                    f"{sp['accepted_len_p50']:.6f}",
                    f"repro_serving_spec_pruned_frac{lab} "
                    f"{sp['pruned_frac']:.6f}",
                ]
        return "\n".join(lines) + "\n"


def serve_frontend(router: Router, host: str = "127.0.0.1",
                   port: int = 8000, verbose: bool = True) -> FrontendServer:
    """Convenience: build + start a FrontendServer; caller owns
    shutdown()."""
    srv = FrontendServer(router, host=host, port=port, verbose=verbose)
    srv.start()
    return srv
