"""Serving observability: request traces, latency histograms, a
tick-phase profiler, and Prometheus text-format plumbing.

Four parts, one low-overhead module, ON by default
(`Scheduler(obs=False)` is the kill-switch; serving_bench --obs gates
the enabled-vs-disabled decode cost at <2%):

  1. TRACES — `Trace` is a per-request span recorder: monotonic-clock
     events (`enqueued`, `admitted`, `prefix_hit`, `prefill_chunk`,
     `first_token`, `preempted`/`resumed`, `spec_step`, `done`/
     `cancelled`) appended O(1) by the scheduler's loop thread.
     `TraceRing` keeps LIVE traces pinned in a dict and FINISHED ones
     in a bounded FIFO — eviction only ever touches the finished side,
     so a long-running request's trace can never be corrupted by churn.
     Traces surface in the `Completion`, the SSE `event: done` payload,
     `GET /v1/trace/<rid>`, and an optional JSONL log (--trace-log).
  2. HISTOGRAMS — fixed log-spaced buckets (`Histogram`) for TTFT,
     queue wait, per-token inter-arrival, and end-to-end latency,
     rendered as real Prometheus histogram families
     (`_bucket`/`_sum`/`_count`) so percentiles come from the SERVER
     (`Histogram.quantile` interpolates inside a bucket; the client
     load report prefers these and cross-checks its own stopwatch).
  3. TICK PROFILER — `TickProfiler` accumulates per-phase wall time
     (admit/decode/prefill/harvest/release) with totals + EMA, exposed
     as `repro_serving_tick_phase_seconds_total` /
     `repro_serving_tick_phase_ema_seconds`; `arm_profile` opens an
     opt-in `jax.profiler` window over the next N ticks
     (serve.py --profile-dir, POST /admin/profile).
  4. PROMETHEUS PLUMBING — `FamilySet` renders conformant text
     exposition (exactly one `# HELP`/`# TYPE` per family, escaped
     label values, trailing newline); `parse_prometheus` parses a full
     scrape back; `merge_scrapes` is the FleetRouter's aggregation:
     per-replica labels preserved, plus a synthesized `replica="fleet"`
     row per family (sums for counters/histograms, max for gauges).

Threading: observe()/add() run on the scheduler loop thread; renders
and quantile reads run on HTTP handler threads.  Every mutation is a
single list/int update under the GIL and every read tolerates a
point-in-time snapshot, so the hot path takes NO lock — only TraceRing
retire/eviction does (it restructures two dicts).
"""
from __future__ import annotations

import bisect
import json
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

MONO = time.monotonic

# canonical span names (docs/observability.md documents the taxonomy)
SPAN_EVENTS = ("enqueued", "admitted", "prefix_hit", "prefill_chunk",
               "first_token", "preempted", "resumed", "spec_step",
               "done", "cancelled")
_TERMINAL = ("done", "cancelled")

# log-spaced default bounds: 100us .. ~105s, ratio 2^0.25 (worst-case
# in-bucket quantile interpolation error ~9% — half the 20% divergence
# gate the client report cross-checks against)
DEFAULT_BOUNDS = tuple(1e-4 * 2.0 ** (i / 4.0) for i in range(81))


# -- request-lifecycle tracing ----------------------------------------------

class Trace:
    """One request's span chain: (event, t, value) triples stamped
    with the monotonic clock, relative to the trace's birth (t0).
    Appends are O(1); a runaway stream cannot grow one unboundedly —
    past max_events new spans are counted in .dropped instead."""

    __slots__ = ("rid", "t0", "events", "dropped", "max_events")

    def __init__(self, rid: int, max_events: int = 512):
        self.rid = int(rid)
        self.t0 = MONO()
        self.events: List[tuple] = []   # (name, dt_seconds, value|None)
        self.dropped = 0
        self.max_events = int(max_events)

    def add(self, name: str, value=None):
        if len(self.events) >= self.max_events:
            self.dropped += 1
            return
        self.events.append((name, MONO() - self.t0, value))

    def has(self, name: str) -> bool:
        return any(e[0] == name for e in self.events)

    def first_t(self, name: str) -> Optional[float]:
        for n, t, _ in self.events:
            if n == name:
                return t
        return None

    def span(self, start: str, end: str) -> Optional[float]:
        """Seconds between the FIRST `start` and FIRST `end` event."""
        a, b = self.first_t(start), self.first_t(end)
        return None if a is None or b is None else b - a

    def to_dict(self) -> dict:
        evs = [{"event": n, "t": round(t, 6)}
               if v is None else {"event": n, "t": round(t, 6), "v": v}
               for n, t, v in self.events]
        d = {"rid": self.rid, "events": evs}
        if self.dropped:
            d["dropped"] = self.dropped
        return d


class TraceRing:
    """Bounded trace store.  Live traces (request not yet terminal)
    are PINNED — only finished traces age out, FIFO past `keep` — so
    eviction under churn can never corrupt an in-flight span chain."""

    def __init__(self, keep: int = 512):
        self.keep = int(keep)
        self._live: Dict[int, Trace] = {}
        self._done: "OrderedDict[int, Trace]" = OrderedDict()
        self._lock = threading.Lock()
        self.evicted = 0

    def start(self, rid: int) -> Trace:
        tr = Trace(rid)
        self._live[int(rid)] = tr
        return tr

    def live(self, rid: int) -> Optional[Trace]:
        return self._live.get(int(rid))

    def get(self, rid: int) -> Optional[Trace]:
        tr = self._live.get(int(rid))
        return tr if tr is not None else self._done.get(int(rid))

    def finish(self, rid: int) -> Optional[Trace]:
        """Move a live trace to the bounded finished side."""
        with self._lock:
            tr = self._live.pop(int(rid), None)
            if tr is None:
                return None
            self._done[int(rid)] = tr
            while len(self._done) > self.keep:
                self._done.popitem(last=False)
                self.evicted += 1
            return tr

    @property
    def n_live(self) -> int:
        return len(self._live)

    @property
    def n_finished(self) -> int:
        return len(self._done)


# -- histograms --------------------------------------------------------------

class Histogram:
    """Fixed-bucket histogram with log-spaced defaults, rendered in
    Prometheus exposition format (`_bucket{le=...}`/`_sum`/`_count`).
    observe() is two list writes — no lock (GIL-atomic; readers take a
    point-in-time snapshot)."""

    __slots__ = ("name", "help", "bounds", "counts", "sum", "count")

    def __init__(self, name: str, help: str,
                 bounds: Sequence[float] = DEFAULT_BOUNDS):
        self.name = name
        self.help = help
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError("histogram bounds must be sorted, unique")
        self.counts = [0] * (len(self.bounds) + 1)  # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.sum += v
        self.count += 1

    def cumulative(self) -> List[int]:
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (0..1) by linear interpolation
        inside the containing bucket — same math Prometheus'
        histogram_quantile() applies to the exported buckets."""
        return quantile_from_buckets(
            list(self.bounds), self.cumulative(), q)

    def merge_from(self, counts: Sequence[int], sum_: float, count: int):
        """Fold another histogram's NON-cumulative counts in (fleet
        aggregation); bucket layouts must match."""
        if len(counts) != len(self.counts):
            raise ValueError("bucket layout mismatch")
        for i, c in enumerate(counts):
            self.counts[i] += int(c)
        self.sum += float(sum_)
        self.count += int(count)


def quantile_from_buckets(bounds: List[float], cumulative: List[int],
                          q: float) -> float:
    """histogram_quantile over (le-bounds, cumulative counts); the
    final bucket is +Inf and clamps to the last finite bound."""
    total = cumulative[-1] if cumulative else 0
    if total <= 0:
        return 0.0
    rank = q * total
    for i, cum in enumerate(cumulative):
        if cum >= rank:
            if i >= len(bounds):        # +Inf bucket
                return bounds[-1] if bounds else 0.0
            lo = bounds[i - 1] if i > 0 else 0.0
            prev = cumulative[i - 1] if i > 0 else 0
            width = cum - prev
            frac = (rank - prev) / width if width > 0 else 1.0
            return lo + (bounds[i] - lo) * frac
    return bounds[-1] if bounds else 0.0


# -- tick-phase profiler -----------------------------------------------------

class TickProfiler:
    """Per-phase wall time accumulated inside Scheduler.tick():
    totals + counts + an EMA per phase, and an opt-in jax.profiler
    window over the next N ticks (arm_profile)."""

    PHASES = ("admit", "decode", "prefill", "harvest", "release")

    def __init__(self, ema_alpha: float = 0.05):
        self.ema_alpha = float(ema_alpha)
        self.total = {p: 0.0 for p in self.PHASES}
        self.count = {p: 0 for p in self.PHASES}
        self.ema = {p: 0.0 for p in self.PHASES}
        self.ticks = 0
        # jax.profiler window state (loop thread only)
        self._prof_left = 0
        self._prof_dir: Optional[str] = None
        self._prof_active = False

    def add(self, phase: str, dt: float):
        self.total[phase] += dt
        n = self.count[phase] = self.count[phase] + 1
        a = self.ema_alpha
        self.ema[phase] = dt if n == 1 else \
            (1.0 - a) * self.ema[phase] + a * dt

    def snapshot(self) -> dict:
        return {p: {"total_s": self.total[p], "count": self.count[p],
                    "ema_s": self.ema[p]} for p in self.PHASES}

    # -- jax.profiler window ------------------------------------------------

    def arm_profile(self, ticks: int, out_dir: str):
        """Capture a device trace of the next `ticks` tick() calls into
        out_dir (TensorBoard-loadable).  Thread-safe to ARM; the loop
        thread opens/closes the actual window at tick boundaries."""
        if ticks < 1:
            raise ValueError(f"ticks must be >= 1, got {ticks}")
        if not out_dir:
            raise ValueError("profiling needs an output dir "
                             "(serve.py --profile-dir)")
        self._prof_dir = str(out_dir)
        self._prof_left = int(ticks)

    @property
    def profile_pending(self) -> int:
        return self._prof_left

    def tick_begin(self):
        if self._prof_left > 0 and not self._prof_active:
            try:
                import jax
                jax.profiler.start_trace(self._prof_dir)
                self._prof_active = True
            except Exception:   # noqa: BLE001 — never take the loop down
                self._prof_left = 0

    def tick_end(self):
        if not self._prof_active:
            return
        self._prof_left -= 1
        if self._prof_left <= 0:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:   # noqa: BLE001
                pass
            self._prof_active = False


# -- the per-scheduler bundle ------------------------------------------------

class ServingObs:
    """Everything one Scheduler records: the trace ring, the four
    latency histograms, the tick profiler, and an optional JSONL trace
    log.  Built by Scheduler(obs=True) — the default; obs=False skips
    construction entirely (the <2% overhead gate's baseline)."""

    def __init__(self, *, trace_keep: int = 512,
                 trace_log: Optional[str] = None):
        self.traces = TraceRing(keep=trace_keep)
        self.ttft = Histogram(
            "repro_serving_ttft_seconds",
            "Submit to first generated token (queue wait + prefill).")
        self.queue_wait = Histogram(
            "repro_serving_queue_wait_seconds",
            "Submit to slot admission (first admission only).")
        self.inter_token = Histogram(
            "repro_serving_inter_token_seconds",
            "Per-token inter-arrival time during decode.")
        self.latency = Histogram(
            "repro_serving_e2e_latency_seconds",
            "Submit to completion (end-to-end request latency).")
        self.ticks = TickProfiler()
        self.trace_log = trace_log
        self._log_f = open(trace_log, "a") if trace_log else None
        self._log_lock = threading.Lock()

    def histograms(self) -> Tuple[Histogram, ...]:
        return (self.ttft, self.queue_wait, self.inter_token,
                self.latency)

    def retire(self, trace: Optional[Trace]):
        """Move a terminal trace to the finished ring and append it to
        the JSONL log (one line per request, loop thread only)."""
        if trace is None:
            return
        self.traces.finish(trace.rid)
        if self._log_f is not None:
            with self._log_lock:
                self._log_f.write(json.dumps(trace.to_dict(),
                                             separators=(",", ":"))
                                  + "\n")
                self._log_f.flush()

    def close(self):
        if self._log_f is not None:
            with self._log_lock:
                self._log_f.close()
                self._log_f = None


# -- Prometheus text exposition ----------------------------------------------

def escape_label_value(v) -> str:
    return (str(v).replace("\\", "\\\\").replace("\n", "\\n")
            .replace('"', '\\"'))


def escape_help(v: str) -> str:
    return v.replace("\\", "\\\\").replace("\n", "\\n")


def fmt_labels(labels: Optional[dict]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{escape_label_value(v)}"'
                     for k, v in labels.items())
    return "{" + inner + "}"


def fmt_value(v) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class FamilySet:
    """Builder for conformant Prometheus text exposition: families are
    declared once (`# HELP` + `# TYPE` exactly once each, no matter how
    many labeled samples — e.g. one line per replica — follow), label
    values are escaped, and render() ends with a trailing newline."""

    def __init__(self):
        self._fam: "OrderedDict[str, dict]" = OrderedDict()

    def declare(self, name: str, mtype: str, help: str):
        if mtype not in ("counter", "gauge", "histogram"):
            raise ValueError(f"unknown metric type {mtype!r}")
        f = self._fam.get(name)
        if f is None:
            self._fam[name] = {"type": mtype, "help": help,
                               "samples": []}
        elif f["type"] != mtype:
            raise ValueError(f"family {name} redeclared as {mtype}, "
                             f"was {f['type']}")

    def sample(self, name: str, labels: Optional[dict], value,
               suffix: str = ""):
        if name not in self._fam:
            raise ValueError(f"family {name} not declared")
        self._fam[name]["samples"].append((suffix, dict(labels or {}),
                                           value))

    def add_histogram(self, hist: Histogram, labels: Optional[dict],
                      name: Optional[str] = None):
        """Declare + emit one histogram's `_bucket`/`_sum`/`_count`
        series under `labels` (cumulative le counts, +Inf last)."""
        n = name or hist.name
        self.declare(n, "histogram", hist.help)
        cum = hist.cumulative()
        for i, b in enumerate(hist.bounds):
            lb = dict(labels or {})
            lb["le"] = fmt_value(b)
            self.sample(n, lb, cum[i], suffix="_bucket")
        lb = dict(labels or {})
        lb["le"] = "+Inf"
        self.sample(n, lb, cum[-1], suffix="_bucket")
        self.sample(n, labels, hist.sum, suffix="_sum")
        self.sample(n, labels, hist.count, suffix="_count")

    def render(self) -> str:
        lines = []
        for name, f in self._fam.items():
            lines.append(f"# HELP {name} {escape_help(f['help'])}")
            lines.append(f"# TYPE {name} {f['type']}")
            for suffix, labels, value in f["samples"]:
                lines.append(f"{name}{suffix}{fmt_labels(labels)} "
                             f"{fmt_value(value)}")
        return "\n".join(lines) + "\n"


def _parse_labels(s: str) -> dict:
    """Parse `k="v",k2="v2"` with \\", \\\\ and \\n escapes."""
    out: dict = {}
    i, n = 0, len(s)
    while i < n:
        j = s.index("=", i)
        key = s[i:j].strip().lstrip(",").strip()
        if s[j + 1] != '"':
            raise ValueError(f"unquoted label value in {s!r}")
        i = j + 2
        buf = []
        while i < n:
            c = s[i]
            if c == "\\":
                nxt = s[i + 1]
                buf.append({"n": "\n", '"': '"', "\\": "\\"}
                           .get(nxt, nxt))
                i += 2
            elif c == '"':
                i += 1
                break
            else:
                buf.append(c)
                i += 1
        out[key] = "".join(buf)
        while i < n and s[i] in ", ":
            i += 1
    return out


def parse_prometheus(text: str):
    """Parse a text-format scrape -> (meta, samples) where meta maps
    family name -> {"type", "help"} and samples is a list of
    (series_name, labels_dict, value).  Raises on malformed lines —
    the conformance test runs every scrape through this."""
    meta: "OrderedDict[str, dict]" = OrderedDict()
    samples: List[Tuple[str, dict, float]] = []
    if text and not text.endswith("\n"):
        raise ValueError("scrape must end with a trailing newline")
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.startswith("# HELP "):
            name, h = line[len("# HELP "):].split(" ", 1)
            meta.setdefault(name, {})["help"] = h
            continue
        if line.startswith("# TYPE "):
            name, t = line[len("# TYPE "):].split(" ", 1)
            if "type" in meta.get(name, {}):
                raise ValueError(f"duplicate # TYPE for {name}")
            meta.setdefault(name, {})["type"] = t.strip()
            continue
        if line.startswith("#"):
            continue
        if "{" in line:
            name = line[:line.index("{")]
            rest = line[line.index("{"):]
            depth_end = _find_label_end(rest)
            labels = _parse_labels(rest[1:depth_end])
            value_s = rest[depth_end + 1:].strip().split()[0]
        else:
            parts = line.split()
            if len(parts) < 2:
                raise ValueError(f"malformed sample line {line!r}")
            name, value_s = parts[0], parts[1]
            labels = {}
        v = float("inf") if value_s == "+Inf" else float(value_s)
        samples.append((name, labels, v))
    return meta, samples


def _find_label_end(s: str) -> int:
    """Index of the closing `}` of the label block s starts with,
    honoring escapes inside quoted values."""
    in_q = False
    i = 1
    while i < len(s):
        c = s[i]
        if in_q:
            if c == "\\":
                i += 1
            elif c == '"':
                in_q = False
        elif c == '"':
            in_q = True
        elif c == "}":
            return i
        i += 1
    raise ValueError(f"unterminated label block in {s!r}")


def family_of(series: str) -> str:
    """Histogram series name -> its family name."""
    for suf in ("_bucket", "_sum", "_count"):
        if series.endswith(suf):
            return series[: -len(suf)]
    return series


def merge_scrapes(scrapes: Sequence[Tuple[str, str]]) -> str:
    """Fleet aggregation: merge N children's /metrics texts into one.

    Every child sample is re-labeled replica=<child name> (overriding
    the child's own in-process replica label), per-family `# HELP` /
    `# TYPE` emitted exactly once, and a synthesized replica="fleet"
    row added per family: SUM for counters and histogram series
    (buckets with equal `le` add), MAX for gauges.
    """
    out = FamilySet()
    # family -> {"type", "help"}; series agg keyed (series, frozen extra
    # labels minus replica)
    sums: "OrderedDict[tuple, float]" = OrderedDict()
    maxes: "OrderedDict[tuple, float]" = OrderedDict()
    types: Dict[str, str] = {}
    for child, text in scrapes:
        meta, samples = parse_prometheus(text)
        for fam, m in meta.items():
            t = m.get("type", "gauge")
            if fam not in types:
                types[fam] = t
                out.declare(fam, t if t in ("counter", "gauge",
                                            "histogram") else "gauge",
                            m.get("help", fam))
        for series, labels, value in samples:
            fam = family_of(series)
            if fam not in types:      # sample without # TYPE: gauge
                types[fam] = "gauge"
                out.declare(fam, "gauge", fam)
            suffix = series[len(fam):]
            lb = dict(labels)
            lb["replica"] = child
            out.sample(fam, lb, value, suffix=suffix)
            extra = tuple(sorted((k, v) for k, v in labels.items()
                                 if k != "replica"))
            key = (fam, suffix, extra)
            if types[fam] == "gauge" and suffix == "":
                maxes[key] = max(maxes.get(key, float("-inf")), value)
            else:                     # counters + histogram series sum
                sums[key] = sums.get(key, 0.0) + value
    for (fam, suffix, extra), v in sums.items():
        lb = dict(extra)
        lb["replica"] = "fleet"
        out.sample(fam, lb, v, suffix=suffix)
    for (fam, suffix, extra), v in maxes.items():
        lb = dict(extra)
        lb["replica"] = "fleet"
        out.sample(fam, lb, v, suffix=suffix)
    return out.render()


def histogram_quantile_from_scrape(text: str, family: str, q: float,
                                   match: Optional[dict] = None) -> \
        Optional[float]:
    """Compute a quantile for one histogram family out of a raw scrape
    (the client report's server-side percentile source).  `match`
    filters on label equality (ignoring `le`); buckets from multiple
    matching series (e.g. several replicas) are summed first."""
    _, samples = parse_prometheus(text)
    buckets: Dict[float, float] = {}
    for series, labels, value in samples:
        if series != family + "_bucket":
            continue
        if match and any(labels.get(k) != str(v)
                         for k, v in match.items()):
            continue
        le = labels.get("le")
        b = float("inf") if le == "+Inf" else float(le)
        buckets[b] = buckets.get(b, 0.0) + value
    if not buckets:
        return None
    bounds = sorted(b for b in buckets if b != float("inf"))
    cum = [int(buckets[b]) for b in bounds]
    if float("inf") in buckets:
        cum.append(int(buckets[float("inf")]))
    else:
        cum.append(cum[-1] if cum else 0)
    return quantile_from_buckets(bounds, cum, q)
