from repro.data.synthetic import (lm_member_datasets, image_member_datasets,
                                  sample_batch, sample_relabel_subset)

__all__ = ["lm_member_datasets", "image_member_datasets", "sample_batch",
           "sample_relabel_subset"]
