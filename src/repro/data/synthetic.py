"""Deterministic synthetic datasets with learnable structure.

The container has no dataset downloads, so the faithful CIFAR-100
experiment runs on a synthetic stand-in with the same shape contract
(32x32x3, 100 classes) and genuine class structure: class prototypes +
Gaussian noise + random horizontal flips (the paper's only augmentation).
Models trained on it exhibit the real learning dynamics EC/MA differ on
(local fit -> aggregation -> re-fit), which is what the reproduction
validates; absolute error rates are not comparable to the paper's table
and EXPERIMENTS.md says so.

LM streams: affine-recurrent token sequences x_{t+1} = (a*x_t + b) mod V
with per-sequence (a, b) drawn from a small pool, plus noise tokens — a
next-token task a small transformer provably reduces below uniform CE.

Everything is keyed by (seed, member, epoch) so runs are bit-reproducible
and each ensemble member holds a DISJOINT shard, like the paper's random
partition of the training set.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# image classification (paper stand-in)
# ---------------------------------------------------------------------------

def image_member_datasets(key, n_members: int, per_member: int,
                          n_classes: int = 100, img: int = 32,
                          noise: float = 0.35) -> Tuple[dict, dict]:
    """-> (train_shards {images (K,n,h,w,3), labels (K,n)}, test set)."""
    kproto, ktrain, ktest = jax.random.split(key, 3)
    protos = jax.random.normal(kproto, (n_classes, img, img, 3)) * 0.8

    def make_split(k, total):
        kl, kn, kf = jax.random.split(k, 3)
        labels = jax.random.randint(kl, (total,), 0, n_classes)
        x = protos[labels] + noise * jax.random.normal(
            kn, (total, img, img, 3))
        flip = jax.random.bernoulli(kf, 0.5, (total,))
        x = jnp.where(flip[:, None, None, None], x[:, :, ::-1, :], x)
        return x.astype(jnp.float32), labels.astype(jnp.int32)

    xtr, ytr = make_split(ktrain, n_members * per_member)
    xte, yte = make_split(ktest, max(per_member, 512))
    train = {"images": xtr.reshape(n_members, per_member, img, img, 3),
             "labels": ytr.reshape(n_members, per_member)}
    test = {"images": xte, "labels": yte}
    return train, test


# ---------------------------------------------------------------------------
# language modeling
# ---------------------------------------------------------------------------

def _affine_stream(key, n_seq: int, seq_len: int, vocab: int,
                   n_rules: int = 0, noise_p: float = 0.05):
    """n_rules=0 scales the pool with the vocab (vocab//4, clamped to
    [2, 16]): a small vocab with as many rules as tokens mixes ~vocab
    affine maps into a near-uniform bigram table, destroying the
    marginal structure the stream promises (tests/test_data.py checks
    bigram entropy is well below uniform)."""
    if n_rules <= 0:
        n_rules = min(16, max(2, vocab // 4))
    kr, k0, kn, kz = jax.random.split(key, 4)
    rule_a = jax.random.randint(kr, (n_rules,), 1, max(vocab - 1, 2))
    rule_b = jax.random.randint(kz, (n_rules,), 0, vocab)
    rid = jax.random.randint(k0, (n_seq,), 0, n_rules)
    x0 = jax.random.randint(kn, (n_seq,), 0, vocab)

    def gen(carry, _):
        x = carry
        nxt = (x * rule_a[rid] + rule_b[rid]) % vocab
        return nxt, nxt

    _, toks = jax.lax.scan(gen, x0, None, length=seq_len)
    toks = toks.T  # (n_seq, seq_len)
    knoise = jax.random.split(key, 1)[0]
    mask = jax.random.bernoulli(knoise, noise_p, toks.shape)
    rnd = jax.random.randint(knoise, toks.shape, 0, vocab)
    return jnp.where(mask, rnd, toks).astype(jnp.int32)


def lm_member_datasets(key, n_members: int, per_member: int, seq_len: int,
                       vocab: int) -> Tuple[dict, dict]:
    """-> ({tokens (K,n,T)}, test {tokens (n_test,T)}). labels = shift."""
    ktr, kte = jax.random.split(key)
    tr = _affine_stream(ktr, n_members * per_member, seq_len + 1, vocab)
    te = _affine_stream(kte, max(per_member // 2, 32), seq_len + 1, vocab)
    train = {"tokens": tr[:, :-1].reshape(n_members, per_member, seq_len),
             "labels": tr[:, 1:].reshape(n_members, per_member, seq_len)}
    test = {"tokens": te[:, :-1], "labels": te[:, 1:]}
    return train, test


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------

def sample_batch(rng: np.random.Generator, shards: dict, batch: int) -> dict:
    """Per-member minibatch: same batch size, independent indices."""
    K, n = jax.tree.leaves(shards)[0].shape[:2]
    idx = rng.integers(0, n, size=(K, batch))
    rows = np.arange(K)[:, None]
    return jax.tree.map(lambda a: a[rows, idx], shards)


def sample_relabel_subset(rng: np.random.Generator, shards: dict,
                          fraction: float) -> Tuple[dict, np.ndarray]:
    """The paper relabels a fraction of D_k (70% default). Returns the
    subset and the indices (so the distill phase can pair pseudo-labels
    with true labels)."""
    K, n = jax.tree.leaves(shards)[0].shape[:2]
    m = max(1, int(n * fraction))
    idx = np.stack([rng.permutation(n)[:m] for _ in range(K)])
    rows = np.arange(K)[:, None]
    subset = jax.tree.map(lambda a: a[rows, idx], shards)
    return subset, idx
