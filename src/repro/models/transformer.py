"""Transformer LM assembly: pattern-driven blocks, scan-over-layers, enc-dec.

A model is `init(key, cfg) -> params` + pure apply functions.  Layers are
grouped into *segments* (cfg.segments()): each segment stacks `count`
repetitions of the layer pattern, applied with jax.lax.scan (+ optional
remat) so 126-layer models lower to compact HLO.

Entry points
  apply(params, cfg, tokens|embeds, ...)    -> (logits, aux)   # train/score
  prefill(params, cfg, tokens|embeds, ...)  -> (last_logits, cache)
  decode_step(params, cfg, cache, tokens)   -> (logits, cache)
  init_cache(cfg, batch, max_seq, dtype)    -> cache pytree
  loss_and_aux(params, cfg, batch)          -> (ce_loss, aux)

Cache pytree mirrors the segment structure:
  {"idx": (), "segments": [per-slot stacked cache, ...], "enc": enc_out?}
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import constrain
from repro.common.types import LayerSpec, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dtype_of, embed_init, embed_lookup,
                                 head_init, lm_logits, mlp_apply, mlp_init,
                                 rmsnorm, rmsnorm_init, sinusoidal_positions)


# ---------------------------------------------------------------------------
# single block
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, spec: LayerSpec, dtype,
                cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm_mix": rmsnorm_init(cfg.d_model)}
    if spec.mixer in ("attn", "attn_local"):
        p["attn"] = attn.attn_init(ks[0], cfg, cfg.attn, dtype)
    elif spec.mixer == "mamba":
        p["mamba"] = ssm_mod.mamba_init(ks[0], cfg, dtype)
    elif spec.mixer == "rwkv":
        p["rwkv"] = ssm_mod.rwkv_init(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if cross:
        p["norm_cross"] = rmsnorm_init(cfg.d_model)
        p["cross"] = attn.cross_attn_init(ks[3], cfg, cfg.attn, dtype)
    p["norm_ffn"] = rmsnorm_init(cfg.d_model)
    if spec.ffn == "dense":
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.ffn.d_ff,
                            cfg.ffn.mlp_type, dtype)
    elif spec.ffn == "moe":
        p["moe"] = moe_mod.moe_init(ks[1], cfg.d_model, cfg.ffn, dtype)
    elif spec.ffn == "rwkv_cmix":
        p["cmix"] = ssm_mod.cmix_init(ks[1], cfg, cfg.ffn.d_ff, dtype)
    else:
        raise ValueError(spec.ffn)
    return p


def _mixer_apply(p, x, spec: LayerSpec, cfg: ModelConfig, positions,
                 causal=True):
    if spec.mixer == "attn":
        return attn.gqa_apply(p["attn"], x, cfg.attn, cfg, positions,
                              cfg.attn.window, cfg.attn.rope_theta, causal) \
            if cfg.attn.kind != "mla" else \
            attn.mla_apply(p["attn"], x, cfg.attn, cfg, positions,
                           cfg.attn.rope_theta)
    if spec.mixer == "attn_local":
        return attn.gqa_apply(p["attn"], x, cfg.attn, cfg, positions,
                              cfg.local_window, cfg.local_rope_theta, causal)
    if spec.mixer == "mamba":
        return ssm_mod.mamba_apply(p["mamba"], x, cfg)
    if spec.mixer == "rwkv":
        return ssm_mod.rwkv_apply(p["rwkv"], x, cfg)
    raise ValueError(spec.mixer)


def _ffn_apply(p, x, spec: LayerSpec, cfg: ModelConfig):
    """-> (out, aux)."""
    if spec.ffn == "dense":
        return mlp_apply(p["mlp"], x, cfg.ffn.mlp_type), 0.0
    if spec.ffn == "moe":
        return moe_mod.moe_apply(p["moe"], x, cfg.ffn)
    if spec.ffn == "rwkv_cmix":
        T = x.shape[1]
        x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :T]
        return ssm_mod.cmix_apply(p["cmix"], x, x_prev), 0.0
    raise ValueError(spec.ffn)


def _block_apply(p, x, spec: LayerSpec, cfg: ModelConfig, positions,
                 enc: Optional[jax.Array] = None, causal: bool = True):
    """Pre-norm residual block. -> (x, aux)."""
    h = _mixer_apply(p, rmsnorm(p["norm_mix"], x, cfg.norm_eps), spec, cfg,
                     positions, causal)
    x = x + h
    if "cross" in p:
        h = attn.cross_attn_apply(p["cross"],
                                  rmsnorm(p["norm_cross"], x, cfg.norm_eps),
                                  enc, cfg.attn)
        x = x + h
    h, aux = _ffn_apply(p, rmsnorm(p["norm_ffn"], x, cfg.norm_eps), spec, cfg)
    return x + h, aux


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _segment_init(key, cfg: ModelConfig, count: int, specs, dtype,
                  cross=False) -> dict:
    """Stack `count` repetitions: leaves get a leading (count,) dim."""
    def one(k):
        kk = jax.random.split(k, len(specs))
        return {f"slot_{i}": _block_init(kk[i], cfg, s, dtype, cross)
                for i, s in enumerate(specs)}

    keys = jax.random.split(key, count)
    reps = [one(k) for k in keys]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *reps)


def init(key, cfg: ModelConfig) -> dict:
    dtype = dtype_of(cfg)
    ks = jax.random.split(key, 8)
    params: Dict[str, Any] = {}
    params.update(embed_init(ks[0], cfg.vocab_size, cfg.d_model, dtype))
    if not cfg.tie_embeddings:
        params.update(head_init(ks[1], cfg.vocab_size, cfg.d_model, dtype))
    params["final_norm"] = rmsnorm_init(cfg.d_model)
    segs = cfg.segments()
    params["segments"] = [
        _segment_init(k, cfg, count, specs, dtype, cross=cfg.enc_dec)
        for k, (count, specs) in zip(jax.random.split(ks[2], len(segs)), segs)
    ]
    if cfg.enc_dec:
        # encoder: plain full-attention blocks over frame embeddings
        enc_specs = (LayerSpec("attn", "dense"),)
        params["enc_segments"] = [_segment_init(
            ks[3], cfg, cfg.n_enc_layers, enc_specs, dtype)]
        params["enc_norm"] = rmsnorm_init(cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# forward (full sequence)
# ---------------------------------------------------------------------------

def _run_segments(segments, cfg: ModelConfig, x, positions, specs_per_seg,
                  enc=None, causal=True, remat=True):
    """x -> (x, total_aux)."""
    aux_total = 0.0
    for seg_params, (count, specs) in zip(segments, specs_per_seg):
        def body(carry, slot_params):
            h, aux = carry
            for i, spec in enumerate(specs):
                h, a = _block_apply(slot_params[f"slot_{i}"], h, spec, cfg,
                                    positions, enc, causal)
                aux = aux + a
            # sequence parallelism: the between-layer residual (the only
            # activation remat saves per layer) shards its seq dim over
            # the "seq" role axis (Megatron-SP); attention re-gathers it.
            h = constrain(h, "batch", "seq", None)
            return (h, aux), None

        body_fn = jax.checkpoint(body) if remat else body
        (x, aux_total), _ = jax.lax.scan(body_fn, (x, aux_total), seg_params)
    return x, aux_total


def _positions_for(cfg: ModelConfig, B: int, T: int, offset: int = 0):
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32) + offset, (B, T))
    if cfg.attn.mrope_sections is not None:
        # text-only stub: temporal/height/width indices coincide
        return jnp.broadcast_to(pos, (3, B, T))
    return pos


def encode(params, cfg: ModelConfig, enc_embeds: jax.Array) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    B, S, _ = enc_embeds.shape
    x = enc_embeds + sinusoidal_positions(S, cfg.d_model).astype(
        enc_embeds.dtype)
    pos = _positions_for(cfg, B, S)
    x, _ = _run_segments(params["enc_segments"], cfg, x, pos,
                         [(cfg.n_enc_layers, (LayerSpec("attn", "dense"),))],
                         causal=False)
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _embed_in(params, cfg, tokens, embeds):
    if embeds is not None:
        return embeds
    x = embed_lookup(params, tokens, cfg)
    return constrain(x, "batch", None, None)


def apply(params, cfg: ModelConfig, tokens=None, embeds=None,
          enc_embeds=None, remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. -> (logits (B,T,V), aux)."""
    x = _embed_in(params, cfg, tokens, embeds)
    B, T = x.shape[:2]
    if cfg.enc_dec and not cfg.attn.use_rope:
        x = x + sinusoidal_positions(T, cfg.d_model).astype(x.dtype)
    pos = _positions_for(cfg, B, T)
    enc = encode(params, cfg, enc_embeds) if cfg.enc_dec else None
    x, aux = _run_segments(params["segments"], cfg, x, pos, cfg.segments(),
                           enc=enc, remat=remat)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return lm_logits(params, x, cfg), aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def ce_loss(logits: jax.Array, labels: jax.Array,
            mask: Optional[jax.Array] = None) -> jax.Array:
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def loss_and_aux(params, cfg: ModelConfig, batch: dict,
                 remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    logits, aux = apply(params, cfg, tokens=batch.get("tokens"),
                        embeds=batch.get("embeds"),
                        enc_embeds=batch.get("enc_embeds"), remat=remat)
    loss = ce_loss(logits, batch["labels"], batch.get("mask"))
    return loss + aux, aux


# ---------------------------------------------------------------------------
# KV/state cache
# ---------------------------------------------------------------------------

def layer_pages(cfg: ModelConfig, spec: LayerSpec, max_seq: int) -> bool:
    """Does this layer page its positional cache under paged serving?

    Full-attention layers (GQA with window 0 or >= max_seq, and MLA —
    always full) hold O(max_seq) per slot, which is what paging fixes.
    Ring-bounded sliding-window layers are already O(window) and keep
    their contiguous per-slot rings; recurrent (mamba/rwkv) state has
    no position axis at all.
    """
    if spec.mixer == "attn":
        return (cfg.attn.kind == "mla" or cfg.attn.window <= 0
                or cfg.attn.window >= max_seq)
    if spec.mixer == "attn_local":
        return cfg.local_window <= 0 or cfg.local_window >= max_seq
    return False


def _slot_cache_init(cfg: ModelConfig, spec: LayerSpec, batch, max_seq,
                     dtype, page_size: int = 0, n_pages: int = 0,
                     kv_dtype: str = "f32") -> dict:
    if page_size > 0 and layer_pages(cfg, spec, max_seq):
        if spec.mixer == "attn" and cfg.attn.kind == "mla":
            c = attn.mla_paged_cache_init(cfg.attn, n_pages, page_size,
                                          dtype, kv_dtype)
        else:
            c = attn.gqa_paged_cache_init(cfg.attn, n_pages, page_size,
                                          dtype, kv_dtype)
    elif spec.mixer == "attn":
        if cfg.attn.kind == "mla":
            c = attn.mla_cache_init(cfg.attn, batch, max_seq, dtype)
        else:
            c = attn.gqa_cache_init(cfg.attn, batch, max_seq,
                                    cfg.attn.window, dtype)
    elif spec.mixer == "attn_local":
        c = attn.gqa_cache_init(cfg.attn, batch, max_seq, cfg.local_window,
                                dtype)
    elif spec.mixer == "mamba":
        c = ssm_mod.mamba_cache_init(cfg, batch, dtype)
    elif spec.mixer == "rwkv":
        c = ssm_mod.rwkv_cache_init(cfg, batch, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn == "rwkv_cmix":
        c["cmix_shift"] = jnp.zeros((batch, 1, cfg.d_model), dtype)
    return c


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               enc_len: int = 0, page_size: int = 0,
               n_pages: int = 0, kv_dtype: str = "f32") -> dict:
    dtype = dtype_of(cfg)
    segments = []
    for count, specs in cfg.segments():
        slot = {f"slot_{i}": _slot_cache_init(cfg, s, batch, max_seq, dtype,
                                              page_size, n_pages, kv_dtype)
                for i, s in enumerate(specs)}
        segments.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (count,) + x.shape), slot))
    cache = {"idx": jnp.zeros((), jnp.int32), "segments": segments}
    if cfg.enc_dec:
        cache["enc"] = jnp.zeros((batch, enc_len or cfg.enc_max_frames,
                                  cfg.d_model), dtype)
    return cache


def _slot_decode(p, c, x, spec: LayerSpec, cfg: ModelConfig, idx,
                 enc=None):
    """One-token block step. x: (B,1,d) -> (x, cache)."""
    h_in = rmsnorm(p["norm_mix"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        if cfg.attn.kind == "mla":
            h, c2 = attn.mla_decode(p["attn"], h_in, c_sub(c), idx, cfg.attn,
                                    cfg, cfg.attn.rope_theta)
        else:
            h, c2 = attn.gqa_decode(p["attn"], h_in, c_sub(c), idx, cfg.attn,
                                    cfg, cfg.attn.window, cfg.attn.rope_theta)
    elif spec.mixer == "attn_local":
        h, c2 = attn.gqa_decode(p["attn"], h_in, c_sub(c), idx, cfg.attn,
                                cfg, cfg.local_window, cfg.local_rope_theta)
    elif spec.mixer == "mamba":
        h, c2 = ssm_mod.mamba_decode(p["mamba"], h_in, c_sub(c), cfg)
    elif spec.mixer == "rwkv":
        h, c2 = ssm_mod.rwkv_decode(p["rwkv"], h_in, c_sub(c), cfg)
    else:
        raise ValueError(spec.mixer)
    x = x + h
    if "cross" in p:
        h = attn.cross_attn_apply(
            p["cross"], rmsnorm(p["norm_cross"], x, cfg.norm_eps), enc,
            cfg.attn)
        x = x + h
    h_f = rmsnorm(p["norm_ffn"], x, cfg.norm_eps)
    if spec.ffn == "rwkv_cmix":
        h = ssm_mod.cmix_apply(p["cmix"], h_f,
                               c["cmix_shift"].astype(h_f.dtype))
        c2["cmix_shift"] = h_f
    else:
        h, _ = _ffn_apply(p, h_f, spec, cfg)
    return x + h, c2


def c_sub(c: dict) -> dict:
    return {k: v for k, v in c.items() if k != "cmix_shift"}


def decode_step(params, cfg: ModelConfig, cache: dict,
                tokens: jax.Array) -> Tuple[jax.Array, dict]:
    """tokens: (B, 1) -> (logits (B, 1, V), cache)."""
    idx = cache["idx"]
    x = _embed_in(params, cfg, tokens, None)
    if cfg.enc_dec and not cfg.attn.use_rope:
        pe = sinusoidal_positions(cfg.max_seq, cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(pe, idx, 1, 0)[None].astype(
            x.dtype)
    enc = cache.get("enc")
    new_segments = []
    for seg_params, seg_cache, (count, specs) in zip(
            params["segments"], cache["segments"], cfg.segments()):

        def body(x, xs):
            sp, sc = xs
            new_sc = {}
            for i, spec in enumerate(specs):
                x, new_sc[f"slot_{i}"] = _slot_decode(
                    sp[f"slot_{i}"], sc[f"slot_{i}"], x, spec, cfg, idx, enc)
            return x, new_sc

        x, new_seg = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_segments.append(new_seg)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, x, cfg)
    new_cache = {"idx": idx + 1, "segments": new_segments}
    if enc is not None:
        new_cache["enc"] = enc
    return logits, new_cache


def init_slot_cache(cfg: ModelConfig, batch: int, max_seq: int,
                    enc_len: int = 0, page_size: int = 0,
                    n_pages: int = 0, kv_dtype: str = "f32") -> dict:
    """Slot-addressable decode cache: `idx` is a (batch,) position vector.

    Each batch row is an independent *slot* at its own sequence position,
    which is what continuous-batching serving needs: a finished request's
    slot is recycled by resetting idx[b] to 0 (stale KV entries are masked
    out by the position bookkeeping, so no reallocation and no zeroing of
    the K/V planes is required — recurrent SSM state DOES need zeroing,
    which repro.serving.kv_cache.reset_slots handles).

    With page_size > 0 the positional planes of full-attention layers
    (layer_pages) are allocated as a shared (n_pages, page_size, ...)
    paged pool instead of per-slot (batch, max_seq, ...) rows, and the
    cache carries a per-slot "page_table" (batch, ceil(max_seq/page))
    mapping logical pages to physical ones (sentinel n_pages =
    unallocated); serving/kv_cache.PageAllocator owns the mapping.

    The serving engine stacks one such cache per ensemble member into a
    leading-(K,) pool (repro.serving.kv_cache.init_pool) and, on a
    ("member", "data") mesh, shards that axis over "member".  The hooks
    below never see the member axis: the engine vmaps them over however
    many members are LOCAL (all K unsharded; K/M inside a shard_map
    body), so a sharded cache needs no changes here.
    """
    cache = init_cache(cfg, batch, max_seq, enc_len, page_size, n_pages,
                       kv_dtype)
    cache["idx"] = jnp.zeros((batch,), jnp.int32)
    if page_size > 0:
        pages_per_slot = -(-max_seq // page_size)
        cache["page_table"] = jnp.full((batch, pages_per_slot), n_pages,
                                       jnp.int32)
    return cache


def absorb_mla_params(cfg: ModelConfig, params: dict) -> dict:
    """Precompute the absorbed-MLA projections (kv_uk / kv_uv) once.

    mla_decode_paged attends in the latent space: queries are folded
    through W_UK before the kernel and outputs through W_UV after it, so
    the per-step gather + kv_up expansion disappears from the hot path.
    This splits every MLA layer's kv_up (..., r, H*(nope+v)) into
    kv_uk (..., r, H, nope) and kv_uv (..., r, H, v) and stores them as
    extra leaves next to kv_up — done once per params install
    (engine __init__ / swap_params), not per decode step.  Works on
    per-layer, (count,)-stacked and (K, count)-stacked trees alike
    (only the trailing dim is reshaped).  No-op for non-MLA archs.
    """
    a = cfg.attn
    if a.kind != "mla":
        return params
    params = dict(params)
    segments = []
    for seg, (count, specs) in zip(params["segments"], cfg.segments()):
        seg = dict(seg)
        for i, spec in enumerate(specs):
            slot = dict(seg[f"slot_{i}"])
            p = slot.get("attn")
            if spec.mixer == "attn" and p is not None and "kv_up" in p:
                p = dict(p)
                w = p["kv_up"].reshape(
                    p["kv_up"].shape[:-1]
                    + (a.n_heads, a.qk_nope_dim + a.v_head_dim))
                p["kv_uk"] = w[..., :a.qk_nope_dim]
                p["kv_uv"] = w[..., a.qk_nope_dim:]
                slot["attn"] = p
            seg[f"slot_{i}"] = slot
        segments.append(seg)
    params["segments"] = segments
    return params


def slot_cache_axes(cache: dict):
    """vmap in/out axes mapping the batch row of a slot cache.

    `idx` carries rows at axis 0; segment leaves are (count, B, ...) so
    their row axis is 1; the encoder output (if any) is (B, S, d).
    """
    axes = {"idx": 0, "segments": [1] * len(cache["segments"])}
    if "enc" in cache:
        axes["enc"] = 0
    return axes


def decode_step_slots(params, cfg: ModelConfig, cache: dict,
                      tokens: jax.Array) -> Tuple[jax.Array, dict]:
    """Per-slot decode step: every row advances at its OWN position.

    tokens: (B, 1); cache from init_slot_cache (idx: (B,)).
    -> (logits (B, 1, V), cache).  Implemented as a row-vmap of the
    scalar-position decode_step, so the two paths cannot drift: a batch
    where all rows share one position is bitwise the decode_step batch.
    Placement-oblivious — the serving engine calls this per member,
    vmapped over the full (K,) stack or over a member shard's local
    slice; either way each call sees ONE member's params and cache.
    """
    axes = slot_cache_axes(cache)

    def one_row(c, t):
        # vmap strips the mapped batch axis; decode_step wants B=1 back
        cb = {"idx": c["idx"],
              "segments": jax.tree.map(lambda x: x[:, None], c["segments"])}
        if "enc" in c:
            cb["enc"] = c["enc"][None]
        logits, nc = decode_step(params, cfg, cb, t[None])
        out = {"idx": nc["idx"],
               "segments": jax.tree.map(lambda x: x[:, 0], nc["segments"])}
        if "enc" in nc:
            out["enc"] = nc["enc"][0]
        return logits[0], out

    step = jax.vmap(one_row, in_axes=(axes, 0), out_axes=(0, axes))
    return step(cache, tokens)


def _slot_prefill(p, c, x, spec: LayerSpec, cfg: ModelConfig, idx, n_tok,
                  enc=None):
    """Chunk block step. x: (B,C,d) at positions idx..idx+C-1; n_tok ()
    valid tokens (padding tail is masked out of every cache/state write).
    -> (x, cache)."""
    h_in = rmsnorm(p["norm_mix"], x, cfg.norm_eps)
    if spec.mixer == "attn":
        if cfg.attn.kind == "mla":
            h, c2 = attn.mla_prefill(p["attn"], h_in, c_sub(c), idx, n_tok,
                                     cfg.attn, cfg, cfg.attn.rope_theta)
        else:
            h, c2 = attn.gqa_prefill(p["attn"], h_in, c_sub(c), idx, n_tok,
                                     cfg.attn, cfg, cfg.attn.window,
                                     cfg.attn.rope_theta)
    elif spec.mixer == "attn_local":
        h, c2 = attn.gqa_prefill(p["attn"], h_in, c_sub(c), idx, n_tok,
                                 cfg.attn, cfg, cfg.local_window,
                                 cfg.local_rope_theta)
    elif spec.mixer == "mamba":
        h, c2 = ssm_mod.mamba_prefill(p["mamba"], h_in, c_sub(c), n_tok, cfg)
    elif spec.mixer == "rwkv":
        h, c2 = ssm_mod.rwkv_prefill(p["rwkv"], h_in, c_sub(c), n_tok, cfg)
    else:
        raise ValueError(spec.mixer)
    x = x + h
    if "cross" in p:
        h = attn.cross_attn_apply(
            p["cross"], rmsnorm(p["norm_cross"], x, cfg.norm_eps), enc,
            cfg.attn)
        x = x + h
    h_f = rmsnorm(p["norm_ffn"], x, cfg.norm_eps)
    if spec.ffn == "rwkv_cmix":
        C = x.shape[1]
        ctx = jnp.concatenate([c["cmix_shift"].astype(h_f.dtype), h_f], 1)
        h = ssm_mod.cmix_apply(p["cmix"], h_f, ctx[:, :C])
        c2["cmix_shift"] = jax.lax.dynamic_slice_in_dim(ctx, n_tok, 1, 1)
    else:
        h, _ = _ffn_apply(p, h_f, spec, cfg)
    return x + h, c2


def prefill_step(params, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                 n_tok: jax.Array) -> Tuple[jax.Array, dict]:
    """Consume a whole prompt chunk in one forward pass.

    tokens: (B, C) prompt chunk at positions idx..idx+C-1 (idx is the
    cache's current position); n_tok: () how many of the C are real —
    the padded tail is masked to a state/cache no-op, so arbitrary
    prompt lengths run through one compiled C-shaped program.  Every
    prompt position's KV/recurrent state is materialized directly into
    the cache and idx advances by n_tok.
    -> (last_logits (B, V) at position idx+n_tok-1, cache): the caller
    samples the FIRST generated token straight from prefill.
    """
    idx = cache["idx"]
    x = _embed_in(params, cfg, tokens, None)
    C = x.shape[1]
    if cfg.enc_dec and not cfg.attn.use_rope:
        pe = sinusoidal_positions(cfg.max_seq, cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(pe, idx, C, 0)[None].astype(
            x.dtype)
    enc = cache.get("enc")
    new_segments = []
    for seg_params, seg_cache, (count, specs) in zip(
            params["segments"], cache["segments"], cfg.segments()):

        def body(x, xs):
            sp, sc = xs
            new_sc = {}
            for i, spec in enumerate(specs):
                x, new_sc[f"slot_{i}"] = _slot_prefill(
                    sp[f"slot_{i}"], sc[f"slot_{i}"], x, spec, cfg, idx,
                    n_tok, enc)
            return x, new_sc

        x, new_seg = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_segments.append(new_seg)
    last = jnp.maximum(n_tok - 1, 0)  # last valid position in the chunk
    xl = jax.lax.dynamic_slice_in_dim(x, last, 1, 1)
    xl = rmsnorm(params["final_norm"], xl, cfg.norm_eps)
    logits = lm_logits(params, xl, cfg)[:, 0]
    new_cache = {"idx": idx + n_tok, "segments": new_segments}
    if enc is not None:
        new_cache["enc"] = enc
    return logits, new_cache


def prefill_slots(params, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                  n_tok: jax.Array) -> Tuple[jax.Array, dict]:
    """Per-slot chunk prefill: every row consumes its OWN n_tok prompt
    tokens starting at its OWN cache position.

    tokens: (B, C); n_tok: (B,); cache from init_slot_cache (idx: (B,)).
    -> (last_logits (B, V), cache).  Implemented as a row-vmap of the
    scalar prefill_step (the decode_step_slots trick), so slots with
    n_tok == 0 are bit-exact no-ops and mixed prefill/idle batches reuse
    one compiled program.  Like decode_step_slots, member-placement-
    oblivious: the engine hands it one member's cache row at a time,
    whether that member lives on this device or is one of a shard's
    local K/M.
    """
    axes = slot_cache_axes(cache)

    def one_row(c, t, n):
        cb = {"idx": c["idx"],
              "segments": jax.tree.map(lambda x: x[:, None], c["segments"])}
        if "enc" in c:
            cb["enc"] = c["enc"][None]
        logits, nc = prefill_step(params, cfg, cb, t[None], n)
        out = {"idx": nc["idx"],
               "segments": jax.tree.map(lambda x: x[:, 0], nc["segments"])}
        if "enc" in nc:
            out["enc"] = nc["enc"][0]
        return logits[0], out

    step = jax.vmap(one_row, in_axes=(axes, 0, 0), out_axes=(0, axes))
    return step(cache, tokens, n_tok)


def verify_step(params, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                n_tok: jax.Array) -> Tuple[jax.Array, dict]:
    """Score a whole chunk in one forward pass: prefill_step's twin that
    keeps logits for ALL C positions instead of just the last one.

    tokens: (B, C) chunk at positions idx..idx+C-1; n_tok: () how many
    are real (the padded tail is masked to a state/cache no-op).
    -> (logits (B, C, V), cache): logits[:, j] is the next-token
    distribution AFTER consuming tokens[:, j] — exactly what speculative
    verify needs to check every drafted position in one call.  The
    chunk's KV is materialized into the cache (positions past the
    accepted prefix are rolled back by the caller, see
    serving.kv_cache.restore_positions).
    """
    idx = cache["idx"]
    x = _embed_in(params, cfg, tokens, None)
    C = x.shape[1]
    if cfg.enc_dec and not cfg.attn.use_rope:
        pe = sinusoidal_positions(cfg.max_seq, cfg.d_model)
        x = x + jax.lax.dynamic_slice_in_dim(pe, idx, C, 0)[None].astype(
            x.dtype)
    enc = cache.get("enc")
    new_segments = []
    for seg_params, seg_cache, (count, specs) in zip(
            params["segments"], cache["segments"], cfg.segments()):

        def body(x, xs):
            sp, sc = xs
            new_sc = {}
            for i, spec in enumerate(specs):
                x, new_sc[f"slot_{i}"] = _slot_prefill(
                    sp[f"slot_{i}"], sc[f"slot_{i}"], x, spec, cfg, idx,
                    n_tok, enc)
            return x, new_sc

        x, new_seg = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_segments.append(new_seg)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, x, cfg)      # (B, C, V): every position
    new_cache = {"idx": idx + n_tok, "segments": new_segments}
    if enc is not None:
        new_cache["enc"] = enc
    return logits, new_cache


def verify_slots(params, cfg: ModelConfig, cache: dict, tokens: jax.Array,
                 n_tok: jax.Array) -> Tuple[jax.Array, dict]:
    """Per-slot chunk verify: every row scores its OWN n_tok chunk
    tokens starting at its OWN cache position, logits at every position.

    tokens: (B, C); n_tok: (B,); cache from init_slot_cache (idx (B,)).
    -> (logits (B, C, V), cache).  Row-vmap of the scalar verify_step
    (the prefill_slots trick): n_tok == 0 rows are bit-exact no-ops, so
    a speculative batch mixing draft-on, draft-off, and idle slots runs
    one compiled program.
    """
    axes = slot_cache_axes(cache)

    def one_row(c, t, n):
        cb = {"idx": c["idx"],
              "segments": jax.tree.map(lambda x: x[:, None], c["segments"])}
        if "enc" in c:
            cb["enc"] = c["enc"][None]
        logits, nc = verify_step(params, cfg, cb, t[None], n)
        out = {"idx": nc["idx"],
               "segments": jax.tree.map(lambda x: x[:, 0], nc["segments"])}
        if "enc" in nc:
            out["enc"] = nc["enc"][0]
        return logits[0], out

    step = jax.vmap(one_row, in_axes=(axes, 0, 0), out_axes=(0, axes))
    return step(cache, tokens, n_tok)


# ---------------------------------------------------------------------------
# paged serving entry points
# ---------------------------------------------------------------------------
# The paged pool shares its full-attention planes across ALL slots, so
# the per-row vmap trick of decode_step_slots / prefill_slots cannot
# carry them (every vmap lane would need the whole plane).  These
# variants run the batch natively at per-row positions instead:
# attention layers are either paged (batch-wide scatter/gather through
# the page table) or ring-bounded (a row-vmap of the scalar-position
# gqa_decode — the plane still has a slot axis there), and recurrent
# mixers are position-free and already batched.  Dispatch is structural
# ("k_pages"/"c_kv_pages" in the layer's cache), so mixed models (jamba,
# gemma3's 5:1 local:global pattern) page exactly their full layers.


def _slot_decode_paged(p, c, x, spec: LayerSpec, cfg: ModelConfig, pos,
                       table):
    """Per-row-position block step. x: (B,1,d); pos: (B,); table: (B,P).
    -> (x, cache)."""
    h_in = rmsnorm(p["norm_mix"], x, cfg.norm_eps)
    cs = c_sub(c)
    if spec.mixer in ("attn", "attn_local"):
        if spec.mixer == "attn":
            window, theta = cfg.attn.window, cfg.attn.rope_theta
        else:
            window, theta = cfg.local_window, cfg.local_rope_theta
        if "c_kv_pages" in c:
            h, c2 = attn.mla_decode_paged(p["attn"], h_in, cs, pos, table,
                                          cfg.attn, cfg, cfg.attn.rope_theta)
        elif "k_pages" in c:
            h, c2 = attn.gqa_decode_paged(p["attn"], h_in, cs, pos, table,
                                          cfg.attn, cfg, window, theta)
        else:
            # ring-bounded sliding-window layer: contiguous per-slot
            # plane, per-row positions via a row vmap (decode_step_slots'
            # one-row trick, applied to just this mixer)
            def one(c_row, x_row, i):
                cr = jax.tree.map(lambda y: y[None], c_row)
                h_r, c2_r = attn.gqa_decode(p["attn"], x_row[None], cr, i,
                                            cfg.attn, cfg, window, theta)
                return h_r[0], jax.tree.map(lambda y: y[0], c2_r)

            h, c2 = jax.vmap(one)(cs, h_in, pos)
    elif spec.mixer == "mamba":
        h, c2 = ssm_mod.mamba_decode(p["mamba"], h_in, cs, cfg)
    elif spec.mixer == "rwkv":
        h, c2 = ssm_mod.rwkv_decode(p["rwkv"], h_in, cs, cfg)
    else:
        raise ValueError(spec.mixer)
    x = x + h
    h_f = rmsnorm(p["norm_ffn"], x, cfg.norm_eps)
    if spec.ffn == "rwkv_cmix":
        h = ssm_mod.cmix_apply(p["cmix"], h_f,
                               c["cmix_shift"].astype(h_f.dtype))
        c2["cmix_shift"] = h_f
    else:
        h, _ = _ffn_apply(p, h_f, spec, cfg)
    return x + h, c2


def decode_step_paged(params, cfg: ModelConfig, cache: dict,
                      tokens: jax.Array) -> Tuple[jax.Array, dict]:
    """Per-slot decode step over a paged cache (init_slot_cache with
    page_size > 0): every row advances at its OWN position, full-
    attention KV lives in shared pages behind cache["page_table"].

    tokens: (B, 1) -> (logits (B, 1, V), cache).  The page table rides
    through unchanged — allocation is host policy
    (serving/kv_cache.PageAllocator), never traced.  enc-dec archs are
    not served paged (the engine rejects them at construction).
    """
    pos = cache["idx"]
    table = cache["page_table"]
    x = _embed_in(params, cfg, tokens, None)
    new_segments = []
    for seg_params, seg_cache, (count, specs) in zip(
            params["segments"], cache["segments"], cfg.segments()):

        def body(x, xs):
            sp, sc = xs
            new_sc = {}
            for i, spec in enumerate(specs):
                x, new_sc[f"slot_{i}"] = _slot_decode_paged(
                    sp[f"slot_{i}"], sc[f"slot_{i}"], x, spec, cfg, pos,
                    table)
            return x, new_sc

        x, new_seg = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_segments.append(new_seg)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, x, cfg)
    return logits, {"idx": pos + 1, "segments": new_segments,
                    "page_table": table}


def _slot_prefill_paged(p, c, x, spec: LayerSpec, cfg: ModelConfig, idx,
                        n_tok, table):
    """Chunk block step over a (possibly) paged layer cache; non-paged
    layers fall through to _slot_prefill unchanged."""
    if not ("k_pages" in c or "c_kv_pages" in c):
        return _slot_prefill(p, c, x, spec, cfg, idx, n_tok, None)
    h_in = rmsnorm(p["norm_mix"], x, cfg.norm_eps)
    cs = c_sub(c)
    if "c_kv_pages" in c:
        h, c2 = attn.mla_prefill_paged(p["attn"], h_in, cs, idx, n_tok,
                                       table, cfg.attn, cfg,
                                       cfg.attn.rope_theta)
    elif spec.mixer == "attn":
        h, c2 = attn.gqa_prefill_paged(p["attn"], h_in, cs, idx, n_tok,
                                       table, cfg.attn, cfg,
                                       cfg.attn.window, cfg.attn.rope_theta)
    else:
        h, c2 = attn.gqa_prefill_paged(p["attn"], h_in, cs, idx, n_tok,
                                       table, cfg.attn, cfg,
                                       cfg.local_window,
                                       cfg.local_rope_theta)
    x = x + h
    h_f = rmsnorm(p["norm_ffn"], x, cfg.norm_eps)
    if spec.ffn == "rwkv_cmix":
        C = x.shape[1]
        ctx = jnp.concatenate([c["cmix_shift"].astype(h_f.dtype), h_f], 1)
        h = ssm_mod.cmix_apply(p["cmix"], h_f, ctx[:, :C])
        c2["cmix_shift"] = jax.lax.dynamic_slice_in_dim(ctx, n_tok, 1, 1)
    else:
        h, _ = _ffn_apply(p, h_f, spec, cfg)
    return x + h, c2


def prefill_step_paged(params, cfg: ModelConfig, cache: dict,
                       tokens: jax.Array,
                       n_tok: jax.Array) -> Tuple[jax.Array, dict]:
    """Consume a whole prompt chunk of ONE slot over a paged cache.

    cache: the slot's row (kv_cache.slot_row of a paged pool): idx (1,),
    page_table (1, P), per-slot planes sliced to one row, paged planes
    whole (they are shared — the chunk scatters into this slot's pages
    in place).  tokens: (1, C); n_tok: () valid tokens.
    -> (last_logits (1, V), cache), prefill_step's contract.

    The chunk writes only positions [idx, idx+n_tok) — pages holding
    positions below idx are READ-ONLY here.  That is what lets a
    prefix-cache admission (serving/prefix.py) hand this slot SHARED
    pages for its cached prefix and start the chunk walk at the hit:
    the prefill attends through the shared pages but never writes one.
    """
    idx = cache["idx"][0]
    table = cache["page_table"][0]
    x = _embed_in(params, cfg, tokens, None)
    new_segments = []
    for seg_params, seg_cache, (count, specs) in zip(
            params["segments"], cache["segments"], cfg.segments()):

        def body(x, xs):
            sp, sc = xs
            new_sc = {}
            for i, spec in enumerate(specs):
                x, new_sc[f"slot_{i}"] = _slot_prefill_paged(
                    sp[f"slot_{i}"], sc[f"slot_{i}"], x, spec, cfg, idx,
                    n_tok, table)
            return x, new_sc

        x, new_seg = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_segments.append(new_seg)
    last = jnp.maximum(n_tok - 1, 0)
    xl = jax.lax.dynamic_slice_in_dim(x, last, 1, 1)
    xl = rmsnorm(params["final_norm"], xl, cfg.norm_eps)
    logits = lm_logits(params, xl, cfg)[:, 0]
    return logits, {"idx": cache["idx"] + n_tok, "segments": new_segments,
                    "page_table": cache["page_table"]}


def _slot_verify_paged(p, c, x, spec: LayerSpec, cfg: ModelConfig, pos,
                       n_tok, table):
    """Chunk block step for batched verify over a (possibly) paged layer
    cache: paged layers use the batched scatter/gather verify attention,
    ring layers row-vmap the scalar chunk prefill.  Recurrent mixers
    cannot roll back a partially-accepted draft (their state has no
    positional axis), so speculative serving gates them out upstream."""
    h_in = rmsnorm(p["norm_mix"], x, cfg.norm_eps)
    cs = c_sub(c)
    if spec.mixer in ("attn", "attn_local"):
        if spec.mixer == "attn":
            window, theta = cfg.attn.window, cfg.attn.rope_theta
        else:
            window, theta = cfg.local_window, cfg.local_rope_theta
        if "c_kv_pages" in c:
            h, c2 = attn.mla_verify_paged(p["attn"], h_in, cs, pos, n_tok,
                                          table, cfg.attn, cfg,
                                          cfg.attn.rope_theta)
        elif "k_pages" in c:
            h, c2 = attn.gqa_verify_paged(p["attn"], h_in, cs, pos, n_tok,
                                          table, cfg.attn, cfg, window,
                                          theta)
        else:
            # ring-bounded sliding-window layer: contiguous per-slot
            # plane, per-row positions via a row vmap of the scalar
            # chunk prefill (the _slot_decode_paged one-row trick)
            def one(c_row, x_row, i, n):
                cr = jax.tree.map(lambda y: y[None], c_row)
                h_r, c2_r = attn.gqa_prefill(p["attn"], x_row[None], cr, i,
                                             n, cfg.attn, cfg, window, theta)
                return h_r[0], jax.tree.map(lambda y: y[0], c2_r)

            h, c2 = jax.vmap(one)(cs, h_in, pos, n_tok)
    else:
        raise ValueError(f"speculative verify needs attention-only "
                         f"layers, got mixer {spec.mixer!r}")
    x = x + h
    h_f = rmsnorm(p["norm_ffn"], x, cfg.norm_eps)
    h, _ = _ffn_apply(p, h_f, spec, cfg)
    return x + h, c2


def verify_step_paged(params, cfg: ModelConfig, cache: dict,
                      tokens: jax.Array,
                      n_tok: jax.Array) -> Tuple[jax.Array, dict]:
    """Score a C-token chunk for EVERY slot at per-row positions over a
    paged cache — the speculative-verify entry point.

    Unlike prefill_step_paged (one slot, (P,) table) this runs the whole
    batch natively: paged planes are shared, so the row-vmap trick
    cannot carry them, and verify must score all slots' drafts in ONE
    call to keep speculative decoding a single jitted program.

    tokens: (B, C) per-slot draft chunks at each row's own position;
    n_tok: (B,) valid tokens per row (0 = frozen no-op row).
    -> (logits (B, C, V), cache), verify_step's all-positions contract.
    """
    pos = cache["idx"]
    table = cache["page_table"]
    x = _embed_in(params, cfg, tokens, None)
    new_segments = []
    for seg_params, seg_cache, (count, specs) in zip(
            params["segments"], cache["segments"], cfg.segments()):

        def body(x, xs):
            sp, sc = xs
            new_sc = {}
            for i, spec in enumerate(specs):
                x, new_sc[f"slot_{i}"] = _slot_verify_paged(
                    sp[f"slot_{i}"], sc[f"slot_{i}"], x, spec, cfg, pos,
                    n_tok, table)
            return x, new_sc

        x, new_seg = jax.lax.scan(body, x, (seg_params, seg_cache))
        new_segments.append(new_seg)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = lm_logits(params, x, cfg)
    return logits, {"idx": pos + n_tok, "segments": new_segments,
                    "page_table": table}


def prefill(params, cfg: ModelConfig, tokens=None, embeds=None,
            enc_embeds=None) -> Tuple[jax.Array, jax.Array]:
    """Forward scoring pass for the prefill shape: last-token logits.

    (The serving engine materializes the KV cache with prefill_step /
    prefill_slots above; this variant keeps the dry-run cells' profile:
    the compute/memory/collective shape of the forward pass, without
    holding logits for all positions.)
    """
    x = _embed_in(params, cfg, tokens, embeds)
    B, T = x.shape[:2]
    if cfg.enc_dec and not cfg.attn.use_rope:
        x = x + sinusoidal_positions(T, cfg.d_model).astype(x.dtype)
    pos = _positions_for(cfg, B, T)
    enc = encode(params, cfg, enc_embeds) if cfg.enc_dec else None
    x, _ = _run_segments(params["segments"], cfg, x, pos, cfg.segments(),
                         enc=enc, remat=False)
    x = rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = lm_logits(params, x, cfg)[:, 0]
    return logits, logits.argmax(-1)
