"""State-space mixers: Mamba selective scan (jamba) and RWKV6 (finch).

Both are implemented in a *chunked* form: an outer lax.scan over time chunks
carrying the recurrent state, with a parallel (matmul-heavy) computation
inside each chunk.  This is the TPU-native shape of these recurrences — the
MXU sees (chunk x chunk) and (chunk x d_state) matmuls instead of a
length-T sequential loop — and it is exactly the structure the Pallas
kernels (kernels/ssm_scan.py, kernels/wkv6.py) tile into VMEM.  The
sequential oracles live in kernels/ref.py.

Decode paths carry O(1) state per layer:
  mamba: conv tail (B, conv_w-1, d_inner) + ssm state (B, d_inner, d_state)
  rwkv6: token-shift tail (B, d)          + wkv state  (B, H, dh, dh)
This is why rwkv6-7b / jamba run the long_500k cell.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import constrain
from repro.common.types import ModelConfig, SSMConfig
from repro.models.layers import dense_init

MAMBA_CHUNK = 128
RWKV_CHUNK = 32  # pairwise-decay buffer is (B,L,L,H,dh): keep L modest


# ===========================================================================
# Mamba
# ===========================================================================

def mamba_dims(cfg: ModelConfig) -> Tuple[int, int]:
    d_inner = cfg.ssm.expand * cfg.d_model
    dt_rank = cfg.ssm.dt_rank or max(1, cfg.d_model // 16)
    return d_inner, dt_rank


def mamba_init(key, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_inner, dt_rank = mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    return {
        # in_proj packs [x, z]
        "mamba_in": dense_init(ks[0], (d, 2 * d_inner), dtype),
        "mamba_conv": dense_init(ks[1], (s.conv_width, d_inner), dtype,
                                 scale=1.0 / math.sqrt(s.conv_width)),
        # x_proj packs [dt, B, C]
        "mamba_dt_x": dense_init(ks[2], (d_inner, dt_rank + 2 * s.d_state),
                                 dtype),
        "mamba_dt_w": dense_init(ks[3], (dt_rank, d_inner), dtype),
        "mamba_dt_b": jnp.full((d_inner,), -4.6, jnp.float32),  # softplus→~0.01
        "mamba_A_log": jnp.log(jnp.tile(
            jnp.arange(1, s.d_state + 1, dtype=jnp.float32), (d_inner, 1))),
        "mamba_D": jnp.ones((d_inner,), jnp.float32),
        "mamba_out": dense_init(ks[4], (d_inner, d), dtype),
    }


def _mamba_conv_full(x, w):
    """Causal depthwise conv via shifted adds. x:(B,T,di) w:(W,di).

    Accumulates in f32 (the decode path does too — keeps both bit-aligned
    through the silu when params are bf16), returns x.dtype.
    """
    W = w.shape[0]
    xf, wf = x.astype(jnp.float32), w.astype(jnp.float32)
    out = xf * wf[-1]
    for i in range(1, W):
        shifted = jnp.pad(xf, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * wf[-1 - i]
    return out


def _mamba_inner(params, xz, cfg: ModelConfig, h0, valid=None):
    """Shared scan core. xz: conv'd x (B,T,di); returns (y, h_T).

    The (B,T,di,N) transition/input tensors are never materialized for the
    full sequence: dt/B/C/x are chunked into the scan xs and a_t/b_t are
    formed per chunk inside the body (live set (B,CH,di,N), then reduced
    against C before the next chunk).

    valid (B,T) marks real positions; where False, dt is forced to 0 so
    the transition is exp(0)=identity and the input term vanishes — the
    state passes through padding untouched (chunk-prefill tails).
    """
    s = cfg.ssm
    d_inner, dt_rank = mamba_dims(cfg)
    B, T, _ = xz.shape
    proj = xz @ params["mamba_dt_x"]
    dt_lo = proj[..., :dt_rank]
    Bm = proj[..., dt_rank: dt_rank + s.d_state].astype(jnp.float32)
    Cm = proj[..., dt_rank + s.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_lo @ params["mamba_dt_w"]
                         + params["mamba_dt_b"])          # (B,T,di)
    if valid is not None:
        dt = jnp.where(valid[..., None], dt, 0.0)
    dt = constrain(dt, "batch", None, "model")
    A = -jnp.exp(params["mamba_A_log"])                    # (di, N)
    xf = xz.astype(jnp.float32)
    dtx = dt * xf                                          # (B,T,di)
    dtx = constrain(dtx, "batch", None, "model")

    nc = -(-T // MAMBA_CHUNK)
    pad = nc * MAMBA_CHUNK - T

    def chunks(t, fill=0.0):
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2),
                        constant_values=fill)
        t = t.reshape((B, nc, MAMBA_CHUNK) + t.shape[2:])
        return jnp.moveaxis(t, 1, 0)                       # (nc, B, CH, ...)

    xs = (chunks(dt), chunks(dtx), chunks(Bm), chunks(Cm))

    @jax.checkpoint  # recompute a/b/hs per chunk in backward
    def chunk_step(h, xs_c):
        dtc, dtxc, Bc, Cc = xs_c
        a = jnp.exp(dtc[..., None] * A)                    # (B,CH,di,N)
        b = dtxc[..., None] * Bc[..., None, :]
        # prepend carry as step 0: h_t = a_t h_{t-1} + b_t
        aa = jnp.concatenate([jnp.ones_like(a[:, :1]), a], 1)
        bb = jnp.concatenate([h[:, None], b], 1)

        def combine(x, y):
            return (x[0] * y[0], y[0] * x[1] + y[1])

        _, hs = jax.lax.associative_scan(combine, (aa, bb), axis=1)
        y_c = jnp.einsum("bldn,bln->bld", hs[:, 1:], Cc)
        return hs[:, -1], y_c

    h_T, ys = jax.lax.scan(chunk_step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, nc * MAMBA_CHUNK, d_inner)[:, :T]
    y = y + xf * params["mamba_D"]
    return y, h_T


def mamba_apply(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x: (B,T,d) -> (B,T,d)."""
    s = cfg.ssm
    d_inner, _ = mamba_dims(cfg)
    B, T, _ = x.shape
    xz = x @ params["mamba_in"]
    xs, z = xz[..., :d_inner], xz[..., d_inner:]
    xs = constrain(xs, None, None, "model")
    xs = jax.nn.silu(_mamba_conv_full(xs, params["mamba_conv"])
                     ).astype(xs.dtype)
    h0 = jnp.zeros((B, d_inner, s.d_state), jnp.float32)
    y, _ = _mamba_inner(params, xs, cfg, h0)
    y = (y.astype(z.dtype) * jax.nn.silu(z))
    y = constrain(y, None, None, "model")
    return y @ params["mamba_out"]


def mamba_cache_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    s = cfg.ssm
    d_inner, _ = mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, d_inner), dtype),
        "ssm": jnp.zeros((batch, d_inner, s.d_state), jnp.float32),
    }


def mamba_decode(params: dict, x: jax.Array, cache: dict,
                 cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """x: (B,1,d) one token."""
    s = cfg.ssm
    d_inner, _ = mamba_dims(cfg)
    xz = x @ params["mamba_in"]
    xs, z = xz[..., :d_inner], xz[..., d_inner:]
    window = jnp.concatenate([cache["conv"].astype(xs.dtype), xs], 1)
    conv_out = jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                          params["mamba_conv"].astype(jnp.float32))
    xc = jax.nn.silu(conv_out)[:, None].astype(xs.dtype)
    y, h = _mamba_inner(params, xc, cfg, cache["ssm"])
    y = (y.astype(z.dtype) * jax.nn.silu(z)) @ params["mamba_out"]
    return y, {"conv": window[:, 1:], "ssm": h}


def mamba_prefill(params: dict, x: jax.Array, cache: dict, n_tok: jax.Array,
                  cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """Multi-token prefill. x: (B,C,d) chunk; n_tok () valid tokens.

    The conv window is seeded from the cached tail (so the chunk joins
    the sequence seamlessly) and the ssm scan starts from the cached
    state with padded positions masked to identity transitions — the
    returned state equals stepping mamba_decode over exactly the n_tok
    valid tokens.  New tails are cut at offset n_tok, so n_tok == 0 is a
    bit-exact no-op.
    """
    s = cfg.ssm
    d_inner, _ = mamba_dims(cfg)
    B, C, _ = x.shape
    xz = x @ params["mamba_in"]
    xs, z = xz[..., :d_inner], xz[..., d_inner:]
    xs = constrain(xs, None, None, "model")
    ctx = jnp.concatenate([cache["conv"].astype(xs.dtype), xs], axis=1)
    conv = _mamba_conv_full(ctx, params["mamba_conv"])[:, s.conv_width - 1:]
    xc = jax.nn.silu(conv).astype(xs.dtype)
    valid = jnp.broadcast_to(jnp.arange(C) < n_tok, (B, C))
    y, h = _mamba_inner(params, xc, cfg, cache["ssm"], valid=valid)
    y = (y.astype(z.dtype) * jax.nn.silu(z))
    y = constrain(y, None, None, "model")
    y = y @ params["mamba_out"]
    new_conv = jax.lax.dynamic_slice_in_dim(ctx, n_tok, s.conv_width - 1, 1)
    return y, {"conv": new_conv, "ssm": h}


# ===========================================================================
# RWKV6 (finch) — data-dependent per-channel decay
# ===========================================================================

def rwkv_dims(cfg: ModelConfig) -> Tuple[int, int]:
    dh = cfg.ssm.rwkv_head_dim
    return cfg.d_model // dh, dh  # (n_heads, head_dim)


def rwkv_init(key, cfg: ModelConfig, dtype) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    H, dh = rwkv_dims(cfg)
    ks = jax.random.split(key, 12)
    p = {
        # token-shift base mix for (r,k,v,g,w) + data-dependent LoRA
        "rwkv_mix_base": jnp.full((5, d), 0.5, jnp.float32),
        "rwkv_mix_lora_a": dense_init(ks[0], (d, s.rwkv_lora_mix),
                                      jnp.float32),
        "rwkv_mix_lora_b": dense_init(ks[1], (s.rwkv_lora_mix, 5 * d),
                                      jnp.float32, scale=0.01),
        "rwkv_r": dense_init(ks[2], (d, d), dtype),
        "rwkv_k": dense_init(ks[3], (d, d), dtype),
        "rwkv_v": dense_init(ks[4], (d, d), dtype),
        "rwkv_g": dense_init(ks[5], (d, d), dtype),
        "rwkv_o": dense_init(ks[6], (d, d), dtype),
        # decay: per-channel base + data-dependent LoRA (the v6 novelty)
        "rwkv_decay_base": jnp.full((d,), -6.0, jnp.float32),
        "rwkv_decay_lora_a": dense_init(ks[7], (d, s.rwkv_lora_decay),
                                        jnp.float32),
        "rwkv_decay_lora_b": dense_init(ks[8], (s.rwkv_lora_decay, d),
                                        jnp.float32, scale=0.01),
        "rwkv_first": dense_init(ks[9], (H, dh), jnp.float32, scale=0.5),
        "rwkv_ln_scale": jnp.ones((d,), jnp.float32),
    }
    # channel-mix (rwkv FFN) params live in transformer.py via cmix leaves
    return p


def _rwkv_proj(params, x, x_prev, cfg: ModelConfig):
    """Token-shift + projections. x:(B,T,d); x_prev:(B,T,d) shifted input."""
    B, T, d = x.shape
    xf = x.astype(jnp.float32)
    # data-dependent mix: mix = base + lora(x)
    lora = jnp.tanh(xf @ params["rwkv_mix_lora_a"]) @ params["rwkv_mix_lora_b"]
    lora = constrain(lora, "batch", None, "model")
    mix = params["rwkv_mix_base"][:, None, None] + lora.reshape(
        B, T, 5, d).transpose(2, 0, 1, 3)  # (5,B,T,d)
    mixed = xf[None] + (x_prev.astype(jnp.float32)[None] - xf[None]) * mix
    mixed = constrain(mixed, None, "batch", None, "model")
    xr, xk, xv, xg, xw = [m.astype(x.dtype) for m in mixed]
    r = constrain(xr @ params["rwkv_r"], "batch", None, "model")
    k = constrain(xk @ params["rwkv_k"], "batch", None, "model")
    v = constrain(xv @ params["rwkv_v"], "batch", None, "model")
    g = jax.nn.silu(constrain(xg @ params["rwkv_g"], "batch", None,
                              "model"))
    # decay in log space: log w = -exp(base + lora)  (strictly < 0)
    dec = params["rwkv_decay_base"] + jnp.tanh(
        xw.astype(jnp.float32) @ params["rwkv_decay_lora_a"]
    ) @ params["rwkv_decay_lora_b"]
    log_w = -jnp.exp(dec.clip(-20.0, 4.0))  # (B,T,d)
    log_w = constrain(log_w, "batch", None, "model")
    return r, k, v, g, log_w


def _wkv_chunked(r, k, v, log_w, u, S0):
    """Chunked wkv recurrence in log space.

    r/k/v: (B,T,H,dh) f32; log_w: (B,T,H,dh) per-key-channel decay (<0);
    u: (H,dh) bonus; S0: (B,H,dh,dh) [key, value] state.
    y_t = r_t @ (S_{t-1} + u ∘ k_t^T v_t);  S_t = W_t ∘ S_{t-1} + k_t^T v_t
    """
    B, T, H, dh = r.shape
    nc = -(-T // RWKV_CHUNK)
    pad = nc * RWKV_CHUNK - T
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        log_w = jnp.pad(log_w, z)  # log w = 0 → w = 1 on padding (harmless)
    L = RWKV_CHUNK

    def to_chunks(x):
        x = x.reshape(B, nc, L, H, dh).transpose(1, 0, 2, 3, 4)
        return constrain(x, None, "batch", None, "model", None)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, log_w))

    @jax.checkpoint  # recompute the (L,L,dh) pairwise tensor in backward
    def chunk_step(S, xs):
        rb, kb, vb, lw = xs  # (B,L,H,dh)
        la = jnp.cumsum(lw, axis=1)            # inclusive ∑ log w
        la_prev = la - lw                       # exclusive
        # r decayed vs chunk start; k re-scaled vs own position
        r_in = rb * jnp.exp(la_prev)
        k_out = kb * jnp.exp(la[:, -1:] - la)   # for state update
        # pairwise decay exp(la_prev[t]-la[j]) for j<t — exponent <= 0, so
        # this is stable for arbitrary data-dependent decays (unlike the
        # separable exp(la_prev[t])·exp(-la[j]) factorization, which
        # overflows when per-step decay is strong).  (B,L,L,H,dh) bounds
        # the memory; RWKV_CHUNK is sized for it.
        ld = la_prev[:, :, None, :, :] - la[:, None, :, :, :]
        # mask j < t strictly; bonus handles j == t
        tri = jnp.tril(jnp.ones((L, L), bool), k=-1)
        scores = jnp.einsum("blhd,bmhd,blmhd->bhlm", rb, kb,
                            jnp.where(tri[None, :, :, None, None],
                                      jnp.exp(ld), 0.0))
        y = jnp.einsum("bhlm,bmhd->blhd", scores, vb)
        # cross-chunk: r decayed to chunk start times S
        y = y + jnp.einsum("blhk,bhkv->blhv", r_in, S)
        # bonus diagonal term
        y = y + jnp.einsum("blhd,blhd,blhv->blhv", rb, kb * u, vb)
        S_new = S * jnp.exp(la[:, -1])[..., None] \
            + jnp.einsum("blhk,blhv->bhkv", k_out, vb)
        return S_new, y

    S_T, ys = jax.lax.scan(chunk_step, S0, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nc * L, H, dh)[:, :T]
    return y, S_T


def _rwkv_groupnorm(y, scale, H, dh, eps=1e-5):
    B, T = y.shape[:2]
    yf = y.reshape(B, T, H, dh).astype(jnp.float32)
    mu = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yf = (yf - mu) * jax.lax.rsqrt(var + eps)
    return (yf.reshape(B, T, H * dh) * scale)


def rwkv_apply(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    B, T, d = x.shape
    H, dh = rwkv_dims(cfg)
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :T]
    r, k, v, g, log_w = _rwkv_proj(params, x, x_prev, cfg)

    def heads(t):
        return t.astype(jnp.float32).reshape(B, T, H, dh)

    S0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    y, _ = _wkv_chunked(heads(r), heads(k), heads(v), heads(log_w),
                        params["rwkv_first"], S0)
    y = _rwkv_groupnorm(y, params["rwkv_ln_scale"], H, dh)
    y = (y.astype(g.dtype) * g)
    y = constrain(y, None, None, "model")
    return y @ params["rwkv_o"]


def rwkv_cache_init(cfg: ModelConfig, batch: int, dtype) -> dict:
    H, dh = rwkv_dims(cfg)
    return {
        "shift": jnp.zeros((batch, 1, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, H, dh, dh), jnp.float32),
    }


def rwkv_decode(params: dict, x: jax.Array, cache: dict,
                cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    B = x.shape[0]
    H, dh = rwkv_dims(cfg)
    r, k, v, g, log_w = _rwkv_proj(params, x, cache["shift"].astype(x.dtype),
                                   cfg)

    def heads(t):
        return t.astype(jnp.float32).reshape(B, H, dh)

    rf, kf, vf, lw = map(heads, (r[:, 0], k[:, 0], v[:, 0], log_w[:, 0]))
    S = cache["wkv"]
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    y = jnp.einsum("bhk,bhkv->bhv", rf, S + params["rwkv_first"][..., None]
                   * kv)
    S = S * jnp.exp(lw)[..., None] + kv
    y = y.reshape(B, 1, H * dh)
    y = _rwkv_groupnorm(y, params["rwkv_ln_scale"], H, dh)
    y = (y.astype(g.dtype) * g) @ params["rwkv_o"]
    return y, {"shift": x, "wkv": S}


def rwkv_prefill(params: dict, x: jax.Array, cache: dict, n_tok: jax.Array,
                 cfg: ModelConfig) -> Tuple[jax.Array, dict]:
    """Multi-token prefill. x: (B,C,d) chunk; n_tok () valid tokens.

    Token shift is seeded from the cached tail; padded positions are
    masked to state no-ops (k -> 0 kills the input term, log_w -> 0 is
    decay 1), so the returned wkv state equals stepping rwkv_decode over
    exactly the n_tok valid tokens.  n_tok == 0 is a bit-exact no-op.
    """
    B, C, d = x.shape
    H, dh = rwkv_dims(cfg)
    ctx = jnp.concatenate([cache["shift"].astype(x.dtype), x], axis=1)
    r, k, v, g, log_w = _rwkv_proj(params, x, ctx[:, :C], cfg)
    valid = (jnp.arange(C) < n_tok)[None, :, None]
    k = jnp.where(valid, k, 0)
    log_w = jnp.where(valid, log_w, 0.0)

    def heads(t):
        return t.astype(jnp.float32).reshape(B, C, H, dh)

    y, S = _wkv_chunked(heads(r), heads(k), heads(v), heads(log_w),
                        params["rwkv_first"], cache["wkv"])
    y = _rwkv_groupnorm(y, params["rwkv_ln_scale"], H, dh)
    y = (y.astype(g.dtype) * g)
    y = constrain(y, None, None, "model")
    y = y @ params["rwkv_o"]
    new_shift = jax.lax.dynamic_slice_in_dim(ctx, n_tok, 1, 1)
    return y, {"shift": new_shift, "wkv": S}


# --- rwkv channel-mix (its FFN flavor) -------------------------------------

def cmix_init(key, cfg: ModelConfig, d_ff: int, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "cmix_mix": jnp.full((d,), 0.5, jnp.float32),
        "cmix_k": dense_init(ks[0], (d, d_ff), dtype),
        "cmix_v": dense_init(ks[1], (d_ff, d), dtype),
    }


def cmix_apply(params: dict, x: jax.Array, x_prev: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    xk = xf + (x_prev.astype(jnp.float32) - xf) * params["cmix_mix"]
    h = jnp.square(jax.nn.relu(xk.astype(x.dtype) @ params["cmix_k"]))
    h = constrain(h, None, None, "model")
    return h @ params["cmix_v"]
