"""Mixture-of-Experts FFN with top-k routing and capacity-based dispatch.

Dispatch is gather/scatter-based (no (T, E, C) one-hot einsum): tokens are
assigned capacity slots via a cumsum over the routing mask, gathered into an
(E, C, d) buffer, run through per-expert FFNs with a single batched einsum,
and combined back with router weights.  Live memory is O(T·k·cap·d).

Sharding: expert weights carry a leading E dim partitioned over the `model`
axis (EP).  The dispatch buffer is constrained to P("model", None, None) so
XLA inserts the token all-to-all at the dispatch/combine boundary — the
classic EP pattern expressed in pjit.

Variants covered:
  - shared experts (deepseek-v2): n_shared always-on experts, fused as one
    dense MLP of width n_shared*expert_ff.
  - dense residual (arctic): a parallel always-on dense MLP added to the MoE
    output.
Router aux loss (load-balance) is returned for the trainer to accumulate.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import constrain
from repro.common.types import FFNConfig
from repro.models.layers import dense_init, mlp_apply, mlp_init


def moe_init(key, d_model: int, f: FFNConfig, dtype) -> dict:
    ks = jax.random.split(key, 5)
    E, ff = f.n_experts, f.expert_ff
    p = {
        "router": dense_init(ks[0], (d_model, E), jnp.float32),
        "experts_gate": dense_init(ks[1], (E, d_model, ff), dtype),
        "experts_up": dense_init(ks[2], (E, d_model, ff), dtype),
        "experts_down": dense_init(ks[3], (E, ff, d_model), dtype),
    }
    if f.n_shared:
        p["shared"] = mlp_init(ks[4], d_model, f.n_shared * ff, "swiglu",
                               dtype)
    if f.dense_residual_ff:
        p["dense_res"] = mlp_init(ks[4], d_model, f.dense_residual_ff,
                                  "swiglu", dtype)
    return p


def _route(router_w, x_f32, top_k: int):
    """x: (T, d) -> (weights (T, k), ids (T, k), aux_loss, probs (T, E))."""
    logits = x_f32 @ router_w  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)  # renormalize top-k
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    E = router_w.shape[-1]
    f_e = jnp.zeros((E,), jnp.float32).at[ids.reshape(-1)].add(
        1.0 / ids.size * E)
    p_e = probs.mean(0)
    aux = (f_e * p_e).sum() * E
    return w, ids, aux, probs


def moe_apply(params: dict, x: jax.Array, f: FFNConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss ())."""
    B, S, d = x.shape
    T = B * S
    xt = constrain(x.reshape(T, d), "batch", None)
    E, k = f.n_experts, f.top_k
    # per-expert capacity; floor of min(T*k, 64) makes small token counts
    # (decode steps, unit tests) effectively dropless
    C = max(int(T * k * f.capacity_factor / E), min(T * k, 64))

    w, ids, aux, _ = _route(params["router"], xt.astype(jnp.float32), k)

    # --- capacity-slot assignment -------------------------------------
    flat_ids = ids.reshape(-1)                       # (T*k,)
    flat_w = w.reshape(-1)
    onehot = jax.nn.one_hot(flat_ids, E, dtype=jnp.int32)       # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)        # arrival rank
    slot = jnp.take_along_axis(pos_in_expert, flat_ids[:, None], 1)[:, 0]
    keep = slot < C                                  # dropped-on-overflow
    dest = jnp.where(keep, flat_ids * C + slot, E * C)  # E*C = trash slot

    # --- dispatch: gather-based ----------------------------------------
    # Invert dest -> slot_to_token with a SMALL int32 scatter, then gather
    # the (E,C,d) dispatch buffer from the tokens.  A d-wide scatter-add
    # here would make the backward pass all-gather the (E*C, d) cotangent
    # to every device (7.6 TB/step measured on deepseek-v2); the gather's
    # backward is a scatter-add into the batch-sharded token cotangent
    # instead.  E*C is the trash slot for dropped tokens; T*k the dummy
    # source row.
    slot_to_tok = jnp.full((E * C + 1,), T * k, jnp.int32)
    slot_to_tok = slot_to_tok.at[dest].set(
        jnp.arange(T * k, dtype=jnp.int32))
    tok_ids = slot_to_tok[: E * C] // k              # (E*C,) source token
    tok_ids = constrain(tok_ids.reshape(E, C), "model", None)
    xt_plus = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], 0)
    safe_ids = jnp.minimum(tok_ids, T)               # dummy row for empties
    disp = jnp.take(xt_plus, safe_ids.reshape(-1), axis=0).reshape(E, C, d)
    disp = constrain(disp, "model", None, None)      # EP boundary

    # --- per-expert FFN -------------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", disp, params["experts_gate"])
    u = jnp.einsum("ecd,edf->ecf", disp, params["experts_up"])
    h = jax.nn.silu(g) * u
    out_e = jnp.einsum("ecf,efd->ecd", h, params["experts_down"])
    out_e = constrain(out_e, "model", None, None)

    # --- combine --------------------------------------------------------
    # In TRAINING, reshard expert outputs to the token (batch) layout
    # BEFORE the combine gather: with flat_out left expert-sharded, the
    # gather's backward scatter-add makes GSPMD all-gather the (T*k, d)
    # cotangent to every device (7.6 TB/step on deepseek-v2).  The
    # explicit reshard is one all-to-all of (E*C, d) each way instead.
    # Forward-only (prefill/decode) the reshard is pure cost (measured:
    # dsv2 prefill t_coll 66 -> 106 s), so it is gated on the train role.
    from repro.common.sharding import layout_flag
    flat_out = jnp.concatenate(
        [out_e.reshape(E * C, d), jnp.zeros((1, d), out_e.dtype)], 0)
    if layout_flag("train"):
        flat_out = constrain(flat_out, "batch", None)
    tok_out = flat_out[dest] * flat_w[:, None].astype(out_e.dtype)
    tok_out = constrain(tok_out, "batch", None)
    y = tok_out.reshape(T, k, d).sum(1)

    if "shared" in params:
        y = y + mlp_apply(params["shared"], xt, "swiglu")
    if "dense_res" in params:
        y = y + mlp_apply(params["dense_res"], xt, "swiglu")
    return y.reshape(B, S, d), aux * f.router_aux_coef
