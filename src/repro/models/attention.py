"""Attention mixers: GQA (with sliding window / M-RoPE variants) and MLA.

Two compute paths, numerically identical:
  - `_attend_dense`: materializes the (q_len, kv_len) score matrix. Used for
    short sequences and as the oracle.
  - `_attend_chunked`: lax.scan over KV chunks with an online-softmax
    accumulator (flash-attention recurrence in pure jnp).  This is what makes
    32k/500k shapes lower with O(seq·chunk) live memory instead of O(seq^2).
    The Pallas kernel (kernels/flash_attention.py) implements the same
    recurrence with explicit VMEM tiling for real TPUs; model code dispatches
    through kernels/ops.py.

Cache layout (decode): {"k": (B, S_max, n_kv, dh), "v": ..., "idx": ()} per
layer.  Sliding-window layers allocate only `window` cache slots and write
round-robin (idx % window) — this is what bounds gemma3's long_500k memory.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import REP, constrain, mesh_axis_size
from repro.common.types import AttnConfig, ModelConfig
from repro.kernels import ops
from repro.models.layers import apply_rope, dense_init


def _kv_spec(n_kv: int):
    """KV heads shard over "model" only when they divide it; otherwise
    they are explicitly REPLICATED (production GQA-TP: each TP rank holds
    all KV heads, Q heads split).  Leaving it unconstrained lets w_k's
    column sharding leak *into* head_dim through the reshape, which turns
    the score contraction into partial-sums + a (B,T,S)-sized all-reduce
    (measured on arctic prefill: 67 TB of ICI traffic)."""
    return "model" if n_kv % mesh_axis_size("model") == 0 else REP

NEG_INF = -2.0 ** 30  # large-negative that survives bf16 round-trips

# chunk size for the online-softmax path; seqs <= this use the dense path
ATTN_CHUNK = 1024


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def attn_init(key, cfg: ModelConfig, a: AttnConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if a.kind == "mla":
        # deepseek-v2 multi-head latent attention
        qd = a.q_dim  # n_heads * (nope + rope)
        p = {
            "kv_down": dense_init(ks[0], (d, a.kv_lora_rank), dtype),
            "k_rope": dense_init(ks[1], (d, a.qk_rope_dim), dtype),
            # per-head up-projections from the shared latent
            "kv_up": dense_init(
                ks[2], (a.kv_lora_rank,
                        a.n_heads * (a.qk_nope_dim + a.v_head_dim)), dtype),
            "w_o": dense_init(ks[3], (a.n_heads * a.v_head_dim, d), dtype),
        }
        if a.q_lora_rank:
            p["q_down"] = dense_init(ks[4], (d, a.q_lora_rank), dtype)
            p["q_up"] = dense_init(ks[5], (a.q_lora_rank, qd), dtype)
        else:
            p["w_q"] = dense_init(ks[4], (d, qd), dtype)
        return p
    p = {
        "w_q": dense_init(ks[0], (d, a.n_heads * a.head_dim), dtype),
        "w_k": dense_init(ks[1], (d, a.n_kv_heads * a.head_dim), dtype),
        "w_v": dense_init(ks[2], (d, a.n_kv_heads * a.head_dim), dtype),
        "w_o": dense_init(ks[3], (a.n_heads * a.head_dim, d), dtype),
    }
    if a.qk_norm:
        p["norm_q"] = jnp.ones((a.head_dim,), jnp.float32)
        p["norm_k"] = jnp.ones((a.head_dim,), jnp.float32)
    return p


# ---------------------------------------------------------------------------
# core attention math (shared by dense / chunked / decode)
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, window: int, causal: bool) -> jax.Array:
    """(q, k) additive mask. window>0 limits lookback (sliding window).

    Negative k positions are the "empty / padded cache slot" sentinel and
    are always masked out.
    """
    ok = k_pos[None, :] >= 0
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _attend_dense(q, k, v, bias, scale) -> jax.Array:
    """q:(B,Tq,H,dh) k/v:(B,Tk,Hkv,dh|dv) bias:(Tq,Tk), or (B,Tq,Tk)
    for per-row masks (paged decode: every slot at its own position)
    -> (B,Tq,H,dv).

    Same precision convention as the chunked path (operands in input
    dtype, f32 MXU accumulation) so dense/chunked dispatch is a pure
    performance choice, never a numerics change.
    """
    B, Tq, H, dh = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    cdt = q.dtype
    qg = q.reshape(B, Tq, Hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(cdt),
                   preferred_element_type=jnp.float32) * scale
    s = s + (bias[:, None, None] if bias.ndim == 3
             else bias[None, None, None])
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(cdt), v.astype(cdt),
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Tq, H, v.shape[-1]).astype(v.dtype)


def _attend_chunked(q, k, v, q_pos, k_pos, window, causal, scale,
                    chunk: int = ATTN_CHUNK) -> jax.Array:
    """Online-softmax over KV chunks; O(Tk/chunk) sequential steps.

    KV chunks are taken with dynamic_slice per step (NOT by restacking
    (nc, B, chunk, ...) scan inputs — at decode that restack materializes
    a full transposed copy of the KV cache per step, and XLA hoists it
    over the layer loop: 2x4.3 GiB/step measured on llama3-405b).
    Memory high-water per step: the (B,Hkv,g,Tq,chunk) score tile.
    """
    B, Tq, H, dh = q.shape
    Tk = k.shape[1]
    Hkv = k.shape[2]
    g = H // Hkv
    dv = v.shape[-1]
    n_chunks = -(-Tk // chunk)
    pad = n_chunks * chunk - Tk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, (0, pad), constant_values=-(10 ** 9))
    # operands stay in the input dtype (bf16 in production) — the MXU
    # accumulates in f32 via preferred_element_type, so softmax stats are
    # exact while score/weight traffic (HBM + any collectives touching
    # them) is halved vs materializing f32 operands.
    cdt = q.dtype
    qf = q.reshape(B, Tq, Hkv, g, dh)

    @jax.checkpoint  # flash-style: recompute per-chunk scores in backward
    def step(carry, i):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, i * chunk, chunk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, i * chunk, chunk, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(k_pos, i * chunk, chunk, axis=0)
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb.astype(cdt),
                       preferred_element_type=jnp.float32) * scale
        s = s + _mask_bias(q_pos, kp, window, causal)[None, None, None]
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(cdt), vb.astype(cdt),
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, g, Tq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Tq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, Tq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  jnp.arange(n_chunks))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, dv)
    return o.astype(v.dtype)


def attend(q, k, v, q_pos, k_pos, *, window: int, causal: bool,
           scale: float, force_dense: Optional[bool] = None) -> jax.Array:
    """Dispatch dense vs chunked on KV length."""
    Tk = k.shape[1]
    dense = Tk <= ATTN_CHUNK if force_dense is None else force_dense
    if dense:
        bias = _mask_bias(q_pos, k_pos, window, causal)
        return _attend_dense(q, k, v, bias, scale)
    return _attend_chunked(q, k, v, q_pos, k_pos, window, causal, scale)


# ---------------------------------------------------------------------------
# GQA apply (train/prefill + decode)
# ---------------------------------------------------------------------------

def _maybe_qknorm(params, q, k, eps):
    if "norm_q" in params:
        def rn(x, w):
            v = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
            return (x.astype(jnp.float32) * jax.lax.rsqrt(v + eps) * w
                    ).astype(x.dtype)
        q, k = rn(q, params["norm_q"]), rn(k, params["norm_k"])
    return q, k


def gqa_apply(params: dict, x: jax.Array, a: AttnConfig, cfg: ModelConfig,
              positions: jax.Array, window: int, theta: float,
              causal: bool = True) -> jax.Array:
    """x: (B, T, d) -> (B, T, d).  positions: (B, T) or (3, B, T) for M-RoPE."""
    B, T, _ = x.shape
    kv = _kv_spec(a.n_kv_heads)
    qs = "model" if a.n_heads % mesh_axis_size("model") == 0 else kv
    qf_ = x @ params["w_q"]
    kf = x @ params["w_k"]
    vf = x @ params["w_v"]
    if kv == REP:
        # replicate the FLAT projections before the head reshape: if the
        # column sharding survives into the reshape, shards land inside
        # head_dim and the score contraction becomes partial-sum +
        # a (B,Hkv,g,Tq,chunk)-sized all-reduce (measured: 33 TB on
        # arctic prefill).  Constraining only the head dim of the 4D view
        # is NOT enough — head_dim stays UNCONSTRAINED and keeps the
        # leaked shards (measured: the AR survived on gemma).  The
        # all-gather here is (B,T,heads*dh) — tiny by comparison.
        kf = constrain(kf, None, None, REP)
        vf = constrain(vf, None, None, REP)
    if qs == REP:
        qf_ = constrain(qf_, None, None, REP)
    q = qf_.reshape(B, T, a.n_heads, a.head_dim)
    k = kf.reshape(B, T, a.n_kv_heads, a.head_dim)
    v = vf.reshape(B, T, a.n_kv_heads, a.head_dim)
    q = constrain(q, None, None, qs, None)
    k = constrain(k, None, None, kv, None)
    v = constrain(v, None, None, kv, None)
    q, k = _maybe_qknorm(params, q, k, cfg.norm_eps)
    pos1d = positions if a.mrope_sections is None else positions[0]
    if a.use_rope:
        q = apply_rope(q, positions, theta, a.mrope_sections)
        k = apply_rope(k, positions, theta, a.mrope_sections)
    scale = 1.0 / math.sqrt(a.head_dim)
    o = attend(q, k, v, pos1d[0], pos1d[0], window=window, causal=causal,
               scale=scale)
    o = constrain(o, None, None, "model" if a.n_heads
                  % mesh_axis_size("model") == 0 else kv, None)
    return o.reshape(B, T, -1) @ params["w_o"]


def gqa_cache_init(a: AttnConfig, batch: int, max_seq: int, window: int,
                   dtype) -> dict:
    slots = min(window, max_seq) if window > 0 else max_seq
    shape = (batch, slots, a.n_kv_heads, a.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_decode(params: dict, x: jax.Array, cache: dict, idx: jax.Array,
               a: AttnConfig, cfg: ModelConfig, window: int,
               theta: float) -> Tuple[jax.Array, dict]:
    """One-token decode. x: (B, 1, d); idx: () current position."""
    B = x.shape[0]
    kv = _kv_spec(a.n_kv_heads)
    kf, vf = x @ params["w_k"], x @ params["w_v"]
    if kv == REP:  # see gqa_apply: keep shards out of head_dim
        kf = constrain(kf, None, None, REP)
        vf = constrain(vf, None, None, REP)
    q = (x @ params["w_q"]).reshape(B, 1, a.n_heads, a.head_dim)
    k = kf.reshape(B, 1, a.n_kv_heads, a.head_dim)
    v = vf.reshape(B, 1, a.n_kv_heads, a.head_dim)
    q, k = _maybe_qknorm(params, q, k, cfg.norm_eps)
    pos = jnp.full((B, 1), idx, jnp.int32)
    if a.mrope_sections is not None:
        pos3 = jnp.broadcast_to(pos, (3,) + pos.shape)
        if a.use_rope:
            q = apply_rope(q, pos3, theta, a.mrope_sections)
            k = apply_rope(k, pos3, theta, a.mrope_sections)
    elif a.use_rope:
        q = apply_rope(q, pos, theta)
        k = apply_rope(k, pos, theta)
    slots = cache["k"].shape[1]
    slot = idx % slots if window > 0 else idx
    k = constrain(k, None, None, kv, None)
    v = constrain(v, None, None, kv, None)
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    # absolute positions of cache slots (round-robin for windows)
    slot_ids = jnp.arange(slots)
    if window > 0:
        # slot s holds the most recent position p <= idx with p % slots == s
        k_pos = idx - ((idx - slot_ids) % slots)
        k_pos = jnp.where(k_pos > idx, -(10 ** 9), k_pos)
    else:
        k_pos = jnp.where(slot_ids <= idx, slot_ids, -(10 ** 9))
    scale = 1.0 / math.sqrt(a.head_dim)
    o = attend(q, ck, cv, pos[0], k_pos, window=window, causal=True,
               scale=scale, force_dense=slots <= ATTN_CHUNK * 4)
    o = o.reshape(B, 1, -1) @ params["w_o"]
    return o, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# GQA chunk prefill
# ---------------------------------------------------------------------------

def chunk_cache_write(plane: jax.Array, chunk: jax.Array, idx: jax.Array,
                      n_tok: jax.Array, window: int) -> jax.Array:
    """Bulk-write a prompt chunk into a positional cache plane.

    plane: (B, S, ...) cache; chunk: (B, C, ...) entries for positions
    idx..idx+n_tok-1 (t >= n_tok is padding and is NOT written).  For
    sliding-window caches the slot for position p is p % S and a chunk
    longer than the ring keeps only the last S positions — the write is
    a single deterministic scatter (losers map to the dropped
    out-of-range index), never a duplicate-index race.  n_tok == 0 is a
    bit-exact no-op.
    """
    S, C = plane.shape[1], chunk.shape[1]
    t = jnp.arange(C)
    if window > 0:
        tgt = (idx + t) % S
        win = (t < n_tok) & (t >= n_tok - S)  # ring: last S positions win
    else:
        tgt = idx + t
        win = t < n_tok
    tgt = jnp.where(win, tgt, S)  # S is out of range -> dropped
    return plane.at[:, tgt].set(chunk, mode="drop")


def _chunk_q_pos(idx: jax.Array, B: int, C: int, mrope: bool):
    pos = jnp.broadcast_to(idx + jnp.arange(C, dtype=jnp.int32), (B, C))
    return jnp.broadcast_to(pos, (3, B, C)) if mrope else pos


def _cache_entry_pos(slots: int, idx: jax.Array, window: int) -> jax.Array:
    """Absolute positions held by cache slots BEFORE a chunk at `idx` is
    written (positions < idx); empty/future slots get the mask sentinel."""
    slot_ids = jnp.arange(slots)
    last = idx - 1
    if window > 0:
        # slot s holds the most recent p <= last with p % slots == s
        pos = last - ((last - slot_ids) % slots)
    else:
        pos = slot_ids
    return jnp.where((pos >= 0) & (pos <= last), pos, -(10 ** 9))


def gqa_prefill(params: dict, x: jax.Array, cache: dict, idx: jax.Array,
                n_tok: jax.Array, a: AttnConfig, cfg: ModelConfig,
                window: int, theta: float) -> Tuple[jax.Array, dict]:
    """Multi-token prefill. x: (B, C, d) chunk at positions idx..idx+C-1;
    n_tok () valid tokens (the tail is padding: masked out of attention
    and never written).  Queries attend causally over the pre-existing
    cache entries plus the chunk itself, then the chunk's K/V land in
    the cache in one bulk write.  -> (out (B, C, d), cache)."""
    B, C, _ = x.shape
    kv = _kv_spec(a.n_kv_heads)
    kf, vf = x @ params["w_k"], x @ params["w_v"]
    if kv == REP:  # see gqa_apply: keep shards out of head_dim
        kf = constrain(kf, None, None, REP)
        vf = constrain(vf, None, None, REP)
    q = (x @ params["w_q"]).reshape(B, C, a.n_heads, a.head_dim)
    k = kf.reshape(B, C, a.n_kv_heads, a.head_dim)
    v = vf.reshape(B, C, a.n_kv_heads, a.head_dim)
    q, k = _maybe_qknorm(params, q, k, cfg.norm_eps)
    pos = _chunk_q_pos(idx, B, C, a.mrope_sections is not None)
    if a.use_rope:
        q = apply_rope(q, pos, theta, a.mrope_sections)
        k = apply_rope(k, pos, theta, a.mrope_sections)
    k = constrain(k, None, None, kv, None)
    v = constrain(v, None, None, kv, None)
    slots = cache["k"].shape[1]
    pos1d = pos if a.mrope_sections is None else pos[0]
    t = jnp.arange(C)
    chunk_pos = jnp.where(t < n_tok, idx + t, -(10 ** 9))
    k_pos = jnp.concatenate([_cache_entry_pos(slots, idx, window),
                             chunk_pos])
    k_all = jnp.concatenate([cache["k"], k], axis=1)
    v_all = jnp.concatenate([cache["v"], v], axis=1)
    scale = 1.0 / math.sqrt(a.head_dim)
    o = attend(q, k_all, v_all, pos1d[0], k_pos, window=window, causal=True,
               scale=scale, force_dense=(slots + C) <= ATTN_CHUNK * 4)
    o = o.reshape(B, C, -1) @ params["w_o"]
    ck = chunk_cache_write(cache["k"], k, idx, n_tok, window)
    cv = chunk_cache_write(cache["v"], v, idx, n_tok, window)
    return o, {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): latent-compressed KV
# ---------------------------------------------------------------------------

def _mla_qkv(params, x, a: AttnConfig):
    B, T, _ = x.shape
    if "q_down" in params:
        q = (x @ params["q_down"]) @ params["q_up"]
    else:
        q = x @ params["w_q"]
    q = q.reshape(B, T, a.n_heads, a.qk_nope_dim + a.qk_rope_dim)
    c_kv = x @ params["kv_down"]            # (B, T, r) latent
    k_r = x @ params["k_rope"]              # (B, T, rope_dim) shared rope key
    return q, c_kv, k_r


def _mla_expand(params, c_kv, a: AttnConfig):
    B, T, _ = c_kv.shape
    kv = (c_kv @ params["kv_up"]).reshape(
        B, T, a.n_heads, a.qk_nope_dim + a.v_head_dim)
    k_c, v = kv[..., :a.qk_nope_dim], kv[..., a.qk_nope_dim:]
    return k_c, v


def mla_absorbed(params: dict, a: AttnConfig) -> Tuple[jax.Array, jax.Array]:
    """(W_UK (r, H, nope), W_UV (r, H, v)) — kv_up split for the
    absorbed decode form.

    Instead of expanding every cached latent to per-head K/V
    (`_mla_expand`, O(S) work per decode step), W_UK folds into the
    query (q_lat[b,h] = q_nope[b,h] @ W_UK[:,h,:]^T, so scores are
    q_lat · c_kv — the latent IS the key) and W_UV folds into the
    output (o[b,h] = o_lat[b,h] @ W_UV[:,h,:], the latent IS the
    value).  Same linear algebra, contraction order swapped.  Prefers
    the precomputed leaves a serving engine installs once per
    swap_params (transformer.absorb_mla_params); the reshape fallback
    keeps the function usable on raw trees.
    """
    if "kv_uk" in params:
        return params["kv_uk"], params["kv_uv"]
    w = params["kv_up"].reshape(-1, a.n_heads, a.qk_nope_dim + a.v_head_dim)
    return w[..., :a.qk_nope_dim], w[..., a.qk_nope_dim:]


def mla_apply(params: dict, x: jax.Array, a: AttnConfig, cfg: ModelConfig,
              positions: jax.Array, theta: float) -> jax.Array:
    B, T, _ = x.shape
    q, c_kv, k_r = _mla_qkv(params, x, a)
    q_c, q_r = q[..., :a.qk_nope_dim], q[..., a.qk_nope_dim:]
    q_r = apply_rope(q_r, positions, theta)
    k_r = apply_rope(k_r[..., None, :], positions, theta)  # (B,T,1,rope)
    k_c, v = _mla_expand(params, c_kv, a)
    q_full = jnp.concatenate([q_c, q_r], -1)
    k_full = jnp.concatenate(
        [k_c, jnp.broadcast_to(k_r, k_c.shape[:-1] + (a.qk_rope_dim,))], -1)
    q_full = constrain(q_full, None, None, "model", None)
    k_full = constrain(k_full, None, None, "model", None)
    scale = 1.0 / math.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    o = attend(q_full, k_full, v, positions[0], positions[0], window=0,
               causal=True, scale=scale)
    o = constrain(o, None, None, "model", None)
    return o.reshape(B, T, -1) @ params["w_o"]


def mla_cache_init(a: AttnConfig, batch: int, max_seq: int, dtype) -> dict:
    # cache the *latent* (this is MLA's point: r + rope_dim per token,
    # not n_heads*dh) — 512+64 vs 128*192 for deepseek-v2.
    return {"c_kv": jnp.zeros((batch, max_seq, a.kv_lora_rank), dtype),
            "k_r": jnp.zeros((batch, max_seq, a.qk_rope_dim), dtype)}


def mla_decode(params: dict, x: jax.Array, cache: dict, idx: jax.Array,
               a: AttnConfig, cfg: ModelConfig,
               theta: float) -> Tuple[jax.Array, dict]:
    B = x.shape[0]
    q, c_kv, k_r = _mla_qkv(params, x, a)
    pos = jnp.full((B, 1), idx, jnp.int32)
    q_c, q_r = q[..., :a.qk_nope_dim], q[..., a.qk_nope_dim:]
    q_r = apply_rope(q_r, pos, theta)
    k_r = apply_rope(k_r[..., None, :], pos, theta)[..., 0, :]
    cc = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, idx, 1)
    cr = jax.lax.dynamic_update_slice_in_dim(cache["k_r"], k_r, idx, 1)
    S = cc.shape[1]
    k_c, v = _mla_expand(params, cc, a)  # (B,S,H,*) expanded on the fly
    k_pos = jnp.where(jnp.arange(S) <= idx, jnp.arange(S), -(10 ** 9))
    q_full = jnp.concatenate([q_c, q_r], -1)
    k_full = jnp.concatenate(
        [k_c, jnp.broadcast_to(cr[..., None, :],
                               k_c.shape[:-1] + (a.qk_rope_dim,))], -1)
    scale = 1.0 / math.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    o = attend(q_full, k_full, v, pos[0], k_pos, window=0, causal=True,
               scale=scale)
    o = o.reshape(B, 1, -1) @ params["w_o"]
    return o, {"c_kv": cc, "k_r": cr}


def mla_prefill(params: dict, x: jax.Array, cache: dict, idx: jax.Array,
                n_tok: jax.Array, a: AttnConfig, cfg: ModelConfig,
                theta: float) -> Tuple[jax.Array, dict]:
    """Multi-token MLA prefill: bulk-write the chunk's latents, then
    attend over the expanded cache (entries past idx+n_tok stay masked,
    exactly as in mla_decode).  -> (out (B, C, d), cache)."""
    B, C, _ = x.shape
    q, c_kv, k_r = _mla_qkv(params, x, a)
    pos = _chunk_q_pos(idx, B, C, False)
    q_c, q_r = q[..., :a.qk_nope_dim], q[..., a.qk_nope_dim:]
    q_r = apply_rope(q_r, pos, theta)
    k_r = apply_rope(k_r[..., None, :], pos, theta)[..., 0, :]
    cc = chunk_cache_write(cache["c_kv"], c_kv, idx, n_tok, 0)
    cr = chunk_cache_write(cache["k_r"], k_r, idx, n_tok, 0)
    S = cc.shape[1]
    k_c, v = _mla_expand(params, cc, a)
    slot_ids = jnp.arange(S)
    k_pos = jnp.where(slot_ids < idx + n_tok, slot_ids, -(10 ** 9))
    q_full = jnp.concatenate([q_c, q_r], -1)
    k_full = jnp.concatenate(
        [k_c, jnp.broadcast_to(cr[..., None, :],
                               k_c.shape[:-1] + (a.qk_rope_dim,))], -1)
    scale = 1.0 / math.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    o = attend(q_full, k_full, v, pos[0], k_pos, window=0, causal=True,
               scale=scale)
    o = o.reshape(B, C, -1) @ params["w_o"]
    return o, {"c_kv": cc, "k_r": cr}


# ---------------------------------------------------------------------------
# paged KV cache (serving): fixed-size pages + per-slot page table
# ---------------------------------------------------------------------------
# The serving engine's paged pool (serving/kv_cache.py) replaces the
# per-slot contiguous (B, max_seq, ...) planes of FULL-attention layers
# with a shared (n_pages, page_size, ...) pool addressed through a
# per-slot page table: logical position p of slot b lives at
# (table[b, p // page_size], p % page_size).  Sliding-window layers keep
# their contiguous rings — they are already O(window), paging buys them
# nothing.  Unallocated table entries carry a sentinel >= n_pages:
# writes drop (scatter mode="drop"), reads clamp and are masked by the
# position bookkeeping — the same stale-entry invariant the contiguous
# pool relies on.


# -- quantized pages --------------------------------------------------------
# kv_dtype selects the STORAGE format of paged planes only ("f32" = the
# model's native dtype, today's layout, bit-identical).  int8/fp8 planes
# carry a per-token, per-kv-head absmax scale in a sidecar plane named
# `<plane>_scale_pages` with the page axes leading — the "_pages" suffix
# means every pool helper (reset/slot_row/copy_pages/snapshot) already
# treats a sidecar exactly like its plane, and per-token granularity
# makes single-token scatter writes rescale-free: a write never has to
# requantize its page neighbors.  Sliding-window rings and recurrent
# state are NOT quantized (they are already O(window)/O(1) and live
# outside the paged pool).

KV_DTYPES = ("f32", "bf16", "int8", "fp8")
_INT8_MAX = 127.0
_FP8_MAX = 448.0  # float8_e4m3fn finite max


def fp8_dtype():
    dt = getattr(jnp, "float8_e4m3fn", None)
    if dt is None:
        raise ValueError("kv_dtype='fp8' needs jax.numpy.float8_e4m3fn, "
                         "which this platform's jax does not provide — "
                         "use 'int8'")
    return dt


def kv_quantized(kv_dtype: str) -> bool:
    return kv_dtype in ("int8", "fp8")


def kv_storage_dtype(kv_dtype: str, dtype):
    """Storage dtype of a paged K/V plane under `kv_dtype` ('f32' keeps
    the model's native dtype)."""
    if kv_dtype == "f32":
        return dtype
    if kv_dtype == "bf16":
        return jnp.bfloat16
    if kv_dtype == "int8":
        return jnp.int8
    if kv_dtype == "fp8":
        return fp8_dtype()
    raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                     f"got {kv_dtype!r}")


def kv_quantize(vals: jax.Array, qdtype) -> Tuple[jax.Array, jax.Array]:
    """(..., d) -> ((..., d) qdtype, (...,) f32 absmax scale).

    scale = absmax(vals)/Q over the trailing feature axis, one scale per
    token (and per kv head, since the head axis precedes the feature
    axis in every paged plane).  An all-zero vector quantizes to zeros
    with scale 0 — dequant reproduces the zeros exactly.
    """
    v = vals.astype(jnp.float32)
    qmax = _INT8_MAX if jnp.issubdtype(qdtype, jnp.integer) else _FP8_MAX
    scale = jnp.max(jnp.abs(v), axis=-1) / qmax
    q = v / jnp.maximum(scale, 1e-30)[..., None]
    if jnp.issubdtype(qdtype, jnp.integer):
        q = jnp.round(q).clip(-_INT8_MAX, _INT8_MAX)
    return q.astype(qdtype), scale


def kv_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of kv_quantize: f32 values (math stays f32 in-register)."""
    return q.astype(jnp.float32) * scale[..., None]


def _scale_name(name: str) -> str:
    return name[: -len("_pages")] + "_scale_pages"


def gqa_paged_cache_init(a: AttnConfig, n_pages: int, page_size: int,
                         dtype, kv_dtype: str = "f32") -> dict:
    sdtype = kv_storage_dtype(kv_dtype, dtype)
    shape = (n_pages, page_size, a.n_kv_heads, a.head_dim)
    c = {"k_pages": jnp.zeros(shape, sdtype),
         "v_pages": jnp.zeros(shape, sdtype)}
    if kv_quantized(kv_dtype):
        ss = (n_pages, page_size, a.n_kv_heads)
        c["k_scale_pages"] = jnp.zeros(ss, jnp.float32)
        c["v_scale_pages"] = jnp.zeros(ss, jnp.float32)
    return c


def mla_paged_cache_init(a: AttnConfig, n_pages: int, page_size: int,
                         dtype, kv_dtype: str = "f32") -> dict:
    # pages hold the latent (MLA's point: r + rope_dim per token).  The
    # rope keys stay in the native dtype under int8/fp8: they are
    # rope_dim/kv_lora_rank of the bytes and feed the kernel as the
    # unquantized `k_extra` feature block, so the dominant latent plane
    # quantizes without a second scale family.
    sdtype = kv_storage_dtype(kv_dtype, dtype)
    rdtype = dtype if kv_quantized(kv_dtype) else sdtype
    c = {"c_kv_pages": jnp.zeros((n_pages, page_size, a.kv_lora_rank),
                                 sdtype),
         "k_r_pages": jnp.zeros((n_pages, page_size, a.qk_rope_dim),
                                rdtype)}
    if kv_quantized(kv_dtype):
        c["c_kv_scale_pages"] = jnp.zeros((n_pages, page_size),
                                          jnp.float32)
    return c


def _scatter_token(plane: jax.Array, vals: jax.Array, table: jax.Array,
                   pos: jax.Array) -> jax.Array:
    """Write one token per slot into a paged plane.

    plane: (n_pages, page, ...); vals: (B, ...); table: (B, P);
    pos: (B,) logical positions.  Slots whose target page is
    unallocated (sentinel) drop the write — the engine only lets rows
    with allocated pages advance, so a dropped write is always a frozen
    slot's garbage step (same invariant as kv_cache.keep_frozen).
    """
    n_pages, page = plane.shape[0], plane.shape[1]
    P = table.shape[1]
    l = pos // page
    off = pos % page
    phys = jnp.take_along_axis(table, jnp.clip(l, 0, P - 1)[:, None],
                               axis=1)[:, 0]
    phys = jnp.where(l < P, phys, n_pages)  # out-of-table -> drop
    # distinct slots own distinct pages (allocator invariant), so the
    # scatter indices never collide on valid rows
    return plane.at[phys, off].set(vals, mode="drop")


def _gather_pages(plane: jax.Array, table: jax.Array) -> jax.Array:
    """(n_pages, page, ...) x (B?, P) -> (B?, P*page, ...) logical view.
    Unallocated entries clamp to an arbitrary live page; callers mask
    them by position."""
    n_pages, page = plane.shape[0], plane.shape[1]
    t = jnp.clip(table, 0, n_pages - 1)
    out = plane[t]
    lead = table.shape[:-1]
    return out.reshape(lead + (table.shape[-1] * page,) + plane.shape[2:])


# -- quantize-on-write / dequantize-on-read wrappers ------------------------
# Every paged write/read goes through these: when the layer's cache
# carries a `<plane>_scale_pages` sidecar the values are quantized on
# the way in (one absmax scale per token written) and dequantized to
# f32 on the way out; otherwise the plane's dtype is a plain cast
# (no-op for kv_dtype='f32', preserving bit-identity with the
# unquantized layout).  Each wrapper returns the dict of UPDATED leaves
# so callers can merge plane + sidecar updates in one place.


def paged_write_token(cache: dict, name: str, vals: jax.Array,
                      table: jax.Array, pos: jax.Array) -> dict:
    plane = cache[name]
    sname = _scale_name(name)
    if sname in cache:
        q, s = kv_quantize(vals, plane.dtype)
        return {name: _scatter_token(plane, q, table, pos),
                sname: _scatter_token(cache[sname], s, table, pos)}
    return {name: _scatter_token(plane, vals.astype(plane.dtype), table,
                                 pos)}


def paged_write_chunk(cache: dict, name: str, chunk: jax.Array,
                      table: jax.Array, idx: jax.Array,
                      n_tok: jax.Array) -> dict:
    plane = cache[name]
    sname = _scale_name(name)
    if sname in cache:
        q, s = kv_quantize(chunk, plane.dtype)
        return {name: chunk_cache_write_paged(plane, q, table, idx, n_tok),
                sname: chunk_cache_write_paged(cache[sname], s, table, idx,
                                               n_tok)}
    return {name: chunk_cache_write_paged(plane, chunk.astype(plane.dtype),
                                          table, idx, n_tok)}


def paged_write_batch(cache: dict, name: str, chunk: jax.Array,
                      table: jax.Array, pos: jax.Array,
                      n_tok: jax.Array) -> dict:
    plane = cache[name]
    sname = _scale_name(name)
    if sname in cache:
        q, s = kv_quantize(chunk, plane.dtype)
        return {name: chunk_scatter_batch(plane, q, table, pos, n_tok),
                sname: chunk_scatter_batch(cache[sname], s, table, pos,
                                           n_tok)}
    return {name: chunk_scatter_batch(plane, chunk.astype(plane.dtype),
                                      table, pos, n_tok)}


def paged_gather(cache: dict, name: str, table: jax.Array,
                 out_dtype=None) -> jax.Array:
    """_gather_pages + dequantization for the dense (prefill/verify)
    read paths.  out_dtype casts the logical view to the compute dtype
    (no-op when the plane already stores it, i.e. kv_dtype='f32')."""
    out = _gather_pages(cache[name], table)
    sname = _scale_name(name)
    if sname in cache:
        out = kv_dequantize(out, _gather_pages(cache[sname], table))
    if out_dtype is not None and out.dtype != out_dtype:
        out = out.astype(out_dtype)
    return out


def chunk_cache_write_paged(plane: jax.Array, chunk: jax.Array,
                            table: jax.Array, idx: jax.Array,
                            n_tok: jax.Array) -> jax.Array:
    """Bulk-write a prompt chunk into a paged plane (one slot).

    plane: (n_pages, page, ...); chunk: (C, ...) entries for positions
    idx..idx+n_tok-1 (t >= n_tok is padding and is NOT written);
    table: (P,) the slot's page-table row.  The paged twin of
    chunk_cache_write — same deterministic single-scatter contract,
    n_tok == 0 is a bit-exact no-op.  No ring arithmetic: paged layers
    are full-attention (window 0 or >= max_seq), so positions never
    wrap inside max_seq.

    Writes land ONLY at positions idx..idx+n_tok-1 — pages below idx
    are read, never written.  Prefix caching (serving/prefix.py) leans
    on exactly that: a prefix-hit slot's chain starts with SHARED
    pages other requests also read, and admission sets idx to the hit
    boundary, so this scatter can never touch them (the partial
    boundary page is copy-on-write-swapped for a private copy before
    the chunk dispatches).
    """
    n_pages, page = plane.shape[0], plane.shape[1]
    P = table.shape[0]
    C = chunk.shape[0]
    t = jnp.arange(C)
    pos = idx + t
    l = pos // page
    off = pos % page
    phys = table[jnp.clip(l, 0, P - 1)]
    phys = jnp.where((t < n_tok) & (l < P), phys, n_pages)  # pad -> drop
    return plane.at[phys, off].set(chunk, mode="drop")


def _rows_bias(lens: jax.Array, S: int, window: int) -> jax.Array:
    """(B, 1, S) additive mask for per-row decode: entries < lens valid,
    window limits lookback from the query position lens-1."""
    kp = jnp.arange(S)
    ok = kp[None, :] < lens[:, None]
    if window > 0:
        ok &= kp[None, :] > (lens[:, None] - 1 - window)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)[:, None]


def gqa_decode_paged(params: dict, x: jax.Array, cache: dict,
                     pos: jax.Array, table: jax.Array, a: AttnConfig,
                     cfg: ModelConfig, window: int,
                     theta: float) -> Tuple[jax.Array, dict]:
    """One-token decode over a paged pool, every row at its OWN position.

    x: (B, 1, d); pos: (B,) per-row positions; table: (B, P) page table;
    cache: {"k_pages": (n_pages, page, n_kv, dh), "v_pages": ...}.  The
    new token's KV scatters into the slot's current page, then attention
    reads the slot's pages through kernels/ops.paged_attention (Pallas
    O(len) kernel on TPU, gather reference elsewhere).
    """
    B = x.shape[0]
    kv = _kv_spec(a.n_kv_heads)
    kf, vf = x @ params["w_k"], x @ params["w_v"]
    if kv == REP:  # see gqa_apply: keep shards out of head_dim
        kf = constrain(kf, None, None, REP)
        vf = constrain(vf, None, None, REP)
    q = (x @ params["w_q"]).reshape(B, 1, a.n_heads, a.head_dim)
    k = kf.reshape(B, 1, a.n_kv_heads, a.head_dim)
    v = vf.reshape(B, 1, a.n_kv_heads, a.head_dim)
    q, k = _maybe_qknorm(params, q, k, cfg.norm_eps)
    pos2 = pos[:, None]  # (B, 1) per-row, vs gqa_decode's shared scalar
    if a.mrope_sections is not None:
        pos3 = jnp.broadcast_to(pos2, (3,) + pos2.shape)
        if a.use_rope:
            q = apply_rope(q, pos3, theta, a.mrope_sections)
            k = apply_rope(k, pos3, theta, a.mrope_sections)
    elif a.use_rope:
        q = apply_rope(q, pos2, theta)
        k = apply_rope(k, pos2, theta)
    k = constrain(k, None, None, kv, None)
    v = constrain(v, None, None, kv, None)
    upd = paged_write_token(cache, "k_pages", k[:, 0], table, pos)
    upd.update(paged_write_token(cache, "v_pages", v[:, 0], table, pos))
    scale = 1.0 / math.sqrt(a.head_dim)
    o = ops.paged_attention(q[:, 0], upd["k_pages"], upd["v_pages"],
                            table, pos + 1, window=window, scale=scale,
                            k_scale=upd.get("k_scale_pages"),
                            v_scale=upd.get("v_scale_pages"))
    o = o.reshape(B, 1, -1) @ params["w_o"]
    return o, upd


def gqa_prefill_paged(params: dict, x: jax.Array, cache: dict,
                      idx: jax.Array, n_tok: jax.Array, table: jax.Array,
                      a: AttnConfig, cfg: ModelConfig, window: int,
                      theta: float) -> Tuple[jax.Array, dict]:
    """Multi-token prefill of ONE slot over a paged pool.

    x: (1, C, d) chunk at positions idx..idx+C-1; table: (P,) the slot's
    page-table row.  Same math as gqa_prefill — queries attend over the
    gathered pre-existing pages plus the chunk, then the chunk's K/V
    land in the slot's pages in one scatter.
    """
    B, C, _ = x.shape
    kv = _kv_spec(a.n_kv_heads)
    kf, vf = x @ params["w_k"], x @ params["w_v"]
    if kv == REP:
        kf = constrain(kf, None, None, REP)
        vf = constrain(vf, None, None, REP)
    q = (x @ params["w_q"]).reshape(B, C, a.n_heads, a.head_dim)
    k = kf.reshape(B, C, a.n_kv_heads, a.head_dim)
    v = vf.reshape(B, C, a.n_kv_heads, a.head_dim)
    q, k = _maybe_qknorm(params, q, k, cfg.norm_eps)
    pos = _chunk_q_pos(idx, B, C, a.mrope_sections is not None)
    if a.use_rope:
        q = apply_rope(q, pos, theta, a.mrope_sections)
        k = apply_rope(k, pos, theta, a.mrope_sections)
    k = constrain(k, None, None, kv, None)
    v = constrain(v, None, None, kv, None)
    k_cache = paged_gather(cache, "k_pages", table[None],
                           k.dtype)            # (1, S, kv, dh)
    v_cache = paged_gather(cache, "v_pages", table[None], v.dtype)
    S = k_cache.shape[1]
    pos1d = pos if a.mrope_sections is None else pos[0]
    t = jnp.arange(C)
    chunk_pos = jnp.where(t < n_tok, idx + t, -(10 ** 9))
    slot_ids = jnp.arange(S)
    cache_pos = jnp.where(slot_ids < idx, slot_ids, -(10 ** 9))
    k_pos = jnp.concatenate([cache_pos, chunk_pos])
    k_all = jnp.concatenate([k_cache, k], axis=1)
    v_all = jnp.concatenate([v_cache, v], axis=1)
    scale = 1.0 / math.sqrt(a.head_dim)
    o = attend(q, k_all, v_all, pos1d[0], k_pos, window=window, causal=True,
               scale=scale, force_dense=(S + C) <= ATTN_CHUNK * 4)
    o = o.reshape(B, C, -1) @ params["w_o"]
    upd = paged_write_chunk(cache, "k_pages", k[0], table, idx, n_tok)
    upd.update(paged_write_chunk(cache, "v_pages", v[0], table, idx, n_tok))
    return o, upd


def mla_decode_paged(params: dict, x: jax.Array, cache: dict,
                     pos: jax.Array, table: jax.Array, a: AttnConfig,
                     cfg: ModelConfig,
                     theta: float) -> Tuple[jax.Array, dict]:
    """MLA one-token decode over paged LATENT planes in the ABSORBED
    projection form, per-row positions.

    The pages hold the compressed latent (c_kv, k_r); the step scatters
    the new token's latent and feeds the latent pages to
    ops.paged_attention DIRECTLY: W_UK is folded into the queries and
    W_UV into the output (mla_absorbed), so attention runs at
    dk = kv_lora_rank + rope_dim / dv = kv_lora_rank with the rope keys
    as the kernel's unquantized `k_extra` block — no `_mla_expand` of
    the whole gathered sequence on the hot path.  Per-step work is
    O(1) in max_seq (plus the kernel's O(len) page walk); greedy output
    is token-exact vs the expanded path at f32 (same linear algebra,
    reassociated contractions).
    """
    B = x.shape[0]
    q, c_kv, k_r = _mla_qkv(params, x, a)
    pos2 = pos[:, None]
    q_c, q_r = q[..., :a.qk_nope_dim], q[..., a.qk_nope_dim:]
    q_r = apply_rope(q_r, pos2, theta)
    k_r = apply_rope(k_r[..., None, :], pos2, theta)[..., 0, :]
    upd = paged_write_token(cache, "c_kv_pages", c_kv[:, 0], table, pos)
    upd.update(paged_write_token(cache, "k_r_pages", k_r[:, 0], table, pos))
    w_uk, w_uv = mla_absorbed(params, a)
    q_lat = jnp.einsum("bhn,rhn->bhr", q_c[:, 0], w_uk)
    q_abs = jnp.concatenate([q_lat, q_r[:, 0]], -1)  # (B, H, r + rope)
    scale = 1.0 / math.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    c_scale = upd.get("c_kv_scale_pages")
    o_lat = ops.paged_attention(
        q_abs, upd["c_kv_pages"][:, :, None], upd["c_kv_pages"][:, :, None],
        table, pos + 1, scale=scale,
        k_scale=None if c_scale is None else c_scale[:, :, None],
        v_scale=None if c_scale is None else c_scale[:, :, None],
        k_extra=upd["k_r_pages"][:, :, None])  # (B, H, r)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv)
    o = o.reshape(B, 1, -1) @ params["w_o"]
    return o, upd


def mla_prefill_paged(params: dict, x: jax.Array, cache: dict,
                      idx: jax.Array, n_tok: jax.Array, table: jax.Array,
                      a: AttnConfig, cfg: ModelConfig,
                      theta: float) -> Tuple[jax.Array, dict]:
    """Multi-token MLA prefill of ONE slot over paged latent planes:
    scatter the chunk's latents, gather + expand, attend with entries
    past idx+n_tok masked — the paged twin of mla_prefill."""
    B, C, _ = x.shape
    q, c_kv, k_r = _mla_qkv(params, x, a)
    pos = _chunk_q_pos(idx, B, C, False)
    q_c, q_r = q[..., :a.qk_nope_dim], q[..., a.qk_nope_dim:]
    q_r = apply_rope(q_r, pos, theta)
    k_r = apply_rope(k_r[..., None, :], pos, theta)[..., 0, :]
    upd = paged_write_chunk(cache, "c_kv_pages", c_kv[0], table, idx,
                            n_tok)
    upd.update(paged_write_chunk(cache, "k_r_pages", k_r[0], table, idx,
                                 n_tok))
    c2 = dict(cache)
    c2.update(upd)
    lat = paged_gather(c2, "c_kv_pages", table[None], c_kv.dtype)  # (1,S,r)
    rop = paged_gather(c2, "k_r_pages", table[None], k_r.dtype)
    S = lat.shape[1]
    k_c, v = _mla_expand(params, lat, a)
    slot_ids = jnp.arange(S)
    k_pos = jnp.where(slot_ids < idx + n_tok, slot_ids, -(10 ** 9))
    q_full = jnp.concatenate([q_c, q_r], -1)
    k_full = jnp.concatenate(
        [k_c, jnp.broadcast_to(rop[..., None, :],
                               k_c.shape[:-1] + (a.qk_rope_dim,))], -1)
    scale = 1.0 / math.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    o = attend(q_full, k_full, v, pos[0], k_pos, window=0, causal=True,
               scale=scale)
    o = o.reshape(B, C, -1) @ params["w_o"]
    return o, upd


# ---------------------------------------------------------------------------
# paged speculative verify: batched multi-token scoring at per-row positions
# ---------------------------------------------------------------------------
# Speculative decoding scores a (gamma+1)-token draft chunk for EVERY
# slot in one call.  The per-slot prefill entry points above handle one
# slot at a time (their tables are (P,)), and the row-vmap trick cannot
# carry the shared paged planes, so these batched siblings scatter the
# whole batch's chunks through (B, P) tables and attend densely with a
# per-row (B, C, S) bias.  Full-attention only (the paged invariant):
# positions never wrap, so stale entries past each row's position are
# masked by causality alone.


def chunk_scatter_batch(plane: jax.Array, chunk: jax.Array,
                        table: jax.Array, pos: jax.Array,
                        n_tok: jax.Array) -> jax.Array:
    """Bulk-write per-slot chunks into a paged plane, ALL slots at once.

    plane: (n_pages, page, ...); chunk: (B, C, ...) entries for row b's
    positions pos[b]..pos[b]+n_tok[b]-1 (the tail is padding and is NOT
    written); table: (B, P); pos/n_tok: (B,).  The batched twin of
    chunk_cache_write_paged: out-of-table or padded targets map to the
    dropped sentinel, distinct slots own distinct pages (allocator
    invariant), so the scatter never races.  n_tok[b] == 0 rows are
    bit-exact no-ops.
    """
    n_pages, page = plane.shape[0], plane.shape[1]
    P = table.shape[1]
    C = chunk.shape[1]
    t = jnp.arange(C)[None, :]
    p = pos[:, None] + t                    # (B, C) logical positions
    l = p // page
    off = p % page
    phys = jnp.take_along_axis(table, jnp.clip(l, 0, P - 1), axis=1)
    phys = jnp.where((t < n_tok[:, None]) & (l < P), phys, n_pages)
    return plane.at[phys, off].set(chunk, mode="drop")


def _verify_bias(pos: jax.Array, S: int, C: int, window: int) -> jax.Array:
    """(B, C, S) additive mask for batched chunk verify: row b's query i
    sits at position pos[b]+i and sees cache entries at positions
    <= pos[b]+i (stale/padded entries live past that, so causality masks
    them); window > 0 limits lookback."""
    q_pos = pos[:, None] + jnp.arange(C)[None, :]       # (B, C)
    kp = jnp.arange(S)[None, None, :]
    ok = kp <= q_pos[:, :, None]
    if window > 0:
        ok &= kp > q_pos[:, :, None] - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def gqa_verify_paged(params: dict, x: jax.Array, cache: dict,
                     pos: jax.Array, n_tok: jax.Array, table: jax.Array,
                     a: AttnConfig, cfg: ModelConfig, window: int,
                     theta: float) -> Tuple[jax.Array, dict]:
    """Score a C-token chunk for every slot over a paged pool.

    x: (B, C, d) draft chunks at positions pos..pos+C-1; n_tok: (B,)
    valid tokens per row (0 freezes the row bit-exactly); table: (B, P).
    Scatter-then-gather: the chunk's K/V land in each slot's pages
    first, then every query attends over the gathered logical view with
    a per-row causal bias — same math as gqa_prefill_paged, batched.
    -> (out (B, C, d), cache).
    """
    B, C, _ = x.shape
    kv = _kv_spec(a.n_kv_heads)
    kf, vf = x @ params["w_k"], x @ params["w_v"]
    if kv == REP:  # see gqa_apply: keep shards out of head_dim
        kf = constrain(kf, None, None, REP)
        vf = constrain(vf, None, None, REP)
    q = (x @ params["w_q"]).reshape(B, C, a.n_heads, a.head_dim)
    k = kf.reshape(B, C, a.n_kv_heads, a.head_dim)
    v = vf.reshape(B, C, a.n_kv_heads, a.head_dim)
    q, k = _maybe_qknorm(params, q, k, cfg.norm_eps)
    p2 = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]  # (B, C)
    rp = (jnp.broadcast_to(p2, (3,) + p2.shape)
          if a.mrope_sections is not None else p2)
    if a.use_rope:
        q = apply_rope(q, rp, theta, a.mrope_sections)
        k = apply_rope(k, rp, theta, a.mrope_sections)
    k = constrain(k, None, None, kv, None)
    v = constrain(v, None, None, kv, None)
    upd = paged_write_batch(cache, "k_pages", k, table, pos, n_tok)
    upd.update(paged_write_batch(cache, "v_pages", v, table, pos, n_tok))
    c2 = dict(cache)
    c2.update(upd)
    kk = paged_gather(c2, "k_pages", table, k.dtype)  # (B, S, n_kv, dh)
    vv = paged_gather(c2, "v_pages", table, v.dtype)
    scale = 1.0 / math.sqrt(a.head_dim)
    o = _attend_dense(q, kk, vv, _verify_bias(pos, kk.shape[1], C, window),
                      scale)
    o = o.reshape(B, C, -1) @ params["w_o"]
    return o, upd


def mla_verify_paged(params: dict, x: jax.Array, cache: dict,
                     pos: jax.Array, n_tok: jax.Array, table: jax.Array,
                     a: AttnConfig, cfg: ModelConfig,
                     theta: float) -> Tuple[jax.Array, dict]:
    """MLA chunk verify over paged latent planes, all slots at once:
    scatter the chunks' latents, gather + expand each row's logical
    view, attend with the per-row causal bias — mla_prefill_paged's
    math, batched over slots.  -> (out (B, C, d), cache)."""
    B, C, _ = x.shape
    q, c_kv, k_r = _mla_qkv(params, x, a)
    p2 = pos[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
    q_c, q_r = q[..., :a.qk_nope_dim], q[..., a.qk_nope_dim:]
    q_r = apply_rope(q_r, p2, theta)
    k_r = apply_rope(k_r[..., None, :], p2, theta)[..., 0, :]
    upd = paged_write_batch(cache, "c_kv_pages", c_kv, table, pos, n_tok)
    upd.update(paged_write_batch(cache, "k_r_pages", k_r, table, pos,
                                 n_tok))
    c2 = dict(cache)
    c2.update(upd)
    lat = paged_gather(c2, "c_kv_pages", table, c_kv.dtype)  # (B, S, r)
    rop = paged_gather(c2, "k_r_pages", table, k_r.dtype)    # (B, S, rope)
    S = lat.shape[1]
    k_c, v = _mla_expand(params, lat, a)
    q_full = jnp.concatenate([q_c, q_r], -1)
    k_full = jnp.concatenate(
        [k_c, jnp.broadcast_to(rop[..., None, :],
                               k_c.shape[:-1] + (a.qk_rope_dim,))], -1)
    scale = 1.0 / math.sqrt(a.qk_nope_dim + a.qk_rope_dim)
    o = _attend_dense(q_full, k_full, v, _verify_bias(pos, S, C, 0), scale)
    o = o.reshape(B, C, -1) @ params["w_o"]
    return o, upd


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg: ModelConfig, a: AttnConfig, dtype) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "w_cross_q": dense_init(ks[0], (d, a.n_heads * a.head_dim), dtype),
        "w_k": dense_init(ks[1], (d, a.n_kv_heads * a.head_dim), dtype),
        "w_v": dense_init(ks[2], (d, a.n_kv_heads * a.head_dim), dtype),
        "w_o": dense_init(ks[3], (a.n_heads * a.head_dim, d), dtype),
    }


def cross_attn_apply(params: dict, x: jax.Array, enc: jax.Array,
                     a: AttnConfig) -> jax.Array:
    B, T, _ = x.shape
    S = enc.shape[1]
    q = (x @ params["w_cross_q"]).reshape(B, T, a.n_heads, a.head_dim)
    k = (enc @ params["w_k"]).reshape(B, S, a.n_kv_heads, a.head_dim)
    v = (enc @ params["w_v"]).reshape(B, S, a.n_kv_heads, a.head_dim)
    scale = 1.0 / math.sqrt(a.head_dim)
    pos_q = jnp.arange(T)
    pos_k = jnp.arange(S)
    o = attend(q, k, v, pos_q, pos_k, window=0, causal=False, scale=scale)
    return o.reshape(B, T, -1) @ params["w_o"]
