"""Network-in-Network (NiN) CNN — the paper's CIFAR-100 architecture [15].

9 conv layers in three NiN blocks (5x5 conv followed by two 1x1 "mlpconv"
layers), max/avg pooling between blocks, global average pooling into the
class logits.  ReLU activations, used with momentum-SGD + l2 regularization
to mirror the paper's Section 5.1 setup.

This model is what examples/ec_vs_ma_faithful.py trains: it is the faithful
EC-DNN reproduction target, while the transformer zoo exercises the
framework at assigned-architecture scale.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

# (kind, out_channels, kernel, stride) — kind: conv | maxpool | avgpool
NIN_SPEC = (
    ("conv", 192, 5, 1), ("conv", 160, 1, 1), ("conv", 96, 1, 1),
    ("maxpool", 0, 3, 2),
    ("conv", 192, 5, 1), ("conv", 192, 1, 1), ("conv", 192, 1, 1),
    ("avgpool", 0, 3, 2),
    ("conv", 192, 3, 1), ("conv", 192, 1, 1),
)


def nin_init(key, n_classes: int = 100, in_ch: int = 3,
             width_mult: float = 1.0) -> dict:
    params = {}
    ch = in_ch
    ks = jax.random.split(key, len(NIN_SPEC) + 1)
    for i, (kind, out, k, _s) in enumerate(NIN_SPEC):
        if kind != "conv":
            continue
        out = max(8, int(out * width_mult))
        params[f"conv_{i}_w"] = dense_init(
            ks[i], (k, k, ch, out), jnp.float32,
            scale=1.0 / (k * (ch ** 0.5)))
        params[f"bias_{i}"] = jnp.zeros((out,), jnp.float32)
        ch = out
    # final 1x1 conv onto class logits
    params["conv_out_w"] = dense_init(ks[-1], (1, 1, ch, n_classes),
                                      jnp.float32, scale=1.0 / (ch ** 0.5))
    params["bias_out"] = jnp.zeros((n_classes,), jnp.float32)
    return params


def _pool(x, k, s, kind):
    init = -jnp.inf if kind == "maxpool" else 0.0
    op = jax.lax.max if kind == "maxpool" else jax.lax.add
    y = jax.lax.reduce_window(x, init, op, (1, k, k, 1), (1, s, s, 1),
                              "SAME")
    if kind == "avgpool":
        y = y / (k * k)
    return y


def nin_apply(params: dict, images: jax.Array) -> jax.Array:
    """images: (B, 32, 32, 3) -> logits (B, n_classes)."""
    x = images
    for i, (kind, _out, k, s) in enumerate(NIN_SPEC):
        if kind == "conv":
            x = jax.lax.conv_general_dilated(
                x, params[f"conv_{i}_w"], (s, s), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            x = jax.nn.relu(x + params[f"bias_{i}"])
        else:
            x = _pool(x, k, s, kind)
    x = jax.lax.conv_general_dilated(
        x, params["conv_out_w"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC")) + params["bias_out"]
    return x.mean(axis=(1, 2))  # global average pool


def nin_loss(params: dict, batch: dict, l2: float = 1e-4
             ) -> Tuple[jax.Array, jax.Array]:
    """-> (loss, logits). batch: {images (B,H,W,C), labels (B,) int}."""
    logits = nin_apply(params, batch["images"])
    nll = jnp.mean(
        jax.nn.logsumexp(logits, -1)
        - jnp.take_along_axis(logits, batch["labels"][:, None], 1)[:, 0])
    reg = sum(jnp.sum(jnp.square(v)) for k, v in params.items()
              if k.endswith("_w"))
    return nll + l2 * reg, logits
