"""Primitive layers: norms, rotary embeddings, MLPs, embeddings.

All layers are (init, apply) function pairs over plain dict pytrees.  Weight
names are the contract with repro.common.sharding — do not rename leaves.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.sharding import constrain
from repro.common.types import ModelConfig


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# bf16 gradient communication (distributed-optimization trick)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def grad_bf16(x):
    """Identity whose cotangent is rounded through bf16.

    Placed at layer boundaries, it halves the payload of every
    TP/SP backward all-reduce/reduce-scatter crossing it (the f32 loss
    upcast otherwise propagates f32 cotangents through the whole
    backward).  Opt-in via layout_ctx(bf16_grads=True) — EXPERIMENTS §Perf
    records the before/after."""
    return x


def _gb_fwd(x):
    return x, None


def _gb_bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype),)


grad_bf16.defvjp(_gb_fwd, _gb_bwd)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int) -> dict:
    return {"norm_scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["norm_scale"]).astype(dt)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float,
               sections: Optional[Tuple[int, ...]] = None) -> jax.Array:
    """Rotate `x` (..., seq, heads, head_dim) by `positions`.

    positions: (..., seq) for standard RoPE or (3, ..., seq) for M-RoPE with
    `sections` giving the per-axis split of the half-dim (qwen2-vl).
    """
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    if sections is None:
        angles = positions[..., None].astype(jnp.float32) * freqs  # (...,seq,half)
    else:
        # positions: (3, ..., seq); build per-frequency position index by
        # section: freq j in section s uses positions[s].
        sec_ids = jnp.repeat(
            jnp.arange(len(sections)), jnp.array(sections),
            total_repeat_length=half)  # (half,)
        pos = jnp.take(positions, sec_ids, axis=0)  # (half, ..., seq)
        pos = jnp.moveaxis(pos, 0, -1)  # (..., seq, half)
        angles = pos.astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    cos = cos[..., None, :]  # broadcast over heads: (...,seq,1,half)
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32)
                  * (-math.log(10000.0) / d))
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


# ---------------------------------------------------------------------------
# MLP (swiglu / geglu / gelu)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, mlp_type: str, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_init(ks[0], (d_model, d_ff), dtype),
         "w_down": dense_init(ks[1], (d_ff, d_model), dtype)}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[2], (d_model, d_ff), dtype)
    return p


def mlp_apply(params: dict, x: jax.Array, mlp_type: str) -> jax.Array:
    up = x @ params["w_up"]
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * up
    elif mlp_type == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"], approximate=True) * up
    else:  # plain gelu
        h = jax.nn.gelu(up, approximate=True)
    h = constrain(h, None, None, "model")
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# Embedding + LM head (vocab sharded over "model")
# ---------------------------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype) -> dict:
    # GPT-style 0.02 std keeps tied-head logits sane at init
    return {"embed": dense_init(key, (vocab, d_model), dtype, scale=0.02)}


def embed_lookup(params: dict, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x


def head_init(key, vocab: int, d_model: int, dtype) -> dict:
    return {"head": dense_init(key, (vocab, d_model), dtype)}


def lm_logits(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    table = params["head"] if "head" in params else params["embed"]
    logits = jnp.einsum("...d,vd->...v", x, table)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return constrain(logits, None, None, "model")
