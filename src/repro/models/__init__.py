"""Model facade: family dispatch between the transformer zoo and the CNN.

All models expose (init, loss_and_aux, predict_logits); the transformer
family adds prefill/decode.  EC-DNN's core only depends on this facade —
it treats any model as "params -> per-example categorical distribution".
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.common.types import ModelConfig


def init(key, cfg: ModelConfig) -> dict:
    if cfg.family == "cnn":
        from repro.models import cnn as _cnn
        # d_model doubles as the NiN width knob (192 = the paper's size)
        return _cnn.nin_init(key, n_classes=cfg.vocab_size,
                             width_mult=cfg.d_model / 192.0)
    from repro.models import transformer as _tf
    return _tf.init(key, cfg)


def loss_and_aux(params, cfg: ModelConfig, batch: dict,
                 remat: bool = True) -> Tuple[jax.Array, jax.Array]:
    if cfg.family == "cnn":
        from repro.models import cnn as _cnn
        loss, _ = _cnn.nin_loss(params, batch)
        return loss, 0.0
    from repro.models import transformer as _tf
    return _tf.loss_and_aux(params, cfg, batch, remat=remat)


def predict_logits(params, cfg: ModelConfig, batch: dict) -> jax.Array:
    """Logits over classes/vocab — what EC-DNN ensembles (Eqn 6)."""
    if cfg.family == "cnn":
        from repro.models import cnn as _cnn
        return _cnn.nin_apply(params, batch["images"])
    from repro.models import transformer as _tf
    logits, _ = _tf.apply(params, cfg, tokens=batch.get("tokens"),
                          embeds=batch.get("embeds"),
                          enc_embeds=batch.get("enc_embeds"), remat=False)
    return logits
