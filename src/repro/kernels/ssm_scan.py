"""Mamba selective-scan h_t = a_t*h_{t-1} + b_t as a chunked Pallas kernel.

TPU adaptation: the GPU kernel's per-thread sequential scan becomes a
chunk-sequential grid with the (BD, N) state block in VMEM scratch; inside
a chunk the recurrence runs as a fori_loop over CH steps of (BD, N)
vector ops (the scan is elementwise — there is no MXU work to recover, so
the win is purely keeping h and the chunk's a/b tiles VMEM-resident
instead of round-tripping HBM per step).

The channel dim is blocked (BD) so d_inner=8192 models stream; grid is
(B, D/BD, T/CH) with time sequential ("arbitrary").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 64
DEFAULT_BD = 256


def _scan_kernel(a_ref, b_ref, h0_ref, hs_ref, hT_ref, h_s):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        h_s[:] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)   # (CH, BD, N)
    b = b_ref[0].astype(jnp.float32)
    ch = a.shape[0]

    def step(t, h):
        h = a[t] * h + b[t]
        hs_ref[0, t] = h.astype(hs_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, ch, step, h_s[:])
    h_s[:] = h

    @pl.when(c == nc - 1)
    def _emit():
        hT_ref[0] = h.astype(hT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def ssm_scan(a, b, h0, chunk: int = DEFAULT_CHUNK, bd: int = DEFAULT_BD,
             interpret: bool = True):
    """a/b: (B,T,D,N) f32, h0: (B,D,N) -> (hs (B,T,D,N), h_T (B,D,N))."""
    B, T, D, N = a.shape
    ch = min(chunk, T)
    bd = min(bd, D)
    pad_t = (-T) % ch
    pad_d = (-D) % bd
    az = jnp.pad(a, ((0, 0), (0, pad_t), (0, pad_d), (0, 0)),
                 constant_values=1.0)
    bz = jnp.pad(b, ((0, 0), (0, pad_t), (0, pad_d), (0, 0)))
    h0z = jnp.pad(h0, ((0, 0), (0, pad_d), (0, 0)))
    Tp, Dp = T + pad_t, D + pad_d

    hs, hT = pl.pallas_call(
        _scan_kernel,
        grid=(B, Dp // bd, Tp // ch),
        in_specs=[
            pl.BlockSpec((1, ch, bd, N), lambda b_, d, c: (b_, c, d, 0)),
            pl.BlockSpec((1, ch, bd, N), lambda b_, d, c: (b_, c, d, 0)),
            pl.BlockSpec((1, bd, N), lambda b_, d, c: (b_, d, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ch, bd, N), lambda b_, d, c: (b_, c, d, 0)),
            pl.BlockSpec((1, bd, N), lambda b_, d, c: (b_, d, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Tp, Dp, N), a.dtype),
            jax.ShapeDtypeStruct((B, Dp, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(az, bz, h0z)
    return hs[:, :T, :D], hT[:, :D]
