"""RWKV6 wkv recurrence (data-dependent decay) as a chunked Pallas kernel.

TPU adaptation of the CUDA wkv6 kernel: instead of one thread per channel
stepping token-by-token, the sequence is cut into CH-token chunks; within
a chunk the recurrence is expanded into dense (CH x CH) decay-weighted
score matmuls (MXU work), and only the (dh x dh) state crosses chunks —
carried in VMEM scratch across the sequential chunk grid dimension.

Per chunk (log-space, exponents always <= 0 so arbitrary per-token decays
cannot overflow — see models/ssm.py for the same recurrence in jnp):
    la      = cumsum(lw)                        (CH, dh)
    y_intra = [(r_t·k_j) decayed by exp(la_{t-1}-la_j)]_{j<t} v
    y_bonus = (r_t·(u∘k_t)) v_t
    y_cross = (r_t ∘ exp(la_{t-1})) S
    S'      = S ∘ exp(la_CH) + Σ_j (k_j ∘ exp(la_CH - la_j))ᵀ v_j

Grid: (B*H, T/CH) with the chunk dim sequential; state scratch (dh, dh).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 32


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref,
                y_ref, sT_ref, s_s):
    c = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        s_s[:] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)     # (CH, dh)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)     # (1, dh) -> broadcast
    S = s_s[:]
    ch = r.shape[0]

    la = jnp.cumsum(lw, axis=0)                     # (CH, dh) inclusive
    la_prev = la - lw                                # exclusive

    # intra-chunk: pairwise decay exp(la_prev[t] - la[j]) masked j < t
    ld = la_prev[:, None, :] - la[None, :, :]        # (CH, CH, dh)
    tri = jax.lax.broadcasted_iota(jnp.int32, (ch, ch), 1) \
        < jax.lax.broadcasted_iota(jnp.int32, (ch, ch), 0)
    w_pair = jnp.where(tri[:, :, None], jnp.exp(ld), 0.0)
    scores = jnp.einsum("td,jd,tjd->tj", r, k, w_pair)
    y = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # bonus (j == t)
    y = y + (r * u * k).sum(axis=1, keepdims=True) * v
    # cross-chunk state contribution
    r_in = r * jnp.exp(la_prev)
    y = y + jax.lax.dot_general(r_in, S, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state update
    k_out = k * jnp.exp(la[-1:] - la)
    s_s[:] = S * jnp.exp(la[-1])[:, None] + jax.lax.dot_general(
        k_out, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(c == nc - 1)
    def _emit():
        sT_ref[0] = s_s[:].astype(sT_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r, k, v, log_w, u, s0, chunk: int = DEFAULT_CHUNK,
         interpret: bool = True):
    """r/k/v/log_w: (B,T,H,dh) f32; u: (H,dh); s0: (B,H,dh,dh).
    -> (y (B,T,H,dh), s_T (B,H,dh,dh))."""
    B, T, H, dh = r.shape
    ch = min(chunk, T)
    pad = (-T) % ch

    def flat(x):
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x.transpose(0, 2, 1, 3).reshape(B * H, T + pad, dh)

    r2, k2, v2, lw2 = map(flat, (r, k, v, log_w))
    u2 = jnp.broadcast_to(u[None], (B, H, dh)).reshape(B * H, 1, dh)
    s02 = s0.reshape(B * H, dh, dh)
    nc = (T + pad) // ch

    y2, sT = pl.pallas_call(
        _wkv_kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, ch, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, ch, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, ch, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, ch, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, dh, dh), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, ch, dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, dh, dh), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, T + pad, dh), r.dtype),
            jax.ShapeDtypeStruct((B * H, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(r2, k2, v2, lw2, u2, s02)

    y = y2[:, :T].reshape(B, H, T, dh).transpose(0, 2, 1, 3)
    return y, sT.reshape(B, H, dh, dh)
