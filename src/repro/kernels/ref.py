"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These are written for clarity and numerical fidelity, not speed: dense
attention materializes the score matrix, the recurrences run step-by-step
lax.scan.  tests/test_kernels.py sweeps shapes/dtypes of each kernel
against these.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attention(q, k, v, causal: bool = True, window: int = 0,
              scale: float | None = None) -> jax.Array:
    """q:(B,T,H,dh) k/v:(B,S,Hkv,dh). GQA by head grouping."""
    B, T, H, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else dh ** -0.5
    qf = q.astype(jnp.float32).reshape(B, T, Hkv, g, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, k.astype(jnp.float32)) * scale
    qp = jnp.arange(T)[:, None]
    kp = jnp.arange(S)[None, :]
    ok = jnp.ones((T, S), bool)
    if causal:
        ok &= kp <= qp
    if window > 0:
        ok &= kp > qp - window
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, H, v.shape[-1]).astype(q.dtype)


def paged_attention(q, k_pages, v_pages, table, lens, window: int = 0,
                    scale: float | None = None, k_scale=None, v_scale=None,
                    k_extra=None) -> jax.Array:
    """Decode attention over a paged KV pool (the kernel's oracle).

    q:       (B, H, dk)            one query per slot (the decode step)
    k_pages: (n_pages, page, Hkv, dk) physical page pool
    v_pages: (n_pages, page, Hkv, dv)
    table:   (B, P) int32          per-slot logical->physical page ids;
                                   entries >= n_pages mean "unallocated"
    lens:    (B,) int32            valid entries per slot (incl. the
                                   token written this step)
    -> (B, H, dv)

    Quantized pools pass k_scale/v_scale (n_pages, page, Hkv) per-token
    absmax scales: pages dequantize to f32 (value * scale) right after
    the gather, so the softmax math is identical to an f32 pool holding
    the dequantized values.  k_extra (n_pages, page, Hkv, dr) is an
    UNQUANTIZED extra key-feature block (absorbed-MLA rope keys)
    concatenated after the dequantized main block; q then carries
    dk + dr features.  All three default to None = today's exact path.

    The gather materializes every slot's P*page logical entries —
    O(max_seq) reads, same as the dense masked decode it replaces; the
    Pallas kernel (kernels/paged_attention.py) is what cuts reads to
    O(len) by walking only live pages.  Entries past `lens` (garbage
    from unallocated / recycled pages) are masked to NEG_INF before the
    softmax, so they contribute exactly 0 — bit-identical to attending
    over a contiguous cache row.
    """
    B, H, dkq = q.shape
    n_pages, page, Hkv, dk = k_pages.shape
    dv = v_pages.shape[-1]
    g = H // Hkv
    P = table.shape[1]
    S = P * page
    scale = scale if scale is not None else dkq ** -0.5
    t = jnp.clip(table, 0, n_pages - 1)
    # (B, P, page, Hkv, d) -> (B, S, Hkv, d), logical position order
    k = k_pages[t].reshape(B, S, Hkv, dk)
    v = v_pages[t].reshape(B, S, Hkv, dv)
    if k_scale is not None:
        k = k.astype(jnp.float32) * k_scale[t].reshape(B, S, Hkv)[..., None]
    if v_scale is not None:
        v = v.astype(jnp.float32) * v_scale[t].reshape(B, S, Hkv)[..., None]
    if k_extra is not None:
        dr = k_extra.shape[-1]
        ke = k_extra[t].reshape(B, S, Hkv, dr)
        k = jnp.concatenate([k.astype(jnp.float32),
                             ke.astype(jnp.float32)], -1)
    kp = jnp.arange(S)
    ok = kp[None, :] < lens[:, None]
    if window > 0:
        ok &= kp[None, :] > (lens[:, None] - 1 - window)
    bias = jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)  # (B, S)
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, dkq)
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k.astype(jnp.float32)) * scale
    s = s + bias[:, None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(B, H, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# fused distill loss (Eqn 9) — per-row components
# ---------------------------------------------------------------------------

def distill_loss_parts(logits, labels, pseudo
                       ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """-> (lse, gold, dot) per row; loss_i = (1+lam)*lse - gold - lam*dot."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    dot = (pseudo.astype(jnp.float32) * lg).sum(-1)
    return lse, gold, dot


def distill_loss(logits, labels, pseudo, lam) -> jax.Array:
    lse, gold, dot = distill_loss_parts(logits, labels, pseudo)
    return ((1.0 + lam) * lse - gold - lam * dot).mean()


# ---------------------------------------------------------------------------
# rwkv6 wkv recurrence
# ---------------------------------------------------------------------------

def wkv6(r, k, v, log_w, u, s0) -> Tuple[jax.Array, jax.Array]:
    """Sequential oracle.  r/k/v/log_w: (B,T,H,dh) f32, u: (H,dh),
    s0: (B,H,dh,dh).  y_t = r_t (S_{t-1} + u kᵀv); S_t = W S_{t-1} + kᵀv."""
    def step(S, xs):
        rt, kt, vt, lw = xs  # (B,H,dh)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, S + u[..., None] * kv)
        S = S * jnp.exp(lw)[..., None] + kv
        return S, y

    xs = jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0), (r, k, v, log_w))
    s_final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1), s_final


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

def ssm_scan(a, b, h0) -> Tuple[jax.Array, jax.Array]:
    """h_t = a_t * h_{t-1} + b_t, sequential.  a/b: (B,T,D,N), h0: (B,D,N).
    Returns (hs (B,T,D,N), h_T)."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0))
    h_final, hs = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(hs, 0, 1), h_final
