"""Fused dual-CE distillation loss (paper Eqn 9) as a Pallas TPU kernel.

    L_i = (1+lam)*logsumexp(z_i) - z_i[y_i] - lam * <p̄_i, z_i>

EC-DNN evaluates this loss every step of the compression phase over LM
vocabs up to 262k — the naive form materializes log_softmax (N, V) f32 and
reads the logits twice (once for the true-label CE, once for the pseudo
CE).  This kernel streams the vocabulary through VMEM in (BN, BV) tiles,
maintaining per-row online-logsumexp, gold-logit and <p̄, z> accumulators
in scratch, so HBM traffic is exactly one read of logits + pseudo —
2x fewer logits bytes than the two-pass form and no (N, V) f32 temporary.

Backward is a second single-pass kernel: given the saved row lse,
    dL/dz = g/N * ((1+lam)*exp(z - lse) - onehot(y) - lam*p̄)
(elementwise per tile; no extra reductions), wired via jax.custom_vjp.

Grid: (N/BN, V/BV), vocab dim sequential ("arbitrary") for the running
accumulators; rows parallel.  BV=512 keeps the working set
(BN*BV*(logits+pseudo)*4B ≈ 2 MB at BN=512) inside one core's VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BN = 256
DEFAULT_BV = 512
NEG_INF = -2.0 ** 30


def _fwd_kernel(labels_ref, logits_ref, pseudo_ref,
                lse_ref, gold_ref, dot_ref, m_s, l_s, g_s, d_s):
    j = pl.program_id(1)
    nv = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_s[:] = jnp.full_like(m_s, NEG_INF)
        l_s[:] = jnp.zeros_like(l_s)
        g_s[:] = jnp.zeros_like(g_s)
        d_s[:] = jnp.zeros_like(d_s)

    z = logits_ref[:].astype(jnp.float32)           # (BN, BV)
    p = pseudo_ref[:].astype(jnp.float32)
    bn, bv = z.shape

    m_old = m_s[:]
    m_new = jnp.maximum(m_old, z.max(axis=1))
    alpha = jnp.exp(m_old - m_new)
    l_s[:] = l_s[:] * alpha + jnp.exp(z - m_new[:, None]).sum(axis=1)
    m_s[:] = m_new
    d_s[:] = d_s[:] + (p * z).sum(axis=1)

    # gold gather: label relative to this vocab tile
    y = labels_ref[:, 0] - j * bv                   # (BN,)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1)
    hit = cols == y[:, None]
    g_s[:] = g_s[:] + jnp.where(hit, z, 0.0).sum(axis=1)

    @pl.when(j == nv - 1)
    def _emit():
        lse_ref[:, 0] = m_s[:] + jnp.log(jnp.maximum(l_s[:], 1e-30))
        gold_ref[:, 0] = g_s[:]
        dot_ref[:, 0] = d_s[:]


def _bwd_kernel(labels_ref, lse_ref, gcoef_ref, logits_ref, pseudo_ref,
                dz_ref):
    j = pl.program_id(1)
    z = logits_ref[:].astype(jnp.float32)
    p = pseudo_ref[:].astype(jnp.float32)
    bn, bv = z.shape
    lse = lse_ref[:, 0]
    g = gcoef_ref[0, 0]       # upstream grad / N
    lam = gcoef_ref[0, 1]
    soft = jnp.exp(z - lse[:, None])
    y = labels_ref[:, 0] - j * bv
    cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bv), 1)
    onehot = (cols == y[:, None]).astype(jnp.float32)
    dz_ref[:] = (g * ((1.0 + lam) * soft - onehot - lam * p)
                 ).astype(dz_ref.dtype)


def _pad_to(x, mult, axis, value=0.0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6))
def fused_distill_loss(logits, labels, pseudo, lam,
                       bn=DEFAULT_BN, bv=DEFAULT_BV, interpret=True):
    loss, _ = _fwd(logits, labels, pseudo, lam, bn, bv, interpret)
    return loss


def _parts(logits, labels, pseudo, bn, bv, interpret):
    """Run the forward kernel over flattened rows. -> (lse, gold, dot)."""
    V = logits.shape[-1]
    z2 = logits.reshape(-1, V)
    p2 = pseudo.reshape(-1, V)
    y2 = labels.reshape(-1, 1).astype(jnp.int32)
    N = z2.shape[0]
    bn = min(bn, max(8, N))
    z2 = _pad_to(_pad_to(z2, bn, 0, value=0.0), bv, 1, value=NEG_INF)
    p2 = _pad_to(_pad_to(p2, bn, 0), bv, 1)
    y2 = _pad_to(y2, bn, 0)
    Np, Vp = z2.shape
    grid = (Np // bn, Vp // bv)
    out_shape = [jax.ShapeDtypeStruct((Np, 1), jnp.float32)] * 3
    lse, gold, dot = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, bv), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bv), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((bn, 1), lambda i, j: (i, 0))] * 3,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bn,), jnp.float32)] * 4,
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(y2, z2, p2)
    return (lse[:N, 0], gold[:N, 0], dot[:N, 0]), (z2, p2, y2, Np, Vp, N)


def _fwd(logits, labels, pseudo, lam, bn, bv, interpret):
    (lse, gold, dot), aux = _parts(logits, labels, pseudo, bn, bv,
                                   interpret)
    lam_f = jnp.asarray(lam, jnp.float32)
    loss = ((1.0 + lam_f) * lse - gold - dot * lam_f).mean()
    res = (logits, labels, pseudo, lam_f, lse)
    return loss, res


def _bwd(bn, bv, interpret, res, g):
    logits, labels, pseudo, lam_f, lse = res
    V = logits.shape[-1]
    z2 = logits.reshape(-1, V)
    p2 = pseudo.reshape(-1, V)
    y2 = labels.reshape(-1, 1).astype(jnp.int32)
    N = z2.shape[0]
    bn_ = min(bn, max(8, N))
    z2p = _pad_to(_pad_to(z2, bn_, 0), bv, 1, value=NEG_INF)
    p2p = _pad_to(_pad_to(p2, bn_, 0), bv, 1)
    y2p = _pad_to(y2, bn_, 0, value=-1)
    lse_p = _pad_to(lse.reshape(-1, 1), bn_, 0)
    Np, Vp = z2p.shape
    gcoef = jnp.stack([g / N, lam_f]).reshape(1, 2)
    dz = pl.pallas_call(
        _bwd_kernel,
        grid=(Np // bn_, Vp // bv),
        in_specs=[
            pl.BlockSpec((bn_, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn_, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
            pl.BlockSpec((bn_, bv), lambda i, j: (i, j)),
            pl.BlockSpec((bn_, bv), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bn_, bv), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Np, Vp), logits.dtype),
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel")),
    )(y2p, lse_p, gcoef, z2p, p2p)
    dz = dz[:N, :V].reshape(logits.shape)
    return dz, None, None, None


fused_distill_loss.defvjp(_fwd, _bwd)
