"""Dispatch layer: Pallas kernels on TPU, pure-jnp refs elsewhere.

Model code calls these entry points; the choice of implementation is a
deployment concern:
  - on TPU (or REPRO_USE_PALLAS=1): compiled Pallas kernels
    (REPRO_USE_PALLAS=1 on CPU runs them in interpret mode — slow,
    used by the kernel test suite);
  - otherwise: the jnp reference path (kernels/ref.py or the chunked jnp
    forms in models/), which is what the CPU dry-run lowers.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def _platform() -> str:
    return jax.devices()[0].platform


def pallas_enabled() -> bool:
    if os.environ.get("REPRO_USE_PALLAS", "") == "1":
        return True
    return _platform() == "tpu"


def _interpret() -> bool:
    return _platform() != "tpu"


# ---------------------------------------------------------------------------

def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    scale=None):
    if pallas_enabled():
        from repro.kernels import flash_attention as fa
        return fa.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=_interpret())
    from repro.kernels import ref
    return ref.attention(q, k, v, causal=causal, window=window, scale=scale)


def paged_attention(q, k_pages, v_pages, table, lens, window: int = 0,
                    scale=None, k_scale=None, v_scale=None, k_extra=None):
    """Decode attention over a paged KV pool (q: one token per slot).

    TPU / REPRO_USE_PALLAS=1: the Pallas kernel walks only each slot's
    live pages (O(len) reads).  Reference path: gather-all-pages + dense
    masked softmax (kernels/ref.py) — O(max_seq) reads like the
    contiguous path, but bit-identical numerics, which is what the
    paged-vs-contiguous engine equivalence tests pin.

    k_scale/v_scale: per-token absmax scales of a quantized pool
    (n_pages, page, Hkv); dequant happens inside the kernel.  k_extra:
    unquantized extra key features (absorbed-MLA rope keys).  None ==
    unquantized pool, exact current program.
    """
    if pallas_enabled():
        from repro.kernels import paged_attention as pa
        return pa.paged_attention(q, k_pages, v_pages, table, lens,
                                  window=window, scale=scale,
                                  k_scale=k_scale, v_scale=v_scale,
                                  k_extra=k_extra, interpret=_interpret())
    from repro.kernels import ref
    return ref.paged_attention(q, k_pages, v_pages, table, lens,
                               window=window, scale=scale,
                               k_scale=k_scale, v_scale=v_scale,
                               k_extra=k_extra)


def fused_distill_loss(logits, labels, pseudo, lam):
    if pallas_enabled():
        from repro.kernels import distill_loss as dl
        return dl.fused_distill_loss(logits, labels, pseudo,
                                     jnp.asarray(lam, jnp.float32),
                                     interpret=_interpret())
    from repro.kernels import ref
    return ref.distill_loss(logits, labels, pseudo, lam)


def wkv6(r, k, v, log_w, u, s0):
    if pallas_enabled():
        from repro.kernels import wkv6 as w6
        return w6.wkv6(r, k, v, log_w, u, s0, interpret=_interpret())
    from repro.kernels import ref
    return ref.wkv6(r, k, v, log_w, u, s0)


def ssm_scan(a, b, h0):
    if pallas_enabled():
        from repro.kernels import ssm_scan as ss
        return ss.ssm_scan(a, b, h0, interpret=_interpret())
    from repro.kernels import ref
    return ref.ssm_scan(a, b, h0)
