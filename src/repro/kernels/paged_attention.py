"""Paged-attention decode as a Pallas TPU kernel.

The serving engine's paged KV pool stores each slot's cache as a chain
of fixed-size pages (serving/kv_cache.PageAllocator); this kernel is
the decode-step attention over that pool: one query per slot, KV read
through the slot's page table.

Grid (B * Hkv, P): one kernel instance streams one (slot, kv-head)'s
live pages sequentially with the (m, l, acc) online-softmax state in
VMEM scratch (the flash_attention recurrence), emitting acc / l at the
last page.  GQA rides the same way as kernels/flash_attention.py: the
g grouped q heads of a kv head form the row dimension, so each page is
fetched ONCE for all g heads.

The page table and per-slot lengths are scalar-prefetched
(pltpu.PrefetchScalarGridSpec), so the BlockSpec index_map — not the
kernel body — resolves logical page j of slot b to the physical page
`table[b, j]`: the pipeline DMAs exactly the pages the slot owns.  Two
properties make the read volume O(len) instead of O(max_seq):

  - grid step j of a slot with `live = ceil(len / page)` pages clamps
    its index_map to the last live page for j >= live; consecutive
    identical block indices are not re-fetched by the pipeline, so dead
    trailing pages cost no DMA;
  - the kernel body skips compute for j >= live via pl.when.

Unallocated table entries (sentinel >= n_pages) are clamped in the
index_map and masked by the position bookkeeping (k_pos < len), so a
partially-grown slot reads garbage it then multiplies by exactly 0.

Supports dk != dv (MLA-shaped heads: the expanded latent has 192-d keys
and 128-d values) and sliding-window masking.  interpret=True runs the
same program on CPU — that is what CI tests against kernels/ref
.paged_attention and ref.attention.  A production kernel would also
fuse the new token's KV scatter; here the scatter is a jnp one-liner in
models/attention.gqa_decode_paged and the kernel only reads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _paged_kernel(table_ref, lens_ref, q_ref, k_ref, v_ref, *rest,
                  page, hkv, scale, window, quant, extra):
    opt = iter(rest[:-4])
    ks_ref = next(opt) if quant else None
    vs_ref = next(opt) if quant else None
    ke_ref = next(opt) if extra else None
    o_ref, m_s, l_s, acc_s = rest[-4:]
    bh = pl.program_id(0)
    j = pl.program_id(1)
    b = bh // hkv

    @pl.when(j == 0)
    def _init():
        m_s[:] = jnp.full_like(m_s, NEG_INF)
        l_s[:] = jnp.zeros_like(l_s)
        acc_s[:] = jnp.zeros_like(acc_s)

    length = lens_ref[b]
    live = (length + page - 1) // page

    @pl.when(j < live)
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32)      # (g, dk [+ dr])
        k = k_ref[0, 0].astype(jnp.float32)      # (page, dk)
        v = v_ref[0, 0].astype(jnp.float32)      # (page, dv)
        g = q.shape[0]
        if quant:
            # per-token absmax scales ride next to the page: dequant in
            # VMEM right after the (cheap) quantized DMA
            k = k * ks_ref[0, 0][:, None]        # (page,) -> column bcast
            v = v * vs_ref[0, 0][:, None]
        if extra:
            # unquantized extra key features (absorbed-MLA rope keys):
            # score = q_main . k_deq + q_extra . k_extra
            dk = k.shape[1]
            s = jax.lax.dot_general(
                q[:, :dk], k, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            s = s + jax.lax.dot_general(
                q[:, dk:], ke_ref[0, 0].astype(jnp.float32),
                (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            s = s * scale
        else:
            s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32) * scale
        k_pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (g, page), 1)
        ok = k_pos < length
        if window > 0:
            ok = ok & (k_pos > length - 1 - window)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m_s[:], s.max(axis=1))
        alpha = jnp.exp(m_s[:] - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_s[:] = l_s[:] * alpha + p.sum(axis=1)
        acc_s[:] = acc_s[:] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[:] = m_new

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        o_ref[0, 0] = (acc_s[:] / jnp.maximum(l_s[:], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale", "interpret"))
def paged_attention(q, k_pages, v_pages, table, lens, window: int = 0,
                    scale: float | None = None, k_scale=None, v_scale=None,
                    k_extra=None, interpret: bool = True):
    """q: (B, H, dk); k_pages: (n_pages, page, Hkv, dk); v_pages:
    (n_pages, page, Hkv, dv); table: (B, P) int32 (>= n_pages means
    unallocated); lens: (B,) int32 valid entries -> (B, H, dv).

    Quantized pools pass k_scale/v_scale (n_pages, page, Hkv) per-token
    absmax scales; each scale page is a tiny extra input block indexed
    by the SAME table lookup as its plane, so dequant (value * scale)
    happens in VMEM after the ~4x-smaller quantized DMA.  k_extra
    (n_pages, page, Hkv, dr) is an unquantized extra key block
    (absorbed-MLA rope keys); q then carries dk + dr features and the
    score is the sum of the two dots.  All three default to None ==
    today's exact unquantized program."""
    B, H, dkq = q.shape
    n_pages, page, Hkv, dk = k_pages.shape
    dv = v_pages.shape[-1]
    g = H // Hkv
    P = table.shape[1]
    scale = scale if scale is not None else dkq ** -0.5
    quant = k_scale is not None
    extra = k_extra is not None

    q2 = q.reshape(B, Hkv, g, dkq)                    # group-major rows
    kp = k_pages.transpose(0, 2, 1, 3)                # (n_pages, Hkv, page, dk)
    vp = v_pages.transpose(0, 2, 1, 3)

    def kv_index(bh, j, table_ref, lens_ref):
        b, h = bh // Hkv, bh % Hkv
        live = (lens_ref[b] + page - 1) // page
        # clamp dead trailing grid steps onto the last live page: the
        # pipeline skips the re-fetch of an unchanged block index, so a
        # slot's DMA volume is its LIVE pages, not P
        jj = jnp.minimum(j, jnp.maximum(live - 1, 0))
        phys = jnp.clip(table_ref[b, jj], 0, n_pages - 1)
        return (phys, h, 0, 0)

    def scale_index(bh, j, table_ref, lens_ref):
        phys, h, _, _ = kv_index(bh, j, table_ref, lens_ref)
        return (phys, h, 0)

    def q_index(bh, j, table_ref, lens_ref):
        return (bh // Hkv, bh % Hkv, 0, 0)

    in_specs = [
        pl.BlockSpec((1, 1, g, dkq), q_index),
        pl.BlockSpec((1, 1, page, dk), kv_index),
        pl.BlockSpec((1, 1, page, dv), kv_index),
    ]
    operands = [q2, kp, vp]
    if quant:
        in_specs += [pl.BlockSpec((1, 1, page), scale_index),
                     pl.BlockSpec((1, 1, page), scale_index)]
        operands += [k_scale.transpose(0, 2, 1),      # (n_pages, Hkv, page)
                     v_scale.transpose(0, 2, 1)]
    if extra:
        dr = k_extra.shape[-1]
        in_specs += [pl.BlockSpec((1, 1, page, dr), kv_index)]
        operands += [k_extra.transpose(0, 2, 1, 3)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B * Hkv, P),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, dv), q_index),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g,), jnp.float32),
            pltpu.VMEM((g, dv), jnp.float32),
        ],
    )
    kern = functools.partial(_paged_kernel, page=page, hkv=Hkv,
                             scale=scale, window=window,
                             quant=quant, extra=extra)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, g, dv), q.dtype),
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(table.astype(jnp.int32), lens.astype(jnp.int32), *operands)
    return out.reshape(B, H, dv)
