"""Flash attention (tiled online-softmax) as a Pallas TPU kernel.

Supports causal + sliding-window masks and GQA natively: q heads are
grouped by their kv head and flattened into the row dimension, so one
kernel instance streams one (batch, kv-head)'s KV once for all g grouped
q heads — KV HBM traffic is 1/g of an MHA-layout kernel, which is the
whole point of GQA on a bandwidth-limited chip.

Layout: q2 (BH, g*T, dh), kv2 (BH, S, dh) where BH = B*Hkv.  Row r of q2
is query position r % T (g-major flattening), which makes the causal /
window mask position-exact even when a row block spans two q heads.

Grid (BH, q_blocks, kv_blocks); kv dim is sequential ("arbitrary") with
the (m, l, acc) online-softmax state in VMEM scratch, emitted as
acc / l at the last kv block.  Block sizes default to (128, 128) — MXU
aligned; dh rides along whole (128 or 256 for the assigned archs).

A production causal kernel would also prune fully-masked upper-triangle
kv blocks via a q-block-dependent grid bound; correctness is identical,
so the oracle sweep (tests/test_kernels.py) covers this version.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -2.0 ** 30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                 scale, t_q, s_valid, causal, window):
    i = pl.program_id(1)
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_s[:] = jnp.full_like(m_s, NEG_INF)
        l_s[:] = jnp.zeros_like(l_s)
        acc_s[:] = jnp.zeros_like(acc_s)

    q = q_ref[0].astype(jnp.float32)               # (BQ, dh)
    k = k_ref[0].astype(jnp.float32)               # (BK, dh)
    v = v_ref[0].astype(jnp.float32)
    bq, bk = q.shape[0], k.shape[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    q_pos = rows % t_q                              # g-major flattening
    k_pos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos < s_valid                            # mask KV padding
    if causal:
        ok = ok & (k_pos <= q_pos)
    if window > 0:
        ok = ok & (k_pos > q_pos - window)
    s = jnp.where(ok, s, NEG_INF)

    m_old = m_s[:]
    m_new = jnp.maximum(m_old, s.max(axis=1))
    alpha = jnp.exp(m_old - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_s[:] = l_s[:] * alpha + p.sum(axis=1)
    acc_s[:] = acc_s[:] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_s[:] = m_new

    @pl.when(j == nk - 1)
    def _emit():
        o_ref[0] = (acc_s[:] / jnp.maximum(l_s[:], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def _pad_axis(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    scale: float | None = None, bq: int = DEFAULT_BQ,
                    bk: int = DEFAULT_BK, interpret: bool = True):
    """q: (B,T,H,dh), k/v: (B,S,Hkv,dh) -> (B,T,H,dh)."""
    B, T, H, dh = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else dh ** -0.5

    # group-major flatten: (B*Hkv, g*T, dh)
    q2 = q.reshape(B, T, Hkv, g, dh).transpose(0, 2, 3, 1, 4) \
        .reshape(B * Hkv, g * T, dh)
    k2 = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, dh)
    v2 = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, dh)

    bq_ = min(bq, g * T)
    bk_ = min(bk, S)
    q2 = _pad_axis(q2, bq_, 1)
    k2 = _pad_axis(k2, bk_, 1)
    v2 = _pad_axis(v2, bk_, 1)
    gt, sp = q2.shape[1], k2.shape[1]

    kern = functools.partial(_attn_kernel, scale=scale, t_q=T, s_valid=S,
                             causal=causal, window=window)
    o2 = pl.pallas_call(
        kern,
        grid=(B * Hkv, gt // bq_, sp // bk_),
        in_specs=[
            pl.BlockSpec((1, bq_, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, gt, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_, dh), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=None if interpret else pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q2, k2, v2)

    o2 = o2[:, : g * T]
    return o2.reshape(B, Hkv, g, T, dh).transpose(0, 3, 1, 2, 4) \
        .reshape(B, T, H, dh)
