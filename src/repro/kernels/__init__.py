"""Pallas TPU kernels for EC-DNN's compute hot-spots.

  flash_attention  tiled online-softmax attention (causal/SWA/GQA)
  distill_loss     fused dual-CE of paper Eqn 9 (+ custom VJP)
  wkv6             RWKV6 chunked recurrence (data-dependent decay)
  ssm_scan         Mamba selective scan, chunk-sequential

Each kernel has a pure-jnp oracle in ref.py; ops.py is the dispatch layer
model code imports.  Kernels are validated with interpret=True on CPU and
target TPU (pl.pallas_call + BlockSpec VMEM tiling) for deployment.
"""
