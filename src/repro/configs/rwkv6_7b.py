"""rwkv6-7b (Finch) [arXiv:2404.05892] — attention-free, data-dependent decay.

EC-DNN is aggregation-layer and attention-agnostic, so the technique applies
unchanged (DESIGN §4).  long_500k runs: the recurrent state is O(1) in
sequence length.
"""
from repro.common.types import (AttnConfig, FFNConfig, LayerSpec,
                                ModelConfig, SSMConfig)

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, vocab_size=65536,
    attn=AttnConfig(n_heads=64, n_kv_heads=64, head_dim=64),  # unused
    ffn=FFNConfig(d_ff=14336),
    ssm=SSMConfig(rwkv_head_dim=64, rwkv_lora_decay=64, rwkv_lora_mix=32),
    pattern=(LayerSpec("rwkv", "rwkv_cmix"),),
    max_seq=1048576,
)

SIZE_CLASS = "small"
SKIP_SHAPES = {}


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=3, d_model=128, vocab_size=512,
        ffn=CONFIG.ffn.__class__(d_ff=256),
        ssm=CONFIG.ssm.__class__(rwkv_head_dim=32, rwkv_lora_decay=16,
                                 rwkv_lora_mix=8),
        max_seq=256)
