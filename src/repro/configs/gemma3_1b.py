"""gemma3-1b [hf:google/gemma-3-1b-pt] — 5:1 local:global SWA, 262k vocab.

head_dim=256 is explicit (not d_model/n_heads).  Local layers use a 512-token
sliding window with rope theta 10k; the global layer uses theta 1M.  The
262_144 vocab is the framework's worst case for pseudo-label compression
(core/compression.py) — dense per-token label distributions would be 1 MB.
"""
from repro.common.types import AttnConfig, FFNConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, vocab_size=262144,
    attn=AttnConfig(kind="gqa", n_heads=4, n_kv_heads=1, head_dim=256,
                    rope_theta=1_000_000.0, qk_norm=True),
    ffn=FFNConfig(d_ff=6912, mlp_type="geglu"),
    pattern=(LayerSpec("attn_local", "dense"),) * 5
            + (LayerSpec("attn", "dense"),),
    local_rope_theta=10_000.0, local_window=512,
    tie_embeddings=True, scale_embeddings=True,
    max_seq=524288,
)

SIZE_CLASS = "small"
# long_500k RUNS: 25/26 of layers are 512-window SWA (bounded cache);
# global layers decode linearly with a replicated kv=1 cache.
SKIP_SHAPES = {}


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=7, d_model=128, vocab_size=512,
        attn=CONFIG.attn.__class__(kind="gqa", n_heads=4, n_kv_heads=1,
                                   head_dim=32, rope_theta=1e6,
                                   qk_norm=True),
        ffn=CONFIG.ffn.__class__(d_ff=256, mlp_type="geglu"),
        local_window=16, max_seq=256)
