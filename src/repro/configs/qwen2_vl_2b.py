"""qwen2-vl-2b [arXiv:2409.12191] — M-RoPE, dynamic-resolution VLM backbone.

Per the assignment the vision frontend is a STUB: input_specs() can feed
precomputed patch embeddings through the `embeds` input; the LM shapes use
ordinary tokens.  M-RoPE sections (16, 24, 24) split the 64-dim rotary
half-space over (temporal, height, width) position streams.
"""
from repro.common.types import AttnConfig, FFNConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, vocab_size=151936,
    attn=AttnConfig(kind="gqa", n_heads=12, n_kv_heads=2, head_dim=128,
                    rope_theta=1_000_000.0, mrope_sections=(16, 24, 24)),
    ffn=FFNConfig(d_ff=8960, mlp_type="swiglu"),
    pattern=(LayerSpec("attn", "dense"),),
    tie_embeddings=True,
    max_seq=131072,
)

SIZE_CLASS = "small"
SKIP_SHAPES = {"long_500k": "pure full-attention arch"}


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=3, d_model=128, vocab_size=512,
        attn=CONFIG.attn.__class__(kind="gqa", n_heads=4, n_kv_heads=2,
                                   head_dim=32, rope_theta=1e6,
                                   mrope_sections=(4, 6, 6)),
        ffn=CONFIG.ffn.__class__(d_ff=256, mlp_type="swiglu"),
        max_seq=256)
