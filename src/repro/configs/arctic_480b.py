"""arctic-480b [hf:Snowflake/snowflake-arctic-base] — 128e top-2 + dense
residual.  Every layer runs a dense FFN in parallel with the MoE branch
(dense_residual_ff), Snowflake's dense-MoE hybrid."""
from repro.common.types import AttnConfig, FFNConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, vocab_size=32000,
    attn=AttnConfig(kind="gqa", n_heads=56, n_kv_heads=8, head_dim=128,
                    rope_theta=10_000.0),
    ffn=FFNConfig(d_ff=4864, mlp_type="swiglu", n_experts=128, top_k=2,
                  moe_d_ff=4864, dense_residual_ff=4864),
    pattern=(LayerSpec("attn", "moe"),),
    max_seq=131072,
)

SIZE_CLASS = "big"
SKIP_SHAPES = {"long_500k": "pure full-attention arch"}


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=3, d_model=128, vocab_size=512,
        attn=CONFIG.attn.__class__(kind="gqa", n_heads=4, n_kv_heads=2,
                                   head_dim=32, rope_theta=1e4),
        ffn=CONFIG.ffn.__class__(d_ff=128, mlp_type="swiglu", n_experts=8,
                                 top_k=2, moe_d_ff=128,
                                 dense_residual_ff=128),
        max_seq=256)
