"""paper_nin — the paper's own CIFAR-100 NiN setup (Section 5.1).

Not an LM: family="cnn" routes through models/cnn.py.  This is the faithful
EC-DNN reproduction config: K in {4, 8}, tau in {20, 30, 40} epochs,
lambda=0.5 annealed over p=tau/2, relabel fraction 0.7, momentum SGD + l2.
"""
from repro.common.types import ECConfig, ModelConfig

CONFIG = ModelConfig(
    name="paper_nin", family="cnn",
    n_layers=9, d_model=192, vocab_size=100,  # vocab_size = n_classes
    max_seq=1024,  # 32*32 pixels; unused by the CNN path
)

SIZE_CLASS = "small"
SKIP_SHAPES = {"train_4k": "cnn: paper's own 32x32 image shape instead",
               "prefill_32k": "cnn", "decode_32k": "cnn",
               "long_500k": "cnn"}

PAPER_EC = ECConfig(tau=40, lam=0.5, p_steps=20, relabel_fraction=0.7,
                    label_mode="dense", aggregator="ec")


def reduced() -> ModelConfig:
    return CONFIG


def width_mult() -> float:
    return 1.0
