"""llama3-405b [arXiv:2407.21783] — dense GQA, 128k vocab."""
from repro.common.types import AttnConfig, FFNConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, vocab_size=128256,
    attn=AttnConfig(kind="gqa", n_heads=128, n_kv_heads=8, head_dim=128,
                    rope_theta=500_000.0),
    ffn=FFNConfig(d_ff=53248, mlp_type="swiglu"),
    pattern=(LayerSpec("attn", "dense"),),
    max_seq=131072,
)

SIZE_CLASS = "big"
# pure full attention: 500k-token decode cache is O(seq) per layer at 126
# layers — sub-quadratic-attention shapes are out of scope (DESIGN §4).
SKIP_SHAPES = {"long_500k": "pure full-attention arch"}


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=4, d_model=256, vocab_size=512,
        attn=CONFIG.attn.__class__(kind="gqa", n_heads=8, n_kv_heads=2,
                                   head_dim=32, rope_theta=500_000.0),
        ffn=CONFIG.ffn.__class__(d_ff=512, mlp_type="swiglu"),
        max_seq=256)
