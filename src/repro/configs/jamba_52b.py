"""jamba-v0.1-52b [arXiv:2403.19887] — Mamba+attn 1:7 hybrid, MoE 16e top-2.

Period of 8 layers with one attention layer (slot 3) and MoE on every odd
slot (e_step=2), matching the published interleave.  32 layers = 4 periods.
"""
from repro.common.types import (AttnConfig, FFNConfig, LayerSpec,
                                ModelConfig, SSMConfig)

_PERIOD = (
    LayerSpec("mamba", "dense"), LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"), LayerSpec("attn", "moe"),
    LayerSpec("mamba", "dense"), LayerSpec("mamba", "moe"),
    LayerSpec("mamba", "dense"), LayerSpec("mamba", "moe"),
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, vocab_size=65536,
    attn=AttnConfig(kind="gqa", n_heads=32, n_kv_heads=8, head_dim=128,
                    use_rope=False),  # jamba attends without rope
    ffn=FFNConfig(d_ff=14336, mlp_type="swiglu", n_experts=16, top_k=2,
                  moe_d_ff=14336),
    ssm=SSMConfig(d_state=16, expand=2, conv_width=4),
    pattern=_PERIOD,
    max_seq=262144,
)

SIZE_CLASS = "big"
# long_500k RUNS: mamba layers carry O(1) state; the 4 attention layers'
# KV caches shard over the model axis (kv=8).
SKIP_SHAPES = {}


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=8, d_model=128, vocab_size=512,
        attn=CONFIG.attn.__class__(kind="gqa", n_heads=4, n_kv_heads=2,
                                   head_dim=32, use_rope=False),
        ffn=CONFIG.ffn.__class__(d_ff=256, mlp_type="swiglu", n_experts=4,
                                 top_k=2, moe_d_ff=256),
        ssm=CONFIG.ssm.__class__(d_state=8, expand=2, conv_width=4),
        max_seq=256)
