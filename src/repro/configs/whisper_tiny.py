"""whisper-tiny [arXiv:2212.04356] — enc-dec audio backbone, conv stub.

4-layer encoder over 1500 precomputed frame embeddings (the mel+conv
frontend is a stub per the assignment; input_specs() supplies the frames)
and a 4-layer decoder with cross-attention.  Decode shapes use max_seq=32k
as an explicit assignment override of the 448-token trained range —
mechanical lowering only (DESIGN §4).  Sinusoidal positions, no RoPE.
"""
from repro.common.types import AttnConfig, FFNConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, vocab_size=51865,
    attn=AttnConfig(kind="gqa", n_heads=6, n_kv_heads=6, head_dim=64,
                    use_rope=False),
    ffn=FFNConfig(d_ff=1536, mlp_type="gelu"),
    pattern=(LayerSpec("attn", "dense"),),
    enc_dec=True, n_enc_layers=4, enc_max_frames=1500,
    max_seq=32768,
)

SIZE_CLASS = "small"
SKIP_SHAPES = {"long_500k": "enc-dec; audio context is 1500 frames by "
                            "construction (pure full attention)"}


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=2, d_model=64, vocab_size=512,
        attn=CONFIG.attn.__class__(kind="gqa", n_heads=2, n_kv_heads=2,
                                   head_dim=32, use_rope=False),
        ffn=CONFIG.ffn.__class__(d_ff=128, mlp_type="gelu"),
        enc_dec=True, n_enc_layers=2, enc_max_frames=64,
        max_seq=128)
