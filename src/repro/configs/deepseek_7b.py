"""deepseek-7b [arXiv:2401.02954] — llama-arch dense MHA (kv == heads)."""
from repro.common.types import AttnConfig, FFNConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, vocab_size=102400,
    attn=AttnConfig(kind="gqa", n_heads=32, n_kv_heads=32, head_dim=128,
                    rope_theta=10_000.0),
    ffn=FFNConfig(d_ff=11008, mlp_type="swiglu"),
    pattern=(LayerSpec("attn", "dense"),),
    max_seq=131072,
)

SIZE_CLASS = "small"
SKIP_SHAPES = {"long_500k": "pure full-attention arch"}


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=3, d_model=128, vocab_size=512,
        attn=CONFIG.attn.__class__(kind="gqa", n_heads=4, n_kv_heads=4,
                                   head_dim=32, rope_theta=1e4),
        ffn=CONFIG.ffn.__class__(d_ff=256, mlp_type="swiglu"),
        max_seq=256)
