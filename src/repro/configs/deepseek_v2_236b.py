"""deepseek-v2-236b [arXiv:2405.04434] — MLA (kv_lora=512), MoE 160e top-6.

2 shared + 160 routed experts (top-6), expert d_ff=1536 per the assignment;
the first layer is dense with d_ff=12288 as published.  MLA caches the 512-d
latent + 64-d rope key instead of per-head KV — 36x smaller decode cache.
"""
from repro.common.types import AttnConfig, FFNConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, vocab_size=102400,
    attn=AttnConfig(kind="mla", n_heads=128, n_kv_heads=128,
                    kv_lora_rank=512, q_lora_rank=1536,
                    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
                    rope_theta=10_000.0),
    ffn=FFNConfig(d_ff=12288, mlp_type="swiglu", n_experts=160, top_k=6,
                  n_shared=2, moe_d_ff=1536),
    pattern=(LayerSpec("attn", "moe"),),
    first_dense_layers=1,
    max_seq=131072,
)

SIZE_CLASS = "big"
SKIP_SHAPES = {"long_500k": "pure full-attention arch"}


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=3, d_model=128, vocab_size=512,
        attn=CONFIG.attn.__class__(kind="mla", n_heads=4, n_kv_heads=4,
                                   kv_lora_rank=32, q_lora_rank=48,
                                   qk_nope_dim=32, qk_rope_dim=16,
                                   v_head_dim=32, rope_theta=1e4),
        ffn=CONFIG.ffn.__class__(d_ff=384, mlp_type="swiglu", n_experts=8,
                                 top_k=2, n_shared=1, moe_d_ff=64),
        max_seq=256)
