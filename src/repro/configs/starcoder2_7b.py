"""starcoder2-7b [arXiv:2402.19173] — dense GQA, RoPE, gelu MLP."""
from repro.common.types import AttnConfig, FFNConfig, LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, vocab_size=49152,
    attn=AttnConfig(kind="gqa", n_heads=36, n_kv_heads=4, head_dim=128,
                    rope_theta=1_000_000.0),
    ffn=FFNConfig(d_ff=18432, mlp_type="gelu"),
    pattern=(LayerSpec("attn", "dense"),),
    max_seq=131072,
)

SIZE_CLASS = "small"
SKIP_SHAPES = {"long_500k": "pure full-attention arch"}


def reduced() -> ModelConfig:
    return CONFIG.with_(
        n_layers=3, d_model=144, vocab_size=512,
        attn=CONFIG.attn.__class__(kind="gqa", n_heads=6, n_kv_heads=2,
                                   head_dim=24, rope_theta=1e6),
        ffn=CONFIG.ffn.__class__(d_ff=288, mlp_type="gelu"),
        max_seq=256)
