"""Architecture registry: --arch <id> -> (ModelConfig, policy helpers).

Also owns the default parallelism policy (DESIGN §5):
  - "small" archs (fit one TP group): EC ensemble axis = "data"
    (K = |data|), params replicated per member + TP over "model".
  - "big" archs: FSDP over "data" inside each member, ensemble axis =
    "pod" (K = |pod| multi-pod; K = 1 single-pod).
  - serving (prefill/decode cells): one model, batch over ("pod","data"),
    TP over "model", FSDP over "data" for big archs.
"""
from __future__ import annotations

import importlib
from typing import Dict, Tuple

from repro.common.types import ModelConfig, ParallelConfig, ShapeConfig

_MODULES = {
    "llama3-405b": "repro.configs.llama3_405b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "jamba-v0.1-52b": "repro.configs.jamba_52b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "arctic-480b": "repro.configs.arctic_480b",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "paper_nin": "repro.configs.paper_nin",
}

ARCH_IDS = tuple(k for k in _MODULES if k != "paper_nin")


def get_module(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch])


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    m = get_module(arch)
    return m.reduced() if reduced else m.CONFIG


def size_class(arch: str) -> str:
    return get_module(arch).SIZE_CLASS


def skip_reason(arch: str, shape_name: str) -> str | None:
    return get_module(arch).SKIP_SHAPES.get(shape_name)


def parallel_policy(arch: str, shape: ShapeConfig,
                    multi_pod: bool) -> ParallelConfig:
    big = size_class(arch) == "big"
    if shape.kind == "train":
        # EC training layout
        if big:
            # members don't fit one TP group: FSDP over "data" inside the
            # member, ensemble across pods (K=1 single-pod: the relabel
            # step still lowers, EC degenerates to self-distillation).
            return ParallelConfig(
                ensemble_axis="pod" if multi_pod else "",
                ensemble_size=2 if multi_pod else 1,
                fsdp_axis="data", model_axis="model",
                batch_axes=("data",),  # FSDP = DP over the param-shard axis
                seq_axis="model",      # SP: layer-boundary residuals
                remat=True)
        # member = one TP group; K = |data| members; member batch gets DP
        # over "pod" when present (constrain() drops it single-pod).
        return ParallelConfig(ensemble_axis="data", ensemble_size=0,
                              fsdp_axis="", model_axis="model",
                              batch_axes=("pod",), remat=True)
    # serving: single model
    return ParallelConfig(ensemble_axis="", ensemble_size=1,
                          fsdp_axis="data" if big else "",
                          model_axis="model",
                          batch_axes=("pod", "data"), remat=False)


def all_cells() -> Tuple[Tuple[str, str], ...]:
    """All (arch, shape) dry-run cells, including documented skips."""
    from repro.common.types import SHAPES
    cells = []
    for arch in ARCH_IDS:
        for shape in SHAPES:
            cells.append((arch, shape))
    return tuple(cells)


def runnable_cells() -> Tuple[Tuple[str, str], ...]:
    return tuple((a, s) for a, s in all_cells()
                 if skip_reason(a, s) is None)
