"""Fault-tolerant checkpointing: atomic, async, keep-N, elastic reshard.

Layout (one directory per step):
    <root>/step_000123/
        index.json          # tree structure + leaf metadata + "committed"
        shard_000.npz       # flattened leaves (chunked every ~512 MB)
    <root>/step_000123.tmp/ # staging dir, atomically renamed on commit

Crash-safety contract: a checkpoint is valid iff its directory has no
".tmp" suffix AND index.json parses with committed=true.  `latest_step`
only returns valid checkpoints, so a process killed mid-save restarts from
the previous round — tests/test_checkpoint.py injects exactly that failure.

Async mode hands the host copy of the pytree to a writer thread so the
training loop only blocks for the device->host transfer, not the fsync.

Elastic: `reshard_members` maps a leading-K member-stacked state onto K'
members (truncate, or cycle-and-perturb to grow) — EC-DNN's ensemble is
naturally elastic since members are independent between aggregations.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

_CHUNK_BYTES = 512 * 1024 * 1024


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _key_str(i: int) -> str:
    return f"leaf_{i:05d}"


def save_checkpoint(root: str, step: int, tree: Any,
                    fail_before_commit: bool = False) -> str:
    """Blocking atomic save. `fail_before_commit` is a test hook that
    simulates a crash after data is written but before the commit rename."""
    final = os.path.join(root, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    host = [np.asarray(jax.device_get(leaf)) for leaf in leaves]

    shards, cur, cur_bytes = [], {}, 0
    for i, arr in enumerate(host):
        cur[_key_str(i)] = arr
        cur_bytes += arr.nbytes
        if cur_bytes >= _CHUNK_BYTES:
            shards.append(cur)
            cur, cur_bytes = {}, 0
    if cur:
        shards.append(cur)
    for s, shard in enumerate(shards):
        np.savez(os.path.join(tmp, f"shard_{s:03d}.npz"), **shard)

    index = {
        "step": step,
        "treedef": jax.tree_util.tree_structure(tree).__repr__(),
        "n_leaves": len(host),
        "shards": len(shards),
        "dtypes": [str(a.dtype) for a in host],
        "shapes": [list(a.shape) for a in host],
        "committed": True,
    }
    with open(os.path.join(tmp, "index.json"), "w") as f:
        json.dump(index, f)
    if fail_before_commit:
        return tmp  # simulate crash: stage dir left behind, never renamed
    if os.path.exists(final):
        shutil.rmtree(final, ignore_errors=True)
    os.replace(tmp, final)
    return final


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = []
    for name in os.listdir(root):
        if name.startswith("step_") and not name.endswith(".tmp"):
            idx = os.path.join(root, name, "index.json")
            try:
                with open(idx) as f:
                    if json.load(f).get("committed"):
                        steps.append(int(name.split("_")[1]))
            except (OSError, ValueError, json.JSONDecodeError):
                continue
    return max(steps) if steps else None


def restore_checkpoint(root: str, step: int, template: Any) -> Any:
    """Restore into the structure of `template` (shapes must match)."""
    path = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(path, "index.json")) as f:
        index = json.load(f)
    arrays: dict = {}
    for s in range(index["shards"]):
        with np.load(os.path.join(path, f"shard_{s:03d}.npz")) as z:
            arrays.update({k: z[k] for k in z.files})
    leaves, treedef = _flatten(template)
    out = []
    for i, ref in enumerate(leaves):
        arr = arrays[_key_str(i)]
        if arr.dtype.kind == "V":
            # npz round-trips ml_dtypes leaves (bfloat16, fp8) as raw
            # void records; view them back through the template dtype
            # (same itemsize) — jnp.asarray has no void cast
            ref_dt = np.dtype(jnp.dtype(ref.dtype))
            if arr.dtype.itemsize != ref_dt.itemsize:
                raise ValueError(
                    f"leaf {i}: stored itemsize {arr.dtype.itemsize} != "
                    f"template {ref_dt} ({index['dtypes'][i]} on disk)")
            arr = arr.view(ref_dt)
        out.append(jnp.asarray(arr, dtype=ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def gc_keep_last(root: str, keep: int) -> None:
    if not os.path.isdir(root):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(root)
        if n.startswith("step_") and not n.endswith(".tmp"))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"),
                      ignore_errors=True)
    # stale staging dirs from crashes are garbage
    for n in os.listdir(root):
        if n.endswith(".tmp"):
            shutil.rmtree(os.path.join(root, n), ignore_errors=True)


class CheckpointManager:
    """Async keep-N manager: save() returns immediately; a writer thread
    drains the queue.  wait() barriers (used before exit / in tests)."""

    def __init__(self, root: str, keep: int = 3, async_save: bool = True):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        os.makedirs(root, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._err: list = []
        if async_save:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, host_tree = item
            try:
                save_checkpoint(self.root, step, host_tree)
                gc_keep_last(self.root, self.keep)
            except Exception as e:  # pragma: no cover
                self._err.append(e)
            self._q.task_done()

    def save(self, step: int, tree: Any) -> None:
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if self.async_save:
            self._q.put((step, host))
        else:
            save_checkpoint(self.root, step, host)
            gc_keep_last(self.root, self.keep)

    def wait(self) -> None:
        if self.async_save:
            self._q.join()
        if self._err:
            raise self._err[0]

    def close(self) -> None:
        if self.async_save:
            self._q.put(None)
            self._q.join()

    def latest(self) -> Optional[int]:
        self.wait()
        return latest_step(self.root)

    def restore(self, template: Any, step: Optional[int] = None) -> Any:
        s = step if step is not None else self.latest()
        if s is None:
            raise FileNotFoundError(f"no checkpoint under {self.root}")
        return restore_checkpoint(self.root, s, template)


def reshard_members(state: Any, k_new: int, perturb: float = 0.0,
                    key=None) -> Any:
    """Elastic K -> K' on a leading-member-axis pytree.

    Shrink: keep the first K' members.  Grow: cycle existing members and
    (optionally) perturb the copies so they diverge — an EC-specific luxury:
    any member set is a valid ensemble, no optimizer state surgery needed.
    """
    def one(x):
        k_old = x.shape[0]
        if k_new <= k_old:
            return x[:k_new]
        reps = -(-k_new // k_old)
        out = jnp.concatenate([x] * reps, axis=0)[:k_new]
        return out

    out = jax.tree.map(one, state)
    if perturb > 0.0 and key is not None:
        leaves, treedef = jax.tree_util.tree_flatten(out)
        keys = jax.random.split(key, len(leaves))
        k_old = jax.tree.leaves(state)[0].shape[0]
        noised = []
        for kk, leaf in zip(keys, leaves):
            if jnp.issubdtype(leaf.dtype, jnp.floating) and k_new > k_old:
                noise = perturb * jax.random.normal(
                    kk, leaf.shape, jnp.float32).astype(leaf.dtype)
                mask = (jnp.arange(k_new) >= k_old).reshape(
                    (k_new,) + (1,) * (leaf.ndim - 1))
                leaf = leaf + noise * mask
            noised.append(leaf)
        out = jax.tree_util.tree_unflatten(treedef, noised)
    return out
