from repro.checkpoint.store import (CheckpointManager, restore_checkpoint,
                                    save_checkpoint, latest_step,
                                    reshard_members)

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "latest_step", "reshard_members"]
