"""Gradient compression for the MA / sync-SGD baselines.

EC-DNN itself needs no gradient traffic between aggregations (that is its
point); these utilities serve the baselines the paper compares against and
the sync mode's bandwidth knob at 1000+-node scale:

  - top-k sparsification with error feedback (memory of dropped residuals
    is re-added next step, preserving convergence),
  - symmetric per-tensor int8 quantization for the wire format.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def topk_compress_with_feedback(grads, residuals, frac: float = 0.01):
    """Keep the top-`frac` fraction of entries (by |g|) per tensor.

    -> (sparse_grads, new_residuals).  sparse + residual == grad exactly.
    """
    def one(g, r):
        g = g.astype(jnp.float32) + r
        flat = g.reshape(-1)
        k = max(1, int(flat.size * frac))
        thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
        mask = (jnp.abs(g) >= thresh).astype(jnp.float32)
        kept = g * mask
        return kept, g - kept

    out = jax.tree.map(one, grads, residuals)
    sparse = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return sparse, new_res


def int8_quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_residuals(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
