from repro.optim.optimizers import (Optimizer, adamw, clip_by_global_norm,
                                    sgd_momentum)
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine
from repro.optim.compression import (topk_compress_with_feedback,
                                     int8_quantize, int8_dequantize)

__all__ = ["Optimizer", "adamw", "sgd_momentum", "clip_by_global_norm",
           "constant", "cosine_decay", "linear_warmup_cosine",
           "topk_compress_with_feedback", "int8_quantize",
           "int8_dequantize"]
