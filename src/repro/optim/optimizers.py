"""Minimal, framework-free optimizers (no optax in this environment).

An Optimizer is an (init, update) pair over arbitrary pytrees:
    state = opt.init(params)
    params, state = opt.update(grads, state, params)
`update` is pure and jit/vmap-safe: EC-DNN vmaps it over the member axis so
each ensemble member carries independent optimizer moments.

The step count lives in the state; schedules are step -> lr functions
evaluated inside update (so one jitted step serves the whole run).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]


def _cast_like(x, ref):
    return x.astype(ref.dtype)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def sgd_momentum(lr: Callable | float, momentum: float = 0.9,
                 weight_decay: float = 0.0,
                 clip_norm: float = 0.0) -> Optimizer:
    """The paper's Section 5.1 optimizer (momentum + l2)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p,
                                                            jnp.float32),
                                   params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if clip_norm > 0:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr_t = jnp.asarray(lr_fn(step), jnp.float32)
        mu = jax.tree.map(
            lambda m, g, p: momentum * m + g.astype(jnp.float32)
            + weight_decay * p.astype(jnp.float32),
            state["mu"], grads, params)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr_t * m).astype(p.dtype),
            params, mu)
        return new_params, {"mu": mu, "step": step}

    return Optimizer(init, update)


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: float = 1.0, moment_dtype=jnp.float32) -> Optimizer:
    """AdamW. moment_dtype=bf16 halves optimizer memory (the update math
    stays f32); at 405B scale this is the difference between optimizer
    state fitting a v5e pod or not (EXPERIMENTS §Perf)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, moment_dtype)  # noqa: E731
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if clip_norm > 0:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr_t = jnp.asarray(lr_fn(step), jnp.float32)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        m = jax.tree.map(
            lambda m_, g: (b1 * m_.astype(jnp.float32) + (1 - b1)
                           * g.astype(jnp.float32)).astype(moment_dtype),
            state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: (b2 * v_.astype(jnp.float32) + (1 - b2)
                           * jnp.square(g.astype(jnp.float32))
                           ).astype(moment_dtype),
            state["v"], grads)

        def upd(p, m_, v_):
            mh = m_.astype(jnp.float32) / c1
            vh = v_.astype(jnp.float32) / c2
            delta = mh / (jnp.sqrt(vh) + eps) \
                + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)

        return jax.tree.map(upd, params, m, v), \
            {"m": m, "v": v, "step": step}

    return Optimizer(init, update)
