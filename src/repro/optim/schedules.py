"""Step -> learning-rate schedules (jit-safe, operate on traced steps)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)


def cosine_decay(lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return fn


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_decay(lr, max(total_steps - warmup, 1), final_frac)

    def fn(step):
        s = step.astype(jnp.float32)
        wu = lr * s / max(warmup, 1)
        return jnp.where(step <= warmup, wu, cos(step - warmup))
    return fn
