"""Serving launcher: batched decode with a single model or an EC ensemble.

EC-DNN_G serving: each ensemble member scores the batch and the output
distributions are averaged (paper Eqn 6) before sampling — the ensemble
IS the product when resources allow.  Single-model mode serves a member /
compressed model (EC-DNN_L).

  python -m repro.launch.serve --arch gemma3-1b --reduced --members 4 \
      --batch 8 --steps 16 --ensemble
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--members", type=int, default=1)
    ap.add_argument("--ensemble", action="store_true",
                    help="EC-DNN_G: average member distributions")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import registry
    from repro.core import ensemble as ens
    from repro.models import transformer as tf

    cfg = registry.get_config(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    K = args.members if args.ensemble else 1
    params = jax.vmap(lambda k: tf.init(k, cfg))(jax.random.split(key, K))

    B = args.batch
    max_seq = args.prompt_len + args.steps
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, args.prompt_len),
                                0, cfg.vocab_size)
    caches = [tf.init_cache(cfg, B, max_seq=max_seq) for _ in range(K)]
    if cfg.enc_dec:
        enc = jnp.zeros((B, cfg.enc_max_frames, cfg.d_model), jnp.bfloat16)
        for c in range(K):
            caches[c]["enc"] = tf.encode(
                jax.tree.map(lambda x: x[c], params), cfg, enc)

    step = jax.jit(lambda p, c, t: tf.decode_step(p, cfg, c, t))

    t0 = time.time()
    tok = prompt[:, :1]
    out_tokens = []
    for i in range(args.prompt_len + args.steps - 1):
        member_logits = []
        for m in range(K):
            pm = jax.tree.map(lambda x: x[m], params)
            logits, caches[m] = step(pm, caches[m], tok)
            member_logits.append(logits[:, 0])
        probs = ens.ensemble_probs(jnp.stack(member_logits))
        if i + 1 < args.prompt_len:
            tok = prompt[:, i + 1: i + 2]  # teacher-force the prompt
        else:
            if args.temperature > 0:
                key, sub = jax.random.split(key)
                tok = jax.random.categorical(
                    sub, jnp.log(probs + 1e-30) / args.temperature)[:, None]
            else:
                tok = probs.argmax(-1)[:, None].astype(jnp.int32)
            out_tokens.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    n_tok = gen.size
    print(f"served batch={B} members={K} steps={args.steps}: "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok/dt:.1f} tok/s)")
    print("sample:", gen[0][:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
