"""Serving launcher: the EC-DNN_G ensemble engine behind a CLI.

EC-DNN_G serving: all K members score each step inside ONE compiled
program (repro.serving.EnsembleEngine) and the output distributions are
averaged (paper Eqn 6) before sampling — the ensemble IS the product
when resources allow.  --members 1 serves a single member / compressed
model (EC-DNN_L) through the identical path.

Static batch (tok/s):
  python -m repro.launch.serve --arch gemma3-1b --reduced --members 4 \
      --batch 8 --steps 16 --ensemble

Continuous batching under synthetic load (tok/s + TTFT + latency
percentiles):
  python -m repro.launch.serve --arch gemma3-1b --reduced --members 4 \
      --ensemble --continuous --requests 32

--quorum "1,1,0,1" drops member 2 (straggler policy): the fused
distribution renormalizes over the survivors, no recompile.

--mesh MxD shards the member axis over M devices (x D data devices,
reserved) and runs every kernel under shard_map — per-device cache and
FLOPs scale with K/M.  On CPU, force host devices first:
  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
  python -m repro.launch.serve --arch gemma3-1b --reduced --members 4 \
      --ensemble --mesh 2x1

HTTP frontend (streaming SSE + /metrics + /healthz, N replicas behind
a least-loaded router, Ctrl-C drains gracefully):
  python -m repro.launch.serve --arch gemma3-1b --reduced --members 4 \
      --ensemble --http --port 8000 --replicas 2
  curl -s localhost:8000/v1/generate -d '{"tokens":[1,2,3],"max_new":8}'
--watch-ckpt DIR polls a CheckpointManager root for newly committed
rounds and hot-swaps each one into the fleet with the zero-downtime
drain -> swap -> rejoin rollout (the paper's train -> compress -> serve
loop, closed).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def serve_http(args, cfg, build_engine):
    """Mount --replicas engines behind the router + HTTP frontend."""
    from repro.serving import client
    from repro.serving.frontend import Replica, Router, serve_frontend

    replicas = [Replica(f"r{i}", build_engine(),
                        prefill_budget=args.prefill_budget,
                        obs=not args.no_obs,
                        trace_log=args.trace_log or None,
                        profile_dir=args.profile_dir or None)
                for i in range(max(1, args.replicas))]
    router = Router(replicas, max_queue_depth=args.max_queue_depth)
    srv = serve_frontend(router, host=args.host, port=args.port,
                         verbose=not args.load,
                         profile_dir=args.profile_dir or None)
    print(f"frontend: {srv.url}  ({len(replicas)} replica(s), "
          f"K={replicas[0].engine.n_members} members, "
          f"{replicas[0].engine.n_slots} slots each)")
    print(f"  POST {srv.url}/v1/generate  "
          '{"tokens": [...], "max_new": N, "stream": true|false}')
    print(f"  GET  {srv.url}/healthz   GET  {srv.url}/metrics")

    try:
        if args.load:
            reqs = client.make_requests(
                args.requests, cfg.vocab_size,
                prompt_len=(max(2, args.prompt_len // 4), args.prompt_len),
                max_new=(max(1, args.steps // 2), args.steps),
                seed=args.seed)
            client.print_report(client.run_http_load(
                srv.url, reqs, concurrency=2 * len(replicas)))
            return 0
        if args.watch_ckpt:
            watch_checkpoints(args.watch_ckpt, router, canary=args.canary)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        print("\ndraining ...")
    finally:
        srv.shutdown(drain=True)
    print("drained; bye")
    return 0


def serve_fleet(args, cfg):
    """--fleet: each replica its own OS process behind a FleetRouter.

    The processes rebuild bit-identical engines from one EngineSpec
    (seed-pinned init), so a request retried after a crash is
    token-exact.  --load drives the synthetic requests through the
    fleet with crash-retry and 429 backoff; --watch-ckpt rolls new
    rounds out over POST /admin/swap (with --canary staging).
    """
    import threading

    from repro.serving import client
    from repro.serving.frontend import EngineSpec, FleetRouter

    spec = EngineSpec(
        arch=args.arch, reduced=args.reduced,
        members=args.members if args.ensemble else 1, seed=args.seed,
        n_slots=args.batch, max_prompt=args.prompt_len,
        max_out=args.steps, prefill_chunk=args.prefill_chunk,
        temperature=args.temperature, top_k=args.top_k,
        eos_id=args.eos_id,
        quorum=([float(x) for x in args.quorum.split(",")]
                if args.quorum else None),
        mesh=args.mesh, paged=args.paged, page_size=args.page_size,
        n_pages=args.n_pages, prefix_cache=args.prefix_cache,
        kv_dtype=args.kv_dtype,
        draft_member0=(args.draft_ckpt == "member0"),
        gamma=args.gamma, spec_sampling=args.spec_sampling,
        ckpt=(args.draft_ckpt if args.draft_ckpt
              not in ("", "member0") else ""),
        prefill_budget=args.prefill_budget,
        obs=not args.no_obs, trace_log=args.trace_log,
        profile_dir=args.profile_dir)
    fleet = FleetRouter(spec, n=max(1, args.replicas), host=args.host,
                        max_queue_depth=args.max_queue_depth)
    print(f"spawning {max(1, args.replicas)} replica process(es) "
          f"(K={spec.members} members each) ...")
    fleet.start()
    for p in fleet.procs:
        print(f"  {p.name}: pid {p.proc.pid}  {p.url}")
    try:
        if args.load:
            reqs = client.make_requests(
                args.requests, cfg.vocab_size,
                prompt_len=(max(2, args.prompt_len // 4), args.prompt_len),
                max_new=(max(1, args.steps // 2), args.steps),
                seed=args.seed)
            done, errs = [], []
            lock = threading.Lock()
            nxt = {"i": 0}

            def worker():
                while True:
                    with lock:
                        i = nxt["i"]
                        if i >= len(reqs):
                            return
                        nxt["i"] += 1
                    try:
                        out = fleet.generate(*reqs[i])
                        with lock:
                            done.append(out)
                    except Exception as e:  # noqa: BLE001
                        with lock:
                            errs.append(repr(e))

            t0 = time.time()
            threads = [threading.Thread(target=worker, daemon=True)
                       for _ in range(2 * len(fleet.procs))]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.time() - t0
            n_tok = sum(r["n_gen"] for r in done)
            s = fleet.stats()
            print(f"fleet served {len(done)}/{len(reqs)} requests "
                  f"({len(errs)} errors) | {n_tok} tokens in "
                  f"{wall:.2f}s = {n_tok / max(wall, 1e-9):.1f} tok/s")
            print(f"  retried {s['retried']}, 429 backoffs "
                  f"{s['backoffs']}, latched {s['latched']}")
            return 1 if errs else 0
        if args.watch_ckpt:
            from repro.checkpoint.store import latest_step
            served = None
            while True:
                latest = latest_step(args.watch_ckpt)
                if latest is not None and latest != served:
                    fleet.rollout(ckpt=args.watch_ckpt, step=latest,
                                  canary=args.canary)
                    served = latest
                    print(f"rolled out round {served} fleet-wide")
                time.sleep(5.0)
        else:
            while True:
                time.sleep(3600)
    except KeyboardInterrupt:
        print("\nterminating fleet ...")
    finally:
        fleet.stop()
    print("fleet down; bye")
    return 0


def watch_checkpoints(root: str, router, poll_s: float = 5.0,
                      canary: float = 0.0):
    """Poll a CheckpointManager root; hot-swap each newly committed
    round into the fleet (drain -> swap -> rejoin, zero drops).
    canary > 0 routes that traffic fraction at one swapped replica
    first and aborts the rollout if it fails.

    The round already on disk at startup is rolled in FIRST: a
    restarted server must serve the trained weights, not the random
    init its engines were constructed with.
    """
    from repro.checkpoint.store import latest_step, restore_checkpoint

    served = None
    print(f"watching {root} "
          f"(round on disk: {latest_step(root)})")
    while True:
        latest = latest_step(root)
        if latest is not None and latest != served:
            template = router.replicas[0].engine.params
            new_params = restore_checkpoint(root, latest, template)
            router.rollout(new_params, canary=canary)
            served = latest
            print(f"rolled out round {served} "
                  f"(swaps: "
                  f"{[r.engine.swaps_done for r in router.replicas]})")
        time.sleep(poll_s)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--members", type=int, default=1)
    ap.add_argument("--ensemble", action="store_true",
                    help="EC-DNN_G: average member distributions")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots (concurrent requests)")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--steps", type=int, default=16,
                    help="max new tokens per request")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt tokens per prefill program (0: "
                         "per-token reference path; default: autotuned "
                         "from --prompt-len and --page-size)")
    ap.add_argument("--prefill-budget", type=int, default=None,
                    help="prompt tokens prefilled per scheduler "
                         "iteration (default: 2 chunks)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV pool: full-attention caches become "
                         "fixed-size pages behind a per-slot page "
                         "table; admission bounds by free pages")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (--paged)")
    ap.add_argument("--n-pages", type=int, default=None,
                    help="physical pages in the pool (--paged; default "
                         "slots x ceil(max_seq/page) = full capacity, "
                         "smaller oversubscribes and relies on "
                         "preemption)")
    ap.add_argument("--kv-dtype", default="f32",
                    choices=["f32", "bf16", "int8", "fp8"],
                    help="paged KV page storage format (--paged): f32 "
                         "keeps the bit-exact native planes; int8/fp8 "
                         "quantize pages with per-token absmax scale "
                         "sidecars dequantized inside the kernel, "
                         "~4x/~4x fewer cache bytes per token so the "
                         "same pool admits ~4x the concurrency")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share KV pages across requests with a common "
                         "prompt prefix (--paged only): a shared-prefix "
                         "trie skips prefill below the hit, refcounted "
                         "copy-on-write pages keep slots isolated")
    ap.add_argument("--draft-ckpt", default="",
                    help="speculative decoding: serve the compressed "
                         "student at this CheckpointManager root as the "
                         "draft model for the ensemble (EC-DNN_L "
                         "drafting for EC-DNN_G); 'member0' drafts with "
                         "member 0's weights (demo without a ckpt)")
    ap.add_argument("--gamma", type=int, default=4,
                    help="draft tokens proposed per speculative "
                         "iteration (--draft-ckpt)")
    ap.add_argument("--spec-sampling", action="store_true",
                    help="stochastic speculative decoding (rejection "
                         "sampling against the fused distribution) "
                         "instead of greedy exact-match accept")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--quorum", default="",
                    help="comma 0/1 per member, e.g. 1,1,0,1")
    ap.add_argument("--mesh", default="",
                    help="'MxD' member x data device grid (e.g. 2x1): "
                         "shard the member axis over M devices; empty "
                         "or 1x1 keeps the single-device path")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching under synthetic load")
    ap.add_argument("--requests", type=int, default=32,
                    help="synthetic requests (--continuous / --load)")
    ap.add_argument("--http", action="store_true",
                    help="serve over HTTP: POST /v1/generate (SSE "
                         "streaming), GET /metrics, GET /healthz; "
                         "Ctrl-C drains gracefully")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="HTTP port (--http; 0 picks an ephemeral one)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="engine replicas behind the frontend router "
                         "(--http); each gets its own cache pool")
    ap.add_argument("--fleet", action="store_true",
                    help="with --http: run each replica as its own OS "
                         "PROCESS (engine + scheduler + HTTP surface) "
                         "behind a crash-latching FleetRouter instead "
                         "of threads in this one")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="backpressure: past this fleet-wide queue "
                         "depth, POST /v1/generate answers 429 with "
                         "Retry-After instead of queueing")
    ap.add_argument("--canary", type=float, default=0.0,
                    help="rollout canary fraction: swap one replica "
                         "first and route this share of traffic at it "
                         "before the fleet-wide swap (--watch-ckpt)")
    ap.add_argument("--load", action="store_true",
                    help="with --http: drive the synthetic requests "
                         "through the HTTP path and print the report "
                         "instead of serving until Ctrl-C")
    ap.add_argument("--watch-ckpt", default="",
                    help="with --http: poll this CheckpointManager "
                         "root and hot-swap each newly committed round "
                         "into the fleet (drain -> swap -> rejoin)")
    ap.add_argument("--no-obs", action="store_true",
                    help="disable the observability layer (request "
                         "traces, latency histograms, tick-phase "
                         "profiler); on by default at <2%% overhead")
    ap.add_argument("--trace-log", default="",
                    help="append one JSON line per finished request "
                         "trace to this file (obs must be on)")
    ap.add_argument("--profile-dir", default="",
                    help="jax.profiler output dir; arms POST "
                         "/admin/profile {\"ticks\": N} to capture "
                         "device traces for N scheduler ticks")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.common import sharding as shd
    from repro.configs import registry
    from repro.models import transformer as tf
    from repro.serving import EnsembleEngine, client

    cfg = registry.get_config(args.arch, reduced=args.reduced)
    if args.http and args.fleet:
        # fleet mode: the replica PROCESSES build the engines; the
        # parent never initializes params at all
        return serve_fleet(args, cfg)
    key = jax.random.PRNGKey(args.seed)
    K = args.members if args.ensemble else 1
    params = jax.vmap(lambda k: tf.init(k, cfg))(jax.random.split(key, K))
    quorum = ([float(x) for x in args.quorum.split(",")]
              if args.quorum else None)
    if quorum is not None and len(quorum) != K:
        raise SystemExit(f"--quorum needs {K} entries, got {len(quorum)}")
    mesh = shd.parse_mesh_arg(args.mesh)

    draft_params = None
    if args.draft_ckpt:
        if args.draft_ckpt == "member0":
            draft_params = jax.tree.map(lambda x: x[0], params)
        else:
            from repro.checkpoint.store import (latest_step,
                                                restore_checkpoint)
            step = latest_step(args.draft_ckpt)
            if step is None:
                raise SystemExit(
                    f"--draft-ckpt {args.draft_ckpt}: no committed round")
            template = tf.init(jax.random.PRNGKey(0), cfg)
            draft_params = restore_checkpoint(args.draft_ckpt, step,
                                              template)
            print(f"draft model: round {step} from {args.draft_ckpt}")

    def build_engine():
        kw = dict(
            n_slots=args.batch, max_prompt=args.prompt_len,
            max_out=args.steps, prefill_chunk=args.prefill_chunk,
            temperature=args.temperature, top_k=args.top_k,
            eos_id=args.eos_id, quorum=quorum, seed=args.seed, mesh=mesh,
            paged=args.paged, page_size=args.page_size,
            n_pages=args.n_pages, prefix_cache=args.prefix_cache,
            kv_dtype=args.kv_dtype)
        if draft_params is not None:
            from repro.serving import SpeculativeEngine
            return SpeculativeEngine(cfg, params, draft_params,
                                     gamma=args.gamma,
                                     spec_sampling=args.spec_sampling,
                                     **kw)
        return EnsembleEngine(cfg, params, **kw)

    if args.http:
        return serve_http(args, cfg, build_engine)

    engine = build_engine()
    place = ("single-device" if mesh is None else
             f"mesh {dict(mesh.shape)} over {mesh.devices.size} devices, "
             f"{K // engine.member_shards} members/device")
    print(f"engine: K={K} members, {args.batch} slots, "
          f"prefill chunk {engine.prefill_chunk}, {place}, "
          f"cache pool {engine.cache_bytes() / 2**20:.1f} MiB/device")
    if args.paged:
        ps = engine.page_stats()
        print(f"paged pool: {ps['n_pages']} pages/device x "
              f"{ps['page_size']} tok ({ps['pages_per_slot']} pages/slot "
              f"max), free list {ps['free_pages']}/{ps['n_pages']} "
              f"({ps['used_pages'] / max(ps['n_pages'], 1):.0%} used)")
        print(f"kv pages: {ps['kv_dtype']} storage, "
              f"{ps['page_bytes']} B/page, "
              f"{ps['bytes_per_token']} B/token across all paged layers")

    if args.continuous:
        reqs = client.make_requests(
            args.requests, cfg.vocab_size,
            prompt_len=(max(2, args.prompt_len // 4), args.prompt_len),
            max_new=(max(1, args.steps // 2), args.steps), seed=args.seed)
        # compile outside the timed run so percentiles measure serving;
        # max_new=2 forces one decode step, so BOTH kernels (prefill +
        # decode) are built here, not inside the first timed iteration
        engine.generate([reqs[0][0]], max_new=2)
        client.print_report(client.run_load(
            engine, reqs, prefill_budget=args.prefill_budget,
            obs=not args.no_obs, trace_log=args.trace_log or None))
        return 0

    B = args.batch
    prompt = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (B, args.prompt_len), 0, cfg.vocab_size))
    engine.generate(list(prompt), max_new=args.steps)  # warmup/compile
    t0 = time.time()
    outs = engine.generate(list(prompt), max_new=args.steps)
    dt = time.time() - t0
    n_tok = sum(len(o) for o in outs)
    print(f"served batch={B} members={K} steps={args.steps}: "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s)")
    if hasattr(engine, "spec_stats"):
        sp = engine.spec_stats()
        print(f"speculation: gamma={sp['gamma']}, "
              f"acceptance {sp['acceptance_rate']:.1%}, "
              f"mean accepted {sp['mean_accepted_len']:.2f} tok/step "
              f"(p50 {sp['accepted_len_p50']:.0f})")
    print("sample:", outs[0][:16].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
