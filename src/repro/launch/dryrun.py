import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract the roofline terms.

MUST be the process entry point (python -m repro.launch.dryrun ...): the
XLA_FLAGS line above runs before any other import — including repro.* —
because jax locks the device count at first backend init.

Per cell this prints/records:
  - compiled.memory_analysis()  (bytes/device: proves the cell fits HBM)
  - compiled.cost_analysis()    (XLA's raw per-device numbers)
  - hlo_analysis.analyze()      (trip-count-corrected FLOPs, HBM traffic,
                                 collective bytes by opcode)
  - the three roofline terms + MODEL_FLOPS/HLO ratio (EXPERIMENTS §Roofline)

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out results.json
  python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k \
      --variant relabel        # lower the ring-relabel aggregation step
"""

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402


def flops_model(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE), D = tokens
    processed per step; decode steps process one token per sequence."""
    import jax
    import jax.numpy as jnp
    from repro.common.types import SHAPES
    from repro.configs import registry
    from repro.models import transformer as tf

    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    params = jax.eval_shape(lambda k: tf.init(k, cfg), jax.random.PRNGKey(0))

    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        n = 1
        for d in leaf.shape:
            n *= d
        name = ""
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        total += n
        if name.startswith("experts"):
            frac = (cfg.ffn.top_k / max(cfg.ffn.n_experts, 1))
            active += n * frac
        elif name == "embed" and not cfg.tie_embeddings:
            pass  # lookup is a gather, not a matmul
        else:
            active += n
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd+bwd vs fwd
    return 2.0 * active * tokens * mult


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             variant: str = "plain") -> dict:
    import jax
    from repro.common.sharding import set_mesh as _set_mesh
    from repro.common.types import SHAPES
    from repro.configs import registry
    from repro.launch import hlo_analysis
    from repro.launch.mesh import (HBM_BW, HBM_BYTES, ICI_BW,
                                   PEAK_FLOPS_BF16, make_production_mesh)
    from repro.launch.specs import build_cell

    skip = registry.skip_reason(arch, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                "status": "skip", "reason": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = 1
    for s in mesh.shape.values():
        chips *= s
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
           "variant": variant, "chips": chips}
    try:
        cell = build_cell(arch, shape_name, mesh, multi_pod,
                          variant=variant)
        # use_mesh (NOT `with mesh:`): only use_mesh installs the abstract
        # mesh that with_sharding_constraint needs — under a bare Mesh
        # context every internal constraint silently no-ops.
        with _set_mesh(mesh):
            lowered = jax.jit(
                cell.fn, in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate).lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        txt = compiled.as_text()
        hc = hlo_analysis.analyze(txt, collect_top=6)

        bytes_per_dev = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
                         + ma.output_size_in_bytes - ma.alias_size_in_bytes)
        t_comp = hc.flops / PEAK_FLOPS_BF16
        t_mem = hc.hbm_bytes / HBM_BW
        t_coll = hc.total_collective_bytes / ICI_BW
        model_fl = flops_model(arch, shape_name) / chips
        dominant = max((t_comp, "compute"), (t_mem, "memory"),
                       (t_coll, "collective"))[1]
        rec.update({
            "status": "ok",
            "step": cell.step_name,
            "meta": {k: (v if not hasattr(v, "__dict__") else str(v))
                     for k, v in cell.meta.items()},
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "bytes_per_device": int(bytes_per_dev),
            "fits_hbm": bool(bytes_per_dev < HBM_BYTES),
            "xla_flops_per_dev": ca.get("flops", 0.0),
            "xla_bytes_per_dev": ca.get("bytes accessed", 0.0),
            "hlo_flops_per_dev": hc.flops,
            "hlo_hbm_bytes_per_dev": hc.hbm_bytes,
            "collective_bytes": {k: v for k, v in
                                 hc.collective_bytes.items() if v},
            "collective_count": {k: v for k, v in
                                 hc.collective_count.items() if v},
            "t_compute_s": t_comp,
            "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "model_flops_per_dev": model_fl,
            "useful_flops_ratio": (model_fl / hc.flops) if hc.flops else 0.0,
            "roofline_fraction": (t_comp / max(t_comp, t_mem, t_coll)
                                  if max(t_comp, t_mem, t_coll) > 0 else 0),
            "top_flops": hc.top_flops,
            "top_bytes": hc.top_bytes,
            "top_coll": hc.top_coll,
        })
        print(f"[{arch} x {shape_name} x {'2x16x16' if multi_pod else '16x16'}"
              f" {variant}] OK {rec['compile_s']}s compile | "
              f"{bytes_per_dev/2**30:.2f} GiB/dev (fits={rec['fits_hbm']}) | "
              f"flops/dev {hc.flops:.3e} (xla {rec['xla_flops_per_dev']:.3e})"
              f" | t_comp {t_comp*1e3:.2f}ms t_mem {t_mem*1e3:.2f}ms "
              f"t_coll {t_coll*1e3:.2f}ms -> {dominant}-bound")
        print(f"    memory_analysis: {ma}")
        print(f"    collectives: {rec['collective_count']}")
    except Exception as e:  # noqa: BLE001
        rec.update({"status": "fail", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        print(f"[{arch} x {shape_name}] FAIL {type(e).__name__}: {e}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--variant", default="plain",
                    choices=["plain", "distill", "relabel"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.configs import registry

    cells = []
    if args.all:
        cells = [(a, s) for a, s in registry.all_cells()]
    else:
        shapes = [args.shape] if args.shape else \
            ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
        archs = [args.arch] if args.arch else list(registry.ARCH_IDS)
        cells = [(a, s) for a in archs for s in shapes]

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch, shape in cells:
        for mp in meshes:
            results.append(run_cell(arch, shape, mp, variant=args.variant))
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"== {n_ok} ok / {n_skip} skip / {n_fail} fail ==")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
