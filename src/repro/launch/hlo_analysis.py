"""Post-SPMD HLO text analyzer: trip-count-corrected roofline terms.

Why not just compiled.cost_analysis()?  Two measured facts (see
EXPERIMENTS.md §Dry-run methodology):
  1. XLA's HloCostAnalysis counts a while-loop body ONCE, but our models
     scan over layers — a 126-layer llama3 train step would be
     under-counted ~126x.
  2. cost_analysis has no collective-bytes view at all.

This parser works on `compiled.as_text()` (post-SPMD, so shapes are
per-device):
  - splits the module into computations,
  - builds a per-computation symbol table (instruction -> shape/bytes),
  - extracts while-loop trip counts from the condition computation's
    `compare(iv, constant), direction=LT` pattern,
  - propagates execution multipliers through the call graph
    (ENTRY -> while bodies x trip, fusions/calls x 1),
  - accumulates dot/convolution FLOPs everywhere, HBM traffic at fusion
    boundaries only, and collective bytes by opcode.

All numbers are PER-CHIP (the SPMD module is per-device).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(%[\w\.\-]+|ROOT\s+%[\w\.\-]+)\s*=\s*(.*)$")


def _shape_bytes(type_str: str) -> int:
    """'f32[64,128]{1,0}' -> bytes; tuples sum their elements."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    type_str: str
    operands: List[str]
    raw: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    symtab: Dict[str, Instr]


def _parse_operands(rest: str) -> List[str]:
    par = rest.find("(")
    if par < 0:
        return []
    depth, end = 0, -1
    for i in range(par, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    if end < 0:
        return []
    inner = rest[par + 1: end]
    ops = []
    depth = 0
    cur = ""
    for ch in inner:
        if ch == "," and depth == 0:
            ops.append(cur.strip())
            cur = ""
        else:
            if ch in "([{":
                depth += 1
            elif ch in ")]}":
                depth -= 1
            cur += ch
    if cur.strip():
        ops.append(cur.strip())
    # newer XLA prints bare "%name" operands; older versions prefix the
    # type ("f32[64,128]{1,0} %name") — take the %name token either way
    out = []
    for o in ops:
        nm = re.search(r"%[\w\.\-]+", o)
        if nm:
            out.append(nm.group(0))
    return out


_HEADER_RE = re.compile(
    r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")


def parse_module(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in txt.splitlines():
        if not line.startswith(" "):  # computation headers are unindented
            header = _HEADER_RE.match(line)
            if header:
                name = header.group(2)
                cur = Computation(name, [], {})
                comps[name] = cur
                if header.group(1):
                    comps["ENTRY"] = cur
                continue
        if cur is None:
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name = m.group(1).replace("ROOT", "").strip()
        rest = m.group(2)
        # "TYPE opcode(operands), attrs" — tuple types may contain
        # /*index=N*/ comments, so scan balanced parens instead of regexing
        if rest.startswith("("):
            depth, end = 0, -1
            for idx, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = idx
                        break
            if end < 0:
                continue
            type_str, after = rest[: end + 1], rest[end + 1:]
        else:
            tm = re.match(r"(\w+\[[\d,]*\](?:{[^}]*})?)", rest)
            if not tm:
                continue
            type_str, after = tm.group(1), rest[tm.end():]
        om = re.match(r"\s+([\w\-]+)\(", after)
        if not om:
            continue
        opcode = om.group(1)
        operands = _parse_operands(after[om.end() - 1:])
        instr = Instr(name, opcode, type_str, operands, rest)
        cur.instrs.append(instr)
        cur.symtab[name] = instr
    return comps


def _while_trip_count(cond: Computation,
                      comps: Dict[str, Computation]) -> int:
    """condition: compare(iv, const) LT, possibly behind a fused compare.

    scan lowers the bound as the only (non-trivial) integer constant in
    the condition computation / its fused callees, so we BFS those and
    take the largest constant found.
    """
    best = 1
    stack, seen = [cond], set()
    while stack:
        c = stack.pop()
        if c.name in seen:
            continue
        seen.add(c.name)
        for ins in c.instrs:
            if ins.opcode == "constant":
                cm = re.search(r"constant\((\d+)\)", ins.raw)
                if cm:
                    best = max(best, int(cm.group(1)))
            elif ins.opcode == "fusion":
                fm = re.search(r"calls=%?([\w\.\-]+)", ins.raw)
                if fm and fm.group(1) in comps:
                    stack.append(comps[fm.group(1)])
    return best


def _dot_flops(ins: Instr, symtab: Dict[str, Instr]) -> float:
    out_dims = _shape_dims(ins.type_str)
    out_n = 1
    for d in out_dims:
        out_n *= d
    cm = re.search(r"lhs_contracting_dims={([\d,]*)}", ins.raw)
    lhs = symtab.get(ins.operands[0]) if ins.operands else None
    if lhs is None:
        return 0.0
    lhs_dims = _shape_dims(lhs.type_str)
    k = 1
    if cm and cm.group(1):
        for d in cm.group(1).split(","):
            if int(d) < len(lhs_dims):
                k *= lhs_dims[int(d)]
    return 2.0 * out_n * k


def _conv_flops(ins: Instr, symtab: Dict[str, Instr]) -> float:
    out_dims = _shape_dims(ins.type_str)
    out_n = 1
    for d in out_dims:
        out_n *= d
    rhs = symtab.get(ins.operands[1]) if len(ins.operands) > 1 else None
    if rhs is None:
        return 0.0
    rhs_dims = _shape_dims(rhs.type_str)  # kernel: spatial..., in, out
    k = 1
    for d in rhs_dims[:-1]:
        k *= d
    return 2.0 * out_n * k


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    collective_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: {c: 0 for c in COLLECTIVES})
    # optional per-op top contributors: (desc, value)
    top_flops: List[Tuple[str, float]] = dataclasses.field(
        default_factory=list)
    top_bytes: List[Tuple[str, float]] = dataclasses.field(
        default_factory=list)
    top_coll: List[Tuple[str, float]] = dataclasses.field(
        default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _meta(ins: Instr) -> str:
    m = re.search(r'op_name="([^"]+)"', ins.raw)
    op_name = m.group(1) if m else ""
    return f"{ins.opcode} {ins.type_str[:48]} {op_name[-70:]}"


def analyze(txt: str, collect_top: int = 0) -> HloCosts:
    comps = parse_module(txt)
    entry = comps.get("ENTRY")
    if entry is None:  # single unnamed computation fallback
        entry = next(iter(comps.values()))

    # call graph: multiplier for each computation
    mult: Dict[str, float] = {}
    fused: Dict[str, bool] = {}

    def visit(comp: Computation, m: float, in_fusion: bool):
        key = comp.name
        mult[key] = mult.get(key, 0.0) + m
        fused[key] = in_fusion
        for ins in comp.instrs:
            if ins.opcode == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", ins.raw)
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.raw)
                if bm and bm.group(1) in comps:
                    trips = 1
                    if cm and cm.group(1) in comps:
                        trips = _while_trip_count(comps[cm.group(1)],
                                                  comps)
                    visit(comps[bm.group(1)], m * trips, in_fusion)
            elif ins.opcode == "fusion":
                fm = re.search(r"calls=%?([\w\.\-]+)", ins.raw)
                if fm and fm.group(1) in comps:
                    visit(comps[fm.group(1)], m, True)
            elif ins.opcode in ("call", "async-start"):
                cm = re.search(r"to_apply=%?([\w\.\-]+)", ins.raw)
                if cm and cm.group(1) in comps:
                    visit(comps[cm.group(1)], m, in_fusion)
            elif ins.opcode == "conditional":
                for br in re.finditer(r"(?:true_computation|"
                                      r"false_computation|branch_\d+)="
                                      r"%?([\w\.\-]+)", ins.raw):
                    if br.group(1) in comps:
                        visit(comps[br.group(1)], m, in_fusion)

    visit(entry, 1.0, False)

    costs = HloCosts()
    tf_, tb_, tc_ = [], [], []
    seen = set()
    for cname, m in mult.items():
        comp = comps[cname]
        if id(comp) in seen:
            continue
        seen.add(id(comp))
        is_fused = fused.get(cname, False)
        for ins in comp.instrs:
            if ins.opcode == "dot":
                fl = m * _dot_flops(ins, comp.symtab)
                costs.flops += fl
                if collect_top:
                    tf_.append((_meta(ins), fl))
            elif ins.opcode == "convolution":
                costs.flops += m * _conv_flops(ins, comp.symtab)
            coll = next((c for c in COLLECTIVES
                         if ins.opcode.startswith(c)), None)
            if coll and not ins.opcode.endswith("-done"):
                b = _shape_bytes(ins.type_str)
                factor = 2.0 if coll == "all-reduce" else 1.0
                costs.collective_bytes[coll] += m * b * factor
                costs.collective_count[coll] += int(m)
                if collect_top:
                    tc_.append((_meta(ins), m * b * factor))
            # HBM traffic at fusion boundaries only
            if not is_fused and ins.opcode not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "call", "conditional"):
                out_b = _shape_bytes(ins.type_str)
                in_b = sum(_shape_bytes(comp.symtab[o].type_str)
                           for o in ins.operands if o in comp.symtab)
                costs.hbm_bytes += m * (out_b + in_b)
                if collect_top:
                    tb_.append((_meta(ins), m * (out_b + in_b)))
    if collect_top:
        costs.top_flops = sorted(tf_, key=lambda x: -x[1])[:collect_top]
        costs.top_bytes = sorted(tb_, key=lambda x: -x[1])[:collect_top]
        costs.top_coll = sorted(tc_, key=lambda x: -x[1])[:collect_top]
    return costs
