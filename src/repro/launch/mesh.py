"""Production mesh construction (DESIGN §5).

A function, not a module constant: importing this module never touches jax
device state, so tests see 1 CPU device unless dryrun.py set
XLA_FLAGS=--xla_force_host_platform_device_count first.
"""
from __future__ import annotations

import jax

from repro.common.sharding import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh():
    """1-device mesh with the production axis names (CPU tests)."""
    return make_mesh((1, 1), ("data", "model"))


# TPU v5e constants for the roofline (EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12     # per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link
HBM_BYTES = 16 * 1024 ** 3   # capacity per chip
