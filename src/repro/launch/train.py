"""Training launcher: EC-DNN / MA-DNN / sync-SGD on any mesh.

On real hardware this is the entry point per host (jax.distributed
initializes from the TPU environment); on CPU it runs reduced configs for
development.  The same Trainer/steps drive both — only mesh + shardings
differ, which is the property the dry-run certifies.

  python -m repro.launch.train --arch gemma3-1b --reduced --rounds 4 \
      --aggregator ec --members 4 --ckpt /tmp/ec_ckpt --resume
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_nin")
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU development)")
    ap.add_argument("--aggregator", default="ec",
                    choices=["ec", "ma", "sync"])
    ap.add_argument("--protocol", default="ring",
                    choices=["ring", "allgather"])
    ap.add_argument("--label-mode", default="dense",
                    choices=["dense", "topk"])
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=4)
    ap.add_argument("--tau", type=int, default=16)
    ap.add_argument("--p-steps", type=int, default=8)
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--relabel-fraction", type=float, default=0.7)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--per-member", type=int, default=256)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--straggler-drop", type=int, default=0,
                    help="simulate N lagging members dropped per round")
    args = ap.parse_args()

    from repro.common.types import ECConfig
    from repro.configs import registry
    from repro.data import image_member_datasets, lm_member_datasets
    from repro.optim import adamw, sgd_momentum
    from repro.runtime.trainer import Trainer

    cfg = registry.get_config(args.arch, reduced=args.reduced)
    key = jax.random.PRNGKey(args.seed)
    rng = np.random.default_rng(args.seed)

    if cfg.family == "cnn":
        train, test = image_member_datasets(
            key, args.members, args.per_member, n_classes=cfg.vocab_size)
        opt = sgd_momentum(args.lr, momentum=0.9)
    else:
        train, test = lm_member_datasets(
            key, args.members, args.per_member, args.seq_len,
            cfg.vocab_size)
        opt = adamw(args.lr)

    ec = ECConfig(tau=args.tau, lam=args.lam, p_steps=args.p_steps,
                  relabel_fraction=args.relabel_fraction,
                  label_mode=args.label_mode, aggregator=args.aggregator,
                  protocol=args.protocol)
    tr = Trainer(cfg, ec, opt, args.members, key, train, test,
                 batch_size=args.batch, ckpt_dir=args.ckpt, seed=args.seed)
    if args.resume and tr.resume():
        print(f"resumed from round {tr.round}")

    for r in range(tr.round, args.rounds):
        mask = None
        if args.straggler_drop:
            mask = np.ones(args.members)
            drop = rng.choice(args.members, args.straggler_drop,
                              replace=False)
            mask[drop] = 0.0
            print(f"round {r}: dropping stragglers {sorted(drop)}")
        loss = tr.run_round(straggler_mask=mask)
        ev = tr.evaluate()
        print(f"round {r:3d} | train {loss:.4f} | local nll "
              f"{ev['local_loss']:.4f} err {ev['local_err']:.4f} | "
              f"{'ens' if args.aggregator == 'ec' else 'global'} nll "
              f"{ev['global_loss']:.4f} err {ev['global_err']:.4f}")
    tr.save()
    if tr.ckpt:
        tr.ckpt.close()
    best, k = tr.best_member()
    print(f"final model: member {k} (EC-DNN_L rule)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
