"""Dry-run cell construction: (arch x shape x mesh) -> lowerable step.

For every cell this module builds
  - the step function (the same builders the Trainer uses — steps.py),
  - abstract inputs (jax.ShapeDtypeStruct, weak-type-correct, no
    allocation anywhere),
  - in/out shardings (NamedSharding) under the production mesh.

Step per shape kind (DESIGN §5):
  train_4k     ec_local_train_step over member-stacked state (plain-CE
               variant is the roofline row; the distill variant and the
               ring-relabel step are lowered for §Dry-run's protocol
               analysis).
  prefill_32k  single-model forward, last-token logits.
  decode_*     single-model decode_step over a seq_len KV/state cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.sharding import make_param_pspecs
from repro.common.types import (ECConfig, ModelConfig, ParallelConfig,
                                SHAPES, ShapeConfig)
from repro.configs import registry
from repro.optim import adamw
from repro.runtime import steps


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def filter_par(par: ParallelConfig, mesh) -> ParallelConfig:
    """Drop axes the active mesh doesn't have (e.g. 'pod' single-pod)."""
    names = set(mesh.axis_names)
    return dataclasses.replace(
        par,
        batch_axes=tuple(a for a in par.batch_axes if a in names),
        ensemble_axis=par.ensemble_axis if par.ensemble_axis in names
        else ("" if par.ensemble_axis else par.ensemble_axis),
        fsdp_axis=par.fsdp_axis if par.fsdp_axis in names else "",
        seq_axis=par.seq_axis if par.seq_axis in names else "")


def abstract_tree(fn, *args, **kw):
    return jax.eval_shape(fn, *args, **kw)


# ---------------------------------------------------------------------------
# per-arch member counts / batch splits
# ---------------------------------------------------------------------------

def ensemble_k(arch: str, mesh, par: ParallelConfig) -> int:
    if not par.ensemble_axis:
        return max(par.ensemble_size, 1)
    if par.ensemble_size:
        return par.ensemble_size
    return mesh.shape[par.ensemble_axis]


def _grad_accum(arch: str, shape: ShapeConfig, mesh, k: int,
                par: ParallelConfig) -> int:
    """Microbatch so each device step holds ~1-2 sequences of activations."""
    per_member = shape.global_batch // k
    if registry.size_class(arch) == "big":
        data = mesh.shape.get("data", 1)
        return max(1, per_member // data)  # -> microbatch 1/device
    pod = mesh.shape.get("pod", 1)
    # recurrent jnp paths (rwkv) carry fatter per-token state: halve the
    # microbatch for the ssm family
    target = 2 if registry.get_config(arch).family == "ssm" else 4
    return max(1, per_member // (target * pod))


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------

def _lm_batch_sds(cfg: ModelConfig, k: int, b: int, t: int) -> Dict:
    batch: Dict[str, Any] = {}
    lead = (k, b, t) if k else (b, t)
    if cfg.family == "vlm":
        # frontend stub: precomputed patch/text embeddings (M-RoPE backbone)
        batch["embeds"] = sds(lead + (cfg.d_model,), jnp.bfloat16)
    else:
        batch["tokens"] = sds(lead, jnp.int32)
    if cfg.enc_dec:
        enc_lead = (k, b) if k else (b,)
        batch["enc_embeds"] = sds(
            enc_lead + (cfg.enc_max_frames, cfg.d_model), jnp.bfloat16)
    batch["labels"] = sds(lead, jnp.int32)
    return batch


def _batch_pspec(cfg: ModelConfig, par: ParallelConfig, k: int) -> Dict:
    ens = par.ensemble_axis or None
    ba = tuple(par.batch_axes) or None
    lead = (ens, ba) if k else (ba,)
    out: Dict[str, P] = {}
    if cfg.family == "vlm":
        out["embeds"] = P(*lead, None, None)
    else:
        out["tokens"] = P(*lead, None)
    if cfg.enc_dec:
        out["enc_embeds"] = P(*lead, None, None)
    out["labels"] = P(*lead, None)
    return out


# ---------------------------------------------------------------------------
# cache pspecs (decode)
# ---------------------------------------------------------------------------

def cache_pspecs(cfg: ModelConfig, cache: Any, par: ParallelConfig,
                 mesh) -> Any:
    """Name+shape-driven layout for KV/state caches.

    full-attn K/V (B,S,kv,dh): kv heads over "model" when divisible, else
    the sequence dim (seq-sharded KV decode).  MLA latents + SSM states
    shard their channel dim; batch always over the batch role axes.
    """
    ba = tuple(par.batch_axes) or None
    if ba is not None and len(ba) == 1:
        ba = ba[0]  # jax 0.4.x PartitionSpec doesn't canonicalize ('x',)
    msize = mesh.shape[par.model_axis]

    def rule(path, leaf):
        name = ""
        for e in reversed(path):
            if isinstance(e, jax.tree_util.DictKey):
                name = str(e.key)
                break
        nd = leaf.ndim
        if nd == 0:
            return P()
        bspec = ba if (leaf.shape[0] % _axsize(mesh, ba) == 0) else None

        if name in ("k", "v"):  # (B, S, kv, dh)
            if leaf.shape[2] % msize == 0:
                return P(bspec, None, par.model_axis, None)
            if leaf.shape[1] % msize == 0:
                return P(bspec, par.model_axis, None, None)
            return P(bspec, None, None, None)
        if name in ("c_kv", "k_r"):  # (B, S, r)
            return P(bspec, par.model_axis
                     if leaf.shape[1] % msize == 0 else None, None)
        if name == "ssm":  # (B, d_inner, N)
            return P(bspec, par.model_axis, None)
        if name == "conv":  # (B, W-1, d_inner)
            return P(bspec, None, par.model_axis)
        if name == "wkv":  # (B, H, dh, dh)
            return P(bspec, par.model_axis
                     if leaf.shape[1] % msize == 0 else None, None, None)
        if name in ("shift", "cmix_shift", "enc"):  # (B, 1|S, d)
            return P(bspec, None, None)
        if name == "idx":
            return P()
        return P(*([None] * nd))

    def pad_stacked(path, leaf):
        # cache leaves under "segments" have a leading (count,) stack dim
        spec = rule(path, _drop_lead(path, leaf))
        if _is_stacked(path):
            return P(None, *spec)
        return spec

    return jax.tree_util.tree_map_with_path(pad_stacked, cache)


def _axsize(mesh, axes) -> int:
    if not axes:
        return 1
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape.get(a, 1)
    return n


def _is_stacked(path) -> bool:
    for e in path:
        if isinstance(e, jax.tree_util.DictKey) and str(e.key) == "segments":
            return True
    return False


def _drop_lead(path, leaf):
    if _is_stacked(path):
        return jax.ShapeDtypeStruct(leaf.shape[1:], leaf.dtype)
    return leaf


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    step_name: str
    fn: Callable
    args: Tuple
    in_shardings: Tuple
    out_shardings: Any
    meta: Dict[str, Any]
    donate: Tuple[int, ...] = ()  # args donated (state / cache buffers)


def _named(mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def build_train_cell(arch: str, mesh, multi_pod: bool,
                     variant: str = "plain",
                     ec: Optional[ECConfig] = None) -> Cell:
    """variant: plain | distill | relabel."""
    from repro.models import transformer as tf
    shape = SHAPES["train_4k"]
    cfg = registry.get_config(arch)
    par = filter_par(registry.parallel_policy(arch, shape, multi_pod), mesh)
    k = ensemble_k(arch, mesh, par)
    b = shape.global_batch // k
    ec = ec or ECConfig(label_mode="topk", top_m=64)
    accum = _grad_accum(arch, shape, mesh, k, par)

    params = abstract_tree(
        lambda key: jax.vmap(lambda kk: tf.init(kk, cfg))(
            jax.random.split(key, k)), jax.random.PRNGKey(0))
    # bf16 Adam moments for the big archs: optimizer state for a 405B
    # member must fit its 256-chip pod alongside params + activations
    moment_dtype = jnp.bfloat16 \
        if registry.size_class(arch) == "big" else jnp.float32
    opt = adamw(1e-4, moment_dtype=moment_dtype)
    opt_state = abstract_tree(lambda p: jax.vmap(opt.init)(p), params)
    state = {"params": params, "opt": opt_state}

    p_pspec = make_param_pspecs(params, par, ensemble=bool(par.ensemble_axis),
                                mesh=mesh)
    o_pspec = abstract_pspecs_like(opt_state, p_pspec)
    s_pspec = {"params": p_pspec, "opt": o_pspec}
    b_sds = _lm_batch_sds(cfg, k, b, shape.seq_len)
    b_pspec = _batch_pspec(cfg, par, k)

    if variant == "relabel":
        from repro.core import aggregation as agg
        logits_fn = steps.make_logits_fn(cfg)
        m = max(1, int(b * ec.relabel_fraction))
        r_sds = _lm_batch_sds(cfg, k, m, shape.seq_len)

        def fn(p, batch):
            return agg.ring_relabel(mesh, p, batch, logits_fn, ec,
                                    axis=par.ensemble_axis or "data")

        return Cell(arch, shape, "relabel_step", fn,
                    (params, r_sds),
                    (_named(mesh, p_pspec), _named(mesh, b_pspec)),
                    None,
                    {"k": k, "per_member": m, "accum": 1, "par": par})

    step = steps.make_local_step(cfg, opt, par=par, grad_accum=accum)
    if variant == "plain":
        fn = lambda s, bb: step(s, bb, None, 0.0)  # noqa: E731
        args = (state, b_sds)
        in_sh = (_named(mesh, s_pspec), _named(mesh, b_pspec))
        out_sh = (_named(mesh, s_pspec), None)
        return Cell(arch, shape, "train_step[plain]", fn, args, in_sh,
                    out_sh, {"k": k, "per_member": b, "accum": accum,
                             "par": par}, donate=(0,))
    else:  # distill
        from repro.core.compression import TopM
        m_top = ec.top_m
        pseudo = TopM(sds((k, b, shape.seq_len, m_top), jnp.float32),
                      sds((k, b, shape.seq_len, m_top), jnp.int32),
                      sds((k, b, shape.seq_len), jnp.float32))
        ens = par.ensemble_axis or None
        ba = tuple(par.batch_axes) or None
        ps_spec = TopM(P(ens, ba, None, None), P(ens, ba, None, None),
                       P(ens, ba, None))
        fn = lambda s, bb, ps: step(s, bb, ps, 0.25)  # noqa: E731
        args = (state, b_sds, pseudo)
        in_sh = (_named(mesh, s_pspec), _named(mesh, b_pspec),
                 _named(mesh, ps_spec))
        out_sh = (_named(mesh, s_pspec), None)

    return Cell(arch, shape, f"train_step[{variant}]", fn, args, in_sh,
                out_sh, {"k": k, "per_member": b, "accum": accum,
                         "par": par}, donate=(0,))


def build_serve_cell(arch: str, shape_name: str, mesh,
                     multi_pod: bool) -> Cell:
    from repro.models import transformer as tf
    shape = SHAPES[shape_name]
    cfg = registry.get_config(arch)
    par = filter_par(registry.parallel_policy(arch, shape, multi_pod), mesh)
    B = shape.global_batch

    # drop batch axes that don't divide this shape's batch (long_500k B=1)
    if B % _axsize(mesh, tuple(par.batch_axes)) != 0:
        keep = []
        for a in par.batch_axes:
            if B % _axsize(mesh, tuple(keep + [a])) == 0:
                keep.append(a)
        par = dataclasses.replace(par, batch_axes=tuple(keep))

    params = abstract_tree(lambda key: tf.init(key, cfg),
                           jax.random.PRNGKey(0))
    p_pspec = make_param_pspecs(params, par, ensemble=False, mesh=mesh)
    prefill_fn, decode_fn = steps.make_serve_fns(cfg, par)
    ba = tuple(par.batch_axes) or None

    if shape.kind == "prefill":
        b_sds = _lm_batch_sds(cfg, 0, B, shape.seq_len)
        b_sds.pop("labels")
        b_pspec = _batch_pspec(cfg, par, 0)
        b_pspec.pop("labels")
        return Cell(arch, shape, "prefill_step", prefill_fn,
                    (params, b_sds),
                    (_named(mesh, p_pspec), _named(mesh, b_pspec)), None,
                    {"k": 1, "per_member": B, "accum": 1, "par": par})

    # decode: one token against a seq_len cache
    cache = abstract_tree(
        lambda: tf.init_cache(cfg, B, max_seq=shape.seq_len))
    c_pspec = cache_pspecs(cfg, cache, par, mesh)
    tok = sds((B, 1), jnp.int32)
    t_pspec = P(ba, None)
    return Cell(arch, shape, "serve_step", decode_fn,
                (params, cache, tok),
                (_named(mesh, p_pspec), _named(mesh, c_pspec),
                 _named(mesh, t_pspec)),
                (None, _named(mesh, c_pspec)),  # logits free, cache aliased
                {"k": 1, "per_member": B, "accum": 1, "par": par},
                donate=(1,))


def abstract_pspecs_like(opt_state: Any, p_pspec: Any) -> Any:
    """Optimizer-state pspecs: moments mirror their parameter, scalars
    replicate."""
    flat_p, _ = jax.tree_util.tree_flatten(p_pspec)

    def rule(path, leaf):
        # match moment tensors by rank against the param tree by position:
        # m/v/mu subtrees are structurally identical to params.
        for e in path:
            if isinstance(e, jax.tree_util.DictKey) \
                    and str(e.key) in ("m", "v", "mu"):
                sub = jax.tree_util.keystr(path[1:])
                return _lookup_pspec(p_pspec, path[1:], leaf)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(rule, opt_state)


def _lookup_pspec(p_pspec, path, leaf):
    node = p_pspec
    for e in path:
        if isinstance(e, jax.tree_util.DictKey):
            node = node[str(e.key)]
        elif isinstance(e, jax.tree_util.SequenceKey):
            node = node[e.idx]
    return node if isinstance(node, P) else P(*([None] * leaf.ndim))


def build_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
               variant: str = "plain") -> Cell:
    if SHAPES[shape_name].kind == "train":
        return build_train_cell(arch, mesh, multi_pod, variant=variant)
    return build_serve_cell(arch, shape_name, mesh, multi_pod)
