"""Step-function builders shared by the Trainer and the launch layer.

Everything here is a pure function factory: given configs it returns
jit-able functions over (state, batch[, pseudo, lam]).  The Trainer wraps
them with jax.jit for 1-device runs; launch/specs.py lowers the same
functions under the production mesh with explicit in/out shardings — the
dry-run therefore exercises exactly the code that trains.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.common.sharding import layout_ctx
from repro.common.types import ModelConfig, ParallelConfig
from repro.core import distill
from repro.optim import Optimizer


def make_logits_fn(cfg: ModelConfig, remat: bool = False) -> Callable:
    if cfg.family == "cnn":
        from repro.models import cnn
        return lambda params, batch: cnn.nin_apply(params, batch["images"])
    from repro.models import transformer as tf

    def fn(params, batch):
        logits, _ = tf.apply(params, cfg, tokens=batch.get("tokens"),
                             embeds=batch.get("embeds"),
                             enc_embeds=batch.get("enc_embeds"),
                             remat=remat)
        return logits
    return fn


def make_member_loss(cfg: ModelConfig) -> Callable:
    """(params, batch, pseudo, lam) -> scalar Eqn-9 loss (+model aux)."""
    if cfg.family == "cnn":
        from repro.models import cnn

        def cnn_loss(params, batch, pseudo, lam):
            logits = cnn.nin_apply(params, batch["images"])
            reg = sum(jnp.sum(jnp.square(v)) for k, v in params.items()
                      if k.endswith("_w"))
            return distill.mixed_ce(logits, batch["labels"], pseudo,
                                    lam) + 1e-4 * reg
        return cnn_loss

    from repro.models import transformer as tf

    def lm_loss(params, batch, pseudo, lam):
        logits, aux = tf.apply(params, cfg, tokens=batch.get("tokens"),
                               embeds=batch.get("embeds"),
                               enc_embeds=batch.get("enc_embeds"),
                               remat=True)
        return distill.mixed_ce(logits, batch["labels"], pseudo, lam) + aux
    return lm_loss


def make_member_grads(cfg: ModelConfig, grad_accum: int = 1) -> Callable:
    """(params, batch, pseudo, lam) -> (loss, grads), microbatched."""
    member_loss = make_member_loss(cfg)

    def fn(params, batch, pseudo, lam):
        if grad_accum <= 1:
            return jax.value_and_grad(member_loss)(params, batch, pseudo,
                                                   lam)

        def split(t):
            # (B, ...) -> (accum, B/accum, ...) keeping the KEPT batch dim
            # contiguous with the original sharding: device d's rows stay
            # on device d every microstep (reshape (B,)->(accum,B/accum)
            # would move the sharded dim onto `accum` and make scan's
            # per-step slice a cross-device gather).
            return jax.tree.map(
                lambda x: x.reshape((-1, grad_accum) + x.shape[1:])
                .swapaxes(0, 1), t)

        def micro(c, mb):
            b, ps = mb
            l, g = jax.value_and_grad(member_loss)(params, b, ps, lam)
            return (c[0] + l, jax.tree.map(
                lambda acc, gi: acc + gi.astype(jnp.float32), c[1], g)), None

        # f32 accumulators: bf16 += across many microbatches loses bits
        zero = (jnp.zeros(()),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params))
        (l, g), _ = jax.lax.scan(micro, zero, (split(batch), split(pseudo)))
        inv = 1.0 / grad_accum
        return l * inv, jax.tree.map(lambda x: x * inv, g)
    return fn


def make_local_step(cfg: ModelConfig, opt: Optimizer,
                    par: Optional[ParallelConfig] = None,
                    grad_accum: int = 1, sync: bool = False) -> Callable:
    """EC local-training step over member-stacked state.

    (state {params, opt}, batch, pseudo, lam) -> (state, mean loss).
    pseudo=None lowers the plain-CE variant.
    """
    member_grads = make_member_grads(cfg, grad_accum)
    batch_axes = tuple(par.batch_axes) if par is not None else ()
    seq_axis = (par.seq_axis or None) if par is not None else None

    def step(state, batch, pseudo, lam):
        with layout_ctx(batch=batch_axes, seq=seq_axis, train=True):
            losses, grads = jax.vmap(
                lambda p, b, ps: member_grads(p, b, ps, lam))(
                state["params"], batch, pseudo)
        if sync:
            grads = jax.tree.map(
                lambda g: jnp.broadcast_to(g.mean(0, keepdims=True),
                                           g.shape), grads)
        new_params, new_opt = jax.vmap(opt.update)(
            grads, state["opt"], state["params"])
        return {"params": new_params, "opt": new_opt}, losses.mean()
    return step


def make_serve_fns(cfg: ModelConfig, par: Optional[ParallelConfig] = None):
    """(prefill_fn, decode_fn) for single-model serving."""
    from repro.models import transformer as tf
    batch_axes = tuple(par.batch_axes) if par is not None else \
        ("pod", "data")

    def prefill_fn(params, batch):
        with layout_ctx(batch=batch_axes):
            return tf.prefill(params, cfg, tokens=batch.get("tokens"),
                              embeds=batch.get("embeds"),
                              enc_embeds=batch.get("enc_embeds"))

    def decode_fn(params, cache, tokens):
        with layout_ctx(batch=batch_axes):
            return tf.decode_step(params, cfg, cache, tokens)

    return prefill_fn, decode_fn
