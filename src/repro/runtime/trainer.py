"""The EC-DNN trainer: rounds of (local SGD -> aggregate -> distill).

Algorithm 1 of the paper, generalized over aggregator:

  aggregator="ec"   local tau steps; relabel a fraction of D_k with the
                    ensemble (ring or allgather protocol); next round's
                    first p steps minimize Eqn 9 with lambda annealing to 0.
  aggregator="ma"   local tau steps; params <- mean_k params (MA-DNN).
  aggregator="sync" every step all-reduces gradients over the member axis
                    (sync-SGD reference; tau is ignored).

State is member-stacked (leading K) and the same jitted steps serve
1-device tests and the 512-chip dry-run (sharding comes from the in/out
shardings the launcher attaches, plus constrain() hints in model code).

Fault tolerance: checkpoint every round via CheckpointManager (async,
atomic, keep-N); `Trainer.resume()` restores the newest committed round.
Straggler policy: at aggregation time members listed as lagging are
excluded from the ensemble via the quorum mask (renormalized 1/(K-r));
MA mode uses the same mask for the parameter mean.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import models
from repro.common.sharding import layout_ctx
from repro.common.types import ECConfig, ModelConfig
from repro.core import aggregation as agg
from repro.core import compression as comp
from repro.core import distill
from repro.core import ensemble as ens
from repro.data import sample_batch, sample_relabel_subset
from repro.checkpoint import CheckpointManager
from repro.optim import Optimizer
from repro.runtime import steps


@dataclasses.dataclass
class TrainerMetrics:
    round_idx: List[int] = dataclasses.field(default_factory=list)
    local_loss: List[float] = dataclasses.field(default_factory=list)
    global_loss: List[float] = dataclasses.field(default_factory=list)
    compressed_loss: List[float] = dataclasses.field(default_factory=list)
    local_err: List[float] = dataclasses.field(default_factory=list)
    global_err: List[float] = dataclasses.field(default_factory=list)
    compressed_err: List[float] = dataclasses.field(default_factory=list)


class Trainer:
    def __init__(self, cfg: ModelConfig, ec: ECConfig, opt: Optimizer,
                 n_members: int, key, train_shards: dict, test_set: dict,
                 batch_size: int, mesh=None, ckpt_dir: Optional[str] = None,
                 seed: int = 0, grad_accum: int = 1):
        self.cfg, self.ec, self.opt = cfg, ec, opt
        self.K = n_members
        self.mesh = mesh
        self.shards = train_shards
        self.test = test_set
        self.batch = batch_size
        self.grad_accum = grad_accum
        self.rng = np.random.default_rng(seed)
        self.metrics = TrainerMetrics()
        self.ckpt = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
        self.pseudo_buffer = None  # (subset_batch, pseudo_targets)
        self.round = 0

        keys = jax.random.split(key, self.K)
        params = jax.vmap(lambda k: models.init(k, cfg))(keys)
        opt_state = jax.vmap(opt.init)(params)
        self.state = {"params": params, "opt": opt_state}

        self._build_steps()

    # ------------------------------------------------------------------
    # jitted step construction
    # ------------------------------------------------------------------

    def _logits(self, params, batch):
        return steps.make_logits_fn(self.cfg)(params, batch)

    def _member_loss(self, params, batch, pseudo, lam):
        return steps.make_member_loss(self.cfg)(params, batch, pseudo, lam)

    def _build_steps(self):
        opt = self.opt
        plain = steps.make_local_step(self.cfg, opt,
                                      grad_accum=self.grad_accum)
        syncs = steps.make_local_step(self.cfg, opt,
                                      grad_accum=self.grad_accum, sync=True)

        self._plain_step = jax.jit(
            lambda s, b: plain(s, b, None, 0.0), donate_argnums=(0,))
        self._sync_step = jax.jit(
            lambda s, b: syncs(s, b, None, 0.0), donate_argnums=(0,))
        self._distill_step = jax.jit(
            lambda s, b, ps, lam: plain(s, b, ps, lam),
            donate_argnums=(0,))
        self._ma_step = jax.jit(
            lambda s, q: {"params": agg.ma_aggregate(s["params"], q),
                          "opt": s["opt"]})

        def eval_members(params, batch):
            with layout_ctx(batch=()):
                logits = jax.vmap(lambda p: self._logits(p, batch))(params)
            member_nll = ens.mean_member_nll(logits, batch["labels"])
            ens_nll = ens.ensemble_nll(logits, batch["labels"])
            preds = logits.argmax(-1)
            member_err = (preds != batch["labels"][None]).mean()
            ens_pred = ens.ensemble_probs(logits).argmax(-1)
            ens_err = (ens_pred != batch["labels"]).mean()
            return member_nll, ens_nll, member_err, ens_err

        self._eval = jax.jit(eval_members)

        def single_eval(params, batch):
            logits = self._logits(params, batch)
            nll = distill.true_ce(logits, batch["labels"])
            err = (logits.argmax(-1) != batch["labels"]).mean()
            return nll, err

        self._single_eval = jax.jit(single_eval)

    # ------------------------------------------------------------------
    # aggregation
    # ------------------------------------------------------------------

    def _relabel(self, quorum=None):
        """Relabel relabel_fraction of each member's shard -> pseudo buffer."""
        subset, _ = sample_relabel_subset(self.rng, self.shards,
                                          self.ec.relabel_fraction)
        logits_fn = lambda p, b: self._logits(p, b)  # noqa: E731
        if self.mesh is not None and self.ec.protocol == "ring" \
                and self.K > 1:
            pseudo = agg.ring_relabel(self.mesh, self.state["params"],
                                      subset, logits_fn, self.ec,
                                      axis=self.ec_axis(), quorum=quorum)
        else:
            pseudo = jax.jit(
                lambda p, b: agg.allgather_relabel(p, b, logits_fn, self.ec,
                                                   quorum=quorum))(
                self.state["params"], subset)
        self.pseudo_buffer = (subset, pseudo)

    def ec_axis(self) -> str:
        return "data"

    # ------------------------------------------------------------------
    # round loop
    # ------------------------------------------------------------------

    def run_round(self, straggler_mask: Optional[np.ndarray] = None):
        """One full round: tau local steps (first p mixed if a pseudo
        buffer exists), then aggregation per the configured method."""
        ec = self.ec
        for t in range(ec.tau):
            if ec.aggregator == "ec" and self.pseudo_buffer is not None \
                    and t < ec.p_steps:
                lam = distill.lam_schedule(t, ec.lam, ec.p_steps)
                batch, pseudo = self._sample_pseudo_batch()
                self.state, loss = self._distill_step(
                    self.state, batch, pseudo, lam)
            else:
                batch = sample_batch(self.rng, self.shards, self.batch)
                step = self._sync_step if ec.aggregator == "sync" \
                    else self._plain_step
                self.state, loss = step(self.state, batch)

        quorum = None
        if straggler_mask is not None:
            quorum = jnp.asarray(straggler_mask, jnp.float32)
        if ec.aggregator == "ec":
            self._relabel(quorum)
        elif ec.aggregator == "ma":
            self.state = self._ma_step(self.state, quorum)
        self.round += 1
        if self.ckpt is not None:
            self.ckpt.save(self.round, self.state)
        return float(loss)

    def _sample_pseudo_batch(self):
        subset, pseudo = self.pseudo_buffer
        n = jax.tree.leaves(subset)[0].shape[1]
        idx = self.rng.integers(0, n, size=(self.K, self.batch))
        rows = np.arange(self.K)[:, None]
        batch = jax.tree.map(lambda a: a[rows, idx], subset)
        take = lambda a: a[rows, idx]  # noqa: E731
        if isinstance(pseudo, comp.TopM):
            ps = comp.TopM(take(pseudo.vals), take(pseudo.idx),
                           take(pseudo.rest))
        else:
            ps = take(pseudo)
        return batch, ps

    # ------------------------------------------------------------------
    # evaluation / reporting (paper Figures 1-3, Table 1)
    # ------------------------------------------------------------------

    def evaluate(self, record: bool = True) -> Dict[str, float]:
        test_b = jax.tree.map(lambda a: a[:256], self.test)
        m_nll, e_nll, m_err, e_err = self._eval(self.state["params"],
                                                test_b)
        out = {"local_loss": float(m_nll), "global_loss": float(e_nll),
               "local_err": float(m_err), "global_err": float(e_err)}
        if self.ec.aggregator == "ma":
            avg = agg.ma_aggregate(self.state["params"])
            one = jax.tree.map(lambda x: x[0], avg)
            nll, err = self._single_eval(one, test_b)
            out["global_loss"], out["global_err"] = float(nll), float(err)
        if record:
            self.metrics.round_idx.append(self.round)
            self.metrics.local_loss.append(out["local_loss"])
            self.metrics.global_loss.append(out["global_loss"])
            self.metrics.local_err.append(out["local_err"])
            self.metrics.global_err.append(out["global_err"])
        return out

    def evaluate_compressed(self) -> Dict[str, float]:
        """After distill steps, members ARE the compressed models."""
        test_b = jax.tree.map(lambda a: a[:256], self.test)
        m_nll, _, m_err, _ = self._eval(self.state["params"], test_b)
        out = {"compressed_loss": float(m_nll),
               "compressed_err": float(m_err)}
        self.metrics.compressed_loss.append(out["compressed_loss"])
        self.metrics.compressed_err.append(out["compressed_err"])
        return out

    def best_member(self):
        """EC-DNN_L: the member with smallest training loss."""
        batch = sample_batch(self.rng, self.shards, min(self.batch, 64))
        with layout_ctx(batch=()):
            losses = jax.vmap(
                lambda p, b: self._member_loss(p, b, None, 0.0))(
                self.state["params"], batch)
        k = int(jnp.argmin(losses))
        return jax.tree.map(lambda x: x[k], self.state["params"]), k

    # ------------------------------------------------------------------
    # fault tolerance / elasticity
    # ------------------------------------------------------------------

    def save(self):
        if self.ckpt is not None:
            self.ckpt.save(self.round, self.state)
            self.ckpt.wait()

    def resume(self) -> bool:
        if self.ckpt is None:
            return False
        latest = self.ckpt.latest()
        if latest is None:
            return False
        self.state = self.ckpt.restore(self.state, latest)
        self.round = latest
        self.pseudo_buffer = None  # relabel happens at next round boundary
        return True

    def reshard(self, k_new: int, key=None):
        from repro.checkpoint import reshard_members
        self.state = reshard_members(self.state, k_new, perturb=1e-3,
                                     key=key)
        self.shards = reshard_members(self.shards, k_new)
        self.K = k_new
        self.pseudo_buffer = None
        self._build_steps()
