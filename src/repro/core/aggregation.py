"""Distributed aggregation protocols for EC-DNN and the MA baseline.

The paper's aggregation step broadcasts all K models to every worker
(K x |params| bytes over InfiniBand) and evaluates the ensemble locally.
On a TPU mesh that cost model inverts: weights are huge (llama3-405b:
810 GB) while the relabel inputs are tokens (~KBs) and the pseudo-label
accumulators are top-M compressed.  So the TPU-native realization rotates
*data* around the ensemble axis instead of weights:

  ring_relabel (shard_map over the ensemble axis, manual; TP stays auto):
    each shard holds its member's params + its relabel batch + an
    accumulator.  K-1 ppermute hops move (batch, accumulator) to the next
    member; each hop the local member scores the visiting batch and merges
    its (compressed) output distribution into the accumulator.  One final
    hop returns the accumulator home.  Per-link traffic:
    K * (batch_tokens * 4B + acc_bytes)   vs   K * |params| for the naive
    broadcast — a ~10^4-10^6x reduction at LM scale (benchmarks/
    aggregation_cost.py quantifies it per arch).  XLA overlaps the
    collective-permute with the member forward pass (async collectives),
    which is the paper's "relabel concurrently with training" mapped to ICI.

  allgather_relabel (pjit, dense): every member scores every batch via an
    implicit all-gather of the (small) batches; the K x K logits then mean
    over members.  Dense-oracle used by tests and for small vocab.

  ma_aggregate: parameter mean over the member axis — one all-reduce of
    |params| bytes (the MA-DNN baseline's cost AND its failure mode).

Straggler policy: a (K,) 0/1 quorum mask; dropped members contribute
nothing and weights renormalize to 1/(K-r) (ensemble of any subset still
carries the Jensen guarantee — DESIGN §3).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Union

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.common.sharding import axis_size as _axis_size
from repro.common.sharding import shard_map as _shard_map
from repro.common.types import ECConfig
from repro.core import compression as comp
from repro.core import ensemble as ens


# ---------------------------------------------------------------------------
# dense oracle (pjit / single-process)
# ---------------------------------------------------------------------------

def allgather_relabel(stacked_params, batches, logits_fn: Callable,
                      ec: ECConfig,
                      quorum: Optional[jax.Array] = None):
    """-> pseudo-label targets for each member's own batch.

    stacked_params: pytree with leading K; batches: pytree with leading K
    (each member's relabel inputs); logits_fn(params, batch) -> (..., V).
    Returns dense probs (K, ..., V) or TopM with leading K.
    """
    K = jax.tree.leaves(batches)[0].shape[0]

    def member_on_all(p):
        return jax.vmap(lambda b: logits_fn(p, b))(batches)  # (K, ..., V)

    all_logits = jax.vmap(member_on_all)(stacked_params)  # (K_member, K_batch, ..., V)
    probs = ens.ensemble_probs(all_logits, weights=quorum,
                               average_probs=ec.average_probs)  # (K_batch, ..., V)
    if ec.label_mode == "topk":
        return comp.from_dense(probs, ec.top_m)
    return probs


# ---------------------------------------------------------------------------
# ring protocol (shard_map over the ensemble mesh axis)
# ---------------------------------------------------------------------------

def _ring_body(local_params, local_batch, logits_fn, ec: ECConfig,
               axis: str, quorum=None, n_vocab_shards: int = 1):
    """Runs on one shard of the ensemble axis. Leading local dim = 1."""
    K = _axis_size(axis)
    me = jax.lax.axis_index(axis)
    perm = [(i, (i + 1) % K) for i in range(K)]

    p1 = jax.tree.map(lambda x: x[0], local_params)
    b1 = jax.tree.map(lambda x: x[0], local_batch)

    w_me = 1.0 if quorum is None else quorum[me]

    def score(batch):
        """Member's (compressed) output distribution on a visiting batch.

        topk mode scores ONE sequence at a time (lax.map) so the dense
        (m, T, V) f32 distribution never materializes — only the member's
        own (1, T, V) logits are transiently live before the top-M prune.
        At gemma's 262k vocab this is the difference between ~48 GB and
        ~0.3 GB of live relabel state per shard.
        """
        if ec.label_mode != "topk":
            logits = logits_fn(p1, batch).astype(jnp.float32)
            return (jax.nn.softmax(logits, -1) if ec.average_probs
                    else logits) * w_me

        def one(b_seq):
            b1x = jax.tree.map(lambda x: x[None], b_seq)
            lg = logits_fn(p1, b1x).astype(jnp.float32)[0]
            out = (jax.nn.softmax(lg, -1) if ec.average_probs else lg) \
                * w_me
            # distributed top-M: per-vocab-shard top-k, merge candidates
            # (avoids all-gathering the (T, V) distribution)
            return comp.from_dense_sharded(out, ec.top_m, n_vocab_shards)

        return jax.lax.map(one, batch)

    def merge(acc, contribution):
        if ec.label_mode == "topk":
            return comp.merge(acc, contribution)
        return acc + contribution

    # hop 0: score own batch
    acc = score(b1)

    def hop(carry, _):
        batch, acc = carry
        batch = jax.tree.map(
            lambda x: jax.lax.ppermute(x, axis, perm), batch)
        acc = jax.tree.map(lambda x: jax.lax.ppermute(x, axis, perm), acc)
        acc = merge(acc, score(batch))
        return (batch, acc), None

    (b_out, acc), _ = jax.lax.scan(hop, (b1, acc), None, length=K - 1)
    # final hop returns the accumulator home (batch no longer needed)
    acc = jax.tree.map(lambda x: jax.lax.ppermute(x, axis, perm), acc)

    denom = jnp.float32(K) if quorum is None else jnp.maximum(
        quorum.sum(), 1.0)
    if ec.label_mode == "topk":
        out = comp.scale(acc, 1.0 / denom)
        out = comp.TopM(*[x[None] for x in out])  # restore leading local dim
    else:
        out = (acc / denom)[None]
    return out


def ring_relabel(mesh, stacked_params, batches, logits_fn: Callable,
                 ec: ECConfig, axis: str = "data",
                 quorum: Optional[jax.Array] = None,
                 extra_manual_axes=(), model_axis: str = "model"):
    """shard_map-launched ring relabel. Returns per-member pseudo targets
    with leading K, sharded like the inputs over `axis`."""
    n_vocab = mesh.shape.get(model_axis, 1)
    body = functools.partial(_ring_body, logits_fn=logits_fn, ec=ec,
                             axis=axis, quorum=quorum,
                             n_vocab_shards=n_vocab)
    in_specs = (P(axis), P(axis))
    if ec.label_mode == "topk":
        out_specs = comp.TopM(P(axis), P(axis), P(axis))
    else:
        out_specs = P(axis)
    manual = {axis, *extra_manual_axes}
    return _shard_map(
        lambda p, b: body(p, b), mesh, in_specs=in_specs,
        out_specs=out_specs, axis_names=manual, check_vma=False)(
            stacked_params, batches)


# ---------------------------------------------------------------------------
# MA baseline + sync-SGD baseline helpers
# ---------------------------------------------------------------------------

def ma_aggregate(stacked_params, quorum: Optional[jax.Array] = None):
    return ens.ma_average(stacked_params, weights=quorum)


def psum_gradients(grads, axis: str):
    """sync-SGD baseline: all-reduce mean of grads over the member axis."""
    return jax.tree.map(lambda g: jax.lax.pmean(g, axis), grads)
