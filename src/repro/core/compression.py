"""Top-M sparse pseudo-label accumulators.

The paper relabels CIFAR (100 classes) with dense ensemble outputs.  At LM
scale a dense per-token distribution is V floats (gemma3: 262k -> 1 MB/token
fp32), which would make the aggregation step weight-broadcast-expensive —
exactly what EC-DNN set out to avoid.  So the ring protocol carries a
*top-M merge-and-prune accumulator*: per token, the M largest (prob, index)
pairs seen so far plus a scalar `rest` holding the pruned mass.

Merge is associative up to pruning; the pruned mass is tracked exactly, so
the accumulated distribution always sums to the true total and the L1 error
vs the dense oracle is bounded by the pruned mass (property-tested in
tests/test_aggregation.py).

Layout: vals (..., M) f32 descending, idx (..., M) i32, rest (..., ) f32.
Padding entries have idx = -1, val = 0.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class TopM(NamedTuple):
    vals: jax.Array   # (..., M) f32, descending
    idx: jax.Array    # (..., M) i32, -1 = empty
    rest: jax.Array   # (...,)  pruned probability mass


def from_dense(probs: jax.Array, m: int) -> TopM:
    """probs (..., V) -> TopM keeping the M heaviest classes."""
    vals, idx = jax.lax.top_k(probs, m)
    rest = probs.sum(-1) - vals.sum(-1)
    return TopM(vals.astype(jnp.float32), idx.astype(jnp.int32),
                rest.astype(jnp.float32))


def from_dense_sharded(probs: jax.Array, m: int, n_shards: int,
                       shard_axis: str = "model") -> TopM:
    """Distributed top-M: local top-M per vocab shard, then a tiny merge.

    lax.top_k over a vocab dimension that is model-sharded makes GSPMD
    all-gather the full (..., V) tensor first (for gemma3's 262k vocab
    that is ~1 GB/token-batch of ICI traffic).  Reshaping to
    (..., n_shards, V/n) with the shard dim constrained onto the same mesh
    axis makes each shard's top-M local; only (..., n_shards*M) candidates
    (a ~2000x smaller tensor) cross the network for the final global
    top-M.  Exact same result as from_dense (the global top-M is always a
    subset of the per-shard top-Ms).
    """
    from repro.common.sharding import constrain
    V = probs.shape[-1]
    if V % n_shards or (V // n_shards) < m:
        return from_dense(probs, m)
    vs = V // n_shards
    p = probs.reshape(probs.shape[:-1] + (n_shards, vs))
    p = constrain(p, *([None] * (probs.ndim - 1)), shard_axis, None)
    lv, li = jax.lax.top_k(p, m)                      # local, no gather
    li = li + jnp.arange(n_shards, dtype=jnp.int32)[:, None] * vs
    cand_v = lv.reshape(probs.shape[:-1] + (n_shards * m,))
    cand_i = li.reshape(probs.shape[:-1] + (n_shards * m,))
    gv, gpos = jax.lax.top_k(cand_v, m)
    gi = jnp.take_along_axis(cand_i, gpos, axis=-1)
    rest = probs.sum(-1) - gv.sum(-1)
    return TopM(gv.astype(jnp.float32), gi.astype(jnp.int32),
                rest.astype(jnp.float32))


def zeros(batch_shape, m: int) -> TopM:
    return TopM(jnp.zeros(batch_shape + (m,), jnp.float32),
                jnp.full(batch_shape + (m,), -1, jnp.int32),
                jnp.zeros(batch_shape, jnp.float32))


def merge(a: TopM, b: TopM) -> TopM:
    """Union the 2M candidates, keep the M heaviest, demote the rest.

    Duplicate indices are combined first (segment-sum over the union) so a
    class present in both inputs is counted once with summed mass.
    """
    m = a.vals.shape[-1]
    vals = jnp.concatenate([a.vals, b.vals], -1)          # (..., 2M)
    idx = jnp.concatenate([a.idx, b.idx], -1)

    # combine duplicates: sort by idx, segment-sum runs of equal idx
    order = jnp.argsort(idx, axis=-1)
    idx_s = jnp.take_along_axis(idx, order, -1)
    vals_s = jnp.take_along_axis(vals, order, -1)
    first = jnp.concatenate(
        [jnp.ones_like(idx_s[..., :1], bool),
         idx_s[..., 1:] != idx_s[..., :-1]], -1)
    # run sums via cumsum differences: value of a run = csum at its end
    # minus csum at the previous run's end (csum nondecreasing: vals >= 0)
    csum = jnp.cumsum(vals_s, -1)
    run_end = jnp.concatenate([first[..., 1:],
                               jnp.ones_like(first[..., :1])], -1)
    prev_end = jnp.concatenate(
        [jnp.zeros_like(csum[..., :1]),
         jnp.where(run_end, csum, 0.0)[..., :-1]], -1)
    prev_end = jax.lax.associative_scan(jnp.maximum, prev_end, axis=-1)
    cand_vals = jnp.where(run_end, csum - prev_end, 0.0)
    cand_idx = jnp.where(run_end, idx_s, -1)
    cand_vals = jnp.where(cand_idx < 0, 0.0, cand_vals)

    keep_vals, pos = jax.lax.top_k(cand_vals, m)
    keep_idx = jnp.take_along_axis(cand_idx, pos, -1)
    dropped = cand_vals.sum(-1) - keep_vals.sum(-1)
    return TopM(keep_vals, jnp.where(keep_vals > 0, keep_idx, -1),
                a.rest + b.rest + dropped)


def scale(t: TopM, s) -> TopM:
    return TopM(t.vals * s, t.idx, t.rest * s)


def to_dense(t: TopM, vocab: int, spread_rest: bool = False) -> jax.Array:
    """Expand to (..., V). spread_rest distributes pruned mass uniformly."""
    flat_idx = jnp.where(t.idx < 0, vocab, t.idx)  # park empties off-range
    dense = jnp.zeros(t.vals.shape[:-1] + (vocab + 1,), jnp.float32)
    dense = _scatter_add_lastdim(dense, flat_idx, t.vals)[..., :vocab]
    if spread_rest:
        dense = dense + t.rest[..., None] / vocab
    return dense


def _scatter_add_lastdim(dense, idx, vals):
    flat_dense = dense.reshape(-1, dense.shape[-1])
    flat_idx = idx.reshape(-1, idx.shape[-1])
    flat_vals = vals.reshape(-1, vals.shape[-1])
    rows = jnp.arange(flat_dense.shape[0])[:, None]
    flat_dense = flat_dense.at[rows, flat_idx].add(flat_vals)
    return flat_dense.reshape(dense.shape)


def normalize(t: TopM) -> TopM:
    total = t.vals.sum(-1) + t.rest
    inv = 1.0 / jnp.maximum(total, 1e-30)
    return TopM(t.vals * inv[..., None], t.idx, t.rest * inv)


def l1_error_bound(t: TopM) -> jax.Array:
    """Guaranteed bound on ||topm - dense_oracle||_1: 2x pruned mass."""
    return 2.0 * t.rest


def bytes_per_token(m: int) -> int:
    """Wire size of one token's accumulator entry (f32 val + i32 idx)."""
    return m * 8 + 4
