"""Compression-phase loss (paper Eqn 9) and the lambda schedule.

  L = CE(f(x), y_true) + lambda * CE(f(x), y_pseudo)

y_pseudo is the ensemble output distribution — dense (..., V) probs for the
faithful CIFAR path, or a TopM sparse accumulator for LM vocabs.  lambda
anneals linearly from lam0 to 0 over p steps (paper: lam0=0.5, p=tau/2), so
the compression phase *is* the start of the next local-training phase — no
extra wall-clock beyond the relabel forward pass.

The dense dual-CE is also implemented as a fused Pallas kernel
(kernels/distill_loss.py) that streams vocab tiles through VMEM, computing
both CE terms in one pass over the logits; `mixed_ce` dispatches through
kernels/ops.py (impl="pallas" on TPU, pure-jnp here).
"""
from __future__ import annotations

from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import compression as comp


def lam_schedule(step_in_round: jax.Array, lam0: float,
                 p_steps: int) -> jax.Array:
    """Linear anneal lam0 -> 0 over p steps, 0 afterwards (Section 4.3)."""
    if p_steps <= 0:
        return jnp.zeros_like(jnp.asarray(step_in_round, jnp.float32))
    frac = 1.0 - jnp.asarray(step_in_round, jnp.float32) / p_steps
    return lam0 * jnp.clip(frac, 0.0, 1.0)


def pseudo_ce_dense(logits: jax.Array, pseudo_probs: jax.Array) -> jax.Array:
    """-sum_c p̄_c log softmax(logits)_c, mean over tokens."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -(pseudo_probs * logp).sum(-1).mean()


def pseudo_ce_topm(logits: jax.Array, t: comp.TopM) -> jax.Array:
    """Sparse CE against a TopM target.

    Only the kept classes contribute (the pruned mass's CE contribution is
    unknowable post-compression); targets are renormalized over the kept
    entries so the loss stays a proper CE up to the documented L1 bound.
    """
    t = comp.normalize(t)
    kept = t.vals.sum(-1)
    w = t.vals / jnp.maximum(kept[..., None], 1e-30)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    safe_idx = jnp.maximum(t.idx, 0)
    gathered = jnp.take_along_axis(logp, safe_idx, axis=-1)
    gathered = jnp.where(t.idx < 0, 0.0, gathered)
    return -(w * gathered).sum(-1).mean()


def true_ce(logits: jax.Array, labels: jax.Array,
            mask: Optional[jax.Array] = None) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    if mask is not None:
        return -(gold * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return -gold.mean()


def mixed_ce(logits: jax.Array, labels: jax.Array,
             pseudo: Union[jax.Array, comp.TopM, None],
             lam: jax.Array, impl: str = "auto") -> jax.Array:
    """Eqn 9. pseudo=None or lam==0 degrades to plain CE."""
    ce = true_ce(logits, labels)
    if pseudo is None:
        return ce
    if isinstance(pseudo, comp.TopM):
        return ce + lam * pseudo_ce_topm(logits, pseudo)
    if impl in ("pallas", "auto"):
        from repro.kernels import ops
        if ops.pallas_enabled() or impl == "pallas":
            # fused kernel computes CE_true + lam*CE_pseudo in one pass
            return ops.fused_distill_loss(logits, labels, pseudo, lam)
    return ce + lam * pseudo_ce_dense(logits, pseudo)
