"""Model aggregation G(w_1..w_K): ensemble (Eqn 6) vs model-average (Eqn 3).

The paper's central observation, in code:
  - `ensemble_probs` averages member OUTPUTS.  Every standard loss is convex
    in the output distribution, so by Jensen
        L(G_E(x), y) <= (1/K) sum_k L(f(w_k; x), y)
    — `jensen_gap` returns the (always >= 0) slack, and
    tests/test_guarantee.py property-checks it.
  - `ma_average` averages member PARAMETERS.  No such bound exists for
    non-convex f; benchmarks/fig12.py reproduces the paper's Figure 1
    failure mode (MA global worse than the mean local model).

All functions take a leading member axis K and are pure jnp — they run
unchanged inside pjit (K = stacked dim) or inside a shard_map body
(K = local members per shard).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def member_log_probs(logits: jax.Array) -> jax.Array:
    return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)


def ensemble_probs(member_logits: jax.Array,
                   weights: Optional[jax.Array] = None,
                   average_probs: bool = True) -> jax.Array:
    """(K, ..., V) member logits -> (..., V) ensemble distribution.

    average_probs=True is the paper's Eqn 6 (mean of softmax outputs);
    False averages logits first (geometric-mean ensemble) — supported as a
    beyond-paper variant, NOT the default.
    `weights` (K,) reweights members (straggler-drop renormalization);
    they are normalized to sum 1.
    """
    K = member_logits.shape[0]
    w = jnp.ones((K,), jnp.float32) if weights is None else weights
    w = w / jnp.maximum(w.sum(), 1e-9)
    wb = w.reshape((K,) + (1,) * (member_logits.ndim - 1))
    if average_probs:
        p = jax.nn.softmax(member_logits.astype(jnp.float32), axis=-1)
        return (p * wb).sum(axis=0)
    lg = (member_logits.astype(jnp.float32) * wb).sum(axis=0)
    return jax.nn.softmax(lg, axis=-1)


def quorum_weights(mask: jax.Array) -> jax.Array:
    """(K,) 0/1 liveness mask -> normalized member weights.

    Dropped members get exactly 0 weight and the rest renormalize to
    1/(K-r) — the straggler policy of core/aggregation.py, reused by the
    serving engine so a slow/dead member degrades the ensemble to the
    surviving subset (which still carries the Jensen guarantee).
    An all-zero quorum falls back to uniform rather than dividing by 0.
    """
    m = mask.astype(jnp.float32)
    alive = m.sum()
    return jnp.where(alive > 0, m / jnp.maximum(alive, 1.0),
                     jnp.ones_like(m) / m.shape[0])


def ensemble_log_probs(member_logits: jax.Array,
                       weights: Optional[jax.Array] = None,
                       member_lp: Optional[jax.Array] = None) -> jax.Array:
    """(K, ..., V) member logits -> (..., V) LOG of the Eqn-6 mixture.

    log sum_k w_k softmax(z_k) computed with logsumexp — the log-space
    twin of ensemble_probs (exp of this matches it to float tolerance)
    used on the serving hot path: batched over arbitrary middle dims,
    quorum-weighted, and safe to feed straight into categorical sampling
    or argmax without the +eps clamp a probs->log round-trip needs.
    Zero-weight members contribute -inf mass, i.e. exactly nothing.
    member_lp: optionally pass member_log_probs(member_logits) if the
    caller needs the per-member log-probs anyway (the speculative
    verify shares one pass between fusion and the pruning test).
    """
    K = member_logits.shape[0]
    w = jnp.ones((K,), jnp.float32) / K if weights is None \
        else weights / jnp.maximum(weights.sum(), 1e-9)
    logw = jnp.log(jnp.maximum(w, 1e-30)).reshape(
        (K,) + (1,) * (member_logits.ndim - 1))
    lp = member_log_probs(member_logits) if member_lp is None \
        else member_lp
    return jax.nn.logsumexp(lp + logw, axis=0)


def ensemble_log_probs_psum(member_logits: jax.Array,
                            weights: Optional[jax.Array] = None,
                            axis_name: str = "member") -> jax.Array:
    """Cross-device Eqn-6 fusion for a member-sharded ensemble.

    The shard_map twin of `ensemble_log_probs`: `member_logits` is the
    LOCAL (K_local, ..., V) shard of the member axis and `weights` the
    matching local slice of the global (K,) quorum vector.  Each device
    fuses its own members in log space, then the shards combine with one
    pmax + one psum over `axis_name` — so only fused (..., V) partials
    cross devices, never K full distributions:

        log sum_k w_k softmax(z_k)
          = m + log( psum_d sum_{k in d} exp(log w_k + log p_k - m) ),
        m = pmax_d max_{k in d} (log w_k + log p_k)

    Weight normalization is global (psum of the local weight mass), so
    quorum semantics — zero-weight members contribute exactly nothing,
    survivors renormalize — match the single-device path.  On a 1-device
    mesh the collectives are identity and this reduces to the
    logsumexp reference bit-for-bit (tested in tests/test_serving_mesh).
    """
    K = member_logits.shape[0]
    w = jnp.ones((K,), jnp.float32) if weights is None else weights
    w_sum = jax.lax.psum(w.sum(), axis_name)
    w = w / jnp.maximum(w_sum, 1e-9)
    logw = jnp.log(jnp.maximum(w, 1e-30)).reshape(
        (K,) + (1,) * (member_logits.ndim - 1))
    lp = member_log_probs(member_logits) + logw
    m = jax.lax.pmax(lp.max(axis=0), axis_name)
    s = jax.lax.psum(jnp.exp(lp - m[None]).sum(axis=0), axis_name)
    return m + jnp.log(s)


def prunable_members(member_logits: jax.Array,
                     fused_log_probs: jax.Array,
                     weights: Optional[jax.Array] = None,
                     member_lp: Optional[jax.Array] = None) -> jax.Array:
    """Members whose entire vote mass cannot flip the fused argmax.

    Speculative verify only needs the fused GREEDY choice per position,
    so a member j is skippable at a position when the mixture minus j's
    contribution, base_j = T - w_j softmax(z_j), already has a top-1
    margin larger than j's whole weight w_j: whatever distribution j
    voted, T = base_j + w_j p_j keeps argmax(T) == argmax(base_j).

    member_logits: (K_local, ..., V) — under shard_map, the LOCAL member
    shard; fused_log_probs: (..., V) the ALREADY-fused (globally psum'd
    on a mesh) Eqn-6 log distribution; weights: the matching local slice
    of the NORMALIZED (K,) quorum vector (None = uniform 1/K over the
    local axis — single-device only); member_lp: optionally the
    member_log_probs(member_logits) a caller already computed for the
    fusion, sparing this test its own softmax pass over (K, ..., V).
    -> (K_local, ...) bool mask.

    Purely local math — T is shared, each device tests only its own
    members, no extra collectives — so the mask composes with the
    quorum vector (zero-weight members are always prunable: their gap
    exceeds a zero mass) and the shard_map member mesh for free.  It is
    a TRACED mask: inside the one fused verify kernel it cannot shrink
    compute, but it prices the skip — a sequential or multi-pass verify
    consumes it directly, and the serving engine surfaces the prunable
    fraction as acceptance telemetry.
    """
    K = member_logits.shape[0]
    w = jnp.full((K,), 1.0 / K, jnp.float32) if weights is None \
        else weights.astype(jnp.float32)
    wb = w.reshape((K,) + (1,) * (fused_log_probs.ndim - 1))
    T = jnp.exp(fused_log_probs.astype(jnp.float32))[None]
    p = jnp.exp(member_lp) if member_lp is not None \
        else jax.nn.softmax(member_logits.astype(jnp.float32), axis=-1)
    base = jnp.maximum(T - wb[..., None] * p, 0.0)
    # top-2 via two masked maxes: lax.top_k is a full sort on CPU and
    # dominates the verify kernel at serving sizes
    m1 = base.max(axis=-1)
    i1 = base.argmax(axis=-1)
    masked = jnp.where(
        jax.nn.one_hot(i1, base.shape[-1], dtype=bool), -jnp.inf, base)
    gap = m1 - masked.max(axis=-1)
    return gap > wb


def ensemble_nll(member_logits: jax.Array, labels: jax.Array,
                 weights: Optional[jax.Array] = None) -> jax.Array:
    """Cross-entropy of the ensemble distribution against int labels."""
    p = ensemble_probs(member_logits, weights)
    gold = jnp.take_along_axis(p, labels[..., None], axis=-1)[..., 0]
    return -jnp.log(jnp.maximum(gold, 1e-30)).mean()


def mean_member_nll(member_logits: jax.Array, labels: jax.Array) -> jax.Array:
    lp = member_log_probs(member_logits)
    gold = jnp.take_along_axis(
        lp, jnp.broadcast_to(labels, member_logits.shape[:-1])[..., None],
        axis=-1)[..., 0]
    return -gold.mean(axis=tuple(range(1, gold.ndim))).mean()


def jensen_gap(member_logits: jax.Array, labels: jax.Array) -> jax.Array:
    """mean_k L(f_k) - L(ensemble)  — provably >= 0 (paper Eqns 4-5)."""
    return mean_member_nll(member_logits, labels) \
        - ensemble_nll(member_logits, labels)


# ---------------------------------------------------------------------------
# MA baseline
# ---------------------------------------------------------------------------

def ma_average(stacked_params, weights: Optional[jax.Array] = None):
    """Parameter mean over the leading member axis, re-broadcast to K.

    Under pjit with the member axis sharded, the mean lowers to one
    all-reduce over the ensemble axis — the classic MA-DNN aggregation —
    and the broadcast back is free (result is replicated).
    """
    def avg(w):
        K = w.shape[0]
        if weights is None:
            m = w.mean(axis=0, keepdims=True)
        else:
            ww = weights / jnp.maximum(weights.sum(), 1e-9)
            m = (w * ww.reshape((K,) + (1,) * (w.ndim - 1))).sum(
                axis=0, keepdims=True)
        return jnp.broadcast_to(m, w.shape).astype(w.dtype)

    return jax.tree.map(avg, stacked_params)
