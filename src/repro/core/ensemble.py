"""Model aggregation G(w_1..w_K): ensemble (Eqn 6) vs model-average (Eqn 3).

The paper's central observation, in code:
  - `ensemble_probs` averages member OUTPUTS.  Every standard loss is convex
    in the output distribution, so by Jensen
        L(G_E(x), y) <= (1/K) sum_k L(f(w_k; x), y)
    — `jensen_gap` returns the (always >= 0) slack, and
    tests/test_guarantee.py property-checks it.
  - `ma_average` averages member PARAMETERS.  No such bound exists for
    non-convex f; benchmarks/fig12.py reproduces the paper's Figure 1
    failure mode (MA global worse than the mean local model).

All functions take a leading member axis K and are pure jnp — they run
unchanged inside pjit (K = stacked dim) or inside a shard_map body
(K = local members per shard).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def member_log_probs(logits: jax.Array) -> jax.Array:
    return jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)


def ensemble_probs(member_logits: jax.Array,
                   weights: Optional[jax.Array] = None,
                   average_probs: bool = True) -> jax.Array:
    """(K, ..., V) member logits -> (..., V) ensemble distribution.

    average_probs=True is the paper's Eqn 6 (mean of softmax outputs);
    False averages logits first (geometric-mean ensemble) — supported as a
    beyond-paper variant, NOT the default.
    `weights` (K,) reweights members (straggler-drop renormalization);
    they are normalized to sum 1.
    """
    K = member_logits.shape[0]
    w = jnp.ones((K,), jnp.float32) if weights is None else weights
    w = w / jnp.maximum(w.sum(), 1e-9)
    wb = w.reshape((K,) + (1,) * (member_logits.ndim - 1))
    if average_probs:
        p = jax.nn.softmax(member_logits.astype(jnp.float32), axis=-1)
        return (p * wb).sum(axis=0)
    lg = (member_logits.astype(jnp.float32) * wb).sum(axis=0)
    return jax.nn.softmax(lg, axis=-1)


def quorum_weights(mask: jax.Array) -> jax.Array:
    """(K,) 0/1 liveness mask -> normalized member weights.

    Dropped members get exactly 0 weight and the rest renormalize to
    1/(K-r) — the straggler policy of core/aggregation.py, reused by the
    serving engine so a slow/dead member degrades the ensemble to the
    surviving subset (which still carries the Jensen guarantee).
    An all-zero quorum falls back to uniform rather than dividing by 0.
    """
    m = mask.astype(jnp.float32)
    alive = m.sum()
    return jnp.where(alive > 0, m / jnp.maximum(alive, 1.0),
                     jnp.ones_like(m) / m.shape[0])


def ensemble_log_probs(member_logits: jax.Array,
                       weights: Optional[jax.Array] = None) -> jax.Array:
    """(K, ..., V) member logits -> (..., V) LOG of the Eqn-6 mixture.

    log sum_k w_k softmax(z_k) computed with logsumexp — the log-space
    twin of ensemble_probs (exp of this matches it to float tolerance)
    used on the serving hot path: batched over arbitrary middle dims,
    quorum-weighted, and safe to feed straight into categorical sampling
    or argmax without the +eps clamp a probs->log round-trip needs.
    Zero-weight members contribute -inf mass, i.e. exactly nothing.
    """
    K = member_logits.shape[0]
    w = jnp.ones((K,), jnp.float32) / K if weights is None \
        else weights / jnp.maximum(weights.sum(), 1e-9)
    logw = jnp.log(jnp.maximum(w, 1e-30)).reshape(
        (K,) + (1,) * (member_logits.ndim - 1))
    lp = member_log_probs(member_logits)
    return jax.nn.logsumexp(lp + logw, axis=0)


def ensemble_log_probs_psum(member_logits: jax.Array,
                            weights: Optional[jax.Array] = None,
                            axis_name: str = "member") -> jax.Array:
    """Cross-device Eqn-6 fusion for a member-sharded ensemble.

    The shard_map twin of `ensemble_log_probs`: `member_logits` is the
    LOCAL (K_local, ..., V) shard of the member axis and `weights` the
    matching local slice of the global (K,) quorum vector.  Each device
    fuses its own members in log space, then the shards combine with one
    pmax + one psum over `axis_name` — so only fused (..., V) partials
    cross devices, never K full distributions:

        log sum_k w_k softmax(z_k)
          = m + log( psum_d sum_{k in d} exp(log w_k + log p_k - m) ),
        m = pmax_d max_{k in d} (log w_k + log p_k)

    Weight normalization is global (psum of the local weight mass), so
    quorum semantics — zero-weight members contribute exactly nothing,
    survivors renormalize — match the single-device path.  On a 1-device
    mesh the collectives are identity and this reduces to the
    logsumexp reference bit-for-bit (tested in tests/test_serving_mesh).
    """
    K = member_logits.shape[0]
    w = jnp.ones((K,), jnp.float32) if weights is None else weights
    w_sum = jax.lax.psum(w.sum(), axis_name)
    w = w / jnp.maximum(w_sum, 1e-9)
    logw = jnp.log(jnp.maximum(w, 1e-30)).reshape(
        (K,) + (1,) * (member_logits.ndim - 1))
    lp = member_log_probs(member_logits) + logw
    m = jax.lax.pmax(lp.max(axis=0), axis_name)
    s = jax.lax.psum(jnp.exp(lp - m[None]).sum(axis=0), axis_name)
    return m + jnp.log(s)


def ensemble_nll(member_logits: jax.Array, labels: jax.Array,
                 weights: Optional[jax.Array] = None) -> jax.Array:
    """Cross-entropy of the ensemble distribution against int labels."""
    p = ensemble_probs(member_logits, weights)
    gold = jnp.take_along_axis(p, labels[..., None], axis=-1)[..., 0]
    return -jnp.log(jnp.maximum(gold, 1e-30)).mean()


def mean_member_nll(member_logits: jax.Array, labels: jax.Array) -> jax.Array:
    lp = member_log_probs(member_logits)
    gold = jnp.take_along_axis(
        lp, jnp.broadcast_to(labels, member_logits.shape[:-1])[..., None],
        axis=-1)[..., 0]
    return -gold.mean(axis=tuple(range(1, gold.ndim))).mean()


def jensen_gap(member_logits: jax.Array, labels: jax.Array) -> jax.Array:
    """mean_k L(f_k) - L(ensemble)  — provably >= 0 (paper Eqns 4-5)."""
    return mean_member_nll(member_logits, labels) \
        - ensemble_nll(member_logits, labels)


# ---------------------------------------------------------------------------
# MA baseline
# ---------------------------------------------------------------------------

def ma_average(stacked_params, weights: Optional[jax.Array] = None):
    """Parameter mean over the leading member axis, re-broadcast to K.

    Under pjit with the member axis sharded, the mean lowers to one
    all-reduce over the ensemble axis — the classic MA-DNN aggregation —
    and the broadcast back is free (result is replicated).
    """
    def avg(w):
        K = w.shape[0]
        if weights is None:
            m = w.mean(axis=0, keepdims=True)
        else:
            ww = weights / jnp.maximum(weights.sum(), 1e-9)
            m = (w * ww.reshape((K,) + (1,) * (w.ndim - 1))).sum(
                axis=0, keepdims=True)
        return jnp.broadcast_to(m, w.shape).astype(w.dtype)

    return jax.tree.map(avg, stacked_params)
