"""Core configuration dataclasses for the repro framework.

Everything is a frozen dataclass so configs are hashable and safe to close
over in jit'd functions.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class AttnConfig:
    kind: str = "gqa"  # "gqa" | "mla"
    n_heads: int = 8
    n_kv_heads: int = 8
    head_dim: int = 128
    rope_theta: float = 10_000.0
    # sliding-window attention (None/0 => full attention)
    window: int = 0
    qk_norm: bool = False
    # M-RoPE (qwen2-vl): section split of the rotary half-dim
    mrope_sections: Optional[Tuple[int, ...]] = None
    # MLA (deepseek-v2)
    kv_lora_rank: int = 512
    q_lora_rank: int = 0  # 0 => full-rank q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # sinusoidal absolute positions instead of RoPE (whisper)
    use_rope: bool = True

    @property
    def q_dim(self) -> int:
        if self.kind == "mla":
            return self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
        return self.n_heads * self.head_dim

    @property
    def kv_groups(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)


@dataclass(frozen=True)
class FFNConfig:
    d_ff: int = 0
    mlp_type: str = "swiglu"  # "swiglu" | "geglu" | "gelu"
    # MoE (only read when a LayerSpec says ffn="moe")
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    moe_d_ff: int = 0  # expert hidden size (defaults to d_ff)
    dense_residual_ff: int = 0  # arctic-style always-on dense FFN in parallel
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    @property
    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff


@dataclass(frozen=True)
class SSMConfig:
    # mamba
    d_state: int = 16
    expand: int = 2
    dt_rank: int = 0  # 0 => d_model // 16
    conv_width: int = 4
    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_lora_decay: int = 64
    rwkv_lora_mix: int = 32


@dataclass(frozen=True)
class LayerSpec:
    """What one transformer block is made of."""

    mixer: str  # "attn" | "attn_local" | "mamba" | "rwkv"
    ffn: str = "dense"  # "dense" | "moe" | "rwkv_cmix"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio | cnn
    n_layers: int
    d_model: int
    vocab_size: int
    attn: AttnConfig = AttnConfig()
    ffn: FFNConfig = FFNConfig()
    ssm: SSMConfig = SSMConfig()
    # Repeating per-layer pattern; tiled to cover n_layers (remainder allowed).
    pattern: Tuple[LayerSpec, ...] = (LayerSpec("attn", "dense"),)
    # leading dense layers before the pattern starts (deepseek-v2 style)
    first_dense_layers: int = 0
    # rope theta for "attn_local" layers (gemma3 uses 10k local / 1M global)
    local_rope_theta: float = 10_000.0
    local_window: int = 0
    tie_embeddings: bool = False
    # encoder-decoder (whisper): n_layers is the decoder depth
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_max_frames: int = 1500
    # "tokens" | "embeds" (VLM/audio stub frontends feed embeddings directly)
    input_mode: str = "tokens"
    max_seq: int = 8192
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # embedding scale (gemma multiplies by sqrt(d_model))
    scale_embeddings: bool = False
    logit_softcap: float = 0.0

    def layer_specs(self) -> Tuple[LayerSpec, ...]:
        lead = (LayerSpec(self.pattern[0].mixer, "dense"),) \
            * self.first_dense_layers
        n = self.n_layers - self.first_dense_layers
        reps = -(-n // len(self.pattern))  # ceil
        return lead + (self.pattern * reps)[:n]

    def segments(self) -> Tuple[Tuple[int, Tuple[LayerSpec, ...]], ...]:
        """Split layers into (count, period_specs) scan segments.

        n_layers = [first_dense] + count * len(pattern) + remainder; the
        remainder becomes a trailing count=1 segment so the apply path is
        uniform.
        """
        segs = []
        if self.first_dense_layers:
            segs.append((self.first_dense_layers,
                         (LayerSpec(self.pattern[0].mixer, "dense"),)))
        p = len(self.pattern)
        full, rem = divmod(self.n_layers - self.first_dense_layers, p)
        if full:
            segs.append((full, self.pattern))
        if rem:
            segs.append((1, self.pattern[:rem]))
        return tuple(segs)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    """How a model is laid out on the mesh."""

    # mesh axis (or tuple of axes) the EC ensemble dimension is sharded over
    ensemble_axis: str = "data"
    ensemble_size: int = 0  # 0 => size of ensemble_axis in the active mesh
    # axis for FSDP-style parameter sharding inside one ensemble member
    # ("" => params replicated within the member, TP only)
    fsdp_axis: str = ""
    model_axis: str = "model"
    # batch sharding axes for the per-member batch dim
    batch_axes: Tuple[str, ...] = ()
    # shard long sequences over this axis for decode/prefill (SP)
    seq_axis: str = ""
    remat: bool = True
    # microbatches for gradient accumulation (1 = no accumulation)
    grad_accum: int = 1


@dataclass(frozen=True)
class ECConfig:
    """The paper's hyper-parameters (Section 4/5)."""

    tau: int = 40  # local SGD steps between aggregations
    lam: float = 0.5  # initial combination coefficient (Eqn 9)
    p_steps: int = 20  # compression steps (paper: tau/2); lambda anneals to 0
    relabel_fraction: float = 0.7  # paper relabels 70% of D_k
    # pseudo-label accumulator: "dense" (exact) | "topk" (merge-prune)
    label_mode: str = "dense"
    top_m: int = 64  # accumulator width in topk mode
    aggregator: str = "ec"  # "ec" | "ma" | "sync" (baselines)
    protocol: str = "ring"  # "ring" | "allgather"
    # average probabilities (paper Eqn 6) or logits
    average_probs: bool = True
    # straggler policy: members whose heartbeat lags get dropped this round
    straggler_drop_max: int = 0
