"""Path-based PartitionSpec rules for model parameter pytrees.

Every parameter in the framework has a standardized leaf name (see
models/*.py); `pspec_for` maps (leaf-name, rank) -> PartitionSpec under a
ParallelConfig.  `make_param_pspecs` walks an abstract param tree and returns
a matching pytree of NamedShardings/PartitionSpecs.

Conventions (TP = `model` axis, FSDP = optional `fsdp` axis):
  - column-parallel weights (d_model, X): P(fsdp, "model")   [shard output dim]
  - row-parallel weights  (X, d_model):  P("model", fsdp)    [shard input dim]
  - embeddings (V, d): vocab over "model", d over fsdp
  - per-expert weights (E, ...): experts over "model" (EP)
  - norms / small lora mats: replicated
Stacked scan segments add a leading None; the EC ensemble adds a leading
ensemble-axis dim on top of that.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.types import ParallelConfig

# leaf-name -> role
_COLUMN = {
    "w_q", "w_k", "w_v", "w_gate", "w_up", "mamba_in", "rwkv_r", "rwkv_k",
    "rwkv_v", "rwkv_g", "cmix_k", "q_up", "kv_up", "w_cross_q",
}
_ROW = {"w_o", "w_down", "mamba_out", "rwkv_o", "cmix_v"}
_EXPERT_COLUMN = {"experts_gate", "experts_up"}
_EXPERT_ROW = {"experts_down"}
_EMBED = {"embed", "head", "enc_embed"}
_REPLICATED_PREFIXES = (
    "norm", "bias", "router", "rwkv_mix", "rwkv_decay", "rwkv_first",
    "mamba_dt", "mamba_A", "mamba_D", "mamba_conv", "q_down", "kv_down",
    "k_rope", "qk_scale", "alibi", "pos",
)


def pspec_for(name: str, ndim: int, par: ParallelConfig) -> P:
    m, f = par.model_axis, (par.fsdp_axis or None)

    def pad(spec_tail):
        # left-pad with None for stacked-segment leading dims
        lead = ndim - len(spec_tail)
        return P(*([None] * lead), *spec_tail)

    if any(name.startswith(p) for p in _REPLICATED_PREFIXES):
        return P(*([None] * ndim))
    if name in _EMBED:
        return pad((m, f))
    if name in _EXPERT_COLUMN:
        return pad((m, f, None))
    if name in _EXPERT_ROW:
        return pad((m, None, f))
    if name in _COLUMN:
        return pad((f, m))
    if name in _ROW:
        return pad((m, f))
    # conservative default: replicate
    return P(*([None] * ndim))


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def make_param_pspecs(params: Any, par: ParallelConfig,
                      ensemble: bool = False, mesh=None) -> Any:
    """Pytree of PartitionSpecs matching `params` (abstract or concrete).

    With `mesh`, specs are sanitized: an axis whose size doesn't divide
    the dimension is dropped (jit in_shardings require divisibility —
    e.g. whisper's 51865 vocab can't split 16 ways, so it replicates).
    """
    def axsize(a):
        if isinstance(a, (tuple, list)):
            n = 1
            for x in a:
                n *= mesh.shape.get(x, 1)
            return n
        return mesh.shape.get(a, 1)

    def sanitize(spec, shape):
        if mesh is None:
            return spec
        clean = []
        for dim, a in zip(shape, tuple(spec) + (None,) * len(shape)):
            clean.append(a if (a is None or dim % axsize(a) == 0) else None)
        return P(*clean)

    def rule(path, leaf):
        name = _leaf_name(path)
        ens_axis = par.ensemble_axis if ensemble else None
        spec = pspec_for(name, leaf.ndim - (1 if ensemble else 0), par)
        if ensemble:
            spec = P(ens_axis or None, *spec)
        return sanitize(spec, leaf.shape)

    return jax.tree_util.tree_map_with_path(rule, params)


def make_shardings(mesh, pspecs: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def _axis_ok(axis, names) -> bool:
    if axis is None:
        return True
    if isinstance(axis, (tuple, list)):
        return all(a in names for a in axis)
    return axis in names


# ---------------------------------------------------------------------------
# layout context: symbolic axes resolved at trace time
# ---------------------------------------------------------------------------
# Model code names *roles* ("batch"); the step function decides what mesh
# axes that role maps to.  EC ensemble training maps "batch" to () because
# the member axis is carried by the stacked leading dim, while single-model
# serving maps it to ("pod", "data").

import contextlib
import threading

BATCH = "batch"  # sentinel usable in constrain() specs
REP = "__replicate__"  # force replication of a dim (None means "free")

_layout = threading.local()


def _layout_map() -> dict:
    return getattr(_layout, "map", {"batch": ("pod", "data"),
                                    "seq": None, "train": False})


def layout_flag(name: str) -> bool:
    return bool(_layout_map().get(name))


@contextlib.contextmanager
def layout_ctx(**roles):
    """layout_ctx(batch=("data",)) remaps symbolic axes inside the block."""
    old = _layout_map()
    _layout.map = {**old, **roles}
    try:
        yield
    finally:
        _layout.map = old


def _resolve(axis):
    if isinstance(axis, str) and axis in _layout_map():
        v = _layout_map()[axis]
        return tuple(v) if isinstance(v, (tuple, list)) else v
    return axis


def ambient_mesh():
    """The mesh constrain() honors, across jax versions.

    jax >= 0.5 installs an *abstract* mesh via jax.sharding.set_mesh and
    exposes it with get_abstract_mesh().  jax 0.4.x has neither public
    API: fall back to the pjit thread-resources mesh that `with mesh:`
    installs.  Returns None when off-mesh (constrain becomes a no-op).
    """
    get_am = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_am is not None:
        m = get_am()
        return None if m is None or m.empty else m
    from jax._src import mesh as _mesh_lib
    m = getattr(_mesh_lib, "get_abstract_mesh", lambda: None)()
    abstract_cls = getattr(jax.sharding, "AbstractMesh", ())
    if abstract_cls and isinstance(m, abstract_cls):
        return m
    env = _mesh_lib.thread_resources.env.physical_mesh
    return None if env.empty else env


def set_mesh(mesh):
    """Version-portable jax.sharding.set_mesh (context manager).

    On jax 0.4.x a Mesh is itself the context manager that installs the
    thread-resources env ambient_mesh() falls back to.
    """
    sm = getattr(jax.sharding, "set_mesh", None)
    return sm(mesh) if sm is not None else mesh


def make_mesh(axis_shapes, axis_names, auto: bool = True):
    """Version-portable jax.make_mesh with all-Auto axis types.

    jax >= 0.5 wants explicit axis_types for sharding-in-types; 0.4.x
    has neither the kwarg nor the enum — plain make_mesh is all-auto.
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(axis_shapes, axis_names)
    kinds = (axis_type.Auto if auto else axis_type.Explicit,)
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=kinds * len(axis_names))


def shard_map(f, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """Version-portable jax.shard_map.

    `axis_names` lists the MANUAL axes (jax >= 0.6 kwarg); on 0.4.x it
    maps to `auto` = every mesh axis not named, and check_vma to the old
    check_rep.
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as sm04
    auto = frozenset() if axis_names is None \
        else frozenset(mesh.axis_names) - set(axis_names)
    return sm04(f, mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma, auto=auto)


# ---------------------------------------------------------------------------
# serving member-axis placement
# ---------------------------------------------------------------------------
# The serving engine's unit of parallelism is the ensemble MEMBER (paper
# Eqn 6: the global model is K independent members, so the member axis is
# embarrassingly parallel at test time).  Stacked params, the KV cache
# pool, and the quorum vector all carry a leading (K,) axis; these
# helpers place that axis over the "member" mesh axis and leave
# everything else replicated ("data" is reserved for slot/batch
# sharding, a ROADMAP follow-up).

MEMBER_AXIS = "member"
DATA_AXIS = "data"


def member_pspec(ndim: int, axis: str = MEMBER_AXIS) -> P:
    """PartitionSpec sharding a leaf's leading member axis, rest replicated."""
    return P(axis, *([None] * (ndim - 1)))


def member_pspecs(tree: Any, axis: str = MEMBER_AXIS) -> Any:
    """Pytree of PartitionSpecs matching `tree`: every leaf's leading
    (K,) member axis shards over `axis`, all other dims replicate.

    This is the serving twin of `make_param_pspecs(..., ensemble=True)`:
    at serving time members never communicate during the forward pass
    (only fused log-probs cross devices, see core.ensemble
    .ensemble_log_probs_psum), so intra-member TP/FSDP axes are left
    unsharded and the member axis carries all the parallelism.
    """
    return jax.tree.map(lambda x: member_pspec(x.ndim, axis), tree)


def replicated_pspecs(tree: Any) -> Any:
    """Pytree of all-None PartitionSpecs (fully replicated leaves)."""
    return jax.tree.map(lambda x: P(*([None] * x.ndim)), tree)


def local_mesh(member: int = 1, data: int = 1,
               axis_names: Tuple[str, str] = (MEMBER_AXIS, DATA_AXIS)):
    """Build a (member, data) mesh from this process's devices,
    degrading gracefully to whatever is available.

    Unlike `make_mesh` (which insists the grid uses every device), this
    takes the FIRST member*data local devices — and when the host has
    fewer, clamps each axis down (member first) so the same shard_map
    code path still runs: a 1-CPU CI box asking for `local_mesh(2, 1)`
    gets a 1x1 mesh and exercises the exact program the 2-device run
    compiles, psum collectives included.  Force N host devices on CPU
    with XLA_FLAGS=--xla_force_host_platform_device_count=N (set before
    jax initializes).
    """
    import numpy as np
    devs = jax.devices()
    member = max(1, min(int(member), len(devs)))
    data = max(1, min(int(data), len(devs) // member))
    grid = np.asarray(devs[: member * data]).reshape(member, data)
    return jax.sharding.Mesh(grid, axis_names)


def parse_mesh_arg(arg: str):
    """'MxD' CLI string -> local_mesh(M, D); '' / '1x1' -> None (the
    unsharded single-device reference path)."""
    if not arg or arg.lower() in ("1x1", "none", "off"):
        return None
    try:
        m, d = (int(x) for x in arg.lower().split("x"))
    except ValueError:
        raise ValueError(f"--mesh wants 'MxD' (e.g. 2x1), got {arg!r}")
    if m * d <= 1:
        return None
    return local_mesh(m, d)


def axis_size(axis: str) -> int:
    """Version-portable jax.lax.axis_size inside shard_map/pmap bodies.

    0.4.x predates lax.axis_size; psum of a unit constant is the classic
    idiom and constant-folds to a Python int.
    """
    fn = getattr(jax.lax, "axis_size", None)
    return fn(axis) if fn is not None else jax.lax.psum(1, axis)


def mesh_axis_size(axis: str) -> int:
    """Size of a mesh axis at trace time (1 off-mesh / absent)."""
    mesh = ambient_mesh()
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.axis_sizes)).get(axis, 1)


def constrain(x, *spec):
    """with_sharding_constraint that degrades to a no-op off-mesh.

    Axes absent from the active mesh are dropped (so model code can always
    name its ideal layout and still run on 1 CPU device in tests), and
    symbolic role axes (BATCH/seq) resolve through layout_ctx.

    Unnamed dims become P.UNCONSTRAINED, NOT None: a None dim in a
    sharding constraint means "force replicated", which silently destroys
    the propagated batch sharding (measured: 30 GiB/device attention
    scores on arctic prefill before this distinction).  Model code that
    says constrain(x, None, None, "model") means "pin TP on this dim,
    leave the rest to propagation" — and that is what this emits.
    """
    mesh = ambient_mesh()
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    U = P.UNCONSTRAINED
    spec = tuple(_resolve(a) for a in spec)
    clean = tuple(
        None if a == REP
        else (a if (a is not None and a != () and _axis_ok(a, names))
              else U)
        for a in spec)
    if x.ndim < len(clean):  # decode paths reuse prefill constraints
        clean = clean[: x.ndim]
    clean = clean + (U,) * (x.ndim - len(clean))
    if all(c is U for c in clean):
        return x
    return jax.lax.with_sharding_constraint(x, P(*clean))
