"""Per-kernel benchmark: correctness sweep + VMEM/roofline accounting.

This container executes Pallas in interpret mode (no wall-clock value),
so each kernel reports its STRUCTURAL numbers for the TPU target instead:
tile shapes, VMEM working set, FLOPs, HBM bytes, arithmetic intensity,
and the v5e roofline bound implied (compute- vs bandwidth-limited) —
plus an allclose check against ref.py at benchmark shapes.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

VMEM_BYTES = 128 * 2 ** 20  # v5e VMEM per core


def report(name, flops, hbm, vmem, err, note=""):
    ai = flops / max(hbm, 1)
    bound = "compute" if ai > PEAK_FLOPS_BF16 / HBM_BW else "bandwidth"
    ok = "OK " if vmem < VMEM_BYTES else "OVER"
    print(f"  {name:34s} flops={flops:9.3e} hbm={hbm:9.3e} "
          f"AI={ai:7.1f} ({bound}-bound) vmem={vmem/2**20:6.1f}MiB[{ok}] "
          f"max_err={err:.2e} {note}")


def bench_flash(fast):
    from repro.kernels.flash_attention import flash_attention
    shapes = [(1, 512, 8, 2, 128, 128, 128)] if fast else [
        (1, 512, 8, 2, 128, 128, 128),
        (1, 1024, 4, 1, 256, 128, 128),   # gemma-like kv=1
        (2, 512, 16, 16, 64, 128, 256),
    ]
    for B, T, H, Hkv, dh, bq, bk in shapes:
        q = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, dh),
                              jnp.bfloat16)
        k = jax.random.normal(jax.random.PRNGKey(1), (B, T, Hkv, dh),
                              jnp.bfloat16)
        v = jax.random.normal(jax.random.PRNGKey(2), (B, T, Hkv, dh),
                              jnp.bfloat16)
        got = flash_attention(q, k, v, bq=bq, bk=bk)
        want = ref.attention(q, k, v)
        err = float(jnp.abs(got.astype(jnp.float32)
                            - want.astype(jnp.float32)).max())
        flops = 4.0 * B * H * T * T * dh / 2  # causal half
        hbm = 2 * (B * T * H * dh + 2 * B * T * Hkv * dh)
        vmem = (bq * dh + 2 * bk * dh) * 4 + bq * bk * 4 \
            + bq * dh * 4 + 2 * bq * 4
        report(f"flash_attn B{B} T{T} H{H}/{Hkv} dh{dh}", flops, hbm,
               vmem, err, f"tiles=({bq},{bk})")


def bench_paged_decode(fast):
    """Decode-shaped attention (q_len=1, long KV): the serving engine's
    hottest read.  Three implementations at the same shape:

      dense decode   — the contiguous engine's per-step read: the full
                       masked max_seq row (ref.attention semantics)
      paged gather   — ref.paged_attention: same O(max_seq) reads, page
                       indirection only (the CPU reference path)
      paged kernel   — kernels/paged_attention.py: walks only the live
                       pages, so HBM reads scale with len, not max_seq

    The reported HBM figures make the win visible structurally: the
    kernel's read volume is live/max_seq of the dense row.  allclose is
    checked against ref.attention's last causal row (the oracle the
    kernel test suite pins)."""
    from repro.kernels.paged_attention import paged_attention as pk
    shapes = [(4, 2048, 128, 64, 8, 2, 64)] if fast else [
        (4, 2048, 128, 64, 8, 2, 64),
        (8, 8192, 256, 128, 4, 1, 128),   # gemma-like kv=1, long budget
        (2, 4096, 512, 64, 16, 16, 64),   # MHA-shaped (MLA-expanded)
    ]
    rng = np.random.default_rng(0)
    for B, S_max, live, page, H, Hkv, dh in shapes:
        P = S_max // page
        n_pages = B * (live // page) + 1
        kp = rng.normal(size=(n_pages, page, Hkv, dh)).astype(np.float32)
        vp = rng.normal(size=(n_pages, page, Hkv, dh)).astype(np.float32)
        q = rng.normal(size=(B, H, dh)).astype(np.float32)
        table = np.full((B, P), n_pages, np.int32)
        ids = rng.permutation(n_pages - 1)
        per = live // page
        for b in range(B):
            table[b, :per] = ids[b * per:(b + 1) * per]
        lens = np.full((B,), live, np.int32)
        got = pk(jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
                 jnp.asarray(table), jnp.asarray(lens))
        t = np.minimum(table[0], n_pages - 1)
        k0 = kp[t].reshape(S_max, Hkv, dh)[None, :live]
        v0 = vp[t].reshape(S_max, Hkv, dh)[None, :live]
        qf = np.zeros((1, live, H, dh), np.float32)
        qf[0, -1] = q[0]
        want = ref.attention(jnp.asarray(qf), jnp.asarray(k0),
                             jnp.asarray(v0))[0, -1]
        err = float(jnp.abs(got[0] - want).max())
        flops = 4.0 * B * H * live * dh
        hbm_dense = 4 * 2 * B * S_max * Hkv * dh   # full masked row, f32
        hbm_paged = 4 * 2 * B * live * Hkv * dh    # live pages only
        vmem = (H // Hkv * dh + 2 * page * dh) * 4 \
            + (H // Hkv) * (dh + 2) * 4
        report(f"paged_decode B{B} S{S_max} len{live} pg{page}", flops,
               hbm_paged, vmem, err,
               f"dense reads {hbm_dense/2**20:.1f}MiB -> paged "
               f"{hbm_paged/2**20:.1f}MiB ({S_max/live:.0f}x fewer)")

        # int8 variant: quantize the same pages per-token/per-head, feed
        # the kernel the int8 planes + f32 scale sidecars, compare with
        # the f32 answer above.  DMA moves 1-byte K/V elements plus one
        # f32 scale per (token, head) — ~4x fewer bytes at dh=64.
        from repro.models.attention import kv_quantize
        kq, ks = kv_quantize(jnp.asarray(kp), jnp.int8)
        vq, vs = kv_quantize(jnp.asarray(vp), jnp.int8)
        got_q = pk(jnp.asarray(q), kq, vq, jnp.asarray(table),
                   jnp.asarray(lens), k_scale=ks, v_scale=vs)
        err_q = float(jnp.abs(got_q[0] - want).max())
        hbm_int8 = B * live * Hkv * (2 * 1 * dh + 2 * 4)  # planes+scales
        report(f"paged_decode int8 B{B} S{S_max} len{live}", flops,
               hbm_int8, vmem, err_q,
               f"f32 reads {hbm_paged/2**20:.2f}MiB -> int8 "
               f"{hbm_int8/2**20:.2f}MiB "
               f"({hbm_paged/hbm_int8:.1f}x fewer)")


def bench_distill(fast):
    from repro.kernels.distill_loss import fused_distill_loss
    shapes = [(256, 8192, 256, 512)] if fast else [
        (256, 8192, 256, 512), (512, 128256, 256, 512),
        (128, 262144, 128, 512)]
    for n, v, bn, bv in shapes:
        logits = jax.random.normal(jax.random.PRNGKey(0), (n, v)) * 2
        labels = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, v)
        pseudo = jax.nn.softmax(
            jax.random.normal(jax.random.PRNGKey(2), (n, v)))
        got = float(fused_distill_loss(logits, labels, pseudo,
                                       jnp.float32(0.5), bn, bv))
        want = float(ref.distill_loss(logits, labels, pseudo, 0.5))
        flops = 6.0 * n * v
        hbm_fused = 2 * 4 * n * v          # one read of logits+pseudo
        vmem = bn * bv * 8 + bn * (4 * 4 + 4)
        report(f"distill_loss N{n} V{v}", flops, hbm_fused, vmem,
               abs(got - want),
               f"vs 2-pass: {2*hbm_fused/hbm_fused:.1f}x logit reads saved")


def bench_wkv(fast):
    from repro.kernels.wkv6 import wkv6
    shapes = [(1, 256, 4, 64, 32)] if fast else [
        (1, 256, 4, 64, 32), (2, 512, 8, 64, 32)]
    for B, T, H, dh, ch in shapes:
        mk = lambda i: jax.random.normal(jax.random.PRNGKey(i),  # noqa
                                         (B, T, H, dh))
        r, k, v = mk(0), mk(1), mk(2)
        lw = -jnp.exp(mk(3).clip(-3, 1))
        u = mk(4)[:, 0, :, :][0] * 0.3
        s0 = jnp.zeros((B, H, dh, dh))
        y, sT = wkv6(r, k, v, lw, u, s0, chunk=ch)
        yr, sr = ref.wkv6(r, k, v, lw, u, s0)
        err = float(jnp.abs(y - yr).max())
        flops = B * H * T * (2 * ch * dh + 4 * dh * dh)
        hbm = 4 * 4 * B * T * H * dh + 2 * 4 * B * H * dh * dh
        vmem = (4 * ch * dh + dh * dh + ch * ch * dh) * 4
        report(f"wkv6 B{B} T{T} H{H} dh{dh} ch{ch}", flops, hbm, vmem, err)


def bench_ssm(fast):
    from repro.kernels.ssm_scan import ssm_scan
    shapes = [(1, 256, 128, 16, 64, 128)] if fast else [
        (1, 256, 128, 16, 64, 128), (2, 512, 512, 16, 64, 256)]
    for B, T, D, N, ch, bd in shapes:
        a = jnp.exp(-jnp.abs(jax.random.normal(jax.random.PRNGKey(0),
                                               (B, T, D, N))))
        b = jax.random.normal(jax.random.PRNGKey(1), (B, T, D, N)) * 0.2
        h0 = jnp.zeros((B, D, N))
        hs, hT = ssm_scan(a, b, h0, chunk=ch, bd=bd)
        hr, hTr = ref.ssm_scan(a, b, h0)
        err = float(jnp.abs(hs - hr).max())
        flops = 3.0 * B * T * D * N
        hbm = 4 * (2 * B * T * D * N + B * T * D * N)  # a,b in; hs out
        vmem = (2 * ch * bd * N + bd * N) * 4
        report(f"ssm_scan B{B} T{T} D{D} N{N}", flops, hbm, vmem, err)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args(argv)
    print("# kernel benchmarks (interpret-mode correctness + v5e "
          "structural roofline)")
    bench_flash(args.fast)
    bench_paged_decode(args.fast)
    bench_distill(args.fast)
    bench_wkv(args.fast)
    bench_ssm(args.fast)
    return 0


if __name__ == "__main__":
    main()
