"""Render the §Roofline table from dry-run JSON results.

  python -m benchmarks.roofline --in results/dryrun_single.json
"""
from __future__ import annotations

import argparse
import json


def fmt_row(r) -> str:
    if r["status"] == "skip":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | skip |"
                f" {r['reason']} |")
    if r["status"] == "fail":
        return (f"| {r['arch']} | {r['shape']} | — | — | — | — | FAIL |"
                f" {r['error'][:60]} |")
    tc, tm, tl = r["t_compute_s"], r["t_memory_s"], r["t_collective_s"]
    return ("| {arch} | {shape} | {gib:.1f}{fit} | {tc:.3g} | {tm:.3g} | "
            "{tl:.3g} | {dom} | {ratio:.2f} |").format(
        arch=r["arch"], shape=r["shape"],
        gib=r["bytes_per_device"] / 2 ** 30,
        fit="" if r["fits_hbm"] else "!",
        tc=tc, tm=tm, tl=tl, dom=r["dominant"],
        ratio=r.get("useful_flops_ratio", 0.0))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--in", dest="inp", required=False,
                    default="results/dryrun_single.json")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args(argv)
    try:
        recs = json.load(open(args.inp))
    except FileNotFoundError:
        print(f"(no dry-run results at {args.inp}; run "
              f"python -m repro.launch.dryrun --all --out {args.inp})")
        return 0
    print("| arch | shape | GiB/dev | t_comp(s) | t_mem(s) | t_coll(s) "
          "| dominant | 6ND/HLO |")
    print("|---|---|---|---|---|---|---|---|")
    for r in recs:
        print(fmt_row(r))
    ok = [r for r in recs if r["status"] == "ok"]
    if ok:
        fits = sum(r["fits_hbm"] for r in ok)
        print(f"\n{len(ok)} compiled, {fits} fit 16 GiB HBM; "
              f"{sum(r['status'] == 'skip' for r in recs)} documented skips;"
              f" {sum(r['status'] == 'fail' for r in recs)} failures")
    return 0


if __name__ == "__main__":
    main()
