"""Paper Table 1: final test error of EC-DNN vs MA-DNN (vs S-DNN).

Trains EC / MA / sequential (K=1) under identical budgets on the synthetic
CIFAR-100 stand-in and reports EC_L, EC_G, MA_L, MA_G, S-DNN test errors.
The claim validated is the ORDERING (EC_G < EC_L <= S and EC_* < MA_*),
not the absolute numbers (synthetic data; see EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Timer, make_data, make_trainer, std_parser


def run(rounds: int, tau: int, K: int, seed: int = 0) -> dict:
    key = jax.random.PRNGKey(seed)
    train, test = make_data(key, K)
    out = {}
    for aggr in ("ec", "ma"):
        tr = make_trainer(aggr, K, tau, key, train, test, seed=seed)
        for _ in range(rounds):
            tr.run_round()
        ev = tr.evaluate(record=False)
        out[f"{aggr.upper()}-DNN_L"] = ev["local_err"]
        out[f"{aggr.upper()}-DNN_G"] = ev["global_err"]
    # S-DNN: one worker, same total budget (rounds*tau steps, all data)
    flat_train = jax.tree.map(
        lambda a: a.reshape((1, -1) + a.shape[2:]), train)
    tr = make_trainer("ec", 1, tau, key, flat_train, test, seed=seed)
    tr.ec = tr.ec.__class__(**{**tr.ec.__dict__, "aggregator": "ma"})
    for _ in range(rounds):
        tr.run_round()
    out["S-DNN"] = tr.evaluate(record=False)["local_err"]
    return out


def main(argv=None):
    ap = std_parser(__doc__)
    args = ap.parse_args(argv)
    rounds = 3 if args.fast else args.rounds
    tau = 6 if args.fast else args.tau
    t = Timer()
    print(f"# Table 1 (synthetic stand-in) K={args.members} tau={tau} "
          f"rounds={rounds}")
    res = run(rounds, tau, args.members, args.seed)
    for k, v in res.items():
        print(f"  {k:10s} test error = {v:.4f}")
    if args.fast:
        print(f"  (fast mode: {rounds * tau} steps is mechanics-checking "
              f"only; ordering claims need --full / EXPERIMENTS.md "
              f"§Faithful)  ({t():.1f}s)")
    else:
        ec_beats_ma = (res["EC-DNN_L"] <= res["MA-DNN_L"] + 0.02
                       and res["EC-DNN_G"] <= res["MA-DNN_G"] + 0.02)
        print(f"  ordering EC<=MA: {'OK' if ec_beats_ma else 'VIOLATED'} "
              f"({t():.1f}s)")
    return res


if __name__ == "__main__":
    main()
