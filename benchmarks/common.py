"""Shared harness for the paper-table benchmarks."""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.common.types import ECConfig, ModelConfig
from repro.data import image_member_datasets
from repro.optim import sgd_momentum
from repro.runtime.trainer import Trainer


def cnn_cfg() -> ModelConfig:
    return ModelConfig(name="nin-bench", family="cnn", n_layers=9,
                       d_model=96, vocab_size=20)


def make_trainer(aggr: str, K: int, tau: int, key, train, test,
                 label_mode: str = "dense", lr: float = 0.05,
                 seed: int = 0) -> Trainer:
    cfg = cnn_cfg()
    ec = ECConfig(tau=tau, lam=0.5, p_steps=max(tau // 2, 1),
                  relabel_fraction=0.7, label_mode=label_mode,
                  aggregator=aggr)
    return Trainer(cfg, ec, sgd_momentum(lr, momentum=0.9), K, key, train,
                   test, batch_size=32, seed=seed)


def make_data(key, K: int, per_member: int = 512, n_classes: int = 20,
              img: int = 16):
    return image_member_datasets(key, K, per_member, n_classes=n_classes,
                                 img=img, noise=0.6)


def std_parser(desc: str) -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=desc)
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--tau", type=int, default=10)
    ap.add_argument("--members", type=int, default=4)
    ap.add_argument("--fast", action="store_true",
                    help="CI-sized run (fewer rounds/steps)")
    ap.add_argument("--seed", type=int, default=0)
    return ap


class Timer:
    def __init__(self):
        self.t0 = time.time()

    def __call__(self) -> float:
        return time.time() - self.t0
