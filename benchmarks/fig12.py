"""Paper Figures 1-2: per-round global-vs-local gap, MA vs EC.

Figure 1 (MA): the parameter-averaged global model is frequently WORSE
than the mean local model (paper: >40% of rounds, up to +40pp error).
Figure 2 (EC): the ensemble global model is better in EVERY round
(Jensen), and the compressed model retains most of the gain.

This benchmark trains both and reports:
  - %% rounds where MA global is worse than the local mean,
  - EC's per-round (local - global) gap (must be >= 0 for nll),
  - EC's compressed-model gap after the distill phase.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Timer, make_data, make_trainer, std_parser


def main(argv=None):
    ap = std_parser(__doc__)
    args = ap.parse_args(argv)
    rounds = 3 if args.fast else max(args.rounds, 4)
    tau = 4 if args.fast else args.tau
    key = jax.random.PRNGKey(args.seed)
    K = args.members
    train, test = make_data(key, K)
    t = Timer()

    ma = make_trainer("ma", K, tau, key, train, test, seed=args.seed)
    ma_gaps = []
    for _ in range(rounds):
        ma.run_round()
        ev = ma.evaluate()
        ma_gaps.append(ev["local_err"] - ev["global_err"])
    ma_bad = float(np.mean([g < 0 for g in ma_gaps]))

    ec = make_trainer("ec", K, tau, key, train, test, seed=args.seed)
    ec_gaps, ec_nll_gaps, comp_gaps = [], [], []
    for _ in range(rounds):
        ec.run_round()
        ev = ec.evaluate()
        ec_gaps.append(ev["local_err"] - ev["global_err"])
        ec_nll_gaps.append(ev["local_loss"] - ev["global_loss"])
        before = ev["local_err"]
        ec.run_round()  # distill phase happens at the head of this round
        comp = ec.evaluate_compressed()
        comp_gaps.append(before - comp["compressed_err"])

    print(f"# Fig 1/2 stand-in  K={K} tau={tau} rounds={rounds}")
    print(f"  MA: global worse than local mean in {ma_bad:.0%} of rounds "
          f"(gaps: {[f'{g:+.3f}' for g in ma_gaps]})")
    print(f"  EC: nll gap (local - ensemble) per round: "
          f"{[f'{g:+.3f}' for g in ec_nll_gaps]}")
    print(f"  EC: err gap per round: {[f'{g:+.3f}' for g in ec_gaps]}")
    print(f"  EC: compressed-model err gain vs pre-distill local: "
          f"{[f'{g:+.3f}' for g in comp_gaps]}")
    jensen_ok = all(g >= -1e-6 for g in ec_nll_gaps)
    print(f"  Jensen (EC ensemble nll <= mean local nll) every round: "
          f"{'OK' if jensen_ok else 'VIOLATED'}  ({t():.1f}s)")
    return {"ma_bad_fraction": ma_bad, "ec_nll_gaps": ec_nll_gaps,
            "jensen_ok": jensen_ok}


if __name__ == "__main__":
    main()
